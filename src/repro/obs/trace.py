"""Structured span tracing for the serving drivers (host side).

Spans bracket the COARSE phases of a serve call -- pack, compile,
staging, the device workload -- not per-iteration events (those live in
the device rings, obs/rings.py; putting a host span around a loop
iteration would reintroduce exactly the per-iteration sync the
scheduler exists to avoid).

Each closed span is appended to an in-memory list and, when the tracer
was given a path, written as one JSON line:

    {"name": "serve.workload", "t0": ..., "dur_s": ..., "attrs": {...}}

Spans also enter the matching ``jax.profiler.TraceAnnotation`` scope,
so a profiler trace collected around a serve call shows the same phase
boundaries the JSON-lines file records.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Dict, Iterator, List, Optional

import jax


class SpanTracer:
    """Collects closed spans; optionally appends them to a JSONL file."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.spans: List[Dict] = []
        self._lock = threading.Lock()

    @contextlib.contextmanager
    def span(self, name: str, **attrs) -> Iterator[Dict]:
        """Time a ``with`` block as span ``name``.  The yielded dict is
        the span's attrs -- callers may add results discovered inside
        the block (e.g. token counts) before it closes."""
        a = dict(attrs)
        t0 = time.time()
        with jax.profiler.TraceAnnotation(name):
            yield a
        rec = dict(name=name, t0=round(t0, 6),
                   dur_s=round(time.time() - t0, 6), attrs=a)
        with self._lock:
            self.spans.append(rec)
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(rec, sort_keys=True) + "\n")

    def drain(self) -> List[Dict]:
        """Return and clear the collected spans."""
        with self._lock:
            out, self.spans = self.spans, []
        return out


_TRACER = SpanTracer()


def get_tracer() -> SpanTracer:
    return _TRACER


def set_trace_path(path: Optional[str]) -> None:
    """Point the process-global tracer's JSONL sink at ``path``."""
    _TRACER.path = path


def span(name: str, **attrs):
    """``with span("serve.pack"): ...`` against the global tracer."""
    return _TRACER.span(name, **attrs)
