"""HLO fingerprints: the zero-overhead-when-off proof, made checkable.

Telemetry must be a static flag compiling to a SEPARATE executable:
with ``obs=None`` the scheduler's serve loop is required to lower to
StableHLO text byte-identical to the pre-telemetry program.  A sha256
of that text is a checkable artifact: serve_bench embeds the
fingerprints (plus the host fingerprint they are only comparable
under) in BENCH_serve.json, and ``--check-regression`` fails if a
metrics-off fingerprint moved on a matching host -- i.e. if ANY code
path started paying for telemetry while it is off.

The lowering text is pre-optimization, so even dead telemetry ops
would change it -- the gate catches "computed but unused" leaks, not
just live overhead.
"""
from __future__ import annotations

import hashlib
from typing import Dict


def hlo_fingerprint(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def scheduler_fingerprint(sched, n_queue: int) -> str:
    """sha256 of the scheduler's lowered serve-loop StableHLO."""
    return hlo_fingerprint(sched.loop_hlo_text(n_queue))


def fingerprint_variants(make_sched, n_queue: int = 2) -> Dict[str, str]:
    """Fingerprint a set of scheduler variants.  ``make_sched`` maps a
    variant name from ``VARIANTS`` to a built scheduler."""
    return {name: scheduler_fingerprint(make_sched(name), n_queue)
            for name in VARIANTS}


#: the serve-loop variants the regression gate covers
VARIANTS = ("contiguous", "paged", "speculative")
