"""Device-resident serving telemetry (see DESIGN.md §13).

The hot-path half lives INSIDE the scheduler's compiled while-loop
carry: fixed-size event rings, per-iteration sample rings and counter
arrays written with masked scatter updates, so the loop still syncs the
host exactly once per workload.  The host half turns the harvested
rings into typed spans / histograms (`rings.harvest_obs`), maintains a
pull-style metrics registry with Prometheus-text and JSON exporters
(`metrics`), and emits structured JSON-lines span traces for the
serving drivers (`trace`).

Telemetry is a STATIC flag: a scheduler built with ``obs=None``
compiles to an executable byte-identical to the pre-telemetry one
(gated by the HLO fingerprint check in benchmarks/serve_bench.py), and
a metrics-on scheduler emits bit-identical tokens -- rings only ever
read values the loop already computes.
"""
from .fingerprint import hlo_fingerprint, scheduler_fingerprint
from .hostinfo import BENCH_SCHEMA_VERSION, host_fingerprint, host_matches
from .metrics import REGISTRY, MetricsRegistry
from .rings import (EV_ADMIT, EV_FINISH, EV_FIRST, ObsConfig, ObsSnapshot,
                    harvest_obs, init_obs_state)
from .trace import SpanTracer, get_tracer, set_trace_path, span

__all__ = [
    "ObsConfig", "ObsSnapshot", "init_obs_state", "harvest_obs",
    "EV_ADMIT", "EV_FIRST", "EV_FINISH",
    "MetricsRegistry", "REGISTRY",
    "SpanTracer", "get_tracer", "set_trace_path", "span",
    "host_fingerprint", "host_matches", "BENCH_SCHEMA_VERSION",
    "hlo_fingerprint", "scheduler_fingerprint",
]
