"""Host fingerprinting for benchmark JSONs and the HLO-fingerprint gate.

The serve/kernel benchmarks document a +-2x wall-clock swing across
hosts; a BENCH_*.json row without the host it ran on is therefore not a
trajectory point, just a number.  ``host_fingerprint()`` captures the
identity that actually moves the numbers (platform, device kind, jax /
jaxlib versions, git sha), and every benchmark JSON embeds it next to a
``schema_version`` so downstream tooling can tell revisions apart.

``host_matches()`` is the comparison the HLO-fingerprint regression
gate uses: StableHLO text is stable for a fixed (jax version, backend,
device kind) triple but not across them, so the zero-overhead-when-off
proof only fires when the baseline was produced by a matching host.
"""
from __future__ import annotations

import platform
import subprocess
from typing import Dict, Optional

# benchmark row schema: bump when a BENCH_*.json field changes meaning
BENCH_SCHEMA_VERSION = 2


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def host_fingerprint() -> Dict[str, Optional[str]]:
    import jax
    import jaxlib
    dev = jax.devices()[0]
    return dict(
        platform=platform.platform(),
        python=platform.python_version(),
        backend=jax.default_backend(),
        device_kind=dev.device_kind,
        jax=jax.__version__,
        jaxlib=jaxlib.__version__,
        git_sha=git_sha(),
    )


# the identity under which compiled-program fingerprints are comparable
_HLO_KEYS = ("backend", "device_kind", "jax", "jaxlib")


def host_matches(a: Optional[Dict], b: Optional[Dict],
                 keys=_HLO_KEYS) -> bool:
    """True when ``a`` and ``b`` describe HLO-comparable hosts."""
    if not isinstance(a, dict) or not isinstance(b, dict):
        return False
    return all(a.get(k) is not None and a.get(k) == b.get(k) for k in keys)
