"""Trace-time value taps: let inner kernels export telemetry scalars.

The serving models are traced into ONE executable (the scheduler's
while-loop switch), and the interesting health signals -- e.g. how many
ADC codes the packed GEMM epilogue clipped -- are born deep inside that
trace, under a ``lax.scan`` over layers and sometimes under a second
scan over accumulate chunks.  Threading an explicit "stats" output
through every model/kernel signature would contaminate dozens of APIs
for a value that only exists when telemetry is on.

Instead, kernels ``emit(name, value)`` into a module-level collector
stack that is only populated while a ``collect()`` context is active
*at trace time*:

  * ``collect()`` is pushed by the scheduler around tracing a switch
    branch (launch/scheduler.py) and drained into the on-device counter
    array in the same trace -- the emitted values are ordinary tracers
    of the enclosing trace, consumed in that same trace.
  * ``active()`` is a plain Python bool, so a kernel traced with no
    collector (telemetry off, or any other caller) contributes ZERO
    extra operations -- the metrics-off HLO is byte-identical.
  * ``scan(body, init, xs)`` relays emissions across a ``lax.scan``
    boundary: tap values emitted inside the body are tracers of the
    body trace and may not leak out, so the relay drains them into
    extra per-step scan outputs and re-emits their sum (over the scan
    axis) in the enclosing trace.  With no collector active it IS
    ``jax.lax.scan`` -- same primitive, same jaxpr.

Emissions are summed per name on drain; every tap value must therefore
be an additive count/total (int32 -- the serve-path lint forbids 64-bit
avals, analysis/tracer.py).
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, List

import jax
import jax.numpy as jnp

# stack of live collector frames (innermost last); trace-time only
_STACK: List[Dict[str, List[jax.Array]]] = []


def active() -> bool:
    """True while some ``collect()`` frame is open (trace-time check)."""
    return bool(_STACK)


def emit(name: str, value) -> None:
    """Record ``value`` under ``name`` in the innermost collector.
    No-op (and no tracing of ``value``'s producers happens at the call
    site -- guard any extra computation with ``active()``) otherwise."""
    if _STACK:
        _STACK[-1].setdefault(name, []).append(jnp.asarray(value))


@contextlib.contextmanager
def collect() -> Iterator[Dict[str, List[jax.Array]]]:
    """Open a collector frame; yields the frame dict (name -> values)."""
    frame: Dict[str, List[jax.Array]] = {}
    _STACK.append(frame)
    try:
        yield frame
    finally:
        _STACK.pop()


def drain_sum(frame: Dict[str, List[jax.Array]], name: str,
              dtype=jnp.int32) -> jax.Array:
    """Sum of everything emitted under ``name`` in ``frame`` (0 if none)."""
    vals = frame.get(name, [])
    if not vals:
        return jnp.zeros((), dtype)
    out = jnp.zeros((), dtype)
    for v in vals:
        out = out + v.astype(dtype)
    return out


def scan(body, init, xs):
    """``jax.lax.scan`` that relays tap emissions across the boundary.

    The body runs under its own collector frame; whatever it emitted
    becomes an extra stacked scan output, summed over the scan axis and
    re-emitted into the enclosing frame.  Inactive -> plain lax.scan.
    """
    if not _STACK:
        return jax.lax.scan(body, init, xs)

    def body2(c, s):
        with collect() as frame:
            c2, ys = body(c, s)
        tapped = {k: drain_sum(frame, k) for k in sorted(frame)}
        return c2, (ys, tapped)

    c2, (ys, tapped) = jax.lax.scan(body2, init, xs)
    for k, v in tapped.items():
        emit(k, jnp.sum(v))
    return c2, ys


def switch(index, branches, *operands):
    """``jax.lax.switch`` that relays tap emissions across the boundary.

    Every branch must emit the SAME set of tap names (lax.switch
    requires structurally identical branch outputs) -- true for
    homogeneous branch sets like the scheduler's draft-depth rungs,
    where each rung runs the same kernels a different number of times.
    Inactive -> plain lax.switch, same jaxpr.
    """
    if not _STACK:
        return jax.lax.switch(index, branches, *operands)

    def wrap(b):
        def b2(*ops):
            with collect() as frame:
                out = b(*ops)
            return out, {k: drain_sum(frame, k) for k in sorted(frame)}
        return b2

    out, tapped = jax.lax.switch(index, [wrap(b) for b in branches],
                                 *operands)
    for k, v in tapped.items():
        emit(k, v)
    return out
