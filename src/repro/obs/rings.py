"""On-device telemetry rings: the hot-path half of the obs subsystem.

The scheduler's while-loop carry gains one ``obs`` subtree (metrics on
only -- ``obs=None`` compiles byte-identical HLO) holding fixed-size
int32 arrays:

  ev       (event_cap, 3)   event ring rows ``(kind, rid, iter)``:
                            request admission, first token, finish --
                            written at exactly the sites that set the
                            carry's ``res_first``/``res_iter`` stamps,
                            so ring-derived TTFT iterations EQUAL
                            ``run_instrumented``'s ``first_iter``.
  ev_n     ()               monotone event cursor.  Writes use scatter
                            ``mode="drop"``: once the ring is full the
                            row write lands out of bounds and is
                            dropped, the cursor keeps counting, and
                            ``max(ev_n - cap, 0)`` is the drop count --
                            overflow degrades to a saturating counter,
                            it never wraps over recorded history.
  it       (iter_cap, 6)    per-iteration sample ring, row = (branch,
                            live slots, tokens emitted, draft delta,
                            accept delta, free pool blocks); indexed by
                            the iteration number with the same
                            ``mode="drop"`` saturation.
  ctr      (N_CTR,)         scalar counters (below) -- these never
                            saturate, so totals stay exact even when
                            the sample rings overflow.
  tick_tok ()               scratch: the switch branch that ran this
                            iteration records how many tokens it
                            emitted; the shared per-iteration tick in
                            the loop tail consumes it.

Counter slots: TOKENS (emitted, all branches), STALL (iterations where
live decoders existed but zero tokens were emitted -- harvest/admit/
mid-prefill iterations inflating the decode timeline), ADC_CLIP (codes
the packed GEMM's ADC epilogue clipped, via obs/taps.py), PREFIX_BLOCKS
(shared-prefix blocks reused instead of recomputed), SHARED_ADMITS
(admissions that copied a donor chain), MIN_FREE (low-water mark of the
paged free list, ``.at[].min`` -- a gauge, initialised to int32 max).

Everything is int32: the serve-path lint (analysis/tracer.py) forbids
64-bit avals in the loop, and iteration counts/ring capacities are far
below 2^31.

Calibration: the device has no clock, so rings record ITERATION stamps.
``harvest_obs`` converts to seconds with the uniform-iteration estimate
``wall_s / n_iter`` -- exact at the workload level, approximate per
iteration (admits cost more than steps).  ``run_instrumented`` remains
the ground truth for per-iteration seconds; the rings' iteration
numbers are exact and are cross-checked against it in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

# event kinds
EV_ADMIT, EV_FIRST, EV_FINISH = 0, 1, 2
EV_NAMES = {EV_ADMIT: "admit", EV_FIRST: "first_token", EV_FINISH: "finish"}

# counter slots
CTR_TOKENS, CTR_STALL, CTR_ADC_CLIP = 0, 1, 2
CTR_PREFIX_BLOCKS, CTR_SHARED_ADMITS, CTR_MIN_FREE = 3, 4, 5
N_CTR = 6

# per-iteration sample columns
IT_BRANCH, IT_LIVE, IT_TOK, IT_DRAFTED, IT_ACCEPTED, IT_FREE = range(6)
_IT_COLS = 6

_I32_MAX = np.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Static ring capacities; part of the executable's shape, so two
    ObsConfigs compile two executables (like slots or prompt_len)."""
    event_cap: int = 256
    iter_cap: int = 1024

    def __post_init__(self):
        if self.event_cap < 1 or self.iter_cap < 1:
            raise ValueError("ring capacities must be >= 1")


def init_obs_state(cfg: ObsConfig) -> Dict:
    import jax.numpy as jnp
    return dict(
        ev=jnp.zeros((cfg.event_cap, 3), jnp.int32),
        ev_n=jnp.zeros((), jnp.int32),
        it=jnp.zeros((cfg.iter_cap, _IT_COLS), jnp.int32),
        ctr=jnp.zeros((N_CTR,), jnp.int32).at[CTR_MIN_FREE].set(_I32_MAX),
        tick_tok=jnp.zeros((), jnp.int32),
    )


#: carry-subtree leaves a metrics-on executable must donate (see the
#: OBS-RING-DONATION rule in analysis/obs_rules.py)
OBS_LEAVES = ("ctr", "ev", "ev_n", "it", "tick_tok")


def ring_push(obs: Dict, kind: int, rid, it, do=True) -> Dict:
    """Append ``(kind, rid, it)`` to the event ring when ``do`` holds.

    The conditional and the saturation share one mechanism: the write
    index is the cursor when ``do`` else one past the end, and scatter
    ``mode="drop"`` discards any out-of-bounds row -- so a full ring
    (cursor >= cap) silently stops recording while the cursor keeps
    counting attempts.
    """
    import jax.numpy as jnp
    do = jnp.asarray(do, jnp.bool_)
    cap = obs["ev"].shape[0]
    idx = jnp.where(do, obs["ev_n"], jnp.int32(cap))
    row = jnp.stack([jnp.asarray(kind, jnp.int32),
                     jnp.asarray(rid, jnp.int32),
                     jnp.asarray(it, jnp.int32)])
    return dict(obs,
                ev=obs["ev"].at[idx].set(row, mode="drop"),
                ev_n=obs["ev_n"] + do.astype(jnp.int32))


def ctr_add(obs: Dict, slot: int, amount) -> Dict:
    import jax.numpy as jnp
    return dict(obs, ctr=obs["ctr"].at[slot].add(
        jnp.asarray(amount, jnp.int32)))


def iter_tick(obs: Dict, n_iter, branch, live_cnt, drafted_d, accepted_d,
              free_blocks) -> Dict:
    """The shared per-iteration sample: one ring row at index ``n_iter``
    (saturating) plus the token/stall counters.  ``obs['tick_tok']`` was
    set by whichever switch branch ran."""
    import jax.numpy as jnp
    tok = obs["tick_tok"]
    row = jnp.stack([jnp.asarray(v, jnp.int32) for v in
                     (branch, live_cnt, tok, drafted_d, accepted_d,
                      free_blocks)])
    stall = ((live_cnt > 0) & (tok == 0)).astype(jnp.int32)
    ctr = (obs["ctr"].at[CTR_TOKENS].add(tok)
           .at[CTR_STALL].add(stall)
           .at[CTR_MIN_FREE].min(jnp.asarray(free_blocks, jnp.int32)))
    return dict(obs, it=obs["it"].at[n_iter].set(row, mode="drop"), ctr=ctr)


# -- host-side harvest ------------------------------------------------------


@dataclasses.dataclass
class ObsSnapshot:
    """Typed view of one workload's harvested rings."""
    n_iter: int
    wall_s: float
    slots: int
    iter_s_est: float                 # wall-clock calibration: wall/n_iter
    counters: Dict[str, int]
    events: List[Dict]                # [{kind, rid, iter}], recorded rows
    dropped_events: int
    recorded_iters: int               # iter-ring rows actually captured
    spans: List[Dict]                 # per-request admit/first/finish spans
    ttft_iters: Dict[int, int]        # rid -> first-token iteration
    occupancy_mean: float
    stall_factor_iters: float
    acceptance_rate: float
    min_free_blocks: Optional[int]
    iter_samples: Dict[str, np.ndarray]

    def ttft_percentiles_iters(self) -> Dict[str, float]:
        ts = sorted(self.ttft_iters.values())
        if not ts:
            return {"ttft_p50_iters": float("nan"),
                    "ttft_p95_iters": float("nan")}
        pick = lambda q: ts[min(len(ts) - 1, int(q * (len(ts) - 1) + 0.5))]
        return {"ttft_p50_iters": float(pick(0.50)),
                "ttft_p95_iters": float(pick(0.95))}

    def ttft_percentiles_s(self) -> Dict[str, float]:
        it = self.ttft_percentiles_iters()
        return {"ttft_p50_s": it["ttft_p50_iters"] * self.iter_s_est,
                "ttft_p95_s": it["ttft_p95_iters"] * self.iter_s_est}

    def to_dict(self) -> Dict:
        # every possibly-undefined statistic follows one convention: NaN
        # (zero-token / zero-iteration workloads) serializes as None, so
        # the snapshot is always valid JSON (NaN is not)
        opt = lambda v, nd: round(v, nd) if v == v else None
        d = dict(n_iter=self.n_iter, wall_s=round(self.wall_s, 4),
                 iter_s_est=self.iter_s_est, slots=self.slots,
                 counters=self.counters,
                 dropped_events=self.dropped_events,
                 recorded_iters=self.recorded_iters,
                 occupancy_mean=opt(self.occupancy_mean, 4),
                 stall_factor_iters=opt(self.stall_factor_iters, 4),
                 acceptance_rate=opt(self.acceptance_rate, 4),
                 min_free_blocks=self.min_free_blocks,
                 spans=self.spans,
                 **{k: round(v, 2) if v == v else None
                    for k, v in self.ttft_percentiles_iters().items()},
                 **{k: round(v, 6) if v == v else None
                    for k, v in self.ttft_percentiles_s().items()})
        return d

    def register(self, registry, prefix: str = "serve") -> None:
        """Publish this snapshot into a metrics registry."""
        c = self.counters
        registry.counter(f"{prefix}_tokens_total",
                         "tokens emitted by the device loop").inc(
            c["tokens"])
        registry.counter(f"{prefix}_stall_iters_total",
                         "iterations with live decoders but no tokens"
                         ).inc(c["stall_iters"])
        registry.counter(f"{prefix}_adc_clip_total",
                         "ADC codes clipped in the packed GEMM path").inc(
            c["adc_clip"])
        registry.counter(f"{prefix}_prefix_blocks_total",
                         "shared-prefix KV blocks reused").inc(
            c["prefix_blocks"])
        registry.counter(f"{prefix}_events_dropped_total",
                         "event-ring rows dropped after saturation").inc(
            self.dropped_events)
        # gauges that are undefined (NaN) for an empty workload -- zero
        # recorded iterations or zero decode steps -- are skipped rather
        # than published (a NaN gauge is noise to every scraper)
        if self.occupancy_mean == self.occupancy_mean:
            registry.gauge(f"{prefix}_occupancy",
                           "mean live-slot fraction over sampled iterations"
                           ).set(self.occupancy_mean)
        if self.stall_factor_iters == self.stall_factor_iters:
            registry.gauge(f"{prefix}_stall_factor_iters",
                           "decode-timeline inflation by non-emitting "
                           "iterations").set(self.stall_factor_iters)
        if self.min_free_blocks is not None:
            registry.gauge(f"{prefix}_free_blocks_min",
                           "paged free-list low-water mark").set(
                self.min_free_blocks)
        if self.acceptance_rate == self.acceptance_rate:
            registry.gauge(f"{prefix}_acceptance_rate",
                           "draft tokens accepted / drafted").set(
                self.acceptance_rate)
        h = registry.histogram(
            f"{prefix}_ttft_seconds", "time to first token (calibrated "
            "from iteration stamps)",
            buckets=(0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0))
        h.observe_many([t * self.iter_s_est
                        for t in self.ttft_iters.values()])


def harvest_obs(cfg: ObsConfig, raw: Dict, *, n_iter: int, wall_s: float,
                slots: int, n_steps: int, n_drafted: int = 0,
                n_accepted: int = 0, paged: bool = False) -> ObsSnapshot:
    """Convert the harvested ``obs`` carry subtree into a typed snapshot.

    ``raw`` is the device dict (or its numpy mirror); one host transfer,
    after the loop already synced.
    """
    ev = np.asarray(raw["ev"])
    ev_n = int(raw["ev_n"])
    it = np.asarray(raw["it"])
    ctr = np.asarray(raw["ctr"])
    n_rec = min(ev_n, cfg.event_cap)
    events = [dict(kind=EV_NAMES.get(int(k), str(int(k))), rid=int(r),
                   iter=int(i)) for k, r, i in ev[:n_rec]]
    rec_it = min(int(n_iter), cfg.iter_cap)
    samples = {name: it[:rec_it, col].copy() for name, col in
               (("branch", IT_BRANCH), ("live", IT_LIVE), ("tok", IT_TOK),
                ("drafted", IT_DRAFTED), ("accepted", IT_ACCEPTED),
                ("free", IT_FREE))}

    by_rid: Dict[int, Dict] = {}
    for e in events:
        by_rid.setdefault(e["rid"], {})[e["kind"]] = e["iter"]
    iter_s = wall_s / max(int(n_iter), 1)
    spans = []
    for rid in sorted(by_rid):
        s = by_rid[rid]
        rec = dict(rid=rid, admit_iter=s.get("admit"),
                   first_iter=s.get("first_token"),
                   finish_iter=s.get("finish"))
        if rec["first_iter"] is not None:
            rec["ttft_s_est"] = round(rec["first_iter"] * iter_s, 6)
        if rec["admit_iter"] is not None and rec["finish_iter"] is not None:
            rec["span_iters"] = rec["finish_iter"] - rec["admit_iter"]
        spans.append(rec)
    ttft = {r["rid"]: r["first_iter"] for r in spans
            if r["first_iter"] is not None}

    live = samples["live"]
    occ = float(np.mean(live) / max(slots, 1)) if rec_it else float("nan")
    stalls = int(ctr[CTR_STALL])
    stall_factor = ((n_steps + stalls) / n_steps if n_steps
                    else float("nan"))
    acc = n_accepted / n_drafted if n_drafted else float("nan")
    min_free = int(ctr[CTR_MIN_FREE])
    counters = dict(tokens=int(ctr[CTR_TOKENS]), stall_iters=stalls,
                    adc_clip=int(ctr[CTR_ADC_CLIP]),
                    prefix_blocks=int(ctr[CTR_PREFIX_BLOCKS]),
                    shared_admits=int(ctr[CTR_SHARED_ADMITS]))
    return ObsSnapshot(
        n_iter=int(n_iter), wall_s=float(wall_s), slots=slots,
        iter_s_est=iter_s, counters=counters, events=events,
        dropped_events=max(ev_n - cfg.event_cap, 0),
        recorded_iters=rec_it, spans=spans, ttft_iters=ttft,
        occupancy_mean=occ, stall_factor_iters=stall_factor,
        acceptance_rate=acc,
        min_free_blocks=(None if (not paged or min_free == int(_I32_MAX))
                         else min_free),
        iter_samples=samples)
