"""Pull-style metrics registry with Prometheus-text and JSON exporters.

Host-side half of the telemetry subsystem: pure Python, no jax imports,
safe to touch from trace-time code (the autotune cache counts its
hits/misses here at lowering time).  Metrics are created lazily and
identified by (name, sorted label items); a second registration with
the same identity returns the same instrument, so module-level callers
never need to coordinate.

Exporters:

  * ``export_prometheus()`` -- the text exposition format (one
    ``# HELP``/``# TYPE`` header per metric family, ``name{labels} value``
    samples, histograms as cumulative ``_bucket``/``_sum``/``_count``).
  * ``snapshot()`` -- a JSON-able dict mirror of the same samples, the
    form embedded into BENCH_serve.json and dumped by ``serve --metrics``.

There is one process-global ``REGISTRY``; tests build private
``MetricsRegistry()`` instances.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

LabelItems = Tuple[Tuple[str, str], ...]


def _label_items(labels: Optional[Dict[str, str]]) -> LabelItems:
    if not labels:
        return ()
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"bad label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(items: LabelItems) -> str:
    if not items:
        return ""
    esc = lambda v: v.replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in items) + "}"


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"                  # Prometheus text-format literal
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Counter:
    """Monotone counter; ``inc`` with a negative amount is an error."""

    def __init__(self, name: str, help: str = "", labels: LabelItems = ()):
        self.name, self.help, self.labels = name, help, labels
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, help: str = "", labels: LabelItems = ()):
        self.name, self.help, self.labels = name, help, labels
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    @property
    def value(self) -> float:
        return self._value


DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics)."""

    def __init__(self, name: str, help: str = "", labels: LabelItems = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.name, self.help, self.labels = name, help, labels
        self.buckets = bs
        self.counts = [0] * (len(bs) + 1)     # + the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def observe_many(self, values: Sequence[float]) -> None:
        for v in values:
            self.observe(v)

    def quantile(self, q: float) -> float:
        """Linear-in-bucket quantile estimate (NaN when empty)."""
        if not self.count:
            return float("nan")
        target = q * self.count
        cum = 0
        lo = 0.0
        for i, b in enumerate(self.buckets):
            if cum + self.counts[i] >= target:
                frac = (target - cum) / max(self.counts[i], 1)
                return lo + frac * (b - lo)
            cum += self.counts[i]
            lo = b
        return self.buckets[-1]


class MetricsRegistry:
    """Create-or-get instruments; export everything on demand."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelItems], object] = {}

    def _get(self, cls, name: str, help: str,
             labels: Optional[Dict[str, str]], **kw):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        key = (name, _label_items(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help, key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, str]] = None,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- exporters ------------------------------------------------------

    def _families(self) -> Dict[str, List[object]]:
        fams: Dict[str, List[object]] = {}
        with self._lock:
            for (name, _), m in sorted(self._metrics.items()):
                fams.setdefault(name, []).append(m)
        return fams

    def export_prometheus(self) -> str:
        """Text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name, ms in self._families().items():
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(ms[0])]
            if ms[0].help:
                lines.append(f"# HELP {name} {ms[0].help}")
            lines.append(f"# TYPE {name} {kind}")
            for m in ms:
                if isinstance(m, Histogram):
                    cum = 0
                    for b, c in zip(list(m.buckets) + [float("inf")],
                                    m.counts):
                        cum += c
                        it = m.labels + (("le", _fmt_value(b)),)
                        lines.append(
                            f"{name}_bucket{_fmt_labels(it)} {cum}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(m.labels)} "
                        f"{_fmt_value(m.sum)}")
                    lines.append(
                        f"{name}_count{_fmt_labels(m.labels)} {m.count}")
                else:
                    lines.append(f"{name}{_fmt_labels(m.labels)} "
                                 f"{_fmt_value(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def snapshot(self) -> Dict:
        """JSON-able mirror of every sample the text exporter emits."""
        out: Dict = {}
        for name, ms in self._families().items():
            fam = []
            for m in ms:
                rec: Dict = {"labels": dict(m.labels)}
                if isinstance(m, Histogram):
                    rec.update(type="histogram",
                               buckets=[[b, c] for b, c in
                                        zip(m.buckets, m.counts)],
                               inf=m.counts[-1], sum=m.sum, count=m.count)
                else:
                    rec.update(type=("counter" if isinstance(m, Counter)
                                     else "gauge"), value=m.value)
                fam.append(rec)
            out[name] = fam
        return out


REGISTRY = MetricsRegistry()
