"""Violation records, the analysis report, JSON emit and baseline diff.

``ANALYSIS.json`` is the machine-readable artifact CI uploads next to
the BENCH jsons: per-rule counts, per-kernel VMEM tables and the
executable census.  A committed copy doubles as the ``--baseline`` for
diff mode -- pre-existing (waived) violations don't block the build,
new ones do.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule firing at one site.

    ``rule`` is the stable identifier (TRACE-*/KERNEL-*/AST-*); ``where``
    locates the site (entry point, kernel@shape, file:line) and is the
    baseline-diff key together with the rule, so the *detail* text can
    improve without resurrecting waived findings.
    """

    rule: str
    where: str
    detail: str

    @property
    def key(self) -> Tuple[str, str]:
        return (self.rule, self.where)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.detail}"


class AnalysisReport:
    """Accumulator shared by the three analyzers."""

    def __init__(self) -> None:
        self.violations: List[Violation] = []
        self.checked: Counter = Counter()          # rule -> sites audited
        self.vmem_table: List[Dict[str, Any]] = []  # one row per dispatch
        self.census: Dict[str, Any] = {}           # executable census
        self.notes: List[str] = []                 # skips/caps, never silent

    # -- recording -----------------------------------------------------

    def check(self, rule: str, n: int = 1) -> None:
        self.checked[rule] += n

    def add(self, rule: str, where: str, detail: str) -> None:
        self.violations.append(Violation(rule, where, detail))

    def note(self, msg: str) -> None:
        self.notes.append(msg)

    def merge(self, other: "AnalysisReport") -> None:
        self.violations.extend(other.violations)
        self.checked.update(other.checked)
        self.vmem_table.extend(other.vmem_table)
        self.census.update(other.census)
        self.notes.extend(other.notes)

    # -- queries -------------------------------------------------------

    @property
    def passed(self) -> bool:
        return not self.violations

    def counts(self) -> Dict[str, int]:
        c: Counter = Counter(v.rule for v in self.violations)
        return dict(sorted(c.items()))

    def new_violations(self, baseline: Optional[set]) -> List[Violation]:
        """Violations not waived by the baseline key set (rule, where)."""
        if not baseline:
            return list(self.violations)
        return [v for v in self.violations if v.key not in baseline]

    # -- JSON ----------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "passed": self.passed,
            "violation_counts": self.counts(),
            "violations": [dataclasses.asdict(v) for v in self.violations],
            "checked": dict(sorted(self.checked.items())),
            "kernel_vmem": self.vmem_table,
            "executable_census": self.census,
            "notes": self.notes,
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=False)
            f.write("\n")

    def summary(self) -> str:
        lines = [
            "checked: " + ", ".join(
                f"{r}={n}" for r, n in sorted(self.checked.items())),
            f"kernel dispatches audited: {len(self.vmem_table)}",
            "executables traced: "
            f"{self.census.get('n_executables', 0)}",
        ]
        if self.violations:
            lines.append(f"VIOLATIONS ({len(self.violations)}):")
            lines += [f"  {v}" for v in self.violations]
        else:
            lines.append("no violations")
        return "\n".join(lines)


def load_baseline(path: str) -> set:
    """Waiver keys from a previously committed ANALYSIS.json.

    Corrupt/missing baselines waive nothing (fail closed): diff mode then
    degrades to strict mode rather than silently passing everything.
    """
    try:
        with open(path) as f:
            data = json.load(f)
        return {(v["rule"], v["where"]) for v in data.get("violations", [])}
    except (OSError, ValueError, KeyError, TypeError):
        return set()
