"""Repo-specific AST lint: the invariants that live in *source shape*,
not in any trace.

- AST-IMPORT-CONFIG  no ``jax.config`` mutation at import time: a module
  that flips x64/platform flags on import changes numerics for every
  importer, ordering-dependently.
- AST-IMPURE-TRACE   no Python ``random``/``time`` calls inside
  jit-decorated functions -- they execute once at trace time and freeze
  a single sample into the executable.
- AST-HOST-SYNC      no ``.item()`` / ``np.asarray()`` /
  ``.block_until_ready()`` reachable from a ``lax.while_loop`` /
  ``lax.switch`` / ``lax.cond`` / ``lax.scan`` body: inside a traced
  body these either fail at trace time or (worse) silently force a
  host round-trip per iteration when the body also runs eagerly.
- AST-STATIC-META    classes registered via
  ``jax.tree_util.register_dataclass`` must be frozen dataclasses --
  their meta fields are jit cache keys and must hash by value.
- AST-NOISE-SEED     in the numerics modules every
  ``jax.random.PRNGKey`` must derive from ``cim_noise_seed`` -- the
  deterministic-noise contract (same plan, same seed => bit-identical
  tokens) dies with one ad-hoc PRNGKey(0).

All rules run on the AST alone (no imports of the linted code), so the
lint can't be defeated by import-time side effects -- and it lints
files the test suite never loads.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Set, Tuple

from .report import AnalysisReport

HOST_SYNC_CALLS = ("item", "block_until_ready")
HOST_SYNC_NP_FUNCS = ("asarray", "array")
LAX_BODY_CONSUMERS = ("while_loop", "switch", "cond", "scan", "fori_loop")
IMPURE_MODULES = ("random", "time")
NOISE_SEED_MODULES = (
    "core/ccim.py", "core/qat.py", "core/engine.py",
    "core/complex_mac.py", "models/layers.py",
)


def _attr_chain(node: ast.AST) -> str:
    """'jax.config.update' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        for node in ast.walk(dec):
            if isinstance(node, ast.Attribute) and node.attr == "jit":
                return True
            if isinstance(node, ast.Name) and node.id == "jit":
                return True
    return False


def _stdlib_aliases(tree: ast.Module) -> Tuple[Set[str], Set[str]]:
    """(module aliases, bare names) bound to python random/time."""
    mods: Set[str] = set()
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in IMPURE_MODULES:
                    mods.add(a.asname or a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.module in IMPURE_MODULES and not node.level:
                for a in node.names:
                    names.add(a.asname or a.name)
    return mods, names


class Linter:
    def __init__(self, relpath: str, src: str, report: AnalysisReport):
        self.relpath = relpath
        self.report = report
        self.tree = ast.parse(src)
        self.funcs: Dict[str, ast.AST] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.funcs.setdefault(node.name, node)

    def _add(self, rule: str, node: ast.AST, detail: str) -> None:
        line = getattr(node, "lineno", 0)
        self.report.add(rule, f"{self.relpath}:{line}", detail)

    # -- AST-IMPORT-CONFIG --------------------------------------------

    def check_import_config(self) -> None:
        self.report.check("AST-IMPORT-CONFIG")

        def scan(stmt: ast.stmt) -> None:
            # function bodies run at call time, not import time
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                return
            if (isinstance(stmt, ast.If)
                    and "__main__" in ast.dump(stmt.test)):
                return   # script entry, not import time
            if isinstance(stmt, ast.Call):
                chain = _attr_chain(stmt.func)
                if ".config.update" in chain or chain.startswith(
                        "config.update"):
                    self._add(
                        "AST-IMPORT-CONFIG", stmt,
                        f"`{chain}(...)` at import time -- global "
                        "numerics flipped for every importer")
            elif isinstance(stmt, (ast.Assign, ast.AugAssign)):
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                for t in targets:
                    chain = _attr_chain(t)
                    if ".config." in chain:
                        self._add("AST-IMPORT-CONFIG", stmt,
                                  f"assignment to `{chain}` at import time")
            for sub in ast.iter_child_nodes(stmt):
                scan(sub)

        for stmt in self.tree.body:
            scan(stmt)

    # -- AST-IMPURE-TRACE ---------------------------------------------

    def check_impure_trace(self) -> None:
        mods, names = _stdlib_aliases(self.tree)
        for fn in ast.walk(self.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not _is_jit_decorated(fn):
                continue
            self.report.check("AST-IMPURE-TRACE")
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func)
                root = chain.split(".", 1)[0]
                if (root in mods and "." in chain) or chain in names:
                    self._add(
                        "AST-IMPURE-TRACE", node,
                        f"`{chain}()` inside jit-decorated "
                        f"`{fn.name}` -- evaluated once at trace time, "
                        "frozen into the executable")

    # -- AST-HOST-SYNC ------------------------------------------------

    def _body_roots(self) -> List[Tuple[str, ast.AST]]:
        """Functions/lambdas passed as bodies to lax control flow."""
        roots: List[Tuple[str, ast.AST]] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            leaf = chain.rsplit(".", 1)[-1]
            if leaf not in LAX_BODY_CONSUMERS:
                continue
            cands = list(node.args)
            for arg in cands:
                if isinstance(arg, ast.Lambda):
                    roots.append((f"lax.{leaf}", arg))
                elif isinstance(arg, ast.Name) and arg.id in self.funcs:
                    roots.append((f"lax.{leaf}", self.funcs[arg.id]))
                elif isinstance(arg, (ast.List, ast.Tuple)):
                    for el in arg.elts:
                        if isinstance(el, ast.Lambda):
                            roots.append((f"lax.{leaf}", el))
                        elif (isinstance(el, ast.Name)
                              and el.id in self.funcs):
                            roots.append((f"lax.{leaf}",
                                          self.funcs[el.id]))
        return roots

    def _scan_host_sync(self, ctx: str, fn: ast.AST,
                        visited: Set[int]) -> None:
        if id(fn) in visited:
            return
        visited.add(id(fn))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            leaf = chain.rsplit(".", 1)[-1]
            if leaf in HOST_SYNC_CALLS:
                self._add(
                    "AST-HOST-SYNC", node,
                    f"`.{leaf}()` reachable from a {ctx} body -- host "
                    "sync per iteration (or trace failure)")
            elif (leaf in HOST_SYNC_NP_FUNCS
                  and chain.split(".", 1)[0] in ("np", "numpy", "onp")):
                self._add(
                    "AST-HOST-SYNC", node,
                    f"`{chain}()` reachable from a {ctx} body -- "
                    "forces device->host materialization")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in self.funcs):
                self._scan_host_sync(ctx, self.funcs[node.func.id], visited)

    def check_host_sync(self) -> None:
        self.report.check("AST-HOST-SYNC")
        for ctx, root in self._body_roots():
            self._scan_host_sync(ctx, root, set())

    # -- AST-STATIC-META ----------------------------------------------

    def check_static_meta(self) -> None:
        classes: Dict[str, ast.ClassDef] = {
            n.name: n for n in ast.walk(self.tree)
            if isinstance(n, ast.ClassDef)}
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _attr_chain(node.func).rsplit(".", 1)[-1] != \
                    "register_dataclass":
                continue
            if not node.args or not isinstance(node.args[0], ast.Name):
                continue
            self.report.check("AST-STATIC-META")
            cls = classes.get(node.args[0].id)
            if cls is None:
                continue   # registered from another module; out of scope
            frozen = False
            for dec in cls.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                if _attr_chain(dec.func).rsplit(".", 1)[-1] != "dataclass":
                    continue
                for kw in dec.keywords:
                    if (kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True):
                        frozen = True
            if not frozen:
                self._add(
                    "AST-STATIC-META", cls,
                    f"`{cls.name}` is registered as a pytree dataclass "
                    "but not @dataclass(frozen=True) -- its static meta "
                    "fields are jit cache keys and must hash by value")

    # -- AST-NOISE-SEED -----------------------------------------------

    def check_noise_seed(self) -> None:
        if not self.relpath.replace(os.sep, "/").endswith(
                NOISE_SEED_MODULES):
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _attr_chain(node.func).rsplit(".", 1)[-1] != "PRNGKey":
                continue
            self.report.check("AST-NOISE-SEED")
            src = "".join(ast.unparse(a) for a in node.args)
            if "cim_noise_seed" not in src:
                self._add(
                    "AST-NOISE-SEED", node,
                    f"PRNGKey({src}) in a numerics module does not "
                    "derive from cim_noise_seed -- breaks the "
                    "deterministic noise-stream contract")

    def run(self) -> None:
        self.check_import_config()
        self.check_impure_trace()
        self.check_host_sync()
        self.check_static_meta()
        self.check_noise_seed()


def lint_source(relpath: str, src: str, report: AnalysisReport) -> None:
    try:
        Linter(relpath, src, report).run()
    except SyntaxError as e:
        report.add("AST-PARSE", relpath, f"unparsable: {e}")


def lint_package(root: str, report: AnalysisReport) -> int:
    """Lint every .py under ``root`` (the src/repro tree); returns the
    number of files linted."""
    n = 0
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            with open(path) as f:
                lint_source(rel, f.read(), report)
            n += 1
    report.census["files_linted"] = n
    return n
