"""``python -m repro.analysis``: run cimlint, emit ANALYSIS.json, gate.

Modes:

  python -m repro.analysis                  report-only (exit 0)
  python -m repro.analysis --strict         exit 1 on any violation
  python -m repro.analysis --strict --baseline ANALYSIS.json
                                            exit 1 only on NEW violations
                                            (committed waivers don't block)

Sections can be skipped (``--skip trace``) for fast iteration; the CI
gate runs all of them.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional, Sequence

from .report import AnalysisReport, load_baseline

SECTIONS = ("lint", "kernels", "trace", "obs", "resilience")
_PKG_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_analysis(sections: Sequence[str] = SECTIONS,
                 arch: str = "minicpm-2b",
                 with_scheduler: bool = True,
                 lint_root: Optional[str] = None) -> AnalysisReport:
    report = AnalysisReport()
    if "lint" in sections:
        from .lint import lint_package
        lint_package(lint_root or _PKG_ROOT, report)
    if "kernels" in sections:
        from .kernels import sweep_kernels
        sweep_kernels(report)
    if "trace" in sections:
        from .tracer import audit_serve_path
        audit_serve_path(report, arch=arch, with_scheduler=with_scheduler)
    if "obs" in sections:
        from .obs_rules import audit_obs
        audit_obs(report, arch=arch)
    if "resilience" in sections:
        from .resilience_rules import audit_resilience
        audit_resilience(report, arch=arch)
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="cimlint: static trace/kernel/AST audit of the "
                    "serving stack")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on (new) violations")
    ap.add_argument("--baseline", default=None,
                    help="previous ANALYSIS.json; its violations are "
                    "waived (diff mode)")
    ap.add_argument("--out", default="ANALYSIS.json",
                    help="report path (default: ANALYSIS.json)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=list(SECTIONS), help="skip a section")
    ap.add_argument("--arch", default="minicpm-2b",
                    help="config registry name for the serve-path audit")
    ap.add_argument("--no-scheduler", action="store_true",
                    help="skip the scheduler while-loop executable "
                    "(fastest trace section)")
    args = ap.parse_args(argv)

    sections = [s for s in SECTIONS if s not in args.skip]
    t0 = time.time()
    report = run_analysis(sections, arch=args.arch,
                          with_scheduler=not args.no_scheduler)
    report.census["sections"] = sections
    report.census["wall_s"] = round(time.time() - t0, 1)
    report.save(args.out)

    print(report.summary())
    print(f"wrote {args.out} ({report.census['wall_s']}s)")

    baseline = load_baseline(args.baseline) if args.baseline else None
    new = report.new_violations(baseline)
    if baseline is not None:
        waived = len(report.violations) - len(new)
        if waived:
            print(f"{waived} violation(s) waived by baseline "
                  f"{args.baseline}")
    if new and args.strict:
        print(f"FAIL: {len(new)} new violation(s)")
        return 1
    if new:
        print(f"{len(new)} violation(s) (report-only mode; use --strict "
              "to gate)")
    return 0
