"""Static Pallas kernel checker: VMEM budgets, block divisibility and
grid-aliasing safety -- proved from the BlockSpecs, never by running.

Mechanism: ``pl.pallas_call`` is monkeypatched with a spy while the real
dispatch wrappers (ops.py entry points) run under ``jax.eval_shape``, so
every record holds the *actual* grid/BlockSpecs the serving path would
launch for that shape -- including autotuned block overrides -- at zero
execution cost.  The spy's fake kernel returns zeros of ``out_shape``,
which keeps the surrounding padding/slicing trace intact.

Checks per recorded dispatch:

- KERNEL-BLOCK  block shapes tile their operands exactly and respect the
  TPU layout floor (lane 128, int8 sublane 32) unless the block spans
  the whole axis (resident whole-axis blocks need no alignment).
- KERNEL-VMEM   per-grid-step footprint: 2x each revolving block (Pallas
  double-buffers any operand whose index map moves across the grid), 1x
  each grid-invariant resident block, plus scratch -- against the 16 MiB
  VMEM budget.
- KERNEL-RACE   every output tile's writer set must be a contiguous run
  of the linearized (row-major, last-axis-innermost) grid -- the only
  order in which revisiting a tile is accumulation-safe on the
  sequential TPU grid (init at first visit, flush at last).

The sweep covers all five kernel families at every plan design point
(n_dcim 0-6 x adc 7-9b x L16/32) and every shape recorded in
TUNING_CACHE.json, so a geometry the DSE roadmap sweeps is verified the
moment it is expressible.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import itertools
import json
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as real_pl

from ..core.ccim import CCIMConfig, _dcim_by_j
from ..kernels.ccim_matmul import autotune
from ..kernels.ccim_matmul import ops as cm_ops
from ..kernels.ccim_matmul.ops import pick_weight_blocks
from .report import AnalysisReport

VMEM_BUDGET = 16 * 1024 * 1024     # bytes per core
LANE = 128
INT8_SUBLANE = 32
_GRID_ENUM_CAP = 32768             # full-enumeration cap for the race check

DESIGN_N_DCIM = tuple(range(0, 7))
DESIGN_ADC_BITS = (7, 8, 9)
DESIGN_ACC_LEN = (16, 32)


# ---------------------------------------------------------------------------
# interception
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpecView:
    """One BlockSpec joined with the operand it blocks."""

    block_shape: Tuple[int, ...]
    index_map: Optional[Callable]
    array_shape: Tuple[int, ...]
    dtype: Any
    is_output: bool = False


@dataclasses.dataclass
class PallasCallRecord:
    """Everything the checker needs about one pallas_call dispatch."""

    name: str
    grid: Tuple[int, ...]
    specs: List[SpecView]
    scratch_bytes: int
    num_scalar_prefetch: int
    scalar_shapes: List[Tuple[Tuple[int, ...], Any]]

    @property
    def where(self) -> str:
        shapes = "/".join(
            "x".join(map(str, s.array_shape))
            for s in self.specs if not s.is_output)
        return f"{self.name}@grid{self.grid}[{shapes}]"


def _kernel_name(kernel) -> str:
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    return getattr(kernel, "__name__", repr(kernel))


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    return tuple(x) if isinstance(x, (tuple, list)) else (x,)


def _scratch_bytes(scratch_shapes) -> int:
    total = 0
    for s in _as_tuple(scratch_shapes):
        shape = getattr(s, "shape", None)
        dtype = getattr(s, "dtype", None)
        if shape is not None and dtype is not None:
            total += math.prod(shape) * jnp.dtype(dtype).itemsize
    return total


@contextlib.contextmanager
def record_pallas_calls(records: List[PallasCallRecord]):
    """Swap ``pl.pallas_call`` for a spy; run wrappers under eval_shape.

    Kernel modules all bind the *module* (``from jax.experimental import
    pallas as pl``), so patching the module attribute intercepts every
    dispatch without touching their code.
    """
    orig = real_pl.pallas_call

    def spy(kernel, *, out_shape, grid=None, in_specs=None, out_specs=None,
            grid_spec=None, scratch_shapes=(), **kw):
        if grid_spec is not None:
            g = _as_tuple(grid_spec.grid)
            ins, outs = grid_spec.in_specs, grid_spec.out_specs
            scratch = grid_spec.scratch_shapes
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)
        else:
            g = _as_tuple(grid)
            ins, outs, scratch, nsp = in_specs, out_specs, scratch_shapes, 0
        in_list, out_list = list(_as_tuple(ins)), list(_as_tuple(outs))
        out_shapes = _as_tuple(out_shape)

        def fake(*operands):
            scalars = operands[:nsp]
            arrays = operands[nsp:]
            specs: List[SpecView] = []
            for spec, op in zip(in_list, arrays):
                bs = tuple(op.shape[i] if b is None else int(b)
                           for i, b in enumerate(spec.block_shape))
                specs.append(SpecView(bs, spec.index_map, tuple(op.shape),
                                      op.dtype))
            for spec, osh in zip(out_list, out_shapes):
                bs = tuple(osh.shape[i] if b is None else int(b)
                           for i, b in enumerate(spec.block_shape))
                specs.append(SpecView(bs, spec.index_map, tuple(osh.shape),
                                      osh.dtype, is_output=True))
            records.append(PallasCallRecord(
                name=_kernel_name(kernel), grid=g, specs=specs,
                scratch_bytes=_scratch_bytes(scratch),
                num_scalar_prefetch=nsp,
                scalar_shapes=[(tuple(s.shape), s.dtype) for s in scalars]))
            return jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return fake

    real_pl.pallas_call = spy
    try:
        yield records
    finally:
        real_pl.pallas_call = orig


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------


def _scalar_args(rec: PallasCallRecord, fill: int) -> list:
    return [np.full(shape, fill, dtype=np.dtype(dt).name
                    if np.issubdtype(np.dtype(dt), np.integer) else dt)
            for shape, dt in rec.scalar_shapes]


def _eval_index_map(spec: SpecView, idx: Tuple[int, ...],
                    scalars: list) -> Optional[Tuple[int, ...]]:
    if spec.index_map is None:
        return tuple(0 for _ in spec.block_shape)
    try:
        out = spec.index_map(*idx, *scalars)
    except Exception:
        return None
    return tuple(int(v) for v in _as_tuple(out))


def _grid_points(grid: Tuple[int, ...]):
    return itertools.product(*(range(g) for g in grid))


def _grid_corners(grid: Tuple[int, ...]):
    """A small grid-point sample: all corners plus the origin-adjacent
    steps -- enough to observe whether an index map moves at all."""
    pts = set(itertools.product(*((0, g - 1) for g in grid)))
    for ax in range(len(grid)):
        if grid[ax] > 1:
            p = [0] * len(grid)
            p[ax] = 1
            pts.add(tuple(p))
    return sorted(pts)


def _is_grid_invariant(rec: PallasCallRecord, spec: SpecView) -> bool:
    """True when the block never revolves: same tile at every grid step
    and no dependence on scalar-prefetch contents (Pallas keeps it
    resident instead of double-buffering)."""
    seen = set()
    for fill in (0, 1):
        scalars = _scalar_args(rec, fill)
        for idx in _grid_corners(rec.grid):
            tile = _eval_index_map(spec, idx, scalars)
            if tile is None:
                return False
            seen.add(tile)
            if len(seen) > 1:
                return False
    return True


def check_blocking(rec: PallasCallRecord, report: AnalysisReport) -> None:
    for si, spec in enumerate(rec.specs):
        report.check("KERNEL-BLOCK")
        kind = "out" if spec.is_output else f"in{si}"
        if len(spec.block_shape) != len(spec.array_shape):
            report.add("KERNEL-BLOCK", f"{rec.where}:{kind}",
                       f"block rank {spec.block_shape} != operand rank "
                       f"{spec.array_shape}")
            continue
        for d, (b, a) in enumerate(zip(spec.block_shape, spec.array_shape)):
            if b <= 0 or a % b != 0:
                report.add(
                    "KERNEL-BLOCK", f"{rec.where}:{kind}",
                    f"dim {d}: block {b} does not tile operand dim {a} "
                    f"(callers pad to block multiples before dispatch)")
        if len(spec.block_shape) < 2:
            continue
        lane, sub = spec.block_shape[-1], spec.block_shape[-2]
        lane_full = lane == spec.array_shape[-1]
        sub_full = sub == spec.array_shape[-2]
        if lane % LANE != 0 and not lane_full:
            report.add("KERNEL-BLOCK", f"{rec.where}:{kind}",
                       f"lane dim {lane} not a multiple of {LANE} and not "
                       "the whole axis")
        if (jnp.dtype(spec.dtype) == jnp.int8
                and sub % INT8_SUBLANE != 0 and not sub_full):
            report.add("KERNEL-BLOCK", f"{rec.where}:{kind}",
                       f"int8 sublane dim {sub} not a multiple of "
                       f"{INT8_SUBLANE} and not the whole axis")


def check_vmem(rec: PallasCallRecord, report: AnalysisReport) -> None:
    report.check("KERNEL-VMEM")
    total = 0
    blocks = []
    for spec in rec.specs:
        nbytes = math.prod(spec.block_shape) * jnp.dtype(spec.dtype).itemsize
        mult = 1 if _is_grid_invariant(rec, spec) else 2
        total += nbytes * mult
        blocks.append({"block": list(spec.block_shape),
                       "dtype": str(jnp.dtype(spec.dtype)),
                       "buffers": mult,
                       "bytes": nbytes * mult,
                       "output": spec.is_output})
    total += rec.scratch_bytes
    report.vmem_table.append({
        "kernel": rec.name, "grid": list(rec.grid),
        "vmem_bytes": total, "budget_bytes": VMEM_BUDGET,
        "scratch_bytes": rec.scratch_bytes, "blocks": blocks,
        "ok": total <= VMEM_BUDGET,
    })
    if total > VMEM_BUDGET:
        report.add("KERNEL-VMEM", rec.where,
                   f"per-grid-step footprint {total} B exceeds the "
                   f"{VMEM_BUDGET} B VMEM budget")


def check_grid_aliasing(rec: PallasCallRecord,
                        report: AnalysisReport) -> None:
    n_steps = math.prod(rec.grid) if rec.grid else 1
    if n_steps > _GRID_ENUM_CAP:
        report.note(f"KERNEL-RACE: {rec.where} grid too large to "
                    f"enumerate ({n_steps} steps > {_GRID_ENUM_CAP}); "
                    "skipped")
        return
    scalars = _scalar_args(rec, 0)
    for spec in rec.specs:
        if not spec.is_output:
            continue
        report.check("KERNEL-RACE")
        writers: Dict[Tuple[int, ...], List[int]] = {}
        for step, idx in enumerate(_grid_points(rec.grid)):
            tile = _eval_index_map(spec, idx, scalars)
            if tile is None:
                report.add("KERNEL-RACE", rec.where,
                           "output index map not statically evaluable")
                return
            writers.setdefault(tile, []).append(step)
        for tile, steps in writers.items():
            if steps[-1] - steps[0] + 1 != len(steps):
                report.add(
                    "KERNEL-RACE", rec.where,
                    f"output tile {tile} written at non-contiguous grid "
                    f"steps {steps[:6]}{'...' if len(steps) > 6 else ''} -- "
                    "a revisit after the tile was flushed clobbers the "
                    "accumulated value")


def check_record(rec: PallasCallRecord, report: AnalysisReport) -> None:
    check_blocking(rec, report)
    check_vmem(rec, report)
    check_grid_aliasing(rec, report)


# ---------------------------------------------------------------------------
# sweep drivers
# ---------------------------------------------------------------------------


def design_points() -> List[CCIMConfig]:
    """Every plan design point the kernels claim to serve statically."""
    return [CCIMConfig(n_dcim_products=nd, adc_bits=adc, acc_len=acc)
            for nd in DESIGN_N_DCIM
            for adc in DESIGN_ADC_BITS
            for acc in DESIGN_ACC_LEN]


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _capture(records: List[PallasCallRecord], fn, *args) -> bool:
    # the dispatch wrappers are jitted: a design point whose static
    # signature matches an earlier one would hit the trace cache and
    # never reach the spy, so every capture starts from a cold cache
    jax.clear_caches()
    with record_pallas_calls(records):
        jax.eval_shape(fn, *args)
    return True


def capture_ccim_matmul(records, *, M: int, K: int, N: int,
                        cfg: CCIMConfig) -> None:
    """One prepacked real-GEMM dispatch (skinny or general route, exactly
    as the engine would pick it for this M)."""
    x_bits = tuple(_dcim_by_j(cfg))
    _, _, Np, Kp = pick_weight_blocks(K, N, cfg.acc_len)
    fn = functools.partial(
        cm_ops.ccim_matmul_int_prepacked, k_dim=K, n_dim=N,
        acc_len=cfg.acc_len, x_bits=x_bits, adc_bits=cfg.adc_bits,
        use_pallas=True, interpret=True)
    _capture(records, fn,
             _sds((M, K), jnp.int32),
             _sds((Kp, Np), jnp.int8),
             _sds((len(x_bits), Kp, Np), jnp.int8))


def capture_ccim_complex(records, *, M: int, K: int, N: int) -> None:
    from ..kernels.ccim_complex import ops as cx_ops
    _, _, Np, Kp = pick_weight_blocks(K, N)
    fn = functools.partial(
        cx_ops.ccim_complex_matmul_int_prepacked, k_dim=K, n_dim=N,
        use_pallas=True, interpret=True)
    plane = _sds((Kp, Np), jnp.int8)
    _capture(records, fn,
             _sds((M, K), jnp.int32), _sds((M, K), jnp.int32),
             plane, plane, plane, plane, plane, plane)


def capture_paged_attn(records, *, B: int, Hq: int, Hkv: int, Dh: int,
                       bs: int, n_blocks: int, n_tbl: int) -> None:
    from ..kernels.paged_attn.kernel import paged_attention_pallas
    fn = functools.partial(paged_attention_pallas, window=8, interpret=True)
    _capture(records, fn,
             _sds((B, Hq, Dh), jnp.float32),
             _sds((n_blocks, bs, Hkv, Dh), jnp.bfloat16),
             _sds((n_blocks, bs, Hkv, Dh), jnp.bfloat16),
             _sds((B, n_tbl), jnp.int32),
             _sds((B,), jnp.int32),
             _sds((), jnp.bool_))


def capture_int8(records, *, M: int, K: int, N: int) -> None:
    from ..kernels.int8_matmul.ops import int8_matmul
    fn = functools.partial(int8_matmul, use_pallas=True, interpret=True)
    _capture(records, fn, _sds((M, K), jnp.float32),
             _sds((K, N), jnp.float32))


# shape classes: one M per TUNING_CACHE bucket (gemv/skinny/wide) -- the
# decode, verify and prefill/train regimes respectively
SHAPE_CLASS_MS = {"gemv": 4, "skinny": 32, "wide": 256}
_SWEEP_K, _SWEEP_N = 512, 512


def tuning_cache_shapes() -> List[Tuple[int, int, int]]:
    """(M, K, N) for every fast_gemm entry in the tuning cache -- real
    serving shapes this host tuned for, re-audited on the Pallas path."""
    shapes = []
    for key, e in sorted(autotune._entries().items()):
        if "|fast_gemm|" in key and all(k in e for k in ("M", "K", "N")):
            shapes.append((int(e["M"]), int(e["K"]), int(e["N"])))
    return sorted(set(shapes))


def validate_tuning_cache(report: AnalysisReport) -> None:
    """Run the autotune loader's legality screen over the RAW cache file.

    The loader itself (autotune._entries) silently drops illegal entries
    at load time -- correct for serving, but the committed artifact
    should not carry any: surfacing them here makes ``--strict`` force a
    cleanup instead of letting a stale entry ride along forever.
    """
    try:
        with open(autotune.cache_path()) as f:
            raw = json.load(f).get("entries", {})
    except (OSError, ValueError, AttributeError):
        report.note("KERNEL-TUNING: no readable tuning cache; skipped")
        return
    if not isinstance(raw, dict):
        raw = {}
    for key, entry in sorted(raw.items()):
        report.check("KERNEL-TUNING")
        why = autotune.entry_violation(key, entry)
        if why:
            report.add("KERNEL-TUNING", key, why)


def sweep_kernels(report: AnalysisReport) -> List[PallasCallRecord]:
    """All five kernel families x every design point x shape classes."""
    records: List[PallasCallRecord] = []

    # families 1+2: real prepacked GEMM, general + skinny routes, at
    # every macro geometry the planner can emit
    for cfg in design_points():
        for M in SHAPE_CLASS_MS.values():
            capture_ccim_matmul(records, M=M, K=_SWEEP_K, N=_SWEEP_N,
                                cfg=cfg)
    # tuned shapes from this host's cache, prototype geometry
    proto = CCIMConfig()
    for (M, K, N) in tuning_cache_shapes():
        capture_ccim_matmul(records, M=M, K=K, N=N, cfg=proto)

    # family 3: fused complex kernel (prototype geometry; Re+Im in one
    # conversion is fixed 2-plane-per-part)
    for M in SHAPE_CLASS_MS.values():
        capture_ccim_complex(records, M=M, K=_SWEEP_K, N=_SWEEP_N)

    # family 4: paged-attention decode read at serving shapes
    capture_paged_attn(records, B=4, Hq=8, Hkv=2, Dh=128, bs=16,
                       n_blocks=64, n_tbl=8)
    capture_paged_attn(records, B=2, Hq=4, Hkv=4, Dh=128, bs=32,
                       n_blocks=16, n_tbl=4)

    # family 5: W8A8 GEMM
    for M in SHAPE_CLASS_MS.values():
        capture_int8(records, M=M, K=_SWEEP_K, N=_SWEEP_N)

    for rec in records:
        check_record(rec, report)
    validate_tuning_cache(report)

    report.census["kernel_dispatches"] = len(records)
    report.census["kernel_names"] = sorted({r.name for r in records})
    report.census["design_points"] = len(design_points())
    return records
