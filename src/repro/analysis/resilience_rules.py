"""Resilience rules: the chaos machinery must be invisible when disarmed.

The fault-injection layer (resilience/faults.py) is a trace-time static
flag, like the obs taps: while no ``inject()`` frame is open, not one
extra op may be traced, and the watchdog reads health exclusively at
segment boundaries of the guarded loop.  Both properties are checkable
from the lowered program, so they are lint rules:

- RES-OFF-PATH   the fault-free serve loop lowers byte-identical
  StableHLO before vs after a FaultModel arm/disarm cycle (the plain
  whole-workload loop AND the segmented guarded loop).  The rule also
  requires the fault-ARMED segment lowering to DIFFER from the clean
  one: an off-path gate that passes because the feature traced nothing
  either way would certify a dead feature.
- RES-HOST-SYNC  the fault-armed segmented loop body -- the exact
  lowering ``GuardedServer.compile_for`` executes under chaos -- must
  contain no host callback / infeed / transfer primitive.  Drift
  severity follows the device iteration clock (``faults.clock`` binds
  ``carry['n_iter']``), so a schedule that needed a host round-trip per
  iteration would break the one-sync-per-segment serving contract.
"""
from __future__ import annotations

from .report import AnalysisReport
from .tracer import HOST_SYNC_PRIMITIVES, walk_jaxpr

# the audited chaos scenario: drift on every analog surface, so any
# epilogue that forgot its gate would change the armed lowering
_AUDIT_FAULT = dict(gain_amp=0.5, offset_lsb=1.0, adc_offset_lsb=0.5,
                    adc_clip_bits=1.0, schedule="ramp", onset=4, period=16)


def audit_resilience(report: AnalysisReport,
                     arch: str = "minicpm-2b") -> None:
    """Run both resilience rules against the real scheduler lowerings."""
    import jax
    import jax.numpy as jnp

    from ..launch import scheduler as sched_mod
    from ..obs import ObsConfig
    from ..obs.fingerprint import hlo_fingerprint
    from ..resilience import faults as rfaults
    from .tracer import reduced_cim_setup

    cfg, packed = reduced_cim_setup(arch)
    fault = rfaults.FaultModel(**_AUDIT_FAULT)
    n_queue = 2

    def make():
        return sched_mod.ContinuousBatchingScheduler(
            packed, cfg, slots=2, prompt_len=8, max_new_cap=4,
            obs=ObsConfig())

    # -- RES-OFF-PATH ------------------------------------------------------
    report.check("RES-OFF-PATH")
    loop_before = hlo_fingerprint(make().loop_hlo_text(n_queue))
    seg_before = hlo_fingerprint(make().segment_hlo_text(n_queue))
    with rfaults.inject(fault):
        seg_armed = hlo_fingerprint(make().segment_hlo_text(n_queue))
    loop_after = hlo_fingerprint(make().loop_hlo_text(n_queue))
    seg_after = hlo_fingerprint(make().segment_hlo_text(n_queue))

    report.census["resilience_off_path"] = {
        "loop_fingerprint": loop_before,
        "segment_fingerprint": seg_before,
        "segment_fingerprint_armed": seg_armed,
        "identical_after_arm_cycle": (loop_before == loop_after
                                      and seg_before == seg_after),
        "armed_segment_differs": seg_armed != seg_before,
    }
    if loop_before != loop_after:
        report.add(
            "RES-OFF-PATH", "scheduler_loop",
            "arming + disarming a FaultModel changed the fault-free "
            "whole-workload loop lowering -- fault-off serving is paying "
            "for the chaos machinery")
    if seg_before != seg_after:
        report.add(
            "RES-OFF-PATH", "segment_loop",
            "arming + disarming a FaultModel changed the fault-free "
            "segmented (guarded) loop lowering")
    if seg_armed == seg_before:
        report.add(
            "RES-OFF-PATH", "segment_loop[armed]",
            "the fault-ARMED segment lowered byte-identically to the "
            "clean one -- injection is not wired into the compiled loop, "
            "so the off-path gate certifies a dead feature")

    # -- RES-HOST-SYNC -----------------------------------------------------
    report.check("RES-HOST-SYNC")
    sched = make()
    carry = sched._init_carry(n_queue, with_obs=True)
    qt = jnp.zeros((n_queue, sched._p_pad), jnp.int32)
    qm = jnp.zeros((n_queue, sched_mod._QM_COLS), jnp.int32)
    qp = jnp.zeros((n_queue, sched._n_pin_cols()), jnp.int32)

    def seg_loop(params, c, budget, q_toks, q_meta, q_pins):
        def body(ci):
            with rfaults.clock(ci["n_iter"]):
                return sched._step_once(params, ci, q_toks, q_meta,
                                        q_pins, n_queue)[0]

        def cond(ci):
            work = (jnp.any(sched._occupied(ci["st"]))
                    | (ci["q_head"] < n_queue))
            return work & (ci["n_iter"] < budget)

        return jax.lax.while_loop(cond, body, c)

    with rfaults.inject(fault):
        jaxpr = jax.make_jaxpr(seg_loop)(packed, carry, jnp.int32(0),
                                         qt, qm, qp)

    def visit(eqn, path):
        if eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            ctx = " > ".join(path) if path else "top level"
            report.add(
                "RES-HOST-SYNC", f"guarded_segment:{eqn.primitive.name}",
                f"host-sync primitive `{eqn.primitive.name}` at {ctx} in "
                "the fault-armed guarded loop -- drift severity and health "
                "signals must stay device-resident between segment "
                "boundaries")

    walk_jaxpr(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, visit)
    report.census["resilience_audit_fault"] = dict(_AUDIT_FAULT)
