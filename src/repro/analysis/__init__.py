"""repro.analysis -- "cimlint": static trace/kernel/AST auditing.

The stack's correctness claims are *static*: the macro geometry (2D
capacitor weighting, folded DCIM planes, ADC width), the deployment
plan, the packed-weight metadata and the Pallas block shapes are all
fixed before a single token is served.  This package proves the
invariants those claims rest on without executing any kernel:

- ``tracer``  -- lower the serve-path executables to jaxprs and audit
  dtypes, control-flow purity, buffer donation and the static-argument
  (recompile-key) space.
- ``kernels`` -- intercept every registered Pallas dispatch under
  ``jax.eval_shape`` and check VMEM budgets, block divisibility and
  grid-aliasing safety for every plan design point.
- ``lint``    -- repo-specific AST rules (import-time config mutation,
  host syncs reachable from traced control flow, noise-seed hygiene).

Run ``python -m repro.analysis --strict`` (see DESIGN.md section 12).
"""
from .report import AnalysisReport, Violation, load_baseline  # noqa: F401
