"""Telemetry (obs) rules: the device rings must stay free.

The scheduler's metrics-on serve loop threads fixed-size event rings and
counter arrays through the ``lax.while_loop`` carry (obs/rings.py).  Two
properties make that telemetry safe to leave on in production, and both
are checkable from the lowered program -- so they are lint rules, not
review comments:

- OBS-RING-DONATION  the obs state enters the whole-workload executable
  as its own donated argument, and every ring leaf must actually alias
  an output (``tf.aliasing_output`` in the lowered HLO).  A silently
  dropped donation re-allocates every ring each workload and -- for the
  iteration ring, the largest leaf -- doubles the telemetry footprint.
- OBS-HOST-SYNC      with metrics ON the loop body must still contain no
  host callback / infeed / transfer primitive.  The rings exist
  precisely so the loop keeps its single host sync; a callback-based
  "metric" would reintroduce one round-trip per iteration.

Both rules audit the REAL scheduler construction (``_lower_loop`` with
``obs=ObsConfig()``), the same lowering ``compile_for`` executes.
"""
from __future__ import annotations

from .report import AnalysisReport
from .tracer import HOST_SYNC_PRIMITIVES, walk_jaxpr


def check_ring_donation(name: str, hlo_text: str, donated_leaves: int,
                        report: AnalysisReport) -> None:
    """Count honored aliases in a metrics-on lowering.

    The obs subtree is the only donated argument of the whole-loop
    executable, so every ``tf.aliasing_output`` attribute in the text
    belongs to a ring leaf; fewer aliases than leaves means XLA dropped
    part of the donation.
    """
    report.check("OBS-RING-DONATION")
    aliased = hlo_text.count("tf.aliasing_output")
    report.census.setdefault("obs_donation", {})[name] = {
        "ring_leaves": donated_leaves, "aliased_buffers": aliased}
    if aliased < donated_leaves:
        report.add(
            "OBS-RING-DONATION", name,
            f"{donated_leaves} telemetry ring leaves donated but only "
            f"{aliased} alias an output -- the rest are copied every "
            "workload")


def check_obs_host_sync(name: str, jaxpr, report: AnalysisReport) -> None:
    """No host-sync primitive anywhere in a metrics-on serve jaxpr."""
    report.check("OBS-HOST-SYNC")

    def visit(eqn, path):
        if eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            ctx = " > ".join(path) if path else "top level"
            report.add(
                "OBS-HOST-SYNC", f"{name}:{eqn.primitive.name}",
                f"host-sync primitive `{eqn.primitive.name}` at {ctx} with "
                "metrics on -- telemetry must ride the device rings, never "
                "a callback")

    walk_jaxpr(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, visit)


def audit_obs(report: AnalysisReport, arch: str = "minicpm-2b") -> None:
    """Lower the metrics-on scheduler loop and run both obs rules."""
    import jax
    import jax.numpy as jnp

    from ..launch import scheduler as sched_mod
    from ..obs import ObsConfig
    from ..obs.rings import OBS_LEAVES
    from .tracer import reduced_cim_setup

    cfg, packed = reduced_cim_setup(arch)
    sched = sched_mod.ContinuousBatchingScheduler(
        packed, cfg, slots=2, prompt_len=8, max_new_cap=4, obs=ObsConfig())
    n_queue = 2

    check_ring_donation("scheduler_loop[obs]",
                        sched._lower_loop(n_queue).as_text(),
                        len(OBS_LEAVES), report)

    carry = sched._init_carry(n_queue)      # with_obs=True: rings inline
    qt = jnp.zeros((n_queue, sched._p_pad), jnp.int32)
    qm = jnp.zeros((n_queue, sched_mod._QM_COLS), jnp.int32)
    qp = jnp.zeros((n_queue, sched._n_pin_cols()), jnp.int32)

    def serve_loop(params, c, q_toks, q_meta, q_pins):
        def body(ci):
            return sched._step_once(params, ci, q_toks, q_meta, q_pins,
                                    n_queue)[0]

        def cond(ci):
            return (jnp.any(sched._occupied(ci["st"]))
                    | (ci["q_head"] < n_queue))

        return jax.lax.while_loop(cond, body, c)

    jaxpr = jax.make_jaxpr(serve_loop)(packed, carry, qt, qm, qp)
    check_obs_host_sync("scheduler_loop[obs]", jaxpr, report)
    report.census["obs_ring_leaves"] = list(OBS_LEAVES)
