"""Trace auditor: lower the serve-path executables to jaxprs and prove
the invariants the AOT serving story rests on.

Entry points audited (built from a real reduced config, packed weights
and both cache layouts -- the same graphs serve.py/scheduler.py compile):

- ``prefill``, ``decode_step``, ``verify_step`` (contiguous cache)
- ``prefill_into_slot`` and the paged ``decode_step`` /
  ``prefill_chunk_into_slot`` slot helpers
- the scheduler's whole while-loop (harvest/admit/step switch inside a
  ``lax.while_loop``, exactly as ``_build_loop`` stages it)

Rules:

- TRACE-F64        no 64-bit aval anywhere in a serve jaxpr (a single
  weak-type promotion doubles decode bandwidth silently).
- TRACE-HOST-SYNC  no callback/infeed/transfer primitive inside the
  executables, *especially* under while/scan/cond bodies -- one host
  round-trip per decode iteration is the difference between an AOT loop
  and a python loop.
- TRACE-DONATION   buffers declared donated actually alias an output in
  the lowered HLO (``tf.aliasing_output``) -- a silently-dropped
  donation doubles the KV-cache footprint.
- TRACE-STATIC-HASH / TRACE-STATIC-LEAK  every static field (ModelConfig,
  DeploymentPlan, packed-weight meta) hashes, and no traced array leaked
  into a static meta position (either one means a recompile per call or
  a crash at dispatch).

Plus the recompile-key census: how many distinct executables and
distinct packed static signatures one config compiles to.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .report import AnalysisReport

# primitives whose presence in a serve executable means a host sync or
# transfer at run time
HOST_SYNC_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
    "device_put",
})

_64BIT = frozenset({"float64", "int64", "uint64", "complex128"})


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> List[Tuple[str, Any]]:
    """(context, jaxpr) pairs nested in one equation's params."""
    out = []
    for k, v in eqn.params.items():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for item in vs:
            j = getattr(item, "jaxpr", None)
            if j is not None and hasattr(j, "eqns"):
                out.append((f"{eqn.primitive.name}.{k}", j))
            elif hasattr(item, "eqns"):
                out.append((f"{eqn.primitive.name}.{k}", item))
    return out


def walk_jaxpr(jaxpr, visit: Callable[[Any, Tuple[str, ...]], None],
               path: Tuple[str, ...] = ()) -> None:
    """Depth-first over every equation; ``path`` names the enclosing
    control-flow contexts (e.g. ('while.body_jaxpr', 'scan.jaxpr'))."""
    for eqn in jaxpr.eqns:
        visit(eqn, path)
        for ctx, sub in _sub_jaxprs(eqn):
            walk_jaxpr(sub, visit, path + (ctx,))


def check_no_f64(name: str, jaxpr, report: AnalysisReport) -> None:
    report.check("TRACE-F64")
    hits: Dict[str, str] = {}

    def visit(eqn, path):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _64BIT and eqn.primitive.name not in hits:
                hits[eqn.primitive.name] = dt

    walk_jaxpr(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, visit)
    for prim, dt in hits.items():
        report.add("TRACE-F64", f"{name}:{prim}",
                   f"{dt} value flows through `{prim}` -- a weak-type or "
                   "x64 promotion doubled a serve-path buffer")


def check_no_host_sync(name: str, jaxpr, report: AnalysisReport) -> None:
    report.check("TRACE-HOST-SYNC")

    def visit(eqn, path):
        if eqn.primitive.name in HOST_SYNC_PRIMITIVES:
            ctx = " > ".join(path) if path else "top level"
            report.add(
                "TRACE-HOST-SYNC", f"{name}:{eqn.primitive.name}",
                f"host-sync primitive `{eqn.primitive.name}` at {ctx}"
                + (" (inside a compiled loop body: one host round-trip "
                   "per iteration)" if path else ""))

    walk_jaxpr(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, visit)


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


def check_donation(name: str, fn, donate_argnums: Tuple[int, ...],
                   args: tuple, report: AnalysisReport) -> None:
    """Lower ``jit(fn, donate_argnums=...)`` and count aliased inputs.

    XLA records an honored donation as a ``tf.aliasing_output`` input
    attribute; every array leaf of a donated argument should carry one
    (the serve path donates caches/carries whose every leaf round-trips
    to an output).  Fewer aliases than donated leaves = buffers silently
    copied every step.
    """
    report.check("TRACE-DONATION")
    donated_leaves = sum(
        len(jax.tree.leaves(args[i])) for i in donate_argnums)
    text = jax.jit(fn, donate_argnums=donate_argnums).lower(*args).as_text()
    aliased = text.count("tf.aliasing_output")
    report.census.setdefault("donation", {})[name] = {
        "donated_leaves": donated_leaves, "aliased_buffers": aliased}
    if aliased < donated_leaves:
        report.add(
            "TRACE-DONATION", name,
            f"{donated_leaves} leaves donated but only {aliased} aliased "
            "an output -- the rest are copied every invocation")


# ---------------------------------------------------------------------------
# static keys
# ---------------------------------------------------------------------------


def _iter_static_meta(tree) -> List[Tuple[str, tuple]]:
    """(leaf-type-name, meta-values) for every registered-dataclass leaf
    carrying static metadata (PackedCimWeights & friends)."""
    from ..core.engine import FusedPackedCimWeights, PackedCimWeights

    found: List[Tuple[str, tuple]] = []

    def visit(x):
        if isinstance(x, PackedCimWeights):
            found.append(("PackedCimWeights", (x.k_dim, x.n_dim, x.cfg)))
        elif isinstance(x, FusedPackedCimWeights):
            found.append(("FusedPackedCimWeights",
                          (x.seg_names, x.seg_dims)))
        return x

    jax.tree.map(visit, tree,
                 is_leaf=lambda x: isinstance(
                     x, (PackedCimWeights, FusedPackedCimWeights)))
    return found


def check_static_keys(cfg, packed_params, report: AnalysisReport) -> None:
    sites: List[Tuple[str, Any]] = [
        ("ModelConfig", cfg),
        ("DeploymentPlan", cfg.cim_plan),
    ]
    metas = _iter_static_meta(packed_params)
    sites += [(f"{kind}[{i}]", meta) for i, (kind, meta) in enumerate(metas)]

    for where, value in sites:
        report.check("TRACE-STATIC-HASH")
        try:
            hash(value)
        except TypeError as e:
            report.add("TRACE-STATIC-HASH", where,
                       f"static value unhashable ({e}) -- every dispatch "
                       "through jit would fail or recompile")

    report.check("TRACE-STATIC-LEAK", len(metas))
    for i, (kind, meta) in enumerate(metas):
        for field in jax.tree.leaves(meta,
                                     is_leaf=lambda x: not isinstance(
                                         x, (tuple, list))):
            if isinstance(field, (jax.Array, np.ndarray)):
                report.add(
                    "TRACE-STATIC-LEAK", f"{kind}[{i}]",
                    f"array of shape {getattr(field, 'shape', '?')} in a "
                    "static meta position -- a traced value leaked into "
                    "the treedef (recompile per call, unhashable key)")

    # treedef of the packed tree is itself a jit cache key
    report.check("TRACE-STATIC-HASH")
    try:
        hash(jax.tree.structure(packed_params))
    except TypeError as e:
        report.add("TRACE-STATIC-HASH", "packed-params treedef",
                   f"treedef unhashable ({e})")

    # recompile census: distinct static signatures = distinct executables
    # one config can demand for its projections (an unhashable meta was
    # already reported above; count it by repr so the census survives)
    sigs = set()
    for _, meta in metas:
        try:
            sigs.add(meta)
        except TypeError:
            sigs.add(repr(meta))
    plan = cfg.cim_plan
    report.census["recompile_keys"] = {
        "packed_leaves": len(metas),
        "distinct_packed_meta": len(sigs),
        "plan_entries": len(plan.entries) if plan is not None else 0,
        "distinct_plan_entries": (
            len({e for _, e in plan.entries} | {plan.default})
            if plan is not None else 1),
    }


# ---------------------------------------------------------------------------
# entry-point assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeEntry:
    name: str
    fn: Callable
    args: tuple
    donate_argnums: Tuple[int, ...] = ()


def reduced_cim_setup(arch: str = "minicpm-2b") -> Tuple[Any, Any]:
    """(cfg, packed_params) for the audited reduced cim-mode config with
    a mixed-fidelity plan -- the same construction serve.py uses."""
    from ..configs import get_config
    from ..models import lm
    from ..plan.plan import (DIGITAL_ENTRY, HYBRID_ENTRY, DeploymentPlan,
                             PlanEntry)
    from ..core.ccim import CCIMConfig

    cfg = get_config(arch, smoke=True)
    plan = DeploymentPlan.from_dict(
        {"wo": DIGITAL_ENTRY,
         "w2": PlanEntry(cfg=CCIMConfig(n_dcim_products=1, adc_bits=8))},
        default=HYBRID_ENTRY)
    cfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=plan)
    params = lm.init(jax.random.PRNGKey(0), cfg)[0]
    packed = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)
    return cfg, packed


def build_serve_entries(arch: str = "minicpm-2b",
                        with_scheduler: bool = True
                        ) -> Tuple[Any, Any, List[ServeEntry]]:
    """Assemble the audited executables from a reduced cim-mode config
    with a mixed-fidelity plan -- the same construction serve.py uses.

    Returns (cfg, packed_params, entries).
    """
    from ..models import lm

    cfg, packed = reduced_cim_setup(arch)

    B, P, S = 2, 8, 4
    max_seq = 32
    cache = lm.init_cache(cfg, B, max_seq)
    pcache = lm.init_paged_cache(cfg, B, n_blocks=12, block_size=8,
                                 n_tbl=6)
    toks = jnp.zeros((B, P), jnp.int32)
    tok1 = jnp.zeros((B, 1), jnp.int32)
    vtoks = jnp.zeros((B, S), jnp.int32)
    live = jnp.ones((B,), jnp.bool_)
    slot = jnp.int32(0)
    one_prompt = jnp.zeros((1, P), jnp.int32)

    entries = [
        ServeEntry("prefill",
                   lambda p, t, c: lm.prefill(p, cfg, t, c),
                   (packed, toks, cache)),
        ServeEntry("decode_step",
                   lambda p, t, c, lv: lm.decode_step(p, cfg, t, c, lv),
                   (packed, tok1, cache, live), donate_argnums=(2,)),
        ServeEntry("verify_step",
                   lambda p, t, c, lv: lm.verify_step(p, cfg, t, c, lv),
                   (packed, vtoks, cache, live), donate_argnums=(2,)),
        ServeEntry("prefill_into_slot",
                   lambda p, t, c, s: lm.prefill_into_slot(p, cfg, t, c, s),
                   (packed, one_prompt, cache, slot), donate_argnums=(2,)),
        ServeEntry("decode_step[paged]",
                   lambda p, t, c, lv: lm.decode_step(p, cfg, t, c, lv),
                   (packed, tok1, pcache, live), donate_argnums=(2,)),
        ServeEntry("prefill_chunk_into_slot[paged]",
                   lambda p, t, c, s: lm.prefill_chunk_into_slot(
                       p, cfg, t, c, s),
                   (packed, one_prompt, pcache, slot), donate_argnums=(2,)),
    ]

    if with_scheduler:
        entries.append(_scheduler_loop_entry(cfg, packed))
    return cfg, packed, entries


def _scheduler_loop_entry(cfg, packed) -> ServeEntry:
    """The whole-workload while-loop, staged exactly like
    ``ContinuousBatchingScheduler._build_loop`` (cond + switch body)."""
    from ..launch import scheduler as sched_mod

    sched = sched_mod.ContinuousBatchingScheduler(
        packed, cfg, slots=2, prompt_len=8, max_new_cap=4)
    n_queue = 2
    carry = sched._init_carry(n_queue)
    qt = jnp.zeros((n_queue, sched._p_pad), jnp.int32)
    qm = jnp.zeros((n_queue, sched_mod._QM_COLS), jnp.int32)
    qp = jnp.zeros((n_queue, sched._n_pin_cols()), jnp.int32)

    def serve_loop(params, c, q_toks, q_meta, q_pins):
        def body(ci):
            return sched._step_once(params, ci, q_toks, q_meta, q_pins,
                                    n_queue)[0]

        def cond(ci):
            return (jnp.any(sched._occupied(ci["st"]))
                    | (ci["q_head"] < n_queue))

        return jax.lax.while_loop(cond, body, c)

    return ServeEntry("scheduler_loop", serve_loop,
                      (packed, carry, qt, qm, qp))


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def audit_serve_path(report: AnalysisReport, arch: str = "minicpm-2b",
                     with_scheduler: bool = True) -> None:
    cfg, packed, entries = build_serve_entries(arch, with_scheduler)
    for e in entries:
        jaxpr = jax.make_jaxpr(e.fn)(*e.args)
        check_no_f64(e.name, jaxpr, report)
        check_no_host_sync(e.name, jaxpr, report)
        if e.donate_argnums:
            check_donation(e.name, e.fn, e.donate_argnums, e.args, report)
    check_static_keys(cfg, packed, report)
    report.census["n_executables"] = len(entries)
    report.census["entry_points"] = [e.name for e in entries]
    report.census["arch"] = arch
