"""AdamW with global-norm clipping, configurable moment dtypes (memory-
critical for the 480B-parameter dry-runs), decoupled weight decay, and
grad-accumulation support.  Pure-pytree implementation (no optax on the
box); update math in fp32 regardless of storage dtypes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .schedules import make_schedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"           # "cosine" | "wsd"
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer memory
    accum_steps: int = 1


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.accum_steps > 1:
        state["accum"] = jax.tree.map(zeros, params)
        state["micro"] = jnp.zeros((), jnp.int32)
    return state


def global_norm(tree) -> Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(params, grads, state, cfg: OptConfig
                 ) -> Tuple[Any, Dict[str, Any]]:
    """One optimizer step. Returns (new_params, new_state)."""
    sched = make_schedule(cfg.schedule, peak_lr=cfg.peak_lr,
                          warmup=cfg.warmup, total=cfg.total_steps)
    step = state["step"] + 1
    lr = sched(step)

    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh, vh = m32 / bc1, v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = dict(state, step=step, m=new_m, v=new_v)
    return new_p, new_state


def accumulate_grads(state, grads, cfg: OptConfig):
    """Error-free micro-batch accumulation (for grad-accum training)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    acc = jax.tree.map(
        lambda a, g: (a.astype(jnp.float32) + g.astype(jnp.float32)).astype(mdt),
        state["accum"], grads)
    return dict(state, accum=acc, micro=state["micro"] + 1)
