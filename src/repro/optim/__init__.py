from .adamw import OptConfig, adamw_update, global_norm, init_opt_state  # noqa: F401
from .schedules import make_schedule, warmup_cosine, wsd  # noqa: F401
