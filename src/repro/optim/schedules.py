"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM's schedule)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> Stable (constant) -> Decay (last decay_frac of training).

    MiniCPM (arXiv:2404.06395): exponential-ish final decay; we use the
    paper's reported 10% decay window with exponential anneal.
    """
    step = jnp.asarray(step, jnp.float32)
    decay_steps = jnp.maximum(total * decay_frac, 1.0)
    decay_start = total - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
    dec = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
    out = jnp.where(step < warmup, warm, peak_lr)
    return jnp.where(step > decay_start, dec, out)


def make_schedule(name: str, **kw):
    if name == "cosine":
        return lambda s: warmup_cosine(s, **kw)
    if name == "wsd":
        return lambda s: wsd(s, **kw)
    raise ValueError(name)
