"""Decoder LM assembly for every architecture family.

One functional model with four entry points:

  init(key, cfg)                          -> (params, axes)
  forward(params, cfg, tokens, ...)       -> logits, aux      (train path)
  prefill(params, cfg, tokens, cache)     -> logits, cache    (inference)
  decode_step(params, cfg, token, cache, live=None)
                                          -> logits, cache    (inference)
  prefill_into_slot(params, cfg, tokens, cache, slot)
                                          -> logits, cache    (serving)

The decode cache tracks a per-slot ``(batch,)`` position vector, and
``reset_slot`` / ``prefill_into_slot`` give the continuous-batching
scheduler (launch/scheduler.py) slot-level admission into a shared pool.

Layer stacks are scanned (stacked params, jax.lax.scan) so compile time is
depth-independent -- required for 40-cell dry-runs on CPU and the right
call for production.  Training bodies are rematerialized (jax.checkpoint)
so the dry-run memory analysis reflects a deployable activation footprint.

Families:
  dense    : [attn, mlp] x L
  moe      : [attn, moe] x L
  ssm      : [mamba2] x L
  hybrid   : groups of `period` mamba layers + ONE shared attn+mlp block
             (zamba2 -- weight co-location showcase, see DESIGN.md)
  vlm      : dense backbone + frontend patch-embedding stub, prefix-LM
  audio    : dense backbone over codec-token frames (frontend stub)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.engine import FusedPackedCimWeights
from ..obs import taps
from . import layers as L
from .config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stacked_init(init_fn, key, n: int):
    """vmap a per-layer init over n layer keys -> stacked params + axes."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(jax.random.PRNGKey(0))  # axes from one instantiation
    axes = jax.tree.map(lambda a: ("layers",) + a, axes,
                        is_leaf=lambda x: isinstance(x, tuple))
    return params, axes


def _block_init(cfg: ModelConfig, dtype):
    """Per-layer (attn/mixer + mlp/moe + norms) init for the scanned stack."""

    def init(key):
        ks = jax.random.split(key, 4)
        p, a = {}, {}
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            p["attn"], a["attn"] = L.attention_init(ks[0], cfg, dtype)
            p["ln1"], a["ln1"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
            p["ln2"], a["ln2"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
            if cfg.family == "moe":
                p["moe"], a["moe"] = L.moe_init(ks[1], cfg, dtype)
                if cfg.d_ff:  # dense residual branch (arctic)
                    p["mlp"], a["mlp"] = L.mlp_init(ks[2], cfg, cfg.d_ff, dtype)
            else:
                p["mlp"], a["mlp"] = L.mlp_init(ks[1], cfg, cfg.d_ff, dtype)
        elif cfg.family in ("ssm", "hybrid"):
            p["mamba"], a["mamba"] = L.mamba2_init(ks[0], cfg, dtype)
            p["ln1"], a["ln1"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
        else:
            raise ValueError(cfg.family)
        return p, a

    return init


def _shared_block_init(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["attn"], a["attn"] = L.attention_init(ks[0], cfg, dtype)
    p["mlp"], a["mlp"] = L.mlp_init(ks[1], cfg, cfg.d_ff, dtype)
    p["ln1"], a["ln1"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
    p["ln2"], a["ln2"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
    return p, a


def init(key, cfg: ModelConfig, pack_cim: bool = False) -> Tuple[Params, Dict]:
    """Initialise params.  ``pack_cim=True`` (requires cfg.cim_mode) runs
    the PTQ weight-conditioning pipeline on every projection at load time,
    returning ``PackedCimWeights`` leaves -- the write-once/compute-many
    deployment shape (see pack_cim_params).  The axes tree describes the
    UNPACKED float params (sharding rules apply to training layouts)."""
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    p, a = {}, {}
    p["embed"] = (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype)
    a["embed"] = ("vocab", "head_embed")
    blk_init = _block_init(cfg, dtype)
    p["layers"], a["layers"] = _stacked_init(blk_init, k_layers, cfg.n_layers)
    if cfg.family == "hybrid":
        p["shared"], a["shared"] = _shared_block_init(cfg, k_shared, dtype)
    p["ln_f"], a["ln_f"] = jnp.zeros((cfg.d_model,), dtype), ("embed",)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = L._init_dense(
            k_head, cfg.d_model, cfg.vocab_size, ("head_embed", "vocab"), dtype=dtype)
    if pack_cim:
        p = pack_cim_params(p, cfg)
    return p, a


# ---------------------------------------------------------------------------
# prepacked CIM weights (weight-stationary serving)
# ---------------------------------------------------------------------------


# Projection leaves consumed by layers._dense -- the matmuls the macro
# executes.  Everything else (embeddings, lm_head, MoE expert einsums,
# routers, convs, norms) stays float.
_CIM_PACKABLE = frozenset({
    "wq", "wk", "wv", "wo",                      # attention
    "w1", "w2", "w3",                            # (shared-)MLP
    "w_z", "w_x", "w_bc", "w_dt", "out_proj",    # mamba2 projections
})


def _walk_packable(tree, visit, path=()):
    """Rebuild ``tree`` with ``visit(plan_path, leaf)`` applied to every
    _dense-consumed projection leaf.  ``plan_path`` is the deployment-plan
    path convention: tree keys joined with "/", the scanned-stack key
    "layers" dropped (one entry covers every depth of a scanned stack) --
    e.g. "attn/wq", "moe/shared/w1", "shared/mlp/w2", "mamba/out_proj".
    MoE expert tensors reuse the w1/w2/w3 names but feed einsums, not
    _dense, so the level directly under "moe" is skipped (the shared
    expert under moe/shared IS packable).
    """
    out = {}
    for k, v in tree.items():
        sub = path if k == "layers" else path + (k,)
        if isinstance(v, dict):
            out[k] = _walk_packable(v, visit, sub)
        elif k in _CIM_PACKABLE and not (len(path) >= 1 and path[-1] == "moe"):
            out[k] = visit("/".join(sub), v)
        else:
            out[k] = v
    return out


def iter_packable_paths(params: Params) -> Dict[str, Tuple[int, ...]]:
    """Deployment-plan path -> leaf shape for every _dense projection.

    The planner's site list: each path is one plan-addressable projection
    (scanned stacks appear once, with their (layers, K, N) stacked shape).
    """
    sites: Dict[str, Tuple[int, ...]] = {}

    def visit(path, v):
        sites[path] = tuple(v.shape)
        return v

    _walk_packable(params, visit)
    return sites


# Projection groups that consume the same input activation -- the fusion
# candidates (models.layers._dense_group consumes the fused leaves).  Which
# members actually fuse is decided per group by the deployment plan: only
# members resolving to the SAME PlanEntry pack together.
_FUSE_GROUPS = (("wq", "wk", "wv"),            # attention QKV
                ("w1", "w3"),                  # SwiGLU gate/up
                ("w_z", "w_x", "w_bc", "w_dt"))  # mamba2 input projections


def _pack_single(path: str, v, cfg: ModelConfig):
    eng = L.cim_engine(cfg, path)
    if eng.fidelity == "float":              # plan keeps this site off-macro
        return v
    if v.ndim == 2:                          # (K, N): shared-block weights
        return eng.pack(v)
    if v.ndim == 3:                          # (layers, K, N): scanned stack
        return jax.vmap(eng.pack)(v)
    return v                                 # MoE expert tensors etc.


def _pack_tree(tree: Params, cfg: ModelConfig, path=()) -> Params:
    """Fusion-aware packing walk (see pack_cim_params).

    At every dict level, each fusion-candidate group splits into
    partitions by resolved PlanEntry; partitions of two or more sites
    concatenate along N and pack as ONE ``FusedPackedCimWeights`` under a
    "wq+wk+wv"-style key (per-channel scales and quantization are column-
    local, so the fused pack is bit-identical per segment to the separate
    packs).  Everything else packs -- or stays raw -- exactly as before.
    """
    def packable(k):
        return (k in _CIM_PACKABLE
                and not (len(path) >= 1 and path[-1] == "moe"))

    out = dict(tree)
    consumed = set()
    if cfg.cim_fuse:
        for members in _FUSE_GROUPS:
            present = [m for m in members
                       if not isinstance(tree.get(m), dict)
                       and getattr(tree.get(m), "ndim", 0) in (2, 3)
                       and packable(m)]
            if len(present) < 2:
                continue
            prefix = "/".join(path) + "/" if path else ""
            for ecfg, fid, g in L.fusion_partitions(cfg, prefix, present):
                eng = L.CimEngine(cfg=ecfg)
                wcat = jnp.concatenate([tree[m] for m in g], axis=-1)
                pk = (jax.vmap(eng.pack)(wcat) if wcat.ndim == 3
                      else eng.pack(wcat))
                out[L.FUSED_SEP.join(g)] = FusedPackedCimWeights(
                    packed=pk, seg_names=tuple(g),
                    seg_dims=tuple(int(tree[m].shape[-1]) for m in g))
                consumed.update(g)
    for k, v in tree.items():
        if k in consumed:
            del out[k]
            continue
        sub = path if k == "layers" else path + (k,)
        if isinstance(v, dict):
            out[k] = _pack_tree(v, cfg, sub)
        elif packable(k):
            out[k] = _pack_single("/".join(sub), v, cfg)
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _pack_cim_params_jit(params: Params, cfg: ModelConfig) -> Params:
    return _pack_tree(params, cfg)


def pack_cim_params(params: Params, cfg: ModelConfig) -> Params:
    """Replace every _dense-consumed projection with PackedCimWeights.

    This is the software analogue of writing the SRAM arrays: per-channel
    SMF scales, integer sign/magnitude contents and folded MSB bit-planes
    are computed ONCE here; prefill/decode then run activation-only
    quantization.  Stacked (scanned) layer weights are packed under vmap,
    so the packed leaves keep their leading layer axis and drop straight
    into the scanned stacks.  Bit-identical to unpacked cim_mode execution.

    The packing pipeline is jit-compiled HERE (cfg is static): eager and
    outer-jit callers get the same fused scale arithmetic, so the packed
    leaves are bit-identical however packing is invoked (regression-tested
    in tests/test_engine.py -- eager packing used to differ in the last
    scale ulp, flipping occasional magnitudes).

    Under a deployment plan (cfg.cim_plan, repro.plan) each projection
    packs under ITS OWN entry's CCIMConfig -- the packed leaf carries that
    config as static pytree metadata, so mixed packs coexist in one
    compiled step -- and plan-fidelity "float" sites stay raw float
    matrices (served as plain matmuls).

    With cfg.cim_fuse (the default) plan-compatible projections that share
    an input activation (QKV; gate/up; the mamba2 input projections) pack
    as ONE wide ``FusedPackedCimWeights`` leaf with per-segment N-offsets
    -- the decode hot path then runs ~3 wide GEMMs per block instead of ~7
    skinny ones, with per-projection outputs (and noise streams) still
    bit-identical to the unfused pack (see DESIGN.md section 9).
    """
    if not cfg.cim_mode:
        raise ValueError("pack_cim_params requires cfg.cim_mode=True")
    return _pack_cim_params_jit(params, cfg)


# ---------------------------------------------------------------------------
# shared forward machinery
# ---------------------------------------------------------------------------


def _is_local_arr(cfg: ModelConfig) -> Array:
    return jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)], jnp.bool_)


def _embed(p, cfg: ModelConfig, tokens: Array,
           frontend_embs: Optional[Array]) -> Tuple[Array, int]:
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.family in ("vlm", "audio") and frontend_embs is not None:
        x = jnp.concatenate([frontend_embs.astype(x.dtype), x], axis=1)
    n_prefix = (frontend_embs.shape[1]
                if (cfg.prefix_lm and frontend_embs is not None) else 0)
    return x, n_prefix


def _logits(p, cfg: ModelConfig, x: Array) -> Array:
    x = L.rms_norm(x, p["ln_f"], cfg.norm_eps)
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = x @ head.astype(x.dtype)
    return L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def _attn_block(blk, x, cfg, positions, is_local, kv=None, cache_pos=None,
                n_prefix=0, return_kv=False, prefix="", block_table=None,
                write_mask=None):
    """``prefix`` qualifies the deployment-plan projection paths: the
    scanned per-layer stacks use "" (paths "attn/wq", "mlp/w1", ...), the
    zamba2 shared block passes "shared/".  ``block_table`` switches the
    KV cache to paged pools and ``write_mask`` redirects non-live rows'
    paged writes to the trash block (see layers.attention_apply)."""
    h, new_kv = L.attention_apply(
        blk["attn"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg, positions,
        is_local, kv_cache=kv, cache_pos=cache_pos, n_prefix=n_prefix,
        return_kv=return_kv, path=prefix + "attn", block_table=block_table,
        write_mask=write_mask)
    x = x + h
    if "moe" in blk:
        h, aux = L.moe_apply(blk["moe"], L.rms_norm(x, blk["ln2"], cfg.norm_eps),
                             cfg, path=prefix + "moe")
        if "mlp" in blk:  # arctic: dense residual in parallel with MoE
            h = h + L.mlp_apply(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps),
                                cfg, path=prefix + "mlp")
    elif "mlp" in blk:
        h, aux = L.mlp_apply(blk["mlp"], L.rms_norm(x, blk["ln2"], cfg.norm_eps)
                             , cfg, path=prefix + "mlp"), jnp.float32(0.0)
    else:
        h, aux = 0.0, jnp.float32(0.0)
    return x + h, new_kv, aux


# ---------------------------------------------------------------------------
# train/eval forward (no cache)
# ---------------------------------------------------------------------------


def hidden_states(params, cfg: ModelConfig, tokens: Array,
                  frontend_embs: Optional[Array] = None,
                  remat: bool = True) -> Tuple[Array, Array]:
    """tokens (B, S_text) -> final hidden (B, S_total, D), aux_loss."""
    x, n_prefix = _embed(params, cfg, tokens, frontend_embs)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    if cfg.family in ("ssm", "hybrid"):
        x = _ssm_stack(params, cfg, x, positions, remat)
        aux = jnp.float32(0.0)
    else:
        def body(x, scanned):
            blk, is_local = scanned
            x, _, aux = _attn_block(blk, x, cfg, positions, is_local,
                                    n_prefix=n_prefix)
            return x, aux
        if remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, (params["layers"], _is_local_arr(cfg)))
        aux = jnp.sum(auxs)
    return x, aux


def forward(params, cfg: ModelConfig, tokens: Array,
            frontend_embs: Optional[Array] = None,
            remat: bool = True) -> Tuple[Array, Array]:
    """tokens (B, S_text) -> logits (B, S_total, V), aux_loss (scalar)."""
    x, aux = hidden_states(params, cfg, tokens, frontend_embs, remat)
    return _logits(params, cfg, x), aux


def _slice_layers(tree, lo: int, hi: int):
    return jax.tree.map(lambda v: v[lo:hi], tree)


def _ssm_stack(params, cfg: ModelConfig, x, positions, remat,
               period_blocks=True):
    """Mamba2 stack; for 'hybrid', one SHARED attn block every `period`."""
    def body(x, blk):
        h, _ = L.mamba2_apply(
            blk["mamba"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg)
        return x + h, None
    if remat:
        body = jax.checkpoint(body)

    if cfg.family == "ssm" or not cfg.shared_attn_period:
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    done = 0
    for g in range(n_groups):
        grp = _slice_layers(params["layers"], g * period, (g + 1) * period)
        x, _ = jax.lax.scan(body, x, grp)
        done = (g + 1) * period
        x, _, _ = _attn_block(params["shared"], x, cfg, positions,
                              jnp.bool_(False), prefix="shared/")
    if done < cfg.n_layers:
        grp = _slice_layers(params["layers"], done, cfg.n_layers)
        x, _ = jax.lax.scan(body, x, grp)
    return x


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict[str, Array]:
    """Allocate the decode cache for `batch` sequences of up to `max_seq`.

    ``pos`` is a per-slot ``(batch,)`` vector: every sequence in the pool
    tracks its own write position, so slots at different depths (continuous
    batching, launch/scheduler.py) share one cache and one compiled step.
    """
    c: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    hkv, dh = cfg.padded_kv_heads, cfg.head_dim
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        shape = (cfg.n_layers, batch, max_seq, hkv, dh)
        c["k"] = jnp.zeros(shape, dtype)
        c["v"] = jnp.zeros(shape, dtype)
    if cfg.family in ("ssm", "hybrid"):
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        W = cfg.ssm_conv_width
        c["ssm"] = jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32)
        c["conv_x"] = jnp.zeros((cfg.n_layers, batch, W - 1, cfg.d_inner),
                                dtype)
        c["conv_bc"] = jnp.zeros((cfg.n_layers, batch, W - 1, 2 * N), dtype)
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        n_inv = cfg.n_layers // cfg.shared_attn_period
        c["shared_k"] = jnp.zeros((n_inv, batch, max_seq, hkv, dh), dtype)
        c["shared_v"] = jnp.zeros((n_inv, batch, max_seq, hkv, dh), dtype)
    return c


def init_paged_cache(cfg: ModelConfig, batch: int, n_blocks: int,
                     block_size: int, n_tbl: int,
                     dtype=jnp.bfloat16) -> Dict[str, Array]:
    """Allocate the PAGED decode cache: per-layer global KV block pools
    plus a per-slot block table.

    KV memory no longer scales with ``batch * max_seq`` -- the pools are
    ``(n_layers, n_blocks, block_size, hkv, dh)`` shared by every slot,
    and slot b's logical row p resolves through ``table[b, p //
    block_size]``.  The presence of the ``"table"`` key is what flips
    prefill/decode_step/verify_step (and the slot helpers) into paged
    mode; SSM/conv state stays per-slot dense (it is O(1) per slot, not
    O(max_seq)).  Block 0 is reserved as the trash block by the
    allocator (launch/scheduler.py); an all-zero table row -- the reset
    state -- therefore points every position at garbage no live slot
    reads.
    """
    c: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32),
                         "table": jnp.zeros((batch, n_tbl), jnp.int32)}
    hkv, dh = cfg.padded_kv_heads, cfg.head_dim
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        shape = (cfg.n_layers, n_blocks, block_size, hkv, dh)
        c["k"] = jnp.zeros(shape, dtype)
        c["v"] = jnp.zeros(shape, dtype)
    if cfg.family in ("ssm", "hybrid"):
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        W = cfg.ssm_conv_width
        c["ssm"] = jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32)
        c["conv_x"] = jnp.zeros((cfg.n_layers, batch, W - 1, cfg.d_inner),
                                dtype)
        c["conv_bc"] = jnp.zeros((cfg.n_layers, batch, W - 1, 2 * N), dtype)
    if cfg.family == "hybrid" and cfg.shared_attn_period:
        n_inv = cfg.n_layers // cfg.shared_attn_period
        c["shared_k"] = jnp.zeros((n_inv, n_blocks, block_size, hkv, dh),
                                  dtype)
        c["shared_v"] = jnp.zeros((n_inv, n_blocks, block_size, hkv, dh),
                                  dtype)
    return c


# ---------------------------------------------------------------------------
# slot-level cache ops (continuous batching, launch/scheduler.py)
# ---------------------------------------------------------------------------
# Every cache entry carries the pool (batch) dimension at axis 1 -- they are
# stacked per layer/group -- except "pos", which is the (batch,) position
# vector itself.  ``slot`` may be a traced scalar, so one compiled
# reset/refill executable serves every slot in the pool.
#
# In PAGED caches (init_paged_cache) the KV entries are global block pools
# with NO batch axis: the slot helpers pass them through whole (every slot
# addresses the pools through its table row), and per-slot state is just
# "pos", "table" and the SSM/conv entries.


_POOL_KEYS = frozenset({"k", "v", "shared_k", "shared_v"})


def is_paged(cache: Dict) -> bool:
    return "table" in cache


def _slot_axis(key: str) -> int:
    return 0 if key in ("pos", "table") else 1


def slot_slice(cache: Dict, slot) -> Dict:
    """Extract a batch-1 view of one pool slot (same structure, batch=1).
    Paged KV pools are returned whole -- they are shared, and the slot's
    table row is what scopes them."""
    paged = is_paged(cache)
    return {k: (v if paged and k in _POOL_KEYS else
                jax.lax.dynamic_slice_in_dim(v, slot, 1, _slot_axis(k)))
            for k, v in cache.items()}


def slot_update(cache: Dict, sub: Dict, slot) -> Dict:
    """Write a batch-1 sub-cache back into pool slot ``slot``."""
    paged = is_paged(cache)
    return {k: (sub[k].astype(cache[k].dtype)
                if paged and k in _POOL_KEYS else
                jax.lax.dynamic_update_slice_in_dim(
                    cache[k], sub[k].astype(cache[k].dtype), slot,
                    _slot_axis(k)))
            for k in cache}


def _zeroed_slot(cache: Dict, slot) -> Dict:
    """A zeroed batch-1 sub-cache for ``slot`` -- the reset state.

    KV rows do not strictly need zeroing -- the attention validity mask
    hides everything at or beyond ``pos`` -- but SSM/conv state feeds the
    recurrence as an initial value, so a freed slot MUST be cleared before
    its next prefill.  One op clears both uniformly.

    Paged caches keep the pools (shared!) AND the slot's table row: block
    mapping is owned by the scheduler's allocator, which arms the table
    BEFORE prefilling and clears it at harvest -- a reset between the two
    must not sever the mapping.
    """
    paged = is_paged(cache)
    sub = slot_slice(cache, slot)
    return {k: (v if paged and k in _POOL_KEYS | {"table"}
                else jnp.zeros_like(v))
            for k, v in sub.items()}


def reset_slot(cache: Dict, slot) -> Dict:
    """Zero one slot's state (KV rows, SSM/conv state, position)."""
    return slot_update(cache, _zeroed_slot(cache, slot), slot)


def prefill_into_slot(params, cfg: ModelConfig, tokens: Array, cache: Dict,
                      slot, frontend_embs: Optional[Array] = None
                      ) -> Tuple[Array, Dict]:
    """Prefill ONE request (tokens (1, P)) into pool slot ``slot``.

    The slot is reset, the prompt runs a batch-1 prefill against the
    slot-sliced cache, and the result is scattered back -- other slots'
    state is untouched, shapes are static, and ``slot`` may be traced, so
    the scheduler refills any freed slot through one AOT-compiled
    executable without recompiling.
    """
    logits, sub = prefill(params, cfg, tokens, _zeroed_slot(cache, slot),
                          frontend_embs)
    return logits, slot_update(cache, sub, slot)


def prefill_chunk_into_slot(params, cfg: ModelConfig, tokens: Array,
                            cache: Dict, slot) -> Tuple[Array, Dict]:
    """Advance ONE slot's prefill by one chunk (tokens (1, C)).

    The chunk starts at the slot's current ``cache["pos"]`` (the
    scheduler arms pos before the first chunk and tracks progress through
    it), runs a batch-1 forward at absolute positions [pos, pos+C), and
    leaves pos at pos+C.  Unlike ``prefill_into_slot`` the slot is NOT
    reset -- earlier chunks' KV rows (or a shared prefix's refcounted
    blocks) are the context this chunk attends to.

    Returns logits for ALL C chunk positions: the scheduler samples the
    request's first token from row ``plen-1 - start`` of the final chunk.
    For attention families every row is bit-identical to the same row of
    a single-shot prefill (row-local GEMMs + per-row softmax over an
    identical masked key stream), so chunked admission preserves the
    token contract.  SSM/hybrid chunks carry conv+SSM state across calls
    and are bit-identical when chunk boundaries align with
    ``cfg.ssm_chunk`` and prompt lengths are chunk multiples (the
    scheduler enforces this; a garbage chunk tail would corrupt the
    recurrent state, unlike attention where the validity horizon masks
    it).
    """
    sub = slot_slice(cache, slot)
    x, n_prefix = _embed(params, cfg, tokens, None)
    B, S, _ = x.shape
    pos = sub["pos"]
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    sub = dict(sub)
    tbl = sub.get("table")

    if cfg.family in ("ssm", "hybrid"):
        x, sub = _ssm_stack_cached(params, cfg, x, positions, sub,
                                   decode=False, chunked=True)
    else:
        def body(x, scanned):
            blk, is_local, ck, cv = scanned
            x, new_kv, _ = _attn_block(blk, x, cfg, positions, is_local,
                                       kv=(ck, cv), cache_pos=pos,
                                       n_prefix=n_prefix, block_table=tbl)
            return x, new_kv
        x, (ck, cv) = taps.scan(
            body, x, (params["layers"], _is_local_arr(cfg), sub["k"],
                      sub["v"]))
        sub["k"], sub["v"] = ck, cv
    sub["pos"] = pos + S
    logits = _logits(params, cfg, x)
    return logits, slot_update(cache, sub, slot)


# ---------------------------------------------------------------------------
# inference: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens: Array, cache: Dict,
            frontend_embs: Optional[Array] = None) -> Tuple[Array, Dict]:
    """Run the prompt, fill the cache, return last-position logits."""
    x, n_prefix = _embed(params, cfg, tokens, frontend_embs)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    cache = dict(cache)
    tbl = cache.get("table")

    if cfg.family in ("ssm", "hybrid"):
        x, cache = _ssm_stack_cached(params, cfg, x, positions, cache,
                                     decode=False)
    else:
        pos0 = jnp.zeros((B,), jnp.int32)
        def body(x, scanned):
            blk, is_local, ck, cv = scanned
            x, new_kv, _ = _attn_block(blk, x, cfg, positions, is_local,
                                       kv=(ck, cv), cache_pos=pos0,
                                       n_prefix=n_prefix, block_table=tbl)
            return x, new_kv
        x, (ck, cv) = taps.scan(
            body, x, (params["layers"], _is_local_arr(cfg), cache["k"], cache["v"]))
        cache["k"], cache["v"] = ck, cv
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, cache


def decode_step(params, cfg: ModelConfig, token: Array, cache: Dict,
                live: Optional[Array] = None) -> Tuple[Array, Dict]:
    """token (B, 1) -> logits (B, 1, V); cache advanced by one position.

    Each slot decodes at its own ``cache["pos"]`` entry.  ``live`` ((B,)
    bool) freezes finished slots: their position does not advance, so a
    dead slot idles at a fixed depth until the scheduler refills it
    (``prefill_into_slot``) -- its logits are computed but discarded.
    In PAGED caches ``live`` additionally masks the side effects a
    frozen slot must not have: its KV write is redirected to the trash
    block (its table may alias blocks a live request reads -- shared
    prefixes, or its own half-prefilled chunks) and its SSM/conv state
    is held (a filling slot's recurrence must survive interleaved pool
    steps until its next chunk).
    """
    x = jnp.take(params["embed"], token, axis=0)
    pos = cache["pos"]
    positions = pos[:, None].astype(jnp.int32)
    cache = dict(cache)
    tbl = cache.get("table")
    wmask = live if (live is not None and tbl is not None) else None

    if cfg.family in ("ssm", "hybrid"):
        old = {k: cache[k] for k in ("ssm", "conv_x", "conv_bc")
               if k in cache}
        x, cache = _ssm_stack_cached(params, cfg, x, positions, cache,
                                     decode=True, write_mask=wmask)
        if wmask is not None:
            m = wmask[None, :, None, None]
            for k, v in old.items():
                keep = m[..., None] if cache[k].ndim == 5 else m
                cache[k] = jnp.where(keep, cache[k], v)
    else:
        def body(x, scanned):
            blk, is_local, ck, cv = scanned
            x, new_kv, _ = _attn_block(blk, x, cfg, positions, is_local,
                                       kv=(ck, cv), cache_pos=pos,
                                       block_table=tbl, write_mask=wmask)
            return x, new_kv
        x, (ck, cv) = taps.scan(
            body, x, (params["layers"], _is_local_arr(cfg), cache["k"], cache["v"]))
        cache["k"], cache["v"] = ck, cv
    adv = jnp.int32(1) if live is None else live.astype(jnp.int32)
    cache["pos"] = pos + adv
    return _logits(params, cfg, x), cache


def verify_step(params, cfg: ModelConfig, tokens: Array, cache: Dict,
                live: Optional[Array] = None) -> Tuple[Array, Dict]:
    """tokens (B, S) -> logits (B, S, V): the speculative-verify forward.

    All S = k+1 positions of a draft block go through the model in ONE
    call -- every projection sees an (B*S, K) GEMM, which at k+1 <= 32
    stays on the prepacked skinny-M kernel path -- writing KV rows at
    per-slot positions ``cache["pos"] + [0..S)``.  ``cache["pos"]`` is
    NOT advanced here: the caller commits the accepted prefix by setting
    pos itself, which is also the whole rollback story -- rows written
    beyond the committed pos are invisible (the attention validity
    horizon masks ``k_pos >= pos + S_query``) and are simply overwritten
    by the next round's writes.

    Position i's logits are bit-identical to what ``decode_step`` would
    produce after committing tokens[:, :i+1]: the attention route is
    pinned to the plain kernel (decode's own S==1 route; flash's online
    softmax has a different reduction order), and everything else is
    row-local float math.  ``live`` matters only for paged caches: it
    redirects non-live rows' draft-block writes to the trash block so a
    pooled verify cannot scribble into blocks a mid-prefill or harvested
    slot's table still aliases.  Restricted to positional-cache families:
    SSM/conv recurrent state advances destructively and cannot be rolled
    back by masking.
    """
    if cfg.family in ("ssm", "hybrid"):
        raise NotImplementedError(
            "speculative verify needs positional KV rollback; the "
            f"{cfg.family!r} family carries recurrent SSM/conv state that "
            "a draft block cannot roll back")
    if cfg.attn_impl != "plain":
        cfg = dataclasses.replace(cfg, attn_impl="plain")
    x = jnp.take(params["embed"], tokens, axis=0)
    S = tokens.shape[1]
    pos = cache["pos"]
    positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    cache = dict(cache)
    tbl = cache.get("table")
    wmask = live if (live is not None and tbl is not None) else None

    def body(x, scanned):
        blk, is_local, ck, cv = scanned
        x, new_kv, _ = _attn_block(blk, x, cfg, positions, is_local,
                                   kv=(ck, cv), cache_pos=pos,
                                   block_table=tbl, write_mask=wmask)
        return x, new_kv
    x, (ck, cv) = taps.scan(
        body, x, (params["layers"], _is_local_arr(cfg), cache["k"],
                  cache["v"]))
    cache["k"], cache["v"] = ck, cv
    return _logits(params, cfg, x), cache


def _ssm_stack_cached(params, cfg: ModelConfig, x, positions, cache,
                      decode: bool, chunked: bool = False,
                      write_mask=None):
    """``chunked=True`` is the mid-prompt prefill mode: conv + SSM state
    carry across chunk calls (a fresh prefill passes zero conv state via
    None -- bit-identical to explicit zeros) and the hybrid shared-attn
    block writes at the slot's current ``pos`` instead of 0.
    ``write_mask`` guards the paged shared-attn KV write for non-live
    rows (decode_step holds their SSM/conv state itself)."""
    pos = cache["pos"]
    tbl = cache.get("table")

    def body(x, scanned):
        blk, ssm_st, cx, cbc = scanned
        h, (new_ssm, new_conv) = L.mamba2_apply(
            blk["mamba"], L.rms_norm(x, blk["ln1"], cfg.norm_eps), cfg,
            ssm_state=ssm_st,
            conv_state=(cx, cbc) if (decode or chunked) else None,
            decode=decode)
        return x + h, (new_ssm, new_conv[0], new_conv[1])

    if cfg.family == "ssm" or not cfg.shared_attn_period:
        x, (ssm, cx, cbc) = taps.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv_x"],
                      cache["conv_bc"]))
        cache["ssm"], cache["conv_x"], cache["conv_bc"] = ssm, cx, cbc
        return x, cache

    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    new_ssm, new_cx, new_cbc, new_k, new_v = [], [], [], [], []
    done = 0

    def run_group(x, lo, hi):
        return taps.scan(
            body, x, (_slice_layers(params["layers"], lo, hi),
                      cache["ssm"][lo:hi], cache["conv_x"][lo:hi],
                      cache["conv_bc"][lo:hi]))

    for g in range(n_groups):
        x, (s_ssm, s_cx, s_cbc) = run_group(x, g * period, (g + 1) * period)
        new_ssm.append(s_ssm)
        new_cx.append(s_cx)
        new_cbc.append(s_cbc)
        x, kv, _ = _attn_block(
            params["shared"], x, cfg, positions, jnp.bool_(False),
            kv=(cache["shared_k"][g], cache["shared_v"][g]),
            cache_pos=pos if (decode or chunked) else jnp.zeros_like(pos),
            prefix="shared/", block_table=tbl, write_mask=write_mask)
        new_k.append(kv[0])
        new_v.append(kv[1])
        done = (g + 1) * period
    if done < cfg.n_layers:
        x, (s_ssm, s_cx, s_cbc) = run_group(x, done, cfg.n_layers)
        new_ssm.append(s_ssm)
        new_cx.append(s_cx)
        new_cbc.append(s_cbc)
    cache["ssm"] = jnp.concatenate(new_ssm, axis=0)
    cache["conv_x"] = jnp.concatenate(new_cx, axis=0)
    cache["conv_bc"] = jnp.concatenate(new_cbc, axis=0)
    cache["shared_k"] = jnp.stack(new_k, axis=0)
    cache["shared_v"] = jnp.stack(new_v, axis=0)
    return x, cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


CE_TARGET_ELEMS = 2e9  # global fp32 logits elements per CE chunk


def _ce_chunk(batch: int, seq: int, vocab: int) -> int:
    """Vocab/batch-adaptive CE chunk: bound the transient logits tensor to
    ~CE_TARGET_ELEMS global elements (8 GB fp32 -> ~32 MB/device on the
    production mesh)."""
    c = int(CE_TARGET_ELEMS / max(batch * vocab, 1))
    c = 1 << max(c.bit_length() - 1, 5)  # floor pow2, >= 32
    return max(32, min(1024, c, seq))


def lm_loss(params, cfg: ModelConfig, tokens: Array,
            frontend_embs: Optional[Array] = None,
            remat: bool = True) -> Array:
    """Next-token CE over the text positions (frontend prefix excluded).

    The (B, S, V) logits tensor is never materialised: CE is a remat'd
    scan over sequence chunks, so peak temp is (B, CE_CHUNK, V) -- at
    gemma2's 256k vocab this is the difference between 40 GB and 1.3 GB of
    per-device loss workspace.
    """
    x, aux = hidden_states(params, cfg, tokens, frontend_embs, remat)
    n_front = x.shape[1] - tokens.shape[1]
    x = x[:, n_front:, :]
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)

    xs, tgt = x[:, :-1, :], tokens[:, 1:]
    B, S1, D = xs.shape
    c = _ce_chunk(B, S1, cfg.vocab_size)
    nc = (S1 + c - 1) // c
    pad = nc * c - S1
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        tgt = jnp.pad(tgt, ((0, 0), (0, pad)), constant_values=-1)
    xs = xs.reshape(B, nc, c, D).swapaxes(0, 1)       # (nc, B, c, D)
    tgt = tgt.reshape(B, nc, c).swapaxes(0, 1)

    def chunk(carry, inp):
        xc, tc = inp
        logits = L.softcap((xc @ head).astype(jnp.float32), cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        sel = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        return carry + jnp.sum((lse - sel) * valid), None

    total, _ = jax.lax.scan(jax.checkpoint(chunk), jnp.float32(0.0), (xs, tgt))
    return total / (B * S1) + aux
