"""Architecture configuration: one dataclass drives every model family."""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

from ..core.ccim import CCIMConfig

if TYPE_CHECKING:  # annotation only -- models must not import repro.plan
    from ..plan.plan import DeploymentPlan


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads

    # attention features
    rope_theta: float = 10000.0
    qk_norm: bool = False              # qwen3
    attn_softcap: Optional[float] = None    # gemma2: 50.0
    logit_softcap: Optional[float] = None   # gemma2: 30.0
    sliding_window: Optional[int] = None    # local-attention window
    layer_pattern: str = "global"      # "global" | "local_global" (alternating)
    attn_impl: str = "flash"           # "flash" (scan, O(S) memory) | "plain"
    flash_block: int = 512             # kv block for the flash scan
    prefix_lm: bool = False            # paligemma: bidirectional prefix

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): one SHARED attention block applied every N ssm layers
    shared_attn_period: int = 0

    # frontends (stubs per the brief: precomputed patch/frame embeddings)
    frontend: Optional[str] = None     # "siglip_stub" | "encodec_stub"
    n_frontend_tokens: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"
    dtype: str = "bfloat16"

    # CIM execution mode (the paper's technique as a first-class feature).
    # cim_cfg/cim_use_pallas are threaded into the execution engine
    # (core.engine.CimEngine) -- no module-global macro config anywhere.
    cim_mode: bool = False             # run linear layers through the macro
    cim_fidelity: str = "fast"
    cim_cfg: Optional[CCIMConfig] = None   # None -> the 28nm prototype macro
    cim_use_pallas: Optional[bool] = None  # None -> auto (TPU backend only)
    # Mixed-fidelity deployment plan (repro.plan): per-projection CCIMConfig
    # + fidelity overriding the single global cim_cfg/cim_fidelity above.
    # Static and hashable, resolved at trace time by layers._dense, so a
    # planned model still compiles to one executable per step -- zero
    # recompiles across decode steps.
    cim_plan: Optional["DeploymentPlan"] = None
    # Horizontal projection fusion (decode hot path): projections that
    # consume the same input activation AND resolve to the same plan entry
    # (QKV, gate/up, the mamba2 input projections) execute as ONE wide
    # macro GEMM -- bit-identical per projection (see DESIGN.md section 9).
    # Static, so fused and unfused models are separate jit cache entries.
    cim_fuse: bool = True
    # Deterministic analog-noise emulation for CIM serving: when set, every
    # _dense projection derives its own noise stream by folding this seed
    # with the projection path (shared across scanned depth -- the same
    # physical-bank reuse the weight-stationary macro has).  None keeps
    # serving noise-free.  The profiler sets it so analog candidates are
    # charged for their mismatch/comparator noise, not just rounding.
    cim_noise_seed: Optional[int] = None

    # schedule hint (minicpm: WSD)
    lr_schedule: str = "cosine"        # "cosine" | "wsd"

    # TP head padding: q (and MHA kv) head counts are padded up to a
    # multiple of this so the head dim divides the 16-way model axis --
    # zero-masked pad heads keep the math exactly equivalent (Megatron
    # pads vocab the same way).  reduced() sets 1 (no pad on CPU smoke).
    tp_head_pad: int = 16

    # ----- derived -----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_heads(self) -> int:
        p = max(self.tp_head_pad, 1)
        return (self.n_heads + p - 1) // p * p if self.n_heads else 0

    @property
    def padded_kv_heads(self) -> int:
        if self.n_kv_heads and self.n_kv_heads == self.n_heads:
            return self.padded_heads           # MHA: pad kv with q
        return self.n_kv_heads                 # GQA: kv heads stay

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(self.n_kv_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_is_local(self, i: int) -> bool:
        return self.layer_pattern == "local_global" and i % 2 == 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test configuration of the same family (tiny dims)."""
        small = dict(
            n_layers=min(self.n_layers, 2 if self.shared_attn_period == 0 else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_head=32,
            d_ff=256 if self.d_ff else 0,  # preserve tree structure (moe)
            vocab_size=512,
            sliding_window=64 if self.sliding_window else None,
            n_experts=min(self.n_experts, 8),
            moe_d_ff=64 if self.n_experts else 0,
            shared_expert_d_ff=64 if self.n_shared_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=32,
            shared_attn_period=2 if self.shared_attn_period else 0,
            n_frontend_tokens=8 if self.frontend else 0,
            flash_block=64,
            tp_head_pad=1,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
