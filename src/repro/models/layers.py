"""Model-zoo building blocks: norms, GQA attention (RoPE / qk-norm /
softcap / sliding-window / prefix-LM), SwiGLU MLP, MoE (shared + routed
top-k, capacity-based dispatch), Mamba2 / SSD.

Functional style: ``*_init(key, cfg) -> (params, axes)`` where ``axes`` is a
same-structure tree of logical-dimension-name tuples consumed by
distributed/sharding.py, and ``*_apply(params, x, ...)`` is pure.

Every matmul routes through ``_dense`` -> ``core.engine.CimEngine`` so any
architecture can run its projections on the emulated C-CIM macro
(cfg.cim_mode) -- the paper's technique as a first-class execution mode.
Projection weights may be prepacked ``PackedCimWeights`` (see
``lm.pack_cim_params``): quantize/decompose once, serve many.
"""
from __future__ import annotations

import math
import zlib
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.ccim import DEFAULT_CONFIG
from ..core.engine import (CimEngine, FusedPackedCimWeights,
                           PackedCimWeights)
from ..kernels.paged_attn import ops as paged_attn_ops
from .config import ModelConfig

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def cim_engine(cfg: ModelConfig, path: Optional[str] = None) -> CimEngine:
    """The execution engine a model config resolves to: macro config,
    fidelity and Pallas routing all come from the config (no module
    globals), so two models in one process can run different macros.

    With a deployment plan (cfg.cim_plan, see repro.plan) the projection
    ``path`` (e.g. "attn/wq") resolves to ITS OWN entry -- per-projection
    macro config and fidelity -- at trace time; plans are static metadata,
    so mixed-fidelity models still compile to one executable per step.
    """
    if cfg.cim_plan is not None and path is not None:
        e = cfg.cim_plan.resolve(path)
        return CimEngine(cfg=e.cfg, fidelity=e.fidelity,
                         use_pallas=cfg.cim_use_pallas)
    return CimEngine(cfg=cfg.cim_cfg or DEFAULT_CONFIG,
                     fidelity=cfg.cim_fidelity,
                     use_pallas=cfg.cim_use_pallas)


def _dense_noise_key(cfg: ModelConfig, path: Optional[str]) -> Optional[Array]:
    """Per-projection deterministic analog-noise stream (cfg.cim_noise_seed).

    The seed is folded with a hash of the projection path, so every
    projection draws independent mismatch/comparator noise while staying
    reproducible.  Scanned layer stacks share one path -- and therefore
    one draw pattern across depth -- mirroring how the weight-stationary
    macro reuses the same physical banks for every layer of a stack.
    """
    if cfg.cim_noise_seed is None:
        return None
    tag = zlib.crc32((path or "").encode("utf-8"))
    return jax.random.fold_in(jax.random.PRNGKey(cfg.cim_noise_seed), tag)


def _dense(x: Array, w, cfg: ModelConfig, path: Optional[str] = None) -> Array:
    """x (..., K) @ w (K, N) -- through the macro when cim_mode is on.

    ``w`` may be a ``PackedCimWeights`` (prepacked array contents from
    ``lm.pack_cim_params``): then the macro runs unconditionally with
    activation-only quantization on the hot path.  ``path`` identifies the
    projection for the deployment plan (per-projection config/fidelity)
    and the deterministic noise stream; plan fidelity "float" bypasses the
    macro entirely.
    """
    if isinstance(w, PackedCimWeights):
        if not cfg.cim_mode:
            raise ValueError(
                "packed CIM weights require cim_mode=True (packed params "
                "are macro array contents, not float matrices)")
        eng = cim_engine(cfg, path)
        if eng.fidelity == "float":
            raise ValueError(
                f"plan assigns fidelity 'float' to {path!r} but its weights "
                "are packed macro array contents; re-pack under the plan "
                "(lm.pack_cim_params leaves float-fidelity sites unpacked)")
        return eng.matmul(x, w, _dense_noise_key(cfg, path))
    if cfg.cim_mode:
        eng = cim_engine(cfg, path)
        if eng.fidelity == "float":
            return x @ w
        return eng.matmul(x, w, _dense_noise_key(cfg, path))
    return x @ w


FUSED_SEP = "+"   # fused param-leaf key: member names joined, e.g. "wq+wk+wv"


def fusion_partitions(cfg: ModelConfig, prefix: str, names) -> list:
    """Partition fusion-candidate projections (which share one input
    activation) by resolved plan entry: [(entry_cfg, fidelity, members)]
    for every partition of two or more fusable members.

    The ONE definition of group compatibility -- pack time
    (lm._pack_tree) and trace time (_dense_group) must agree, or fused
    packs would go unconsumed.  Only 'fast'/'exact' fuse: 'float'
    bypasses the macro, and the broadcast/bit_true fidelities draw noise
    with non-column-local shapes.
    """
    part: Dict[Tuple, list] = {}
    for n in names:
        eng = cim_engine(cfg, prefix + n)
        if eng.fidelity in ("fast", "exact"):
            part.setdefault((eng.cfg, eng.fidelity), []).append(n)
    return [(c, f, g) for (c, f), g in part.items() if len(g) >= 2]


def _split_segments(y: Array, names, dims) -> Dict[str, Array]:
    """Split a fused (..., sum(dims)) output back into per-projection
    results at the static per-segment N-offsets."""
    offs = np.cumsum((0,) + tuple(dims))
    return {n: jax.lax.slice_in_dim(y, int(offs[i]), int(offs[i + 1]),
                                    axis=-1)
            for i, n in enumerate(names)}


def _seg_noise(cfg: ModelConfig, prefix: str, names) -> Optional[Tuple]:
    """Per-segment noise keys for a fused group -- each segment draws the
    SAME stream its unfused projection would (path-folded seed), which is
    what keeps fusion bit-identical under analog-noise emulation."""
    if cfg.cim_noise_seed is None:
        return None
    return tuple(_dense_noise_key(cfg, prefix + n) for n in names)


def _dense_fused(x: Array, leaf: FusedPackedCimWeights, cfg: ModelConfig,
                 prefix: str, names) -> Dict[str, Array]:
    """Serve one pack-time-fused projection group (lm.pack_cim_params):
    one activation quantization + one wide macro GEMM, split per segment."""
    if not cfg.cim_mode:
        raise ValueError(
            "fused packed CIM weights require cim_mode=True (packed params "
            "are macro array contents, not float matrices)")
    eng = cim_engine(cfg, prefix + names[0])
    if eng.fidelity == "float":
        raise ValueError(
            f"plan assigns fidelity 'float' to {prefix}{names[0]!r} but the "
            "group was packed as macro array contents; re-pack under the "
            "serving plan (pack_cim_params fuses by the plan's entries)")
    for s in names[1:]:
        e2 = cim_engine(cfg, prefix + s)
        if (e2.cfg, e2.fidelity) != (eng.cfg, eng.fidelity):
            raise ValueError(
                f"fused group {prefix}{'+'.join(names)} was packed under one "
                f"plan entry, but the serving plan resolves {prefix}{s!r} "
                f"differently ({e2.fidelity} vs {eng.fidelity}); re-pack "
                "under the serving plan (the unfused path would refuse the "
                "same mismatch)")
    y = eng.matmul(x, leaf, _seg_noise(cfg, prefix, names))
    return _split_segments(y, names, leaf.seg_dims)


def _dense_group(x: Array, p: Params, names, cfg: ModelConfig,
                 prefix: str) -> Dict[str, Array]:
    """Run a block's projections that all consume ``x``, fusing plan-
    compatible sites into one wide macro GEMM (DESIGN.md section 9).

    Three routes, every one bit-identical per projection to ``_dense``:
      * pack-time fused leaves (``FusedPackedCimWeights``, key
        "wq+wk+wv") -- the packed serving hot path.  These are ALWAYS
        served fused: the leaf structure is the execution plan, and
        ``cfg.cim_fuse`` governs grouping at pack/trace time, not how an
        already-fused pack executes (re-pack with cim_fuse=False for a
        per-projection pack);
      * trace-time fusion of raw float weights under cim_mode and
        cfg.cim_fuse: members resolving to the same plan entry
        concatenate along N for the call (unpacked serving / QAT get the
        same 7 -> ~3 GEMM collapse);
      * everything else (float fidelity, heterogeneous entries, cim off,
        cfg.cim_fuse=False) falls through to per-projection ``_dense``.
    """
    remaining = list(names)
    out: Dict[str, Array] = {}
    for key, leaf in p.items():
        if isinstance(leaf, FusedPackedCimWeights):
            segs = key.split(FUSED_SEP)
            if all(s in remaining for s in segs):
                out.update(_dense_fused(x, leaf, cfg, prefix, segs))
                remaining = [n for n in remaining if n not in segs]
    if cfg.cim_mode and cfg.cim_fuse and len(remaining) > 1:
        fusable = [n for n in remaining
                   if p.get(n) is not None
                   and not isinstance(p[n], PackedCimWeights)]
        for ecfg, fid, g in fusion_partitions(cfg, prefix, fusable):
            eng = CimEngine(cfg=ecfg, fidelity=fid,
                            use_pallas=cfg.cim_use_pallas)
            wcat = jnp.concatenate([p[n] for n in g], axis=-1)
            dims = tuple(int(p[n].shape[-1]) for n in g)
            nkeys = _seg_noise(cfg, prefix, g)
            y = eng.matmul(x, wcat, nkeys,
                           noise_segments=dims if nkeys else None)
            out.update(_split_segments(y, g, dims))
            remaining = [n for n in remaining if n not in g]
    for n in remaining:
        out[n] = _dense(x, p[n], cfg, prefix + n)
    return out


def _init_dense(key, d_in, d_out, axes, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    return w.astype(dtype), axes


def rms_norm(x: Array, w: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x (B, S, H, D), positions (B, S) -> rotated x.

    Rotation of each (even, odd) pair by angle pos/theta^(2i/D): this IS a
    complex multiply x * e^{i phi} -- the workload class the paper's complex
    MAC targets (see DESIGN.md §4).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) -- flash-style scan + plain + decode paths
# ---------------------------------------------------------------------------


def _head_mask(cfg: ModelConfig) -> Optional[Array]:
    """(padded_heads,) 1/0 mask; None when no padding is in effect."""
    if cfg.padded_heads == cfg.n_heads:
        return None
    return (jnp.arange(cfg.padded_heads) < cfg.n_heads).astype(jnp.float32)


def attention_init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    """Projections sized with TP-padded head counts; pad head slots are
    zero-initialised and masked after attention, so they stay exactly zero
    through training (zero grads) -- the math never sees them."""
    dh, d = cfg.head_dim, cfg.d_model
    hq, hkv = cfg.padded_heads, cfg.padded_kv_heads
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = _init_dense(ks[0], d, hq * dh, ("embed", "heads"), dtype=dtype)
    p["wk"], a["wk"] = _init_dense(ks[1], d, hkv * dh, ("embed", "kv_heads"), dtype=dtype)
    p["wv"], a["wv"] = _init_dense(ks[2], d, hkv * dh, ("embed", "kv_heads"), dtype=dtype)
    p["wo"], a["wo"] = _init_dense(ks[3], hq * dh, d, ("heads", "embed"), dtype=dtype)
    mask = _head_mask(cfg)
    if mask is not None:
        mq = jnp.repeat(mask, dh)[None, :].astype(dtype)
        p["wq"] = p["wq"] * mq
        p["wo"] = p["wo"] * mq.T
        if hkv == hq:  # MHA: kv heads padded alongside q heads
            p["wk"] = p["wk"] * mq
            p["wv"] = p["wv"] * mq
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = jnp.zeros((dh,), dtype), ("head_dim",)
        p["k_norm"], a["k_norm"] = jnp.zeros((dh,), dtype), ("head_dim",)
    return p, a


def _ambient_mesh():
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - jax internals moved
        return None
    return None if mesh.empty else mesh


def _shard_batch_dim(x, expert_dim: Optional[int] = None):
    """Pin dim 0 of ``x`` to the data-parallel axes (dispatch buffers:
    GSPMD otherwise merges per-shard scatters with a full-size all-reduce
    -- measured 43 GB/layer on qwen2-moe).  When ``expert_dim`` is given
    and divisible by the model axis, it is sharded too (EP layout for the
    expert GEMMs -- arctic's 128 experts)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    if not dp or x.shape[0] % math.prod(sizes[a] for a in dp) != 0:
        return x
    if (expert_dim is not None and "model" in sizes
            and x.shape[expert_dim] % sizes["model"] == 0):
        # EP-divisible experts (arctic): GSPMD's own (B/data, E/model)
        # placement beats any pin we tried -- forcing either E-replicated
        # (303 s) or E-sharded-with-ZeRO-weights (444 s) regressed vs 61 s
        # unpinned (EXPERIMENTS.md iteration 13). Leave it alone.
        return x
    spec = jax.sharding.PartitionSpec(dp, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, spec)


def _head_constraints(q, k, v):
    """Pin attention shardings: q sharded on (padded) heads over 'model',
    k/v REPLICATED over 'model'.

    Without this, GSPMD reshards the (B,S,Hkv*dh) kv projection by
    splitting head_dim, which turns every flash QK/AV dot into a partial
    sum: measured 429 GB/step/device of score all-reduces on qwen3-14b.
    Replicating kv costs one (B,S,Hkv,dh) all-gather per layer instead
    (~80x less traffic at GQA ratios)."""
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    con = jax.lax.with_sharding_constraint
    q = con(q, jax.sharding.PartitionSpec(U, None, "model", None))
    k = con(k, jax.sharding.PartitionSpec(U, None, None, None))
    v = con(v, jax.sharding.PartitionSpec(U, None, None, None))
    return q, k, v


def _qkv(p, x, cfg: ModelConfig, positions, path="attn"):
    B, S, _ = x.shape
    dh = cfg.head_dim
    hq, hkv = cfg.padded_heads, cfg.padded_kv_heads
    qkv = _dense_group(x, p, ("wq", "wk", "wv"), cfg, f"{path}/")
    q = qkv["wq"].reshape(B, S, hq, dh)
    k = qkv["wk"].reshape(B, S, hkv, dh)
    v = qkv["wv"].reshape(B, S, hkv, dh)
    q, k, v = _head_constraints(q, k, v)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, is_local, window, n_prefix):
    """(..., Sq, Sk) boolean mask. is_local may be a traced scalar."""
    causal = q_pos[:, :, None] >= k_pos[:, None, :]
    if n_prefix:
        causal = causal | (k_pos[:, None, :] < n_prefix)
    if window is not None:
        local = causal & (q_pos[:, :, None] - k_pos[:, None, :] < window)
        causal = jnp.where(is_local, local, causal)
    return causal


def _flash_blocks(k, v, k_pos, blk):
    B, Sk, Hkv, Dh = k.shape
    n_blk = (Sk + blk - 1) // blk
    pad = n_blk * blk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=10 ** 9)
    kb = k.reshape(B, n_blk, blk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, blk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, n_blk, blk).transpose(1, 0, 2)
    return kb, vb, pb, pad


def _flash_fwd(cfg, n_prefix, q, k, v, q_pos, k_pos, is_local):
    """Forward scan over KV blocks; returns (out, m, l) softmax stats."""
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    blk = min(cfg.flash_block, k.shape[1])
    kb, vb, pb, _ = _flash_blocks(k, v, k_pos, blk)
    qg = q.reshape(B, Sq, Hkv, G, Dh) * (Dh ** -0.5)

    def step(carry, blk_in):
        m, l, acc = carry
        kc, vc, pc = blk_in
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                       preferred_element_type=jnp.float32)
        s = softcap(s, cfg.attn_softcap)
        msk = _mask(q_pos, pc, is_local, cfg.sliding_window, n_prefix)
        s = jnp.where(msk[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hkv,G,Sq,Dh) f32
    return out, m, l


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def flash_attention(cfg: ModelConfig, n_prefix, q, k, v, q_pos, k_pos,
                    is_local) -> Array:
    """FlashAttention with a block-recomputing backward (O(S) memory in
    fwd AND bwd -- plain scan AD would stack the full attention matrix:
    measured 384 GiB/device on arctic train_4k before this custom VJP)."""
    B, Sq, Hq, Dh = q.shape
    out, _, _ = _flash_fwd(cfg, n_prefix, q, k, v, q_pos, k_pos, is_local)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _flash_vjp_fwd(cfg, n_prefix, q, k, v, q_pos, k_pos, is_local):
    B, Sq, Hq, Dh = q.shape
    out, m, l = _flash_fwd(cfg, n_prefix, q, k, v, q_pos, k_pos, is_local)
    y = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dh).astype(q.dtype)
    return y, (q, k, v, q_pos, k_pos, is_local, out, m, l)


def _flash_vjp_bwd(cfg, n_prefix, res, dy):
    q, k, v, q_pos, k_pos, is_local, out, m, l = res
    B, Sq, Hq, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    blk = min(cfg.flash_block, Sk)
    kb, vb, pb, pad = _flash_blocks(k, v, k_pos, blk)
    scale = Dh ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(jnp.float32) * scale
    dyg = (dy.reshape(B, Sq, Hkv, G, Dh)
           .transpose(0, 2, 3, 1, 4).astype(jnp.float32))  # (B,Hkv,G,Sq,Dh)
    l_safe = jnp.maximum(l, 1e-30)
    # D_i = sum_d dy_i * out_i  (out already normalised)
    Drow = jnp.sum(dyg * out, axis=-1)                      # (B,Hkv,G,Sq)

    def step(dq, blk_in):
        kc, vc, pc = blk_in
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc,
                       preferred_element_type=jnp.float32)
        z = softcap(s, cfg.attn_softcap)
        msk = _mask(q_pos, pc, is_local, cfg.sliding_window, n_prefix)
        z = jnp.where(msk[:, None, None, :, :], z, -1e30)
        p = jnp.exp(z - m[..., None]) / l_safe[..., None]   # (B,Hkv,G,Sq,blk)
        dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, dyg)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dyg, vc.astype(jnp.float32))
        dz = p * (dp - Drow[..., None])
        if cfg.attn_softcap is not None:
            # mask BEFORE the tanh'-factor: masked z = -1e30 would give
            # 0 * inf = NaN otherwise
            factor = 1.0 - (z / cfg.attn_softcap) ** 2
            factor = jnp.where(msk[:, None, None, :, :], factor, 0.0)
            dz = dz * factor
        dq_new = dq + jnp.einsum("bhgqk,bkhd->bqhgd", dz, kc.astype(jnp.float32))
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", dz, qg)
        return dq_new, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hkv, G, Dh), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, (kb, vb, pb))
    dq = (dq * scale).reshape(B, Sq, Hq, Dh).astype(q.dtype)
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, -1, Hkv, Dh)
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, -1, Hkv, Dh)
    if pad:
        dk, dv = dk[:, :Sk], dv[:, :Sk]
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype), None, None, None)


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def plain_attention(q, k, v, q_pos, k_pos, cfg: ModelConfig, is_local,
                    n_prefix=0) -> Array:
    B, Sq, Hq, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh) * (Dh ** -0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    s = softcap(s, cfg.attn_softcap)
    msk = _mask(q_pos, k_pos, is_local, cfg.sliding_window, n_prefix)
    s = jnp.where(msk[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, Hq, Dh)


def attention_apply(
    p: Params,
    x: Array,                       # (B, S, D)
    cfg: ModelConfig,
    positions: Array,               # (B, S)
    is_local,                       # scalar bool (traced ok)
    kv_cache: Optional[Tuple[Array, Array]] = None,  # (B,Smax,Hkv,Dh) x2
    cache_pos: Optional[Array] = None,               # (B,): per-slot write idx
    n_prefix: int = 0,
    return_kv: bool = False,
    path: str = "attn",
    block_table: Optional[Array] = None,             # (B, n_tbl) int32 paged
    write_mask: Optional[Array] = None,              # (B,) bool: rows that write
):
    """Returns (out (B,S,D), new_kv or None).

    ``cache_pos`` is a per-slot ``(B,)`` vector: each batch row writes its
    S new KV entries at its own position (continuous batching -- slots sit
    at different depths), and each row's validity horizon is its own
    ``cache_pos + S``.  ``path`` is the deployment-plan projection prefix
    (the zamba2 shared block passes "shared/attn").

    With ``block_table`` the cache is PAGED: ``kv_cache`` holds global
    ``(n_blocks, block_size, Hkv, Dh)`` pools shared by every row, and
    row b's logical position p lives at pool[table[b, p//bs], p%bs].
    Writes become a flat-index scatter through the table, reads gather
    the table back into a dense per-row view and run the SAME masked
    attention as the contiguous path (bit-identical tokens -- the
    validity horizon does not care where rows physically live), except
    S==1 decode reads, which route to the fused gather+attention kernel
    in kernels/paged_attn when that backend path is enabled.  Rows whose
    table entries are 0 hit the reserved trash block: harvested slots
    park there so their frozen-position writes cannot corrupt blocks
    that were recycled to live slots.

    ``write_mask`` (paged only) redirects masked-OUT rows' KV writes to
    the trash block.  The contiguous cache never needs it (a dead slot's
    frozen-position writes stay inside its own region), but paged pools
    are SHARED: a pooled decode/verify step would otherwise scribble a
    non-live slot's garbage row into a block another request is still
    reading (mid-chunked-prefill slots sit inside refcounted shared
    blocks).  Live rows are untouched, so masking is bit-invisible.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, path)
    new_kv = None
    if kv_cache is not None and block_table is not None:
        ck, cv = kv_cache
        nb, bs, hkv, dh = ck.shape
        n_tbl = block_table.shape[1]
        pos_w = cache_pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        blk = jnp.take_along_axis(block_table,
                                  jnp.minimum(pos_w // bs, n_tbl - 1), axis=1)
        if write_mask is not None:
            blk = jnp.where(write_mask[:, None], blk, 0)  # -> trash block
        flat = (blk * bs + pos_w % bs).reshape(-1)
        ckf = ck.reshape(nb * bs, hkv, dh).at[flat].set(
            k.astype(ck.dtype).reshape(B * S, hkv, dh))
        cvf = cv.reshape(nb * bs, hkv, dh).at[flat].set(
            v.astype(cv.dtype).reshape(B * S, hkv, dh))
        new_kv = (ckf.reshape(ck.shape), cvf.reshape(cv.shape))
        if S == 1 and n_prefix == 0 and paged_attn_ops.kernel_enabled():
            out = paged_attn_ops.paged_attention_decode(
                q[:, 0], new_kv[0], new_kv[1], block_table, cache_pos + 1,
                is_local, softcap=cfg.attn_softcap,
                window=cfg.sliding_window)[:, None]
            return _attn_out(p, out, cfg, B, S, path), new_kv
        L = n_tbl * bs
        idx = (block_table[:, :, None] * bs
               + jnp.arange(bs, dtype=jnp.int32)[None, None, :]).reshape(B, L)
        k_full = jnp.take(ckf, idx, axis=0)
        v_full = jnp.take(cvf, idx, axis=0)
        k_pos = jnp.broadcast_to(jnp.arange(L)[None, :], (B, L))
        valid = k_pos < (cache_pos[:, None] + S)
        k_pos = jnp.where(valid, k_pos, 10 ** 9)
    elif kv_cache is not None:
        ck, cv = kv_cache

        def row_write(c, u, s):
            return jax.lax.dynamic_update_slice(c, u, (s, 0, 0))

        ck = jax.vmap(row_write)(ck, k.astype(ck.dtype), cache_pos)
        cv = jax.vmap(row_write)(cv, v.astype(cv.dtype), cache_pos)
        new_kv = (ck, cv)
        k_pos = jnp.broadcast_to(jnp.arange(ck.shape[1])[None, :], (B, ck.shape[1]))
        valid = k_pos < (cache_pos[:, None] + S)
        k_pos = jnp.where(valid, k_pos, 10 ** 9)  # mask out unwritten slots
        k_full, v_full = ck, cv
    else:
        k_pos = positions
        k_full, v_full = k, v
        if return_kv:
            new_kv = (k, v)

    if cfg.attn_impl == "flash" and S > 1:
        out = flash_attention(cfg, n_prefix, q, k_full, v_full, positions,
                              k_pos, is_local)
    else:
        out = plain_attention(q, k_full, v_full, positions, k_pos, cfg,
                              is_local, n_prefix)
    return _attn_out(p, out, cfg, B, S, path), new_kv


def _attn_out(p: Params, out: Array, cfg: ModelConfig, B: int, S: int,
              path: str) -> Array:
    """Shared attention epilogue: TP-pad head masking + wo projection."""
    mask = _head_mask(cfg)
    if mask is not None:
        # zero the TP-pad heads: keeps wo/wq pad slots at exactly zero
        # through training (their grads vanish here)
        out = out * mask[None, None, :, None].astype(out.dtype)
    return _dense(out.reshape(B, S, -1), p["wo"], cfg, f"{path}/wo")


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=jnp.bfloat16):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["w1"], a["w1"] = _init_dense(ks[0], cfg.d_model, d_ff, ("embed", "ff"), dtype=dtype)
    p["w3"], a["w3"] = _init_dense(ks[1], cfg.d_model, d_ff, ("embed", "ff"), dtype=dtype)
    p["w2"], a["w2"] = _init_dense(ks[2], d_ff, cfg.d_model, ("ff", "embed"), dtype=dtype)
    return p, a


def mlp_apply(p: Params, x: Array, cfg: ModelConfig, path: str = "mlp") -> Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    gu = _dense_group(x, p, ("w1", "w3"), cfg, f"{path}/")
    h = act(gu["w1"]) * gu["w3"]
    return _dense(h, p["w2"], cfg, f"{path}/w2")


# ---------------------------------------------------------------------------
# MoE: shared experts + routed top-k with capacity (scatter dispatch)
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(D)
    p, a = {}, {}
    p["router"], a["router"] = _init_dense(ks[0], D, E, ("embed", "experts_r"),
                                           dtype=jnp.float32)
    p["w1"] = (jax.random.normal(ks[1], (E, D, F), jnp.float32) * s).astype(dtype)
    p["w3"] = (jax.random.normal(ks[2], (E, D, F), jnp.float32) * s).astype(dtype)
    p["w2"] = (jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F)).astype(dtype)
    a["w1"] = ("experts", "embed", "moe_ff")
    a["w3"] = ("experts", "embed", "moe_ff")
    a["w2"] = ("experts", "moe_ff", "embed")
    if cfg.shared_expert_d_ff:
        p["shared"], a["shared"] = mlp_init(ks[4], cfg, cfg.shared_expert_d_ff, dtype)
    return p, a


def _moe_ffn(p: Params, buf: Array, cfg: ModelConfig) -> Array:
    """Per-expert SwiGLU over a dispatched buffer (..., E, C, D)."""
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("...ecd,edf->...ecf", buf, p["w1"])) * jnp.einsum(
        "...ecd,edf->...ecf", buf, p["w3"])
    return jnp.einsum("...ecf,efd->...ecd", h, p["w2"])


def _moe_small(p, xf, eidx, gate_vals, cfg):
    """Exact (no-drop) path for small token counts (decode)."""
    T, D = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    C = T * K
    ef = eidx.reshape(T * K)
    one_hot = jax.nn.one_hot(ef, E, dtype=jnp.int32)
    pos = jnp.cumsum(one_hot, axis=0) - one_hot
    myp = jnp.take_along_axis(pos, ef[:, None], axis=1)[:, 0]
    xk = jnp.repeat(xf, K, axis=0)
    buf = jnp.zeros((E, C, D), xf.dtype).at[ef, myp].add(xk)
    out_buf = _moe_ffn(p, buf, cfg)
    yk = out_buf[ef, myp] * gate_vals.reshape(T * K, 1).astype(xf.dtype)
    return jnp.sum(yk.reshape(T, K, D), axis=1)


def _moe_grouped(p, x, eidx, gate_vals, cfg):
    """GShard-style group-local dispatch (training/prefill scale).

    Each batch row is a dispatch group: expert positions are computed by a
    SORT within the group (counts + exclusive-cumsum over E), so every
    intermediate is O(S*K) per group -- no (T*K, E) cumsum, and the
    dispatch scatter is group-local, which GSPMD keeps on the data shard
    (measured: 191 GB/dev temp + 3.9 TB/dev collectives with a global
    scatter vs ~tens of GB after this rewrite).  Capacity is per group:
    C_g = ceil(S*K/E * capacity_factor); overflow tokens drop (standard).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    SK = S * K
    C = int(math.ceil(SK / E * cfg.capacity_factor))
    ef = eidx.reshape(B, SK)                                   # (B, SK)
    order = jnp.argsort(ef, axis=1, stable=True)               # (B, SK)
    e_sorted = jnp.take_along_axis(ef, order, axis=1)
    counts = jnp.sum(jax.nn.one_hot(ef, E, dtype=jnp.int32), axis=1)  # (B,E)
    starts = jnp.cumsum(counts, axis=1) - counts               # exclusive
    pos_sorted = (jnp.arange(SK)[None, :]
                  - jnp.take_along_axis(starts, e_sorted, axis=1))
    keep = (pos_sorted < C).astype(x.dtype)                    # (B, SK)
    pos_c = jnp.minimum(pos_sorted, C - 1)

    tok_sorted = order // K                                    # source token
    x_sorted = jnp.take_along_axis(
        x, tok_sorted[..., None], axis=1)                      # (B, SK, D)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, SK))
    buf = _shard_batch_dim(jnp.zeros((B, E, C, D), x.dtype), expert_dim=1)
    buf = buf.at[bidx, e_sorted, pos_c].add(x_sorted * keep[..., None])
    buf = _shard_batch_dim(buf, expert_dim=1)

    out_buf = _moe_ffn(p, buf, cfg)                            # (B, E, C, D)

    y_sorted = out_buf[bidx, e_sorted, pos_c] * keep[..., None]
    y_sorted = _shard_batch_dim(y_sorted)
    inv = jnp.argsort(order, axis=1)                           # unsort
    yk = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)  # (B, SK, D)
    yk = yk * gate_vals.reshape(B, SK, 1).astype(x.dtype)
    return jnp.sum(yk.reshape(B, S, K, D), axis=2).reshape(B * S, D)


def moe_apply(p: Params, x: Array, cfg: ModelConfig,
              path: str = "moe") -> Tuple[Array, Array]:
    """Returns (y, aux_loss). Experts shard over 'model' (EP); dispatch is
    group-local so only the expert GEMM's buffers cross shards.  ``path``
    prefixes the shared expert's deployment-plan projection paths."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(T, D)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if T * K <= 4096:
        y = _moe_small(p, xf, eidx, gate_vals, cfg)
    else:
        y = _moe_grouped(p, x, eidx.reshape(B, S, K),
                         gate_vals.reshape(B, S, K), cfg)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_loss

    if cfg.shared_expert_d_ff:
        y = y + mlp_apply(p["shared"], x, cfg,
                          path=f"{path}/shared").reshape(T, D)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) -- chunked parallel scan for train/prefill, step for decode
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    # component projections (not one fused in_proj): each output dim is
    # TP-divisible (d_inner, 2*state), where the fused 2*DI+2*N+H is not --
    # this is what lets the SSM stack shard over "model" at all
    p["w_z"], a["w_z"] = _init_dense(ks[0], D, DI, ("embed", "ssm_inner"),
                                     dtype=dtype)
    p["w_x"], a["w_x"] = _init_dense(ks[4], D, DI, ("embed", "ssm_inner"),
                                     dtype=dtype)
    p["w_bc"], a["w_bc"] = _init_dense(ks[5], D, 2 * N, ("embed", "state"),
                                       dtype=dtype)
    p["w_dt"], a["w_dt"] = _init_dense(ks[6], D, H, ("embed", "ssm_heads"),
                                       dtype=dtype)
    # separate depthwise convs per stream (x, B, C): no concat/split on a
    # sharded channel dim -> no resharding collective-permutes in the scan
    p["conv_x"] = (jax.random.normal(ks[1], (W, DI), jnp.float32) / W).astype(dtype)
    a["conv_x"] = ("conv", "ssm_inner")
    p["conv_b"] = (jax.random.normal(ks[7], (W, 2 * N), jnp.float32) / W).astype(dtype)
    a["conv_b"] = ("conv", "state")
    p["conv_bias_x"] = jnp.zeros((DI,), dtype)
    a["conv_bias_x"] = ("ssm_inner",)
    p["conv_bias_b"] = jnp.zeros((2 * N,), dtype)
    a["conv_bias_b"] = ("state",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32))
    a["A_log"] = ("ssm_heads",)
    p["D_skip"] = jnp.ones((H,), jnp.float32)
    a["D_skip"] = ("ssm_heads",)
    p["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                   math.log(1e-3), math.log(1e-1)))))
    a["dt_bias"] = ("ssm_heads",)
    p["gate_norm"] = jnp.zeros((DI,), dtype)
    a["gate_norm"] = ("ssm_inner",)
    p["out_proj"], a["out_proj"] = _init_dense(ks[3], DI, D,
                                               ("ssm_inner", "embed"), dtype=dtype)
    return p, a


def _segsum(a):
    """(..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv. u (B,S,C), w (W,C). state (B,W-1,C) for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
        u_p = jnp.concatenate([pad, u], axis=1)
    else:
        u_p = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(u_p[:, i : i + u.shape[1], :] * w[i] for i in range(W)) + b
    new_state = u_p[:, -(W - 1):, :] if W > 1 else None
    return jax.nn.silu(out), new_state


def mamba2_apply(p: Params, x: Array, cfg: ModelConfig,
                 ssm_state=None, conv_state=None, decode: bool = False):
    """x (B,S,D). Returns (y, (new_ssm_state, new_conv_state))."""
    B, S, D = x.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = _dense_group(x, p, ("w_z", "w_x", "w_bc", "w_dt"), cfg, "mamba/")
    z, xc, BCc, dt_raw = (proj[n] for n in ("w_z", "w_x", "w_bc", "w_dt"))
    cs_x = cs_bc = None
    if conv_state is not None:
        cs_x, cs_bc = conv_state
    xc, new_cx = _causal_conv(xc, p["conv_x"], p["conv_bias_x"], cs_x)
    BCc, new_cbc = _causal_conv(BCc, p["conv_b"], p["conv_bias_b"], cs_bc)
    new_conv = (new_cx, new_cbc)
    Bc, Cc = jnp.split(BCc, [N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)

    # pad S to a chunk multiple; padded steps get dt=0 => identity decay,
    # zero state update, so the recurrence is unaffected
    S_orig = S
    if not decode:
        Q0 = min(cfg.ssm_chunk, S)
        pad = (Q0 - S % Q0) % Q0
        if pad:
            z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
            xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
            Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
            Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            S = S + pad

    xh = xc.reshape(B, S, H, P).astype(jnp.float32)
    Bh = Bc.astype(jnp.float32)                                       # (B,S,N)
    Ch = Cc.astype(jnp.float32)

    if decode:
        # single-step recurrence: state (B,H,P,N)
        a = jnp.exp(dt[:, 0] * A[None, :])                            # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bh[:, 0], xh[:, 0])
        new_state = ssm_state * a[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Ch[:, 0], new_state)
        y = y + p["D_skip"][None, :, None] * xh[:, 0]
        y = y.reshape(B, 1, DI)
    else:
        Q = min(cfg.ssm_chunk, S)
        assert S % Q == 0, (S, Q)
        nc = S // Q
        xb = xh.reshape(B, nc, Q, H, P)
        Bb = Bh.reshape(B, nc, Q, N)
        Cb = Ch.reshape(B, nc, Q, N)
        dtb = dt.reshape(B, nc, Q, H)
        a = dtb * A  # (B,nc,Q,H) log-decay
        a_t = a.transpose(0, 1, 3, 2)                                 # (B,nc,H,Q)
        Lmat = jnp.exp(_segsum(a_t))                                  # (B,nc,H,Q,Q)
        # intra-chunk (diagonal) term
        scores = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)                # (B,nc,Q,Q)
        y_diag = jnp.einsum("bcqk,bchqk,bckh,bckhp->bcqhp",
                            scores, Lmat, dtb, xb)
        # decay from step q to end of chunk: sum_{i>q} a_i
        a_cum = jnp.cumsum(a_t, axis=-1)                              # (B,nc,H,Q)
        decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)               # (B,nc,H,Q)
        states = jnp.einsum("bchq,bcqh,bcqn,bcqhp->bchpn",
                            decay_to_end, dtb, Bb, xb)                # (B,nc,H,P,N)
        chunk_decay = jnp.exp(a_cum[..., -1])                         # (B,nc,H)

        def scan_fn(h, inp):
            st, dec = inp
            h_new = h * dec[..., None, None] + st
            return h_new, h
        init = (ssm_state if ssm_state is not None
                else jnp.zeros((B, H, P, N), jnp.float32))
        new_state, h_prev = jax.lax.scan(
            scan_fn,
            init,
            (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        )
        h_prev = h_prev.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,P,N)
        decay_from_start = jnp.exp(a_cum)                             # (B,nc,H,Q)
        y_off = jnp.einsum("bcqn,bchq,bchpn->bcqhp",
                           Cb, decay_from_start, h_prev)
        y = (y_diag + y_off).reshape(B, S, H, P)
        y = y + p["D_skip"][None, None, :, None] * xh
        y = y.reshape(B, S, DI)

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["gate_norm"], cfg.norm_eps)
    out = _dense(y, p["out_proj"], cfg, "mamba/out_proj")
    if not decode and S != S_orig:
        out = out[:, :S_orig]
    return out, (new_state, new_conv)
