from .pipeline import DataConfig, Prefetcher, batch_at  # noqa: F401
