"""Deterministic synthetic token pipeline with skip-ahead resume.

Design goals for 1000+-node training:
  * step-indexed batches: batch(step) is a pure function of (seed, step,
    shard) -- restart/elastic-reshard needs no data-loader state, a
    straggler can never desynchronise the fleet, and any host can
    recompute any shard (failure recovery without a data service).
  * host-sharded: each host materialises only its rows.
  * background prefetch with a bounded queue (hides host latency).

The generator is Philox-free: a splitmix-style integer hash of
(seed, step, row, col) -- identical on every platform, no RNG state.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_frontend_tokens: int = 0   # VLM/audio stubs: emit frontend embeddings
    d_model: int = 0             # needed when n_frontend_tokens > 0


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    return z ^ (z >> np.uint64(31))


def batch_at(cfg: DataConfig, step: int, shard: int = 0, n_shards: int = 1
             ) -> dict:
    """The shard's rows of global batch `step`. Pure function -> skip-ahead."""
    rows = cfg.global_batch // n_shards
    row0 = shard * rows
    r = np.arange(rows, dtype=np.uint64)[:, None] + np.uint64(row0)
    c = np.arange(cfg.seq_len, dtype=np.uint64)[None, :]
    base = (np.uint64(cfg.seed) * np.uint64(0x51D2FA7) +
            np.uint64(step) * np.uint64(0x9E3779B1))
    h = _splitmix64(base + r * np.uint64(1_000_003) + c)
    tokens = (h % np.uint64(cfg.vocab_size)).astype(np.int32)
    out = {"tokens": tokens}
    if cfg.n_frontend_tokens:
        f = np.arange(cfg.n_frontend_tokens, dtype=np.uint64)[None, :, None]
        d = np.arange(cfg.d_model, dtype=np.uint64)[None, None, :]
        hf = _splitmix64(base + r[:, :, None] * np.uint64(7919) +
                         f * np.uint64(104_729) + d)
        out["frontend_embs"] = (
            (hf % np.uint64(2048)).astype(np.float32) / 1024.0 - 1.0)
    return out


class Prefetcher:
    """Bounded background prefetch of step-indexed batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2,
                 shard: int = 0, n_shards: int = 1):
        self.cfg, self.shard, self.n_shards = cfg, shard, n_shards
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = batch_at(self.cfg, step, self.shard, self.n_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._t.join(timeout=2)
