"""``python -m repro.checkpoint --verify <dir>``: checkpoint integrity CLI.

Fully decompresses every stored leaf (a truncated archive fails HERE, not
deep inside a later restore), validates meta.json, and prints a summary.
Exit status 0 = intact, 1 = corrupt/mismatched, 2 = no checkpoint found.
"""
from __future__ import annotations

import argparse
import sys

from .manager import CheckpointError, latest_step, verify


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.checkpoint")
    ap.add_argument("ckpt_dir", help="checkpoint directory (holds step_* "
                    "subdirectories)")
    ap.add_argument("--verify", action="store_true",
                    help="round-trip every stored leaf and validate "
                    "meta.json (the default and only action for now)")
    ap.add_argument("--step", type=int, default=None,
                    help="step to check (default: latest)")
    args = ap.parse_args(argv)

    try:
        report = verify(args.ckpt_dir, step=args.step)
    except FileNotFoundError as e:
        print(f"NOT FOUND: {e}", file=sys.stderr)
        return 2
    except CheckpointError as e:
        print(f"CORRUPT: {e}", file=sys.stderr)
        return 1
    print(f"OK: step {report['step']} of {args.ckpt_dir} -- "
          f"{report['n_leaves']} leaves, {report['n_bytes']} bytes, "
          f"meta {report['meta']}")
    latest = latest_step(args.ckpt_dir)
    if latest != report["step"]:
        print(f"    (latest step in dir is {latest})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
