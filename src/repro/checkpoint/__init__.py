from .manager import latest_step, load_meta, restore, save  # noqa: F401
