from .manager import (CheckpointError, latest_step, load_meta,  # noqa: F401
                      restore, save, verify)
