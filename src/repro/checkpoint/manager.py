"""Fault-tolerant checkpointing: atomic writes, keep-last-k, resume,
cross-mesh resharding on load (elastic scaling), optional async save.

Format: one .npz of flattened tree leaves (keyed by path) + meta.json.
Atomicity: write into ``<dir>/tmp.<step>`` then os.rename -- a crashed save
never corrupts the latest checkpoint (restart-safety on node failure).
Loading device_puts each leaf to the *target* sharding, so a checkpoint
written on a 16x16 mesh restores onto 2x16x16 (or 1 CPU) unchanged.

Registered-dataclass pytrees (e.g. core.engine.PackedCimWeights) round-trip
too: their GetAttrKey/SequenceKey path entries key the npz just like dict
keys, so a deployment can checkpoint PREPACKED params and pay the PTQ
weight-conditioning cost once per deployment instead of once per process.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_part(p) -> str:
    """One path entry -> npz key segment (DictKey.key, GetAttrKey.name,
    SequenceKey.idx / FlattenedIndexKey.key all normalise to their value)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _path_key(path) -> str:
    return "/".join(_path_part(p) for p in path)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz round-trip safe staging
            arr = arr.astype(np.float32)
        flat[_path_key(path)] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None,
         keep_last: int = 3, background: bool = False):
    """Atomic checkpoint of an arbitrary pytree."""
    def _save():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep_last)

    if background:
        t = threading.Thread(target=_save, daemon=False)
        t.start()
        return t
    _save()
    return None


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target``; device_put to ``shardings``
    (same-structure tree of NamedSharding) when given -- this is the elastic
    re-shard path."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "state.npz")
    data = np.load(path)
    leaves_p, tdef = jax.tree_util.tree_flatten_with_path(target)
    flat_shard = (tdef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(leaves_p))
    out = []
    for (p, leaf), shd in zip(leaves_p, flat_shard):
        arr = data[_path_key(p)]
        assert arr.shape == tuple(leaf.shape), \
            (_path_key(p), arr.shape, leaf.shape)
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # handles bf16 staging
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(tdef, out)


def load_meta(ckpt_dir: str, step: Optional[int] = None) -> dict:
    step = step if step is not None else latest_step(ckpt_dir)
    with open(os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")) as f:
        return json.load(f)
