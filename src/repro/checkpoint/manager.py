"""Fault-tolerant checkpointing: atomic writes, keep-last-k, resume,
cross-mesh resharding on load (elastic scaling), optional async save.

Format: one .npz of flattened tree leaves (keyed by path) + meta.json.
Atomicity: write into ``<dir>/tmp.<step>`` then os.rename -- a crashed save
never corrupts the latest checkpoint (restart-safety on node failure).
Loading device_puts each leaf to the *target* sharding, so a checkpoint
written on a 16x16 mesh restores onto 2x16x16 (or 1 CPU) unchanged.

Registered-dataclass pytrees (e.g. core.engine.PackedCimWeights) round-trip
too: their GetAttrKey/SequenceKey path entries key the npz just like dict
keys, so a deployment can checkpoint PREPACKED params and pay the PTQ
weight-conditioning cost once per deployment instead of once per process.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint exists but cannot be restored (truncated archive,
    missing/mismatched leaves, unreadable metadata).  Distinct from
    FileNotFoundError -- callers that fall back to cold start on *absent*
    checkpoints should NOT silently swallow a *corrupt* one."""


def _path_part(p) -> str:
    """One path entry -> npz key segment (DictKey.key, GetAttrKey.name,
    SequenceKey.idx / FlattenedIndexKey.key all normalise to their value)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _path_key(path) -> str:
    return "/".join(_path_part(p) for p in path)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz round-trip safe staging
            arr = arr.astype(np.float32)
        flat[_path_key(path)] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[dict] = None,
         keep_last: int = 3, background: bool = False):
    """Atomic checkpoint of an arbitrary pytree."""
    def _save():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **_flatten(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep_last)

    if background:
        t = threading.Thread(target=_save, daemon=False)
        t.start()
        return t
    _save()
    return None


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def _open_npz(path: str):
    """Open a checkpoint archive, normalising every way a short write or
    disk corruption surfaces (bad zip directory, truncated member) into
    one clear CheckpointError."""
    if not os.path.exists(path):
        raise FileNotFoundError(f"no checkpoint archive at {path}")
    try:
        return np.load(path)
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        raise CheckpointError(
            f"checkpoint archive {path} is truncated or corrupt "
            f"({type(e).__name__}: {e}); the save was interrupted after "
            "the atomic rename or the file was damaged on disk -- fall "
            "back to an earlier step") from e


def _read_leaf(data, path: str, key: str) -> np.ndarray:
    """Read one leaf array, converting a truncated member (zlib/zip error
    mid-decompress) into a CheckpointError naming the leaf."""
    try:
        return data[key]
    except KeyError:
        raise CheckpointError(
            f"checkpoint {path} is missing leaf {key!r}: the target pytree "
            "structure does not match what was saved (stale code, wrong "
            "arch, or a partially-written archive). "
            f"Archive holds {len(data.files)} leaves.") from None
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        raise CheckpointError(
            f"checkpoint {path}: leaf {key!r} is unreadable "
            f"({type(e).__name__}: {e}) -- the archive is truncated or "
            "corrupt") from e


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target``; device_put to ``shardings``
    (same-structure tree of NamedSharding) when given -- this is the elastic
    re-shard path.

    Raises ``CheckpointError`` (never a bare KeyError/AssertionError from
    deep inside unflatten) when the archive is truncated/corrupt, a target
    leaf is absent from it, or a leaf's stored shape disagrees with the
    target -- each error names the offending leaf path.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "state.npz")
    data = _open_npz(path)
    leaves_p, tdef = jax.tree_util.tree_flatten_with_path(target)
    flat_shard = (tdef.flatten_up_to(shardings) if shardings is not None
                  else [None] * len(leaves_p))
    out = []
    for (p, leaf), shd in zip(leaves_p, flat_shard):
        key = _path_key(p)
        arr = _read_leaf(data, path, key)
        if arr.shape != tuple(leaf.shape):
            raise CheckpointError(
                f"checkpoint {path}: leaf {key!r} has shape {arr.shape} "
                f"but the restore target expects {tuple(leaf.shape)} -- "
                "the checkpoint was written for a different model/plan "
                "configuration")
        arr = jax.numpy.asarray(arr).astype(leaf.dtype)  # handles bf16 staging
        out.append(jax.device_put(arr, shd) if shd is not None else arr)
    return jax.tree_util.tree_unflatten(tdef, out)


def verify(ckpt_dir: str, step: Optional[int] = None,
           target: Any = None) -> Dict[str, Any]:
    """Round-trip integrity check of one checkpoint, without restoring.

    Fully decompresses every stored leaf (catching truncation anywhere in
    the archive, not just a bad central directory), parses meta.json, and
    -- when ``target`` is given -- diffs the stored key set and shapes
    against the target pytree.  Returns a summary dict; raises
    ``CheckpointError`` on the first problem found.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "state.npz")
    data = _open_npz(path)
    n_bytes = 0
    shapes: Dict[str, tuple] = {}
    for key in data.files:
        arr = _read_leaf(data, path, key)   # full decompress
        shapes[key] = arr.shape
        n_bytes += arr.nbytes
    try:
        meta = load_meta(ckpt_dir, step)
    except (FileNotFoundError, json.JSONDecodeError) as e:
        raise CheckpointError(
            f"checkpoint step {step} in {ckpt_dir}: meta.json is missing "
            f"or unparsable ({e})") from e
    if meta.get("step") != step:
        raise CheckpointError(
            f"checkpoint {path}: meta.json records step {meta.get('step')} "
            f"but the directory is step_{step:010d}")
    report = dict(step=step, n_leaves=len(shapes), n_bytes=n_bytes,
                  meta=meta, ok=True)
    if target is not None:
        want = {_path_key(p): tuple(leaf.shape) for p, leaf in
                jax.tree_util.tree_flatten_with_path(target)[0]}
        missing = sorted(set(want) - set(shapes))
        extra = sorted(set(shapes) - set(want))
        if missing or extra:
            raise CheckpointError(
                f"checkpoint {path}: pytree structure mismatch -- "
                f"missing leaves {missing[:5]}{'...' if len(missing) > 5 else ''}, "
                f"unexpected leaves {extra[:5]}{'...' if len(extra) > 5 else ''}")
        for key, shape in want.items():
            if shapes[key] != shape:
                raise CheckpointError(
                    f"checkpoint {path}: leaf {key!r} stored shape "
                    f"{shapes[key]} != target shape {shape}")
        report["target_leaves_matched"] = len(want)
    return report


def load_meta(ckpt_dir: str, step: Optional[int] = None) -> dict:
    step = step if step is not None else latest_step(ckpt_dir)
    with open(os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")) as f:
        return json.load(f)
