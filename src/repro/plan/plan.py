"""DeploymentPlan: the per-projection D/A split as a static, hashable value.

The paper's core claim is that the digital/analog boundary is a *design
knob*: route the top-k bit-products to exact counting logic (DCIM) and the
rest to the capacitor array (ACIM), trading accuracy against area/energy.
`CCIMConfig` already parameterizes every knob, but a single global config
wastes the knob -- different projections of the same LM have wildly
different noise sensitivity, so a per-projection assignment dominates any
single setting on the accuracy/cost Pareto front.

A ``DeploymentPlan`` is that assignment: projection path -> ``PlanEntry``
(a ``CCIMConfig`` + an execution fidelity).  It is deliberately STATIC
metadata, not a pytree of arrays:

  * entries are a sorted tuple, the whole plan is hashable and equality-
    comparable, so it rides inside the (frozen, hashable) ``ModelConfig``
    and through ``jax.jit`` static arguments;
  * ``models.layers._dense`` resolves its projection path against the plan
    AT TRACE TIME, so a planned model compiles to exactly one executable
    per entry-distinct projection -- mixed fidelities coexist in one
    AOT-compiled serve loop with zero recompiles across decode steps;
  * ``lm.pack_cim_params`` packs each projection under its own entry's
    config, and the packed leaf carries that config as pytree metadata, so
    a mixed pack is self-describing.

Path convention (see ``models.lm.iter_packable_paths``): the path is the
params-tree path with the scanned-stack key ``"layers"`` dropped, e.g.
``"attn/wq"``, ``"mlp/w1"``, ``"mamba/out_proj"``, ``"moe/shared/w3"``,
``"shared/attn/wo"`` (the zamba2 shared block).  Lookup falls back from
the full path to the basename (so ``{"wq": ...}`` targets every wq) to the
plan default.  Scanned layer stacks share one entry across depth by
construction -- that is what keeps K/N/config static under ``lax.scan``.

Fidelities a plan may assign (``PLAN_FIDELITIES``):

  float   bypass the macro entirely (full-precision matmul) -- used by the
          profiler to isolate one projection, and for layers a deployment
          keeps off-macro.
  exact   all-digital CIM [11]: exact integer MAC of the SMF-quantized
          operands (quantization is the only error) -- the accuracy
          ceiling, costed as 49 bit-products of counting logic.
  fast    the hybrid/analog macro emulation (moment-matched fast path);
          the entry's ``CCIMConfig`` sets the D/A split (``n_dcim_products``
          6..1 hybrid, 0 all-analog), ADC width and accumulate length.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Tuple

from ..core.ccim import CCIMConfig, DEFAULT_CONFIG

PLAN_FIDELITIES = ("float", "exact", "fast")


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One projection's execution assignment: macro config + fidelity."""

    cfg: CCIMConfig = DEFAULT_CONFIG
    fidelity: str = "fast"
    label: str = ""                    # human-readable candidate name

    def __post_init__(self):
        if self.fidelity not in PLAN_FIDELITIES:
            raise ValueError(
                f"plan fidelity {self.fidelity!r} not in {PLAN_FIDELITIES} "
                "(bit_true needs a fabricated macro instance and is a "
                "profiling tool, not a deployment fidelity)")


FLOAT_ENTRY = PlanEntry(fidelity="float", label="float")
DIGITAL_ENTRY = PlanEntry(fidelity="exact", label="digital")
HYBRID_ENTRY = PlanEntry(fidelity="fast", label="hybrid3")  # paper prototype


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    """Projection path -> PlanEntry, with a default for unlisted paths.

    ``entries`` is a name-sorted tuple of ``(path, PlanEntry)`` pairs so
    two plans with the same assignment compare/hash equal regardless of
    construction order.  Build with ``DeploymentPlan.from_dict``.
    """

    entries: Tuple[Tuple[str, PlanEntry], ...] = ()
    default: PlanEntry = FLOAT_ENTRY

    @classmethod
    def from_dict(cls, entries: Mapping[str, PlanEntry],
                  default: PlanEntry = FLOAT_ENTRY) -> "DeploymentPlan":
        return cls(entries=tuple(sorted(entries.items())), default=default)

    @classmethod
    def uniform(cls, entry: PlanEntry) -> "DeploymentPlan":
        """A global single-config plan (the baseline the planner beats)."""
        return cls(entries=(), default=entry)

    def as_dict(self) -> Dict[str, PlanEntry]:
        return dict(self.entries)

    def resolve(self, path: Optional[str]) -> PlanEntry:
        """Entry for ``path``: exact match, then basename, then default."""
        if path is None:
            return self.default
        d = dict(self.entries)
        if path in d:
            return d[path]
        base = path.rsplit("/", 1)[-1]
        if base in d:
            return d[base]
        return self.default

    def replace_entry(self, path: str, entry: PlanEntry) -> "DeploymentPlan":
        d = self.as_dict()
        d[path] = entry
        return DeploymentPlan.from_dict(d, default=self.default)

    def summary(self) -> Dict[str, str]:
        """path -> short label (for reports/benchmark JSON)."""
        def name(e: PlanEntry) -> str:
            if e.label:
                return e.label
            if e.fidelity != "fast":
                return e.fidelity
            return (f"hybrid{e.cfg.n_dcim_products}/adc{e.cfg.adc_bits}"
                    f"/L{e.cfg.acc_len}")
        out = {p: name(e) for p, e in self.entries}
        out["<default>"] = name(self.default)
        return out


def plan_for_sites(sites: Iterable[str], entry: PlanEntry,
                   default: PlanEntry = FLOAT_ENTRY) -> DeploymentPlan:
    """Every listed site at ``entry`` (profiling / global baselines)."""
    return DeploymentPlan.from_dict({s: entry for s in sites}, default=default)
