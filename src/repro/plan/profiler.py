"""Sensitivity profiler: how much does each projection's D/A split hurt?

For every plan-addressable projection site (``lm.iter_packable_paths``)
and every candidate macro design point, the profiler runs ONE calibration
batch through the model with a plan that puts ONLY that site on the
candidate (every other site stays full-precision float) and measures the
relative RMS degradation of the output logits against the float
reference:

    rms(site, cand) = ||logits_planned - logits_float|| / ||logits_float||

This is the end-to-end sensitivity -- it folds in everything between the
projection and the output (residual dilution, norm re-scaling, downstream
saturation), which per-projection local error cannot see, and it reuses
the exact serving plumbing (``cfg.cim_plan`` -> ``layers._dense``), so
what the profiler measures is literally what deployment executes.

Analog candidates are charged for their mismatch + comparator noise, not
just rounding: profiling runs with ``cfg.cim_noise_seed`` set, which makes
every projection draw a deterministic moment-matched noise stream (the
same mechanism noisy serving uses), so the measurement is reproducible.

Isolation is exact under quantization because single-site plans use the
profiler's own float default -- the probe never perturbs other sites.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig
from .candidates import Candidate
from .plan import DeploymentPlan, FLOAT_ENTRY

Array = jax.Array

PROFILE_NOISE_SEED = 0x50524F46  # "PROF"


def calibration_batch(cfg: ModelConfig, batch: int = 2, seq_len: int = 16,
                      seed: int = 0) -> np.ndarray:
    """Uniform-random calibration token ids (a synthetic placeholder;
    pass real data-pipeline tokens to the profiler for a deployment
    plan calibrated on representative inputs)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (batch, seq_len), dtype=np.int32)


def _float_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, cim_mode=False, cim_plan=None,
                               cim_noise_seed=None)


def reference_logits(params, cfg: ModelConfig, tokens: Array) -> Array:
    """Full-precision reference forward (macro off everywhere)."""
    logits, _ = lm.forward(params, _float_cfg(cfg), jnp.asarray(tokens),
                           remat=False)
    return logits


def planned_logits(params, cfg: ModelConfig, tokens: Array,
                   plan: DeploymentPlan,
                   noise_seed: Optional[int] = PROFILE_NOISE_SEED) -> Array:
    """Forward under ``plan`` (the exact serving path, traced per plan)."""
    pcfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=plan,
                               cim_cfg=None, cim_noise_seed=noise_seed)
    logits, _ = lm.forward(params, pcfg, jnp.asarray(tokens), remat=False)
    return logits


def rel_rms(a: Array, ref: Array) -> float:
    num = float(jnp.linalg.norm((a - ref).astype(jnp.float32)))
    den = float(jnp.linalg.norm(ref.astype(jnp.float32)))
    return num / max(den, 1e-12)


@dataclasses.dataclass
class SensitivityProfile:
    """Per-site, per-candidate end-to-end RMS degradation table."""

    sites: List[str]                       # plan paths, params-tree order
    site_shapes: Dict[str, Tuple[int, ...]]
    labels: List[str]                      # candidate labels, sweep order
    rms: Dict[str, Dict[str, float]]       # site -> label -> rel RMS
    # per-token execution multiplicity (default 1): the zamba2 shared
    # block's weights are parked once but EXECUTE once per layer group
    site_mults: Dict[str, int] = dataclasses.field(default_factory=dict)

    def weights_per_site(self, site: str) -> int:
        """Weights parked on the array for this site (area accounting)."""
        n = 1
        for d in self.site_shapes[site]:
            n *= d
        return n

    def macs_per_token(self, site: str) -> int:
        """MACs one token spends in this site: parked weights times how
        often the projection executes per token (shared blocks > 1)."""
        return self.weights_per_site(site) * self.site_mults.get(site, 1)

    def as_table(self) -> Dict[str, Dict[str, float]]:
        return {s: dict(self.rms[s]) for s in self.sites}


def profile_sensitivities(
    params, cfg: ModelConfig, tokens: Array,
    candidates: Sequence[Candidate],
    sites: Optional[Sequence[str]] = None,
    noise_seed: Optional[int] = PROFILE_NOISE_SEED,
    ref: Optional[Array] = None,
    verbose: bool = False,
) -> SensitivityProfile:
    """One forward per (site, candidate), each isolating a single site.

    Returns the sensitivity table the Pareto search consumes.  Runtime is
    ``len(sites) * len(candidates)`` calibration forwards -- profiling is
    an offline, per-deployment cost, exactly like PTQ packing.  ``ref``
    lets callers that already computed the float reference logits pass
    them in instead of paying another forward.
    """
    shapes = lm.iter_packable_paths(params)
    if sites is None:
        sites = list(shapes)
    tokens = jnp.asarray(tokens)
    if ref is None:
        ref = reference_logits(params, cfg, tokens)
    rms: Dict[str, Dict[str, float]] = {}
    for site in sites:
        if site not in shapes:
            raise ValueError(f"unknown projection site {site!r}; "
                             f"known: {sorted(shapes)}")
        row: Dict[str, float] = {}
        for cand in candidates:
            plan = DeploymentPlan.from_dict({site: cand.entry},
                                            default=FLOAT_ENTRY)
            out = planned_logits(params, cfg, tokens, plan, noise_seed)
            row[cand.label] = rel_rms(out, ref)
            if verbose:
                print(f"[profile] {site:20s} {cand.label:18s} "
                      f"rms {row[cand.label]:.5f}")
        rms[site] = row
    # shared-block projections execute once per layer group per token
    n_groups = (cfg.n_layers // cfg.shared_attn_period
                if cfg.shared_attn_period else 1)
    mults = {s: n_groups for s in sites if s.startswith("shared/")}
    return SensitivityProfile(
        sites=list(sites),
        site_shapes={s: shapes[s] for s in sites},
        labels=[c.label for c in candidates],
        rms=rms,
        site_mults=mults,
    )
