"""Candidate macro design points the deployment planner sweeps.

One ``Candidate`` = a ``PlanEntry`` (CCIMConfig + fidelity) plus its
modeled per-MAC cost from ``core.costmodel.macro_cost``.  The default
sweep walks the knobs the paper exposes:

  * ``n_dcim_products`` 6..0 -- the D/A boundary itself, from almost-all-
    digital counting logic down to the all-analog capacitor array;
  * ``adc_bits`` -- sized per split by ``min_adc_bits`` (the conservative
    no-clipping rule; the prototype's top-3/7b point is kept verbatim);
  * ``acc_len`` -- longer accumulates amortize per-conversion overheads
    (drivers, clocking) over more MACs at the price of array area;
  * fidelity "exact" -- all-digital CIM [11], the accuracy ceiling and
    cost ceiling.

Costs are folded into one scalar (``combined_cost``) as a weighted sum of
energy/MAC, deployment area and conversion latency, each normalized to the
all-digital design -- the knapsack currency of ``plan.search``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.ccim import CCIMConfig, DEFAULT_CONFIG
from ..core.costmodel import MacroCost, macro_cost
from .plan import PlanEntry

# (energy, area, latency) weights of the combined modeled-cost scalar.
DEFAULT_COST_WEIGHTS = (0.5, 0.3, 0.2)


def min_adc_bits(cfg: CCIMConfig) -> int:
    """Smallest SAR resolution that never clips a full accumulate.

    Exhaustive over the 128x128 magnitude-product table: the worst-case
    analog sum is ``acc_len * max(|I||W| - dcim_lsb * dcim(|I|,|W|))``,
    and the bipolar ADC must cover it at LSB ``dcim_lsb``.  Reproduces
    the prototype's 7-bit choice for the top-3 split.
    """
    m = np.arange(cfg.max_mag + 1)
    prod = m[:, None] * m[None, :]
    d = np.zeros_like(prod)
    for j, k in cfg.dcim_products:
        d = d + ((m[:, None] >> j) & 1) * ((m[None, :] >> k) & 1) * (
            (1 << (j + k)) // cfg.dcim_lsb)
    acim_max = int(cfg.acc_len * (prod - d * cfg.dcim_lsb).max())
    if acim_max <= 0:
        return 1
    return max(1, math.ceil(math.log2(acim_max / cfg.dcim_lsb)) + 1)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One sweepable design point with its modeled per-MAC cost."""

    entry: PlanEntry
    cost: MacroCost

    @property
    def label(self) -> str:
        return self.entry.label


def make_candidate(label: str, cfg: CCIMConfig = DEFAULT_CONFIG,
                   fidelity: str = "fast") -> Candidate:
    entry = PlanEntry(cfg=cfg, fidelity=fidelity, label=label)
    return Candidate(entry=entry, cost=macro_cost(cfg, fidelity))


def combined_cost(c: Candidate, digital: Candidate,
                  weights: Tuple[float, float, float] = DEFAULT_COST_WEIGHTS
                  ) -> float:
    """Scalar modeled cost per MAC, normalized so all-digital == 1.0."""
    we, wa, wl = weights
    return (we * c.cost.energy_pj_per_mac / digital.cost.energy_pj_per_mac
            + wa * c.cost.area_mm2_per_kb / digital.cost.area_mm2_per_kb
            + wl * c.cost.latency_cyc_per_mac
            / digital.cost.latency_cyc_per_mac)


def default_candidates(base: CCIMConfig = DEFAULT_CONFIG,
                       n_dcim_sweep: Sequence[int] = (6, 5, 4, 3, 2, 1, 0),
                       acc_len_sweep: Sequence[int] = (16, 32),
                       include_digital: bool = True) -> List[Candidate]:
    """The planner's default design space, most-accurate first.

    Every point is servable end-to-end: the fast-GEMM path handles any
    config, and the generalized prepacked Pallas kernel takes each
    point's plane count / LSB / ADC half-range as static meta.
    """
    cands: List[Candidate] = []
    if include_digital:
        cands.append(make_candidate("digital", base, fidelity="exact"))
    for acc_len in acc_len_sweep:
        for k in n_dcim_sweep:
            cfg = dataclasses.replace(base, n_dcim_products=k, acc_len=acc_len)
            adc = min_adc_bits(cfg)
            if k == base.n_dcim_products and acc_len == base.acc_len:
                adc = base.adc_bits          # the taped-out prototype point
            cfg = dataclasses.replace(cfg, adc_bits=adc)
            name = "hybrid" if k else "analog"
            cands.append(make_candidate(
                f"{name}{k}/adc{adc}/L{acc_len}", cfg))
    return cands


def candidates_by_label(cands: Sequence[Candidate]) -> Dict[str, Candidate]:
    return {c.label: c for c in cands}


def prototype_candidate(base: CCIMConfig = DEFAULT_CONFIG) -> Candidate:
    """The paper's 28nm operating point (top-3 split, 7b SAR, L=16)."""
    return make_candidate(
        f"hybrid{base.n_dcim_products}/adc{base.adc_bits}/L{base.acc_len}",
        base)


def digital_candidate(base: CCIMConfig = DEFAULT_CONFIG) -> Candidate:
    return make_candidate("digital", base, fidelity="exact")
