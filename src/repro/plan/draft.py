"""Draft-plan derivation for plan-cascade speculative decoding.

The paper's D/A boundary trades accuracy for conversion cost; PR 4 made it
a per-projection deployment decision.  Speculative decoding turns the same
knob into a LATENCY knob: an aggressive all-analog plan drafts k tokens
cheaply, the deployed plan verifies all k+1 positions in one wide skinny-M
GEMM, and standard accept/resample keeps the output distribution exactly
the verify plan's.  The key system property (``core.engine.pack_compatible``)
is that an all-analog entry with the pack's ``n_mag_bits``/``acc_len`` can
serve the SAME ``PackedCimWeights`` arrays the verify plan uses -- zero
extra memory, zero repacks: the software twin of both splits sharing every
bit-cell of the 2D-weighted capacitor array.

Derivation maps each verify entry to its analog shadow:

  float  -> unchanged (the projection is off-macro; draft == verify there,
            so it contributes no acceptance loss);
  exact/fast -> ``n_dcim_products=0`` at the same ``acc_len``, with
            ``adc_bits`` the aggressiveness knob: ``min_adc_bits`` (no
            clipping -- quantization/rounding is the only draft error) down
            to narrower SARs that clip large accumulates and draft faster
            but get rejected more.

Acceptance is therefore a function of the D/A split distance between the
two plans -- ``draft_plan_sweep`` enumerates that axis for the benchmark
study (acceptance rate / tokens-per-round / tok/s per point).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from ..core.ccim import DEFAULT_CONFIG
from .candidates import min_adc_bits
from .plan import DeploymentPlan, PlanEntry


def derive_draft_entry(entry: PlanEntry, adc_bits: Optional[int] = None,
                       adc_delta: int = 0) -> PlanEntry:
    """The all-analog shadow of one plan entry (same pack, no planes).

    ``adc_bits`` forces an absolute SAR width; otherwise the width is the
    entry's conservative no-clip ``min_adc_bits`` plus ``adc_delta``
    (negative deltas draft more aggressively -- narrower SARs clip large
    accumulates).  Resolving per entry matters because different
    ``acc_len`` need different no-clip widths.
    """
    if entry.fidelity == "float":
        return entry
    cfg = dataclasses.replace(entry.cfg, n_dcim_products=0)
    bits = adc_bits if adc_bits is not None else max(
        1, min_adc_bits(cfg) + adc_delta)
    cfg = dataclasses.replace(cfg, adc_bits=bits)
    return PlanEntry(cfg=cfg, fidelity="fast",
                     label=f"draft-analog0/adc{bits}/L{cfg.acc_len}")


def derive_draft_plan(plan: DeploymentPlan, adc_bits: Optional[int] = None,
                      adc_delta: int = 0) -> DeploymentPlan:
    """Entry-wise analog shadow of a deployment plan.

    The mapping is key-preserving, so path resolution (exact / basename /
    default) matches the verify plan site for site, and members of a fused
    projection group that agreed under the verify plan still agree under
    the draft plan (``layers.fusion_partitions`` keeps fusing them).
    """
    return DeploymentPlan.from_dict(
        {p: derive_draft_entry(e, adc_bits, adc_delta)
         for p, e in plan.entries},
        default=derive_draft_entry(plan.default, adc_bits, adc_delta))


def draft_plan_for_model(model_cfg, adc_bits: Optional[int] = None,
                         adc_delta: int = 0) -> DeploymentPlan:
    """Draft plan for any model config (planned or global-CIM).

    Accepts anything with ``cim_plan`` / ``cim_cfg`` / ``cim_fidelity``
    attributes.  A planned config derives entry-wise; a global-CIM config
    derives from a uniform plan over its single entry.  For a non-CIM
    (float) config this degenerates to draft == verify -- self-speculation,
    where acceptance is 1 and the win is pure multi-token amortization.
    """
    plan = getattr(model_cfg, "cim_plan", None)
    if plan is None:
        base = PlanEntry(cfg=getattr(model_cfg, "cim_cfg", None)
                         or DEFAULT_CONFIG,
                         fidelity=getattr(model_cfg, "cim_fidelity", "fast"))
        plan = DeploymentPlan.uniform(base)
    return derive_draft_plan(plan, adc_bits, adc_delta)


def draft_plan_sweep(plan: DeploymentPlan,
                     adc_deltas: Sequence[int] = (0, -1, -2),
                     ) -> List[Tuple[str, DeploymentPlan]]:
    """(label, draft_plan) points of increasing draft aggressiveness.

    Delta 0 is the conservative no-clip analog shadow; each further delta
    narrows every entry's SAR by that many bits below its own no-clip
    width.  Labels carry the default entry's resulting width for display.
    """
    points = []
    for d in adc_deltas:
        dp = derive_draft_plan(plan, adc_delta=d)
        cands = [e for _, e in dp.entries] + [dp.default]
        named = next((e for e in cands if e.fidelity != "float"),
                     derive_draft_entry(PlanEntry(), adc_delta=d))
        points.append((f"analog0/adc{named.cfg.adc_bits}", dp))
    return points
