"""Pareto search: sensitivities + modeled costs -> a DeploymentPlan.

Greedy knapsack on modeled-cost-saved per unit accuracy-lost:

  1. every site starts on the most accurate candidate (all-digital);
  2. repeatedly apply the (site, cheaper-candidate) move with the best
     ratio  (combined cost saved) / (rms^2 added), as long as the
     PREDICTED total error  sqrt(sum_site rms_site^2)  stays within the
     budget (per-site output-RMS contributions add in variance for
     independent error sources -- the same argument the fast path's
     moment matching rests on);
  3. validate END TO END: one forward under the final plan measures the
     actual output RMS (and an lm_loss delta); if validation exceeds the
     budget, the highest-rms^2 moves are reverted (re-validating each
     time) until it passes.

The budget defaults to what the GLOBAL single-config prototype plan
achieves, expressed in both spaces: the predicted-space budget is the
prototype's own sqrt-sum-of-squares (no forward needed), the validation
budget its measured RMS.  With that default the search returns a plan
that is accuracy-no-worse than running the paper's macro everywhere,
while spending digital precision only where the model is sensitive --
the planned-mixed point that Pareto-dominates the global config.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from ..models.config import ModelConfig
from .candidates import (Candidate, DEFAULT_COST_WEIGHTS, candidates_by_label,
                         combined_cost, default_candidates, digital_candidate,
                         prototype_candidate)
from .plan import DeploymentPlan, PlanEntry
from .profiler import (PROFILE_NOISE_SEED, SensitivityProfile,
                       planned_logits, profile_sensitivities,
                       reference_logits, rel_rms)

Array = jax.Array


# ---------------------------------------------------------------------------
# modeled cost of an assignment
# ---------------------------------------------------------------------------


def assignment_cost(assignment: Dict[str, Candidate],
                    profile: SensitivityProfile,
                    weights: Tuple[float, float, float] = DEFAULT_COST_WEIGHTS
                    ) -> Dict[str, float]:
    """Modeled per-token cost of a site->candidate assignment.

    energy: pJ/token over every planned MAC.  area: mm^2 to park the
    weights at each design's density (weight-stationary deployment).
    latency: conversion-cycles/token.  combined: MAC-weighted average of
    each site's digital-normalized scalar (1.0 == all-digital).
    """
    dig = digital_candidate()
    energy = area = latency = 0.0
    comb_num = macs_tot = 0.0
    for site, cand in assignment.items():
        # energy/latency scale with per-token EXECUTIONS (shared blocks
        # run once per layer group); area with the weights parked once
        macs = profile.macs_per_token(site)
        energy += macs * cand.cost.energy_pj_per_mac
        area += (profile.weights_per_site(site) * 8 / 1024 / 8
                 * cand.cost.area_mm2_per_kb)
        latency += macs * cand.cost.latency_cyc_per_mac
        comb_num += macs * combined_cost(cand, dig, weights)
        macs_tot += macs
    return dict(energy_pj_per_token=energy, area_mm2=area,
                latency_cyc_per_token=latency,
                combined=comb_num / max(macs_tot, 1.0))


def predicted_rms(assignment: Dict[str, Candidate],
                  profile: SensitivityProfile) -> float:
    """sqrt(sum of per-site isolated rms^2) -- the variance-additive proxy."""
    return math.sqrt(sum(
        profile.rms[s][c.label] ** 2 for s, c in assignment.items()))


def plan_from_assignment(assignment: Dict[str, Candidate],
                         default: Optional[PlanEntry] = None
                         ) -> DeploymentPlan:
    return DeploymentPlan.from_dict(
        {s: c.entry for s, c in assignment.items()},
        default=default or digital_candidate().entry)


# ---------------------------------------------------------------------------
# greedy knapsack + end-to-end validation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanSearchResult:
    plan: DeploymentPlan
    assignment: Dict[str, str]            # site -> candidate label
    profile: SensitivityProfile
    predicted_rms: float
    measured_rms: float                   # end-to-end validation forward
    budget_predicted: float
    budget_measured: float
    cost: Dict[str, float]                # planned-mixed modeled cost
    cost_digital: Dict[str, float]
    cost_budget_plan: Dict[str, float]    # the uniform budget baseline
    moves: List[Tuple[str, str, float]]   # (site, label, score) applied
    n_reverts: int

    def summary(self) -> Dict:
        return dict(
            assignment=dict(self.assignment),
            predicted_rms=round(self.predicted_rms, 6),
            measured_rms=round(self.measured_rms, 6),
            budget_measured=round(self.budget_measured, 6),
            cost={k: round(v, 6) for k, v in self.cost.items()},
            cost_digital={k: round(v, 6) for k, v in
                          self.cost_digital.items()},
            cost_budget_plan={k: round(v, 6) for k, v in
                              self.cost_budget_plan.items()},
            n_moves=len(self.moves), n_reverts=self.n_reverts,
        )


def pareto_search(
    params, cfg: ModelConfig, tokens,
    candidates: Optional[Sequence[Candidate]] = None,
    sites: Optional[Sequence[str]] = None,
    budget_candidate: Optional[Candidate] = None,
    budget_scale: float = 1.0,
    rms_budget: Optional[float] = None,
    cost_weights: Tuple[float, float, float] = DEFAULT_COST_WEIGHTS,
    noise_seed: Optional[int] = PROFILE_NOISE_SEED,
    profile: Optional[SensitivityProfile] = None,
    ref=None,
    validate_tol: float = 1.02,
    verbose: bool = False,
) -> PlanSearchResult:
    """Profile + search + validate: the whole planner in one call.

    ``budget_candidate`` (default: the paper's prototype point) defines
    the accuracy budget as "whatever running THAT design everywhere would
    cost in accuracy"; ``budget_scale`` tightens it (0.6 -> beat the
    uniform baseline's RMS by 40%, which forces genuinely mixed plans:
    digital on the sensitive projections, cheap splits elsewhere).  Pass
    ``rms_budget`` to target an absolute output RMS instead (it then
    bounds both predicted and measured error).
    """
    candidates = list(candidates) if candidates is not None \
        else default_candidates(cfg.cim_cfg) if cfg.cim_cfg \
        else default_candidates()
    by_label = candidates_by_label(candidates)
    # candidate identity is label-keyed everywhere (profile columns,
    # assignments): colliding labels would silently alias RMS/cost rows
    if len(by_label) != len(candidates):
        seen = set()
        dupes = {c.label for c in candidates
                 if c.label in seen or seen.add(c.label)}
        raise ValueError(f"duplicate candidate labels {sorted(dupes)}")
    dig = digital_candidate()
    if by_label.setdefault(dig.label, dig) != dig:
        raise ValueError(
            f"candidate label {dig.label!r} is reserved for the all-digital "
            "point the greedy search starts from; rename the colliding "
            "candidate")
    if dig.label not in {c.label for c in candidates}:
        candidates = [dig] + candidates
    budget_candidate = budget_candidate or prototype_candidate()
    if by_label.setdefault(budget_candidate.label,
                           budget_candidate) != budget_candidate:
        raise ValueError(
            f"candidate label {budget_candidate.label!r} collides with the "
            "budget candidate but describes a different design point")
    if budget_candidate.label not in {c.label for c in candidates}:
        candidates = candidates + [budget_candidate]

    if ref is None:
        ref = reference_logits(params, cfg, tokens)   # ONE float reference
    if profile is None:
        profile = profile_sensitivities(params, cfg, tokens, candidates,
                                        sites=sites, noise_seed=noise_seed,
                                        ref=ref, verbose=verbose)
    else:
        if sites is not None:
            unknown = [s for s in sites if s not in profile.rms]
            if unknown:
                raise ValueError(
                    f"sites {unknown} not in the precomputed profile "
                    f"(profiled: {sorted(profile.sites)})")
            profile = SensitivityProfile(
                sites=list(sites),
                site_shapes={s: profile.site_shapes[s] for s in sites},
                labels=list(profile.labels),
                rms={s: dict(profile.rms[s]) for s in sites},
                site_mults={s: profile.site_mults.get(s, 1) for s in sites})
        # a precomputed profile may predate the digital/budget candidates
        # appended above: profile just the missing columns and merge
        have = set(profile.labels)
        missing = [c for c in candidates if c.label not in have]
        if missing:
            extra = profile_sensitivities(
                params, cfg, tokens, missing, sites=profile.sites,
                noise_seed=noise_seed, ref=ref, verbose=verbose)
            profile = SensitivityProfile(
                sites=list(profile.sites),
                site_shapes=dict(profile.site_shapes),
                labels=list(profile.labels) + list(extra.labels),
                rms={s: {**profile.rms[s], **extra.rms[s]}
                     for s in profile.sites},
                site_mults=dict(profile.site_mults))
    sites = list(profile.sites)

    # budgets: predicted-space from the table, measured from one forward
    uniform_budget = {s: budget_candidate for s in sites}
    if rms_budget is not None:
        budget_pred = budget_meas = float(rms_budget)
    else:
        budget_pred = predicted_rms(uniform_budget, profile) * budget_scale
        budget_meas = budget_scale * rel_rms(
            planned_logits(params, cfg, tokens,
                           plan_from_assignment(uniform_budget), noise_seed),
            ref)

    # greedy: all-digital start, cheapest-per-accuracy moves first
    assignment = {s: dig for s in sites}
    cost_of = lambda c: combined_cost(c, dig, cost_weights)
    moves: List[Tuple[str, str, float]] = []
    while True:
        best = None
        cur_sq = sum(profile.rms[s][assignment[s].label] ** 2 for s in sites)
        for s in sites:
            cur = assignment[s]
            for cand in candidates:
                dc = (cost_of(cur) - cost_of(cand)) * profile.macs_per_token(s)
                if dc <= 0:
                    continue
                drms = (profile.rms[s][cand.label] ** 2
                        - profile.rms[s][cur.label] ** 2)
                new_rms = math.sqrt(max(cur_sq + drms, 0.0))
                if new_rms > budget_pred:
                    continue
                score = dc / max(drms, 1e-12)
                if best is None or score > best[0]:
                    best = (score, s, cand)
        if best is None:
            break
        score, s, cand = best
        assignment[s] = cand
        moves.append((s, cand.label, score))
        if verbose:
            print(f"[search] {s} -> {cand.label} (score {score:.3g}, "
                  f"pred rms {predicted_rms(assignment, profile):.5f})")

    # end-to-end validation; revert most-damaging moves until within budget
    def measure(asg):
        return rel_rms(planned_logits(params, cfg, tokens,
                                      plan_from_assignment(asg), noise_seed),
                       ref)
    measured = measure(assignment)
    n_reverts = 0
    while measured > budget_meas * validate_tol and any(
            assignment[s].label != dig.label for s in sites):
        worst = max((s for s in sites if assignment[s].label != dig.label),
                    key=lambda s: profile.rms[s][assignment[s].label])
        assignment[worst] = dig
        n_reverts += 1
        measured = measure(assignment)
        if verbose:
            print(f"[search] revert {worst} -> digital "
                  f"(measured rms {measured:.5f})")

    plan = plan_from_assignment(assignment)
    return PlanSearchResult(
        plan=plan,
        assignment={s: assignment[s].label for s in sites},
        profile=profile,
        predicted_rms=predicted_rms(assignment, profile),
        measured_rms=measured,
        budget_predicted=budget_pred,
        budget_measured=budget_meas,
        cost=assignment_cost(assignment, profile, cost_weights),
        cost_digital=assignment_cost({s: dig for s in sites}, profile,
                                     cost_weights),
        cost_budget_plan=assignment_cost(uniform_budget, profile,
                                         cost_weights),
        moves=moves,
        n_reverts=n_reverts,
    )


def evaluate_plan(params, cfg: ModelConfig, tokens, plan: DeploymentPlan,
                  profile: SensitivityProfile,
                  cost_weights: Tuple[float, float, float]
                  = DEFAULT_COST_WEIGHTS,
                  noise_seed: Optional[int] = PROFILE_NOISE_SEED,
                  ref=None) -> Dict[str, float]:
    """Measured RMS + modeled cost of an arbitrary plan over the profiled
    sites (benchmark helper: global baselines and the planned point share
    one evaluation path).  Pass ``ref`` (the float reference logits) to
    avoid recomputing the reference forward per evaluated plan."""
    from ..core.costmodel import macro_cost
    if ref is None:
        ref = reference_logits(params, cfg, tokens)
    measured = rel_rms(planned_logits(params, cfg, tokens, plan, noise_seed),
                       ref)
    assignment = {}
    for s in profile.sites:
        e = plan.resolve(s)
        if e.fidelity == "float":
            continue        # off-macro site: no macro cost to model
        assignment[s] = Candidate(entry=e, cost=macro_cost(e.cfg, e.fidelity))
    out = assignment_cost(assignment, profile, cost_weights)
    out["measured_rms"] = measured
    return out
