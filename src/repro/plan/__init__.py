# Mixed-fidelity deployment planner: per-projection D/A split search.
# The D/A boundary is the paper's design knob; this subsystem turns it
# into a per-projection deployment decision -- profile sensitivity, search
# the accuracy/cost Pareto front, serve the resulting plan unchanged
# through CimEngine + the continuous-batching scheduler (DESIGN.md §8).
from .plan import (  # noqa: F401
    DIGITAL_ENTRY,
    FLOAT_ENTRY,
    HYBRID_ENTRY,
    DeploymentPlan,
    PLAN_FIDELITIES,
    PlanEntry,
    plan_for_sites,
)
from .candidates import (  # noqa: F401
    Candidate,
    DEFAULT_COST_WEIGHTS,
    combined_cost,
    default_candidates,
    digital_candidate,
    make_candidate,
    min_adc_bits,
    prototype_candidate,
)
from .draft import (  # noqa: F401
    derive_draft_entry,
    derive_draft_plan,
    draft_plan_for_model,
    draft_plan_sweep,
)
from .profiler import (  # noqa: F401
    SensitivityProfile,
    calibration_batch,
    planned_logits,
    profile_sensitivities,
    reference_logits,
    rel_rms,
)
from .search import (  # noqa: F401
    PlanSearchResult,
    assignment_cost,
    evaluate_plan,
    pareto_search,
    plan_from_assignment,
    predicted_rms,
)
