"""Functional accuracy baselines the paper compares against (Fig. 6).

  * duplicated-weight C-CIM [3]  -- two independent macro instances (two
    mismatch draws), weights quantized twice; 1.5x area.
  * sequential C-CIM             -- same macro reused over 4 passes (fully
    correlated mismatch), 2.2x latency.
  * all-analog CIM [4-5]         -- every bit-product through the cap array
    + a wider ADC; MSB caps carry the dominant mismatch -> worse RMS.
  * all-digital CIM [11]         -- exact (only quantization of operands),
    the accuracy ceiling; costed in costmodel.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ccim
from .ccim import CCIMConfig, DEFAULT_CONFIG, MacroInstance

Array = jax.Array


def all_digital_mac(x_q: Array, w_q: Array) -> Array:
    """Exact integer MAC (all-digital CIM [11])."""
    return jnp.sum(x_q.astype(jnp.int32) * w_q.astype(jnp.int32), axis=-1)


def all_analog_config(cfg: CCIMConfig = DEFAULT_CONFIG) -> CCIMConfig:
    """All bit-products in analog; ADC must cover the full product range.

    Range of sum(|I||W|) = 16*127^2 < 2^18 -> with LSB 2^11 the ADC needs
    8 bits; conventional designs [4-5] also burn input DACs (not modelled
    for accuracy -- their variation is the paper's motivation)."""
    return dataclasses.replace(cfg, n_dcim_products=0, adc_bits=8)


def all_analog_mac(x_q, w_q, macro, cfg=None, noise_key=None):
    cfg = all_analog_config(cfg or DEFAULT_CONFIG)
    return ccim.hybrid_mac_bit_true(x_q, w_q, macro, cfg, noise_key)


def duplicated_cmac(
    x_re, x_im, w_re, w_im,
    macro_a: MacroInstance, macro_b: MacroInstance,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Baseline (a): Re lane on die-copy A, Im lane on die-copy B."""
    keys = jax.random.split(noise_key, 4) if noise_key is not None else (None,) * 4
    ac = ccim.hybrid_mac_bit_true(x_re, w_re, macro_a, cfg, keys[0])["y8"]
    bd = ccim.hybrid_mac_bit_true(x_im, w_im, macro_a, cfg, keys[1])["y8"]
    ad = ccim.hybrid_mac_bit_true(x_re, w_im, macro_b, cfg, keys[2])["y8"]
    bc = ccim.hybrid_mac_bit_true(x_im, w_re, macro_b, cfg, keys[3])["y8"]
    return ac - bd, ad + bc


def sequential_cmac(
    x_re, x_im, w_re, w_im,
    macro: MacroInstance,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Baseline (b): all four sub-MACs sequenced on ONE macro."""
    keys = jax.random.split(noise_key, 4) if noise_key is not None else (None,) * 4
    ac = ccim.hybrid_mac_bit_true(x_re, w_re, macro, cfg, keys[0])["y8"]
    bd = ccim.hybrid_mac_bit_true(x_im, w_im, macro, cfg, keys[1])["y8"]
    ad = ccim.hybrid_mac_bit_true(x_re, w_im, macro, cfg, keys[2])["y8"]
    bc = ccim.hybrid_mac_bit_true(x_im, w_re, macro, cfg, keys[3])["y8"]
    return ac - bd, ad + bc
