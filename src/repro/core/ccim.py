"""Bit-true behavioural model of the hybrid digital/analog complex-CIM macro.

Implements the arithmetic of the 28nm C-CIM prototype:

  * 8-bit signed-magnitude (SMF) operands:  v = (-1)^s * m,  m in [0,127].
  * Per ``acc_len``-element accumulate (one ADC conversion):
      - DCIM: the top-3 bit-products (6,6),(6,5),(5,6) -- 50.8% of the total
        contribution -- computed exactly with counting logic, range [-64,+64].
      - ACIM: the remaining 46 bit-products summed in charge domain on a 2-D
        binary-weighted capacitor array (unit cap 48 aF, 2.96% rms mismatch),
        digitised by a 7-bit SAR ADC (CDAC LSB = 16 C).
      - Post-digital adder: y8 = DCIM + ADC code, representing sum(I*W)/2^11.
  * Complex MAC: four real sub-MACs sharing one co-located (Re,Im) weight
    array; Re/Im outputs produced in parallel (see complex_mac.py).

Everything is jax.jit compatible.  Analog non-idealities are explicit
functions of a "fabricated" macro instance (frozen mismatch draws), so the
same die gives the same static error pattern -- as in silicon.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import taps
from ..resilience import faults as rfaults

Array = jax.Array

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CCIMConfig:
    """Static configuration of the macro (defaults = the 28nm prototype)."""

    n_mag_bits: int = 7                 # SMF magnitude bits (MSB of 8b is sign)
    acc_len: int = 16                   # elements summed per ADC conversion
    n_dcim_products: int = 3            # top-k bit-products routed to DCIM
    adc_bits: int = 7                   # SAR ADC resolution
    sigma_unit: float = 0.0296          # 48aF M7-M7 fringe cap mismatch (rms)
    adc_lsb_units: int = 16             # CDAC LSB built from 16 unit caps
    # 'per_unit': independent eps per (row, j, k) cell  (16 local 2D arrays)
    # 'per_macro': one shared (j, k) eps  (fully correlated across rows)
    mismatch_granularity: str = "per_unit"
    # 'conservative': DNL = sigma_u * sqrt(2^N - 1)   (paper: 0.33 LSB rms)
    # 'averaged':     per-bit sigma improves as 1/sqrt(#unit caps)
    adc_mismatch_model: str = "conservative"
    # dynamic noise (comparator input-referred + supply), in ADC LSB rms;
    # 0.45 calibrates the model to the measured 0.435% rms C-MAC error
    # (mismatch + rounding alone give 0.29%). Applied only when a noise_key
    # is provided, so deterministic paths stay deterministic.
    comparator_noise_lsb: float = 0.45
    # VREF+/- polarity-path gain mismatch (the VREFCLK direction flip,
    # Fig. 3) -- puts the max INL step at the zero crossing as measured.
    sigma_vref_pol: float = 0.002
    use_split_dac: bool = True          # split-DAC halves the cap count (area)

    # ---- derived ----------------------------------------------------------
    @property
    def max_mag(self) -> int:
        return (1 << self.n_mag_bits) - 1  # 127

    @property
    def dcim_products(self) -> Tuple[Tuple[int, int], ...]:
        """The top-k (j, k) bit-product cells ordered by significance."""
        cells = [(j, k) for j in range(self.n_mag_bits) for k in range(self.n_mag_bits)]
        cells.sort(key=lambda jk: (-(jk[0] + jk[1]), -jk[0]))
        return tuple(cells[: self.n_dcim_products])

    @property
    def dcim_lsb(self) -> int:
        """Significance of the least weighted DCIM product (=2^11 for top-3).

        With no DCIM products (all-analog baseline) the ADC LSB stays at
        2^11 and the ADC must be wider instead (see baselines.py)."""
        if not self.dcim_products:
            return 1 << (2 * self.n_mag_bits - 3)
        return 1 << min(j + k for j, k in self.dcim_products)

    @property
    def adc_half_range(self) -> int:
        return 1 << (self.adc_bits - 1)  # 64 for 7b

    @property
    def fast_noise_correction(self) -> float:
        """Variance correction for the fast path under split-DAC.

        The fast path's matched variance assumes sigma_jk = sigma_u /
        sqrt(2^(j+k)); the split-DAC floors the effective unit count at
        2^ceil(s/2) (see fabricate).  For uniform bit statistics the
        aggregate variance scales by sum(2^2s/eff) / sum(2^s) over the
        ACIM cells -- a config-level scalar."""
        if not self.use_split_dac:
            return 1.0
        num = den = 0.0
        for j in range(self.n_mag_bits):
            for k in range(self.n_mag_bits):
                if (j, k) in self.dcim_products:
                    continue
                s = j + k
                eff = min(2.0 ** s, 2.0 ** math.ceil(s / 2))
                num += (2.0 ** (2 * s)) / eff
                den += 2.0 ** s
        return num / den

    def dcim_weight_table(self) -> np.ndarray:
        """(7,7) integer table: 2^(j+k)/dcim_lsb on DCIM cells, 0 elsewhere."""
        t = np.zeros((self.n_mag_bits, self.n_mag_bits), np.int32)
        for j, k in self.dcim_products:
            t[j, k] = (1 << (j + k)) // self.dcim_lsb
        return t

    def acim_weight_table(self) -> np.ndarray:
        """(7,7) integer table: 2^(j+k) on ACIM cells, 0 on DCIM cells."""
        t = np.zeros((self.n_mag_bits, self.n_mag_bits), np.int64)
        for j in range(self.n_mag_bits):
            for k in range(self.n_mag_bits):
                t[j, k] = 1 << (j + k)
        for j, k in self.dcim_products:
            t[j, k] = 0
        return t

    @property
    def dcim_max(self) -> int:
        """Max |DCIM| for a full accumulate: 16 * (2+1+1) = 64 (paper)."""
        per_elem = sum((1 << (j + k)) // self.dcim_lsb for j, k in self.dcim_products)
        return self.acc_len * per_elem


DEFAULT_CONFIG = CCIMConfig()


# ---------------------------------------------------------------------------
# Fabrication: draw the static analog error pattern of one die
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MacroInstance:
    """Frozen mismatch draws for one fabricated macro.

    eps_array : relative cap error of each 2-D array cell.
        shape (acc_len, 7, 7) for 'per_unit', (7, 7) for 'per_macro'.
        Cell (j,k) holds 2^(j+k) unit caps => sigma = sigma_u / sqrt(2^(j+k)).
    adc_cap_eps : relative error of each binary CDAC capacitor, shape (adc_bits,).
    """

    eps_array: Array
    adc_cap_eps: Array
    vref_pol_eps: Array  # scalar: +/- reference path gain asymmetry


def fabricate(key: Array, cfg: CCIMConfig = DEFAULT_CONFIG) -> MacroInstance:
    """Monte-Carlo 'tape-out': draw the static mismatch of one macro."""
    k1, k2, k3 = jax.random.split(key, 3)
    nb = cfg.n_mag_bits
    jk = jnp.arange(nb)
    # sigma of a cap built from 2^(j+k) unit caps scales as 1/sqrt(count)
    sig2d = cfg.sigma_unit / jnp.sqrt(
        (2.0 ** jk)[:, None] * (2.0 ** jk)[None, :]
    )  # (7,7)
    if cfg.use_split_dac:
        # Split-DAC: LSB section realised behind an attenuation cap, so the
        # *effective* unit count of low-significance cells stops growing --
        # their relative mismatch floors at sigma_unit (they are 1-2 physical
        # caps each).  Model: sigma = sigma_u / sqrt(min(2^(j+k), 2^ceil((j+k)/2)))
        eff = jnp.minimum(
            (2.0 ** jk)[:, None] * (2.0 ** jk)[None, :],
            2.0 ** jnp.ceil((jk[:, None] + jk[None, :]) / 2.0),
        )
        sig2d = cfg.sigma_unit / jnp.sqrt(eff)
    shape = (cfg.acc_len, nb, nb) if cfg.mismatch_granularity == "per_unit" else (nb, nb)
    eps_array = jax.random.normal(k1, shape) * sig2d  # broadcast over rows

    if cfg.adc_mismatch_model == "conservative":
        # paper's sizing rule: DNL = sigma_u*sqrt(2^N-1) = 0.33 LSB rms
        sig_bit = cfg.sigma_unit / jnp.sqrt(2.0 ** jnp.arange(cfg.adc_bits))
    else:
        n_units = cfg.adc_lsb_units * (2.0 ** jnp.arange(cfg.adc_bits))
        sig_bit = cfg.sigma_unit / jnp.sqrt(n_units)
    adc_cap_eps = jax.random.normal(k2, (cfg.adc_bits,)) * sig_bit
    vref_pol_eps = jax.random.normal(k3, ()) * cfg.sigma_vref_pol
    return MacroInstance(eps_array=eps_array, adc_cap_eps=adc_cap_eps,
                         vref_pol_eps=vref_pol_eps)


def ideal_macro(cfg: CCIMConfig = DEFAULT_CONFIG) -> MacroInstance:
    shape = (
        (cfg.acc_len, cfg.n_mag_bits, cfg.n_mag_bits)
        if cfg.mismatch_granularity == "per_unit"
        else (cfg.n_mag_bits, cfg.n_mag_bits)
    )
    return MacroInstance(
        eps_array=jnp.zeros(shape), adc_cap_eps=jnp.zeros((cfg.adc_bits,)),
        vref_pol_eps=jnp.zeros(()),
    )


# ---------------------------------------------------------------------------
# SMF quantization
# ---------------------------------------------------------------------------


def quantize_smf(x: Array, scale: Array, cfg: CCIMConfig = DEFAULT_CONFIG) -> Array:
    """float -> integer in [-127, 127] (signed-magnitude has no -128)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -cfg.max_mag, cfg.max_mag).astype(jnp.int32)


def smf_scale(x: Array, axis=None, keepdims: bool = False,
              cfg: CCIMConfig = DEFAULT_CONFIG) -> Array:
    """Symmetric max-abs scale so that max |q| = 127.

    The fold is written as a multiply by the precomputed reciprocal
    rather than ``amax / max_mag``: XLA's jit simplifier rewrites
    divide-by-constant into exactly this multiply, so the explicit form
    makes the scale BIT-IDENTICAL between eager and jit execution (it
    used to differ by one ulp, which could flip a rounded magnitude --
    the old PR-3 eager-vs-jit packing caveat).
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    inv = np.float32(1.0) / np.float32(cfg.max_mag)
    return jnp.maximum(amax, 1e-12) * inv


def split_sign_mag(q: Array) -> Tuple[Array, Array]:
    """SMF decomposition: sign in {-1,+1}, magnitude in [0,127]."""
    return jnp.where(q < 0, -1, 1).astype(jnp.int32), jnp.abs(q).astype(jnp.int32)


def bit_planes(mag: Array, n_bits: int) -> Array:
    """(...,) int magnitudes -> (..., n_bits) {0,1} planes, LSB first."""
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    return ((mag[..., None] >> shifts) & 1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# 7-bit SAR ADC with CDAC mismatch (bipolar, samples mid-scale 0x40)
# ---------------------------------------------------------------------------


def sar_adc(
    v_lsb: Array,
    adc_cap_eps: Array,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
) -> Array:
    """Convert ``v_lsb`` (analog value in ideal-LSB units, signed) to a code.

    Successive approximation against *real* (mismatched) CDAC weights; the
    returned code is the ideal-binary interpretation of the decided bits --
    exactly how CDAC mismatch becomes DNL/INL in silicon.
    """
    half = cfg.adc_half_range
    x = jnp.clip(v_lsb, -half, half - 1) + half + 0.5  # unipolar, mid-tread
    real_w = (2.0 ** jnp.arange(cfg.adc_bits)) * (1.0 + adc_cap_eps)
    acc = jnp.zeros_like(x)
    code = jnp.zeros_like(x, dtype=jnp.int32)
    keys = (
        jax.random.split(noise_key, cfg.adc_bits) if noise_key is not None else None
    )
    for b in range(cfg.adc_bits - 1, -1, -1):
        trial = acc + real_w[b]
        cmp_in = x
        if keys is not None and cfg.comparator_noise_lsb > 0:
            cmp_in = x + cfg.comparator_noise_lsb * jax.random.normal(keys[b], x.shape)
        bit = (cmp_in >= trial).astype(jnp.int32)
        acc = acc + bit * real_w[b]
        code = code + bit * (1 << b)
    return code - half  # back to signed, in [-64, +63]


# ---------------------------------------------------------------------------
# Hybrid MAC -- bit-true path (the oracle; exact silicon arithmetic)
# ---------------------------------------------------------------------------


def _signed_bits(q: Array, cfg: CCIMConfig) -> Array:
    """(..., L) ints -> (..., L, n_bits) sign-carrying bit planes."""
    s, m = split_sign_mag(q)
    return s[..., None] * bit_planes(m, cfg.n_mag_bits)


def hybrid_mac_bit_true(
    x_q: Array,
    w_q: Array,
    macro: MacroInstance,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
) -> dict:
    """One macro conversion: MAC of ``x_q`` and ``w_q`` over the last axis.

    x_q, w_q : int arrays in [-127,127], trailing axis = acc_len (broadcast
               batch dims allowed).
    Returns dict(y8, dcim, adc_code, a_real, exact) where ``exact`` is the
    full-precision integer dot product and ``y8`` the macro's 8-bit output
    (y8 * 2^11 approximates ``exact``).
    """
    xb = _signed_bits(x_q, cfg)  # (..., L, 7) in {-1,0,1}
    wb = _signed_bits(w_q, cfg)
    # signed bit-product tensor: (..., L, 7, 7); entry = sigma_i * Ij * Wk
    bp = xb[..., :, :, None] * wb[..., :, None, :]

    dcim_w = jnp.asarray(cfg.dcim_weight_table())          # (7,7) small ints
    acim_w = jnp.asarray(cfg.acim_weight_table(), jnp.float32)
    eps = macro.eps_array                                   # (L,7,7) or (7,7)
    real_w = acim_w * (1.0 + eps)                           # broadcasts

    dcim = jnp.sum(bp * dcim_w, axis=(-3, -2, -1))          # exact int
    a_real = jnp.sum(bp.astype(jnp.float32) * real_w, axis=(-3, -2, -1))
    a_ideal = jnp.sum(bp.astype(jnp.int32) * acim_w.astype(jnp.int32), axis=(-3, -2, -1))

    # VREFCLK polarity-path asymmetry: +/- conversions see slightly
    # different reference gains (max INL lands at the zero crossing)
    a_real = a_real * (1.0 + macro.vref_pol_eps * jnp.sign(a_real))
    adc_code = sar_adc(a_real / cfg.dcim_lsb, macro.adc_cap_eps, cfg, noise_key)
    y8 = dcim + adc_code
    exact = jnp.sum(x_q.astype(jnp.int32) * w_q.astype(jnp.int32), axis=-1)
    return dict(y8=y8, dcim=dcim, adc_code=adc_code, a_real=a_real,
                a_ideal=a_ideal, exact=exact)


def hybrid_mac_ideal(x_q: Array, w_q: Array, cfg: CCIMConfig = DEFAULT_CONFIG) -> Array:
    """Mismatch-free macro output (only ADC rounding/clipping remains)."""
    xb = _signed_bits(x_q, cfg)
    wb = _signed_bits(w_q, cfg)
    bp = xb[..., :, :, None] * wb[..., :, None, :]
    dcim = jnp.sum(bp * jnp.asarray(cfg.dcim_weight_table()), axis=(-3, -2, -1))
    a = jnp.sum(bp.astype(jnp.int32) * jnp.asarray(cfg.acim_weight_table()),
                axis=(-3, -2, -1))
    half = cfg.adc_half_range
    code = jnp.clip(jnp.floor(a / cfg.dcim_lsb + 0.5), -half, half - 1)
    return dcim + code.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Hybrid MAC -- fast path (moment-matched; 0 bit-planes)
# ---------------------------------------------------------------------------
#
# For i.i.d. per-(row, j, k) cap mismatch, the analog error
#     A_real - A_ideal = sum_i sigma_i sum_jk B_ijk 2^(j+k) eps_ijk
# has variance  sigma_u^2 * sum_i sum_jk B_ijk 2^(j+k)  (since
# Var[2^(j+k) eps] = 2^(j+k) sigma_u^2), i.e. sigma_u^2 times the *unsigned*
# ACIM magnitude sum -- computable from |x| * |w| alone.  The fast path
# exploits this: exact integer arithmetic for DCIM + ideal-ACIM, plus one
# Gaussian with the exactly matched variance.  2 multiplies per element
# instead of 49 bit-products.  This is the TPU-deployable emulation; tests
# verify its first two error moments against the bit-true oracle.


def _dcim_terms(x_q: Array, w_q: Array, cfg: CCIMConfig) -> Tuple[Array, Array]:
    """Per-element DCIM value and unsigned ACIM magnitude (no bit planes)."""
    sx, mx = split_sign_mag(x_q)
    sw, mw = split_sign_mag(w_q)
    sig = sx * sw
    d_elem = jnp.zeros_like(mx)
    for j, k in cfg.dcim_products:
        d_elem = d_elem + ((mx >> j) & 1) * ((mw >> k) & 1) * (
            (1 << (j + k)) // cfg.dcim_lsb
        )
    prod = mx.astype(jnp.int32) * mw.astype(jnp.int32)
    acim_mag = prod - d_elem.astype(jnp.int32) * cfg.dcim_lsb  # unsigned, >= 0
    return sig * d_elem, sig.astype(jnp.int32) * acim_mag, acim_mag


def hybrid_mac_fast(
    x_q: Array,
    w_q: Array,
    noise_key: Optional[Array],
    cfg: CCIMConfig = DEFAULT_CONFIG,
) -> dict:
    """Moment-matched macro model: exact ints + one matched Gaussian + ADC."""
    d_elem, a_elem, a_mag = _dcim_terms(x_q, w_q, cfg)
    dcim = jnp.sum(d_elem, axis=-1)
    a_ideal = jnp.sum(a_elem, axis=-1)
    var = (cfg.sigma_unit**2 * cfg.fast_noise_correction
           * jnp.sum(a_mag, axis=-1).astype(jnp.float32))
    var = var + (cfg.comparator_noise_lsb * cfg.dcim_lsb) ** 2  # dynamic noise
    a_real = a_ideal.astype(jnp.float32)
    if noise_key is not None:
        a_real = a_real + jnp.sqrt(var) * jax.random.normal(noise_key, a_real.shape)
    half = cfg.adc_half_range
    code = jnp.clip(jnp.floor(a_real / cfg.dcim_lsb + 0.5), -half, half - 1).astype(
        jnp.int32
    )
    y8 = dcim + code
    exact = jnp.sum(x_q.astype(jnp.int32) * w_q.astype(jnp.int32), axis=-1)
    return dict(y8=y8, dcim=dcim, adc_code=code, a_real=a_real, a_ideal=a_ideal,
                exact=exact)


# ---------------------------------------------------------------------------
# Fast path, matmul-ized (the GEMM-shaped formulation of hybrid_mac_fast)
# ---------------------------------------------------------------------------
#
# hybrid_mac_fast applied to a broadcast (M,1,C,L) x (1,N,C,L) pair
# materializes O(M*N*C*L) intermediates elementwise -- memory-bound.  Every
# per-chunk quantity it needs is a sum over L of per-element products, so
# each is ONE batched (C,M,L)x(C,L,N) matmul instead:
#
#   exact_c    = x_c . w_c                         (signed int dot)
#   dcim_c     = sum_j xj_c . (sum_k 2^(j+k)/2^11 * wk_c)   (signed planes,
#                one dot per distinct x bit-plane -- 2 for the top-3 split)
#   a_ideal_c  = exact_c - 2^11 * dcim_c
#   |acim|_c   = |x|_c . |w|_c - 2^11 * dcim_mag_c (unsigned planes)
#
# The dots run in float32: every contraction is a sum of <= acc_len
# products of 7-bit magnitudes (< 2^24), so float32 accumulation is exact
# and the result is bit-identical to the broadcast formulation -- while the
# MXU / vector FMA units do the work.  The optimization_barrier keeps XLA
# from fusing the operand prep into the GEMM loops (which knocks the CPU
# backend off its fast GEMM path).  This is the default GEMM hot path.


_CHUNK_BLOCK = 16  # ADC conversions processed per scan step (cache-sized)
_SKINNY_M = 16     # at/below this M the scan collapses to one step (decode)
_UNROLL_BLOCKS = 4  # chunk loops at/below this length unroll (no while-op)


def _dcim_by_j(cfg: CCIMConfig) -> dict:
    """dcim_products grouped by the x bit-plane index j (insertion order)."""
    by_j: dict = {}
    for j, k in cfg.dcim_products:
        by_j.setdefault(j, []).append(k)
    return by_j


def fold_dcim_planes(wq: Array, cfg: CCIMConfig = DEFAULT_CONFIG) -> list:
    """Folded signed DCIM planes of integer weights, one per distinct j.

    Plane_j = sign(w) * sum_{k in ks(j)} (2^(j+k)/dcim_lsb) * bit_k(|w|):
    the k-planes of w fold into a single weighted plane per x bit-plane
    (dcim = x6 . (2*w6 + w5) + x5 . w6 for the top-3 split; values fit
    int8).  The ONE definition of the fold -- the fast GEMM, the Pallas
    prepacked kernels and engine packing all consume it.
    """
    sw, mw = split_sign_mag(wq)
    planes = []
    for j, ks in _dcim_by_j(cfg).items():
        wsum = jnp.zeros_like(mw)
        for k in ks:
            wsum = wsum + ((mw >> k) & 1) * ((1 << (j + k)) // cfg.dcim_lsb)
        planes.append(sw * wsum)
    return planes


def fast_gemm_weight_ops(
    wq: Array,                       # (C, L, N) ints in [-127, 127]
    cfg: CCIMConfig = DEFAULT_CONFIG,
) -> Tuple[Array, Array]:
    """Weight-side operand prep for the fast GEMM (the weight-stationary
    half of the dataflow -- computable ONCE per weight matrix).

    Returns (wf, w_planes): the float copy of the chunked weights and the
    folded DCIM planes as ONE float32 (C, J*L, N) array -- the per-j
    planes concatenate along L, so the whole DCIM term is a single
    batched dot against the matching concatenated x planes (decode-shaped
    calls are launch-bound: one dot instead of J).  Planes carry the
    weight sign; their abs() is the magnitude plane the noisy path needs.
    """
    wf = wq.astype(jnp.float32)
    planes = [p.astype(jnp.float32) for p in fold_dcim_planes(wq, cfg)]
    C, L, N = wq.shape
    w_pl = (jnp.concatenate(planes, axis=1) if planes
            else jnp.zeros((C, 0, N), jnp.float32))
    return wf, w_pl


def hybrid_mac_fast_gemm(
    xq: Array,                       # (M, C, L) ints in [-127, 127]
    wq: Array,                       # (C, L, N) ints in [-127, 127]
    noise_key: Optional[Array],
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_segments: Optional[Tuple[int, ...]] = None,
) -> Array:
    """Chunked fast-path GEMM; returns sum_c y8_c as (M, N) int32 (unscaled).

    Bit-identical (including the noise draw) to summing hybrid_mac_fast's
    y8 over the (M,1,C,L) x (1,N,C,L) broadcast of the same operands.
    """
    wf, w_pl = fast_gemm_weight_ops(wq, cfg)
    return hybrid_mac_fast_gemm_prepacked(xq, wf, w_pl, noise_key, cfg,
                                          noise_segments=noise_segments)


def _fast_gemm_noise(noise_key, M: int, N: int, C: int,
                     noise_segments: Optional[Tuple[int, ...]]) -> Array:
    """The fast path's (C, M, N) mismatch/comparator noise draw.

    Drawn in the broadcast path's (M, N, C) layout, then re-laid-out, so
    noisy results stay bit-identical to hybrid_mac_fast.  For a fused
    projection group (see models.layers._dense_group) ``noise_key`` is a
    tuple of per-segment keys and ``noise_segments`` the per-segment N
    sizes: each segment draws from ITS OWN stream -- exactly the draw the
    unfused per-projection call would make -- and the draws concatenate
    along N, so fusion stays bit-identical even under analog noise.
    """
    if noise_segments is not None:
        assert len(noise_segments) == len(noise_key), (
            noise_segments, len(noise_key))
        assert sum(noise_segments) == N, (noise_segments, N)
        draw = jnp.concatenate(
            [jax.random.normal(k, (M, n, C))
             for k, n in zip(noise_key, noise_segments)], axis=1)
    else:
        draw = jax.random.normal(noise_key, (M, N, C))
    return jnp.transpose(draw, (2, 0, 1))


def hybrid_mac_fast_gemm_prepacked(
    xq: Array,                       # (M, C, L) ints in [-127, 127]
    wf: Array,                       # (C, L, N) float32 weight copy
    w_pl: Array,                     # (C, J*L, N) concatenated folded planes
    noise_key: Optional[Array],
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_segments: Optional[Tuple[int, ...]] = None,
    chunk_block: Optional[int] = None,
) -> Array:
    """Fast-path GEMM on prepacked weight operands (see fast_gemm_weight_ops).

    Only activation-side quantities are derived here -- the weight side
    streams from storage exactly as bit-cells do in the silicon macro.
    The chunk axis is processed ``chunk_block`` conversions at a time
    inside a scan, so the (Cb, M, N) partials stay cache-resident instead
    of streaming O(C*M*N) intermediates through memory.  Noise-free runs
    need exactly TWO batched dots per step: the exact dot, plus one dot
    of the L-concatenated x bit-planes against the L-concatenated folded
    weight planes (bit-identical to per-j dots -- every partial is an
    exact integer in float32); the magnitude dots feeding the matched
    variance exist only when a noise_key is given.

    ``chunk_block`` is a pure scheduling knob: partials are summed in
    int32, so ANY block size gives bit-identical results.  None consults
    the persisted tuning cache (kernels.ccim_matmul.autotune) at trace
    time, falling back to one single step for skinny (decode-shaped) M --
    a scan over tiny (cb, M, L) x (cb, L, N) batched GEMMs is pure
    dispatch overhead when the (C, M, N) partials already fit in cache.
    At skinny M the single-step path also drops the chunk-axis blocking
    machinery and the operand-prep barrier entirely: decode is bound by
    kernel-launch count, and fusing the tiny prep/epilogue chains is a
    win there (the barrier exists to protect the LARGE-shape GEMM loops).
    """
    M, C, L = xq.shape
    sx, mx = split_sign_mag(xq)
    xT = lambda v: jnp.transpose(v, (1, 0, 2))              # -> (C, M, L)
    xf = xT(xq).astype(jnp.float32)
    sxf, mxT = xT(sx).astype(jnp.float32), xT(mx)

    # one x bit-plane per distinct j, concatenated along L to pair with
    # the (C, J*L, N) folded weight planes in ONE batched dot
    x_pl, xm_pl = [], []
    for j in _dcim_by_j(cfg):
        xbit = ((mxT >> j) & 1).astype(jnp.float32)
        x_pl.append(sxf * xbit)
        xm_pl.append(xbit)
    n_j = len(x_pl)
    xcat = (jnp.concatenate(x_pl, axis=-1) if n_j
            else jnp.zeros((C, M, 0), jnp.float32))

    noisy = noise_key is not None
    ops = [xf, wf, xcat, w_pl]
    if noisy:
        # |folded signed plane| == the magnitude plane (the fold weights
        # are non-negative), so the mags need no separate storage
        xmcat = (jnp.concatenate(xm_pl, axis=-1) if n_j
                 else jnp.zeros((C, M, 0), jnp.float32))
        ops += [jnp.abs(xf), jnp.abs(wf), xmcat, jnp.abs(w_pl)]
        ops.append(_fast_gemm_noise(noise_key, M, wf.shape[-1], C,
                                    noise_segments))

    if chunk_block is None:
        from ..kernels.ccim_matmul.autotune import tuned_chunk_block
        chunk_block = tuned_chunk_block(M, C, wf.shape[-1], cfg.acc_len)
    cb = min(chunk_block, C)
    n_blk = (C + cb - 1) // cb

    if M > _SKINNY_M:
        # barrier: keep XLA from fusing operand prep into the GEMM loops
        # (the CPU backend falls off its fast GEMM path otherwise).  At
        # skinny M the GEMMs are launch-bound, not loop-bound -- fusing
        # the tiny prep chains is strictly better, so no barrier there.
        ops = list(jax.lax.optimization_barrier(tuple(ops)))

    dyn_var = (cfg.comparator_noise_lsb * cfg.dcim_lsb) ** 2
    lsb, half = float(cfg.dcim_lsb), cfg.adc_half_range
    # telemetry tap (obs/taps.py): count ADC codes the clip saturates.
    # Trace-time flag -- with no collector open (telemetry off) the
    # lowered program is unchanged
    tap_clip = taps.active()
    # fault injection (resilience/faults.py): same static-flag contract.
    # Drift perturbs only the ANALOG quantities -- the a_real partial
    # before conversion and the SAR conversion itself -- never the exact
    # DCIM adder, matching where the physics lives.  Terms are severity-
    # scaled by the armed model's clock, which may be a traced loop
    # counter: one executable covers the whole drift schedule.
    fault_on = rfaults.active()
    if fault_on:
        f_gain, f_off, f_adc_off, f_scale = rfaults.epilogue_terms(
            wf.shape[-1])
        half_eff = jnp.maximum(1.0, jnp.floor(half * f_scale))

    def step(acc, inp, bmask=None):
        if noisy:
            bxf, bwf, bxc, bwc, bmx, bmw, bxmc, bwmc, bnoise = inp
        else:
            bxf, bwf, bxc, bwc = inp
        # float32 GEMMs and epilogue are exact: every value is an integer
        # well below 2^24 (|chunk dot| <= acc_len * 127^2)
        a_real = jnp.matmul(bxf, bwf)                       # (cb, M, N)
        dcim = jnp.matmul(bxc, bwc) if n_j else jnp.zeros_like(a_real)
        a_real = a_real - dcim * lsb                        # = ideal ACIM
        if noisy:
            a_mag = jnp.matmul(bmx, bmw) - lsb * (
                jnp.matmul(bxmc, bwmc) if n_j else 0.0)
            var = cfg.sigma_unit**2 * cfg.fast_noise_correction * a_mag
            a_real = a_real + jnp.sqrt(var + dyn_var) * bnoise
        if fault_on:
            # capacitor-array drift: per-column gain/offset on the analog
            # partial, then ADC conversion offset and clip escalation
            a_real = a_real * f_gain + f_off * lsb
            raw = jnp.floor(a_real / lsb + 0.5 + f_adc_off)
            code = jnp.clip(raw, -half_eff, half_eff - 1)
        else:
            raw = jnp.floor(a_real / lsb + 0.5)
            code = jnp.clip(raw, -half, half - 1)
        y8 = (dcim + code).astype(jnp.int32)
        if bmask is not None:
            y8 = y8 * bmask[:, None, None]
        clip = None
        if tap_clip:
            lo, hi = (-half_eff, half_eff - 1) if fault_on else \
                (-half, half - 1)
            over = ((raw < lo) | (raw > hi)).astype(jnp.int32)
            if bmask is not None:
                over = over * bmask[:, None, None]    # phantom chunks
            clip = jnp.sum(over)
        return acc + jnp.sum(y8, axis=0), clip

    acc0 = jnp.zeros((M, wf.shape[-1]), jnp.int32)
    if n_blk == 1:
        # single step (the decode shape): no chunk-axis padding, blocking
        # reshapes or phantom-chunk mask -- the step runs on the raw ops
        out, clip = step(acc0, tuple(ops))
        if tap_clip:
            taps.emit("adc_clip", clip)
        return out

    # pad the chunk axis to the scan block; phantom chunks are masked so
    # the noisy path sees exactly C conversions, as in silicon
    pad = n_blk * cb - C
    mask = jnp.ones((C,), jnp.int32)
    blk = lambda v: jnp.pad(v, ((0, pad),) + ((0, 0),) * (v.ndim - 1)).reshape(
        n_blk, cb, *v.shape[1:]
    )
    xs = jax.tree_util.tree_map(blk, tuple(ops))
    bmasks = blk(mask)
    if n_blk <= _UNROLL_BLOCKS:
        # short chunk loops unroll: lax.scan lowers to a while-op whose
        # loop-carry copies and trip machinery cost more than the math at
        # decode shapes (int32 partial sums -- order-identical to the scan)
        acc = acc0
        clip = jnp.zeros((), jnp.int32)
        for i in range(n_blk):
            acc, c = step(acc, jax.tree_util.tree_map(lambda v: v[i], xs),
                          bmasks[i])
            if tap_clip:
                clip = clip + c
        if tap_clip:
            taps.emit("adc_clip", clip)
        return acc
    out, clips = jax.lax.scan(lambda a, i: step(a, i[:-1], i[-1]), acc0,
                              xs + (bmasks,))
    if tap_clip:
        taps.emit("adc_clip", jnp.sum(clips))
    return out


# ---------------------------------------------------------------------------
# Macro-tiled integer matmul (the GEMM engine built from conversions)
# ---------------------------------------------------------------------------


def _pad_to_chunks(k: int, acc_len: int) -> int:
    return (k + acc_len - 1) // acc_len


def _kernel_numerics_match(cfg: CCIMConfig) -> bool:
    """True when ``cfg`` matches the constants the Pallas kernels hardcode
    (prototype accumulate length, SMF width, top-3 DCIM split, 7b ADC)."""
    d = DEFAULT_CONFIG
    return (cfg.acc_len == d.acc_len and cfg.n_mag_bits == d.n_mag_bits
            and cfg.dcim_products == d.dcim_products
            and cfg.adc_bits == d.adc_bits)


def cim_matmul_int(
    x_q: Array,
    w_q: Array,
    macro: Optional[MacroInstance],
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    fidelity: str = "fast",
    *,
    use_pallas: Optional[bool] = None,
    noise_segments: Optional[Tuple[int, ...]] = None,
) -> Array:
    """Integer GEMM through the macro:  (M,K) @ (K,N) -> (M,N) int64.

    K is tiled into acc_len-element chunks; each chunk is one ADC conversion
    producing an 8-bit partial, accumulated digitally at weight 2^11 --
    exactly how a compiler would tile a GEMM onto a bank of these macros.

    fidelity:
      'fast'            matmul-ized moment-matched path (the default hot path)
      'fast_broadcast'  legacy elementwise-broadcast fast path (reference)
      'bit_true'        per-bit-product oracle with the fabricated mismatch
      'exact'           full-precision integer dot (no macro arithmetic)

    use_pallas: route noise-free 'fast' GEMMs through the Pallas TPU kernel
    (kernels.ccim_matmul -- identical ideal-analog numerics).  None = auto
    (only on a TPU backend, with defaults-config numerics).

    ``w_q`` may be a ``engine.PackedCimWeights`` (weight-stationary
    execution: quantize/decompose once, serve many) -- bit-identical to
    passing the raw integer weights.
    """
    from .engine import PackedCimWeights, packed_cim_matmul_int
    if isinstance(w_q, PackedCimWeights):
        return packed_cim_matmul_int(x_q, w_q, macro, cfg, noise_key,
                                     fidelity, use_pallas=use_pallas,
                                     noise_segments=noise_segments)
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (K, K2)
    if noise_segments is not None and fidelity not in ("fast", "exact"):
        raise ValueError(
            "per-segment noise streams (fused projection groups) are only "
            f"defined for the 'fast'/'exact' fidelities, got {fidelity!r}")
    # an armed fault model (resilience/faults) lives in the XLA epilogue
    # only -- the Pallas kernel models the nominal macro
    if (fidelity == "fast" and noise_key is None
            and _kernel_numerics_match(cfg) and not rfaults.active()):
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        if use_pallas:
            from ..kernels.ccim_matmul import ops as _kops
            return _kops.ccim_matmul_int(x_q, w_q, use_pallas=True)
    C = _pad_to_chunks(K, cfg.acc_len)
    pad = C * cfg.acc_len - K
    xq = jnp.pad(x_q, ((0, 0), (0, pad)))
    wq = jnp.pad(w_q, ((0, pad), (0, 0)))
    xq = xq.reshape(M, C, cfg.acc_len)              # (M,C,L)
    wq = wq.reshape(C, cfg.acc_len, N)              # (C,L,N)

    if fidelity == "fast":
        # per-conversion partials are accumulated digitally inside the scan
        return hybrid_mac_fast_gemm(xq, wq, noise_key, cfg,
                                    noise_segments) * cfg.dcim_lsb
    elif fidelity == "fast_broadcast":
        xc = xq[:, None, :, :]                      # (M,1,C,L)
        wc = jnp.transpose(wq, (2, 0, 1))[None]     # (1,N,C,L)
        out = hybrid_mac_fast(xc, wc, noise_key, cfg)
    elif fidelity == "bit_true":
        assert macro is not None
        xc = xq[:, None, :, :]
        wc = jnp.transpose(wq, (2, 0, 1))[None]
        out = hybrid_mac_bit_true(xc, wc, macro, cfg, noise_key)
    elif fidelity == "exact":
        return jnp.einsum("mcl,cln->mn", xq.astype(jnp.int32), wq.astype(jnp.int32))
    else:
        raise ValueError(fidelity)
    # digital accumulation of per-conversion partials, each worth 2^11
    return jnp.sum(out["y8"].astype(jnp.int32), axis=-1) * cfg.dcim_lsb


# ---------------------------------------------------------------------------
# Float-in/float-out CIM linear (quantize -> macro GEMM -> dequantize)
# ---------------------------------------------------------------------------


def cim_matmul(
    x: Array,
    w: Array,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    macro: Optional[MacroInstance] = None,
    fidelity: str = "fast",
    per_channel: bool = True,
    use_pallas: Optional[bool] = None,
    noise_segments: Optional[Tuple[int, ...]] = None,
) -> Array:
    """float (M,K) @ (K,N) through the emulated macro, dequantized.

    ``w`` may be a ``engine.PackedCimWeights``; activation quantization
    then runs per call while the weight conditioning is served prepacked.
    """
    from .engine import PackedCimWeights, packed_cim_matmul
    if isinstance(w, PackedCimWeights):
        return packed_cim_matmul(x, w, cfg, noise_key=noise_key, macro=macro,
                                 fidelity=fidelity, use_pallas=use_pallas,
                                 noise_segments=noise_segments)
    sx = smf_scale(x, axis=-1, keepdims=True, cfg=cfg)          # per row
    sw = (
        smf_scale(w, axis=0, keepdims=True, cfg=cfg)
        if per_channel
        else smf_scale(w, cfg=cfg)
    )
    xq = quantize_smf(x, sx, cfg)
    wq = quantize_smf(w, sw, cfg)
    y_int = cim_matmul_int(xq, wq, macro, cfg, noise_key, fidelity,
                           use_pallas=use_pallas,
                           noise_segments=noise_segments)
    return y_int.astype(jnp.float32) * sx * jnp.reshape(sw, (1, -1))


def contribution_table(cfg: CCIMConfig = DEFAULT_CONFIG) -> np.ndarray:
    """Fig. 2 analysis: fractional contribution of each (j,k) bit product."""
    nb = cfg.n_mag_bits
    w = np.array([[2.0 ** (j + k) for k in range(nb)] for j in range(nb)])
    return w / w.sum()
