"""Complex MAC on the hybrid macro: the paper's headline feature.

The complex bit-cell co-locates Re and Im of each weight in the same 6T
array, so one weight residency serves all four real sub-MACs of

    (a + bi)(c + di) = (ac - bd) + (ad + bc)i

and the Re / Im outputs are produced in parallel (one array pass).  The
compared baselines (see baselines.py / costmodel.py):

  (a) duplicated-weight C-CIM [3]: two weight copies, parallel, 1.5x area;
  (b) sequential C-CIM: one copy, 2.2x latency, extra orchestration logic.

Numerically all three produce the same *kind* of result (4 real hybrid
MACs); they differ in cost and in error correlation (duplicated weights
see two independent mismatch draws).  This module implements the
*this-work* dataflow.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import ccim
from .ccim import CCIMConfig, DEFAULT_CONFIG, MacroInstance
from .engine import PackedComplexCimWeights

Array = jax.Array


def complex_cim_matmul_int(
    x_re: Array, x_im: Array,            # (M, K) ints in [-127,127]
    w_re, w_im=None,                     # (K, N) ints -- ONE co-located copy
    macro: Optional[MacroInstance] = None,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    fidelity: str = "fast",
    *,
    use_pallas: Optional[bool] = None,
):
    """Integer complex GEMM; returns (y_re, y_im) int64 at scale 2^11.

    Noise-free 'fast' GEMMs route to the fused single-pass Pallas kernel
    (kernels.ccim_complex): one weight-tile residency serves all four real
    sub-MACs and emits Re/Im together, as in the silicon.  use_pallas=None
    means auto (TPU backend with defaults-config numerics only); other
    fidelities / noisy runs fall back to four macro GEMM passes.

    ``w_re`` may be a ``engine.PackedComplexCimWeights`` (then ``w_im``
    must be omitted): the co-located pair is packed once and served from
    storage -- bit-identical to passing the raw integer pair.
    """
    packed = w_re if isinstance(w_re, PackedComplexCimWeights) else None
    if packed is not None:
        assert w_im is None, "packed operand carries both Re and Im"
    else:
        assert w_im is not None
    if (fidelity == "fast" and noise_key is None
            and ccim._kernel_numerics_match(cfg)):
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        if use_pallas:
            if packed is not None:
                from ..kernels.ccim_complex import (
                    ccim_complex_matmul_int_prepacked)
                re, im = packed.re, packed.im
                return ccim_complex_matmul_int_prepacked(
                    x_re, x_im, re.pallas_w, im.pallas_w,
                    re.pallas_planes[0], re.pallas_planes[1],
                    im.pallas_planes[0], im.pallas_planes[1],
                    k_dim=re.k_dim, n_dim=re.n_dim, use_pallas=True)
            from ..kernels.ccim_complex import ccim_complex_matmul_int
            return ccim_complex_matmul_int(x_re, x_im, w_re, w_im,
                                           use_pallas=True)
    keys = (None,) * 4
    if noise_key is not None:
        keys = jax.random.split(noise_key, 4)
    if packed is not None:
        w_re, w_im = packed.re, packed.im  # cim_matmul_int takes packed too
    mm = lambda a, b, k: ccim.cim_matmul_int(a, b, macro, cfg, k, fidelity,
                                             use_pallas=use_pallas)
    # four real sub-MACs sharing the same weight arrays (no duplication)
    ac = mm(x_re, w_re, keys[0])
    bd = mm(x_im, w_im, keys[1])
    ad = mm(x_re, w_im, keys[2])
    bc = mm(x_im, w_re, keys[3])
    return ac - bd, ad + bc


def complex_cim_matmul(
    x: Array,                            # (M, K) complex
    w,                                   # (K, N) complex, or packed pair
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    macro: Optional[MacroInstance] = None,
    fidelity: str = "fast",
    use_pallas: Optional[bool] = None,
) -> Array:
    """Float complex (M,K) @ (K,N) through the macro, dequantized.

    Re and Im of each operand share one scale (they share the array's
    full-scale), as in the silicon where both live on the same bitlines.
    ``w`` may be a ``engine.PackedComplexCimWeights`` from
    ``pack_complex_cim_weights`` -- bit-identical, weight conditioning
    amortized across calls.
    """
    xr, xi = jnp.real(x), jnp.imag(x)
    sx = ccim.smf_scale(jnp.maximum(jnp.abs(xr), jnp.abs(xi)), axis=-1,
                        keepdims=True, cfg=cfg)
    q = lambda v, s: ccim.quantize_smf(v, s, cfg)
    if isinstance(w, PackedComplexCimWeights):
        yr, yi = complex_cim_matmul_int(
            q(xr, sx), q(xi, sx), w, None, macro, cfg, noise_key, fidelity,
            use_pallas=use_pallas,
        )
        sw = w.re.scale
    else:
        wr, wi = jnp.real(w), jnp.imag(w)
        sw = ccim.smf_scale(jnp.maximum(jnp.abs(wr), jnp.abs(wi)), axis=0,
                            keepdims=True, cfg=cfg)
        yr, yi = complex_cim_matmul_int(
            q(xr, sx), q(xi, sx), q(wr, sw), q(wi, sw), macro, cfg, noise_key,
            fidelity, use_pallas=use_pallas,
        )
    scale = sx * jnp.reshape(sw, (1, -1))
    return (yr * scale + 1j * (yi * scale)).astype(jnp.complex64)


def complex_mac_reference(x: Array, w: Array) -> Array:
    """fp32 software oracle (the paper's comparison target in Fig. S3)."""
    return x @ w
