# The paper's primary contribution: bit-true hybrid digital/analog
# complex-CIM macro model + differentiable CIM execution mode + cost model.
from .ccim import (  # noqa: F401
    CCIMConfig,
    DEFAULT_CONFIG,
    MacroInstance,
    bit_planes,
    cim_matmul,
    cim_matmul_int,
    contribution_table,
    fabricate,
    hybrid_mac_bit_true,
    hybrid_mac_fast,
    hybrid_mac_fast_gemm,
    hybrid_mac_ideal,
    ideal_macro,
    quantize_smf,
    sar_adc,
    smf_scale,
    split_sign_mag,
)
from .complex_mac import (  # noqa: F401
    complex_cim_matmul,
    complex_cim_matmul_int,
    complex_mac_reference,
)
from .engine import (  # noqa: F401
    CimEngine,
    FusedPackedCimWeights,
    PackedCimWeights,
    PackedComplexCimWeights,
    pack_cim_weights,
    pack_complex_cim_weights,
    pack_compatible,
    pack_quantized_cim_weights,
    packed_cim_matmul,
    packed_cim_matmul_int,
)
from .qat import cim_linear, cim_linear_packed, maybe_cim_linear  # noqa: F401
from . import baselines, costmodel  # noqa: F401
