"""Differentiable CIM execution mode (straight-through estimator).

``cim_linear`` is the drop-in replacement for ``x @ w`` used by the model
zoo when a config enables CIM execution.  Forward runs the emulated macro
(fast fidelity by default -- exact DCIM ints + moment-matched analog error
+ ADC quantization); backward is the straight-through estimator, so QAT
and LoRA-style error-recovery finetuning both work.

The noise key is threaded explicitly: deterministic under jit, different
per call-site/step if the caller splits keys (as train loops do).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import taps
from .ccim import CCIMConfig, DEFAULT_CONFIG, cim_matmul
from .engine import PackedCimWeights, packed_cim_matmul

Array = jax.Array


def _cim_linear_impl(x, w, noise_key, cfg, fidelity, use_pallas,
                     noise_segments):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = cim_matmul(x2.astype(jnp.float32), w.astype(jnp.float32), cfg,
                   noise_key=noise_key, fidelity=fidelity,
                   use_pallas=use_pallas, noise_segments=noise_segments)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _cim_linear_ste(x: Array, w: Array, noise_key: Optional[Array],
                    cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
                    use_pallas: Optional[bool] = None,
                    noise_segments: Optional[tuple] = None) -> Array:
    return _cim_linear_impl(x, w, noise_key, cfg, fidelity, use_pallas,
                            noise_segments)


def _fwd(x, w, noise_key, cfg, fidelity, use_pallas, noise_segments):
    return (_cim_linear_impl(x, w, noise_key, cfg, fidelity, use_pallas,
                             noise_segments), (x, w))


def _bwd(cfg, fidelity, use_pallas, noise_segments, res, g):
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w).astype(x.dtype)
    gw = jnp.einsum("...k,...n->kn", x, g).astype(w.dtype)
    return gx, gw, None


_cim_linear_ste.defvjp(_fwd, _bwd)


def cim_linear(x: Array, w: Array, noise_key: Optional[Array],
               cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
               use_pallas: Optional[bool] = None,
               noise_segments: Optional[tuple] = None) -> Array:
    """(..., K) @ (K, N) through the macro, STE gradients.

    use_pallas routes noise-free 'fast' forwards through the Pallas TPU
    kernel (None = auto: only on a TPU backend).  ``noise_segments``
    (static) with a tuple of keys as ``noise_key`` draws per-segment
    noise streams for a fused projection group (models.layers).

    With a telemetry tap collector open (obs/taps.py) the primal runs
    WITHOUT the custom_vjp wrapper: custom_vjp traces its primal in a
    sub-trace, so tap values emitted inside it would leak out as foreign
    tracers.  The primal math is the same function either way, and the
    serving loop (the only taps user) never differentiates.
    """
    if taps.active():
        return _cim_linear_impl(x, w, noise_key, cfg, fidelity, use_pallas,
                                noise_segments)
    return _cim_linear_ste(x, w, noise_key, cfg, fidelity, use_pallas,
                           noise_segments)


# ---------------------------------------------------------------------------
# Packed-weight STE overload (weight-stationary serving / error-recovery
# finetuning of activations around frozen array contents)
# ---------------------------------------------------------------------------


def _zero_cotangent(tree):
    """Structure-matching zero cotangent: float0 for integer leaves (the
    packed bit-cell contents are not differentiable), zeros elsewhere."""
    def z(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return np.zeros(leaf.shape, jax.dtypes.float0)
        return jnp.zeros_like(leaf)
    return jax.tree.map(z, tree)


def _cim_linear_packed_impl(x, packed, noise_key, cfg, fidelity, use_pallas,
                            noise_segments):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = packed_cim_matmul(x2.astype(jnp.float32), packed, cfg,
                          noise_key=noise_key, fidelity=fidelity,
                          use_pallas=use_pallas,
                          noise_segments=noise_segments)
    return y.reshape(*lead, packed.n_dim).astype(x.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _cim_linear_packed_ste(x: Array, packed: PackedCimWeights,
                           noise_key: Optional[Array],
                           cfg: CCIMConfig = DEFAULT_CONFIG,
                           fidelity: str = "fast",
                           use_pallas: Optional[bool] = None,
                           noise_segments: Optional[tuple] = None) -> Array:
    return _cim_linear_packed_impl(x, packed, noise_key, cfg, fidelity,
                                   use_pallas, noise_segments)


def _fwd_packed(x, packed, noise_key, cfg, fidelity, use_pallas,
                noise_segments):
    y = _cim_linear_packed_impl(x, packed, noise_key, cfg, fidelity,
                                use_pallas, noise_segments)
    return y, (x, packed)


def _bwd_packed(cfg, fidelity, use_pallas, noise_segments, res, g):
    x, packed = res
    w_deq = packed.dequantized()
    gx = jnp.einsum("...n,kn->...k", g, w_deq).astype(x.dtype)
    return gx, _zero_cotangent(packed), None


_cim_linear_packed_ste.defvjp(_fwd_packed, _bwd_packed)


def cim_linear_packed(x: Array, packed: PackedCimWeights,
                      noise_key: Optional[Array],
                      cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
                      use_pallas: Optional[bool] = None,
                      noise_segments: Optional[tuple] = None) -> Array:
    """(..., K) @ packed -> (..., N) through the macro, STE gradients.

    Forward is bit-identical to ``cim_linear`` on the float weights the
    pack was built from; backward uses the DEQUANTIZED packed weights
    (sign*mag*scale) -- the gradient the activations actually see through
    the frozen array, which is what error-recovery finetuning wants.

    Like ``cim_linear``, an open tap collector routes around the
    custom_vjp wrapper so ADC-clip telemetry can escape the primal.
    """
    if taps.active():
        return _cim_linear_packed_impl(x, packed, noise_key, cfg, fidelity,
                                       use_pallas, noise_segments)
    return _cim_linear_packed_ste(x, packed, noise_key, cfg, fidelity,
                                  use_pallas, noise_segments)


def maybe_cim_linear(x: Array, w: Union[Array, PackedCimWeights],
                     cim_cfg: Optional[CCIMConfig],
                     noise_key: Optional[Array] = None) -> Array:
    """Dense matmul unless a CIM config is provided (the model-zoo hook).
    Packed weights always execute on the macro (they ARE array contents)."""
    if isinstance(w, PackedCimWeights):
        return cim_linear_packed(x, w, noise_key, cim_cfg or DEFAULT_CONFIG,
                                 "fast")
    if cim_cfg is None:
        return x @ w
    return cim_linear(x, w, noise_key, cim_cfg, "fast")
