"""Differentiable CIM execution mode (straight-through estimator).

``cim_linear`` is the drop-in replacement for ``x @ w`` used by the model
zoo when a config enables CIM execution.  Forward runs the emulated macro
(fast fidelity by default -- exact DCIM ints + moment-matched analog error
+ ADC quantization); backward is the straight-through estimator, so QAT
and LoRA-style error-recovery finetuning both work.

The noise key is threaded explicitly: deterministic under jit, different
per call-site/step if the caller splits keys (as train loops do).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .ccim import CCIMConfig, DEFAULT_CONFIG, cim_matmul

Array = jax.Array


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def cim_linear(x: Array, w: Array, noise_key: Optional[Array],
               cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
               use_pallas: Optional[bool] = None) -> Array:
    """(..., K) @ (K, N) through the macro, STE gradients.

    use_pallas routes noise-free 'fast' forwards through the Pallas TPU
    kernel (None = auto: only on a TPU backend).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = cim_matmul(x2.astype(jnp.float32), w.astype(jnp.float32), cfg,
                   noise_key=noise_key, fidelity=fidelity,
                   use_pallas=use_pallas)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)


def _fwd(x, w, noise_key, cfg, fidelity, use_pallas):
    return cim_linear(x, w, noise_key, cfg, fidelity, use_pallas), (x, w)


def _bwd(cfg, fidelity, use_pallas, res, g):
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w).astype(x.dtype)
    gw = jnp.einsum("...k,...n->kn", x, g).astype(w.dtype)
    return gx, gw, None


cim_linear.defvjp(_fwd, _bwd)


def maybe_cim_linear(x: Array, w: Array, cim_cfg: Optional[CCIMConfig],
                     noise_key: Optional[Array] = None) -> Array:
    """Dense matmul unless a CIM config is provided (the model-zoo hook)."""
    if cim_cfg is None:
        return x @ w
    return cim_linear(x, w, noise_key, cim_cfg, "fast")
