"""Differentiable CIM execution mode (straight-through estimator).

``cim_linear`` is the drop-in replacement for ``x @ w`` used by the model
zoo when a config enables CIM execution.  Forward runs the emulated macro
(fast fidelity by default -- exact DCIM ints + moment-matched analog error
+ ADC quantization); backward is the straight-through estimator, so QAT
and LoRA-style error-recovery finetuning both work.

The noise key is threaded explicitly: deterministic under jit, different
per call-site/step if the caller splits keys (as train loops do).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .ccim import CCIMConfig, DEFAULT_CONFIG, cim_matmul
from .engine import PackedCimWeights, packed_cim_matmul

Array = jax.Array


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def cim_linear(x: Array, w: Array, noise_key: Optional[Array],
               cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
               use_pallas: Optional[bool] = None,
               noise_segments: Optional[tuple] = None) -> Array:
    """(..., K) @ (K, N) through the macro, STE gradients.

    use_pallas routes noise-free 'fast' forwards through the Pallas TPU
    kernel (None = auto: only on a TPU backend).  ``noise_segments``
    (static) with a tuple of keys as ``noise_key`` draws per-segment
    noise streams for a fused projection group (models.layers).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = cim_matmul(x2.astype(jnp.float32), w.astype(jnp.float32), cfg,
                   noise_key=noise_key, fidelity=fidelity,
                   use_pallas=use_pallas, noise_segments=noise_segments)
    return y.reshape(*lead, w.shape[-1]).astype(x.dtype)


def _fwd(x, w, noise_key, cfg, fidelity, use_pallas, noise_segments):
    return (cim_linear(x, w, noise_key, cfg, fidelity, use_pallas,
                       noise_segments), (x, w))


def _bwd(cfg, fidelity, use_pallas, noise_segments, res, g):
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w).astype(x.dtype)
    gw = jnp.einsum("...k,...n->kn", x, g).astype(w.dtype)
    return gx, gw, None


cim_linear.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# Packed-weight STE overload (weight-stationary serving / error-recovery
# finetuning of activations around frozen array contents)
# ---------------------------------------------------------------------------


def _zero_cotangent(tree):
    """Structure-matching zero cotangent: float0 for integer leaves (the
    packed bit-cell contents are not differentiable), zeros elsewhere."""
    def z(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return np.zeros(leaf.shape, jax.dtypes.float0)
        return jnp.zeros_like(leaf)
    return jax.tree.map(z, tree)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def cim_linear_packed(x: Array, packed: PackedCimWeights,
                      noise_key: Optional[Array],
                      cfg: CCIMConfig = DEFAULT_CONFIG, fidelity: str = "fast",
                      use_pallas: Optional[bool] = None,
                      noise_segments: Optional[tuple] = None) -> Array:
    """(..., K) @ packed -> (..., N) through the macro, STE gradients.

    Forward is bit-identical to ``cim_linear`` on the float weights the
    pack was built from; backward uses the DEQUANTIZED packed weights
    (sign*mag*scale) -- the gradient the activations actually see through
    the frozen array, which is what error-recovery finetuning wants.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = packed_cim_matmul(x2.astype(jnp.float32), packed, cfg,
                          noise_key=noise_key, fidelity=fidelity,
                          use_pallas=use_pallas,
                          noise_segments=noise_segments)
    return y.reshape(*lead, packed.n_dim).astype(x.dtype)


def _fwd_packed(x, packed, noise_key, cfg, fidelity, use_pallas,
                noise_segments):
    y = cim_linear_packed(x, packed, noise_key, cfg, fidelity, use_pallas,
                          noise_segments)
    return y, (x, packed)


def _bwd_packed(cfg, fidelity, use_pallas, noise_segments, res, g):
    x, packed = res
    w_deq = packed.dequantized()
    gx = jnp.einsum("...n,kn->...k", g, w_deq).astype(x.dtype)
    return gx, _zero_cotangent(packed), None


cim_linear_packed.defvjp(_fwd_packed, _bwd_packed)


def maybe_cim_linear(x: Array, w: Union[Array, PackedCimWeights],
                     cim_cfg: Optional[CCIMConfig],
                     noise_key: Optional[Array] = None) -> Array:
    """Dense matmul unless a CIM config is provided (the model-zoo hook).
    Packed weights always execute on the macro (they ARE array contents)."""
    if isinstance(w, PackedCimWeights):
        return cim_linear_packed(x, w, noise_key, cim_cfg or DEFAULT_CONFIG,
                                 "fast")
    if cim_cfg is None:
        return x @ w
    return cim_linear(x, w, noise_key, cim_cfg, "fast")
