"""Prepacked-weight CIM execution engine: quantize/decompose once, serve many.

The silicon macro is weight-stationary: quantized signed-magnitude weights
are written into the SRAM array once and every subsequent MAC only streams
activations.  The software stack mirrors that here -- ``pack_cim_weights``
runs the full weight conditioning pipeline (per-channel SMF scale ->
integer quantization -> sign/magnitude split -> folded MSB DCIM planes ->
backend-specific layouts) ONE time, and ``packed_cim_matmul`` serves every
later call with activation-only work.  Outputs are bit-identical to the
unpacked path for every fidelity, including the noise draw: packing is a
caching transform, not an approximation.

Storage layouts carried by ``PackedCimWeights`` (all derived from the same
integer weights, each feeding one consumer):

  sign/mag        raw SMF storage, (K, N) int8 -- the bit-cell contents;
                  reconstructs w_q for the bit_true / broadcast / exact
                  fidelities (cold paths).
  gemm_w/gemm_planes
                  (C, L, N) float32 chunked copies for the matmul-ized
                  fast path (hybrid_mac_fast_gemm_prepacked): the float
                  weight copy plus one folded signed DCIM plane per
                  distinct x bit-plane j.
  pallas_w/pallas_planes
                  (Kp, Np) int8 block-padded tiles for the Pallas kernels
                  (padding is M-independent by construction, see
                  kernels.ccim_matmul.ops.pick_weight_blocks).

The trade is deliberate: ~4x the weight bytes of a bf16 matrix buys zero
per-call weight conditioning -- the same area-for-latency trade the 2D
capacitor array makes in silicon.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .ccim import (
    CCIMConfig,
    DEFAULT_CONFIG,
    MacroInstance,
    _dcim_by_j,
    _pad_to_chunks,
    cim_matmul_int,
    fold_dcim_planes,
    hybrid_mac_fast_gemm_prepacked,
    quantize_smf,
    smf_scale,
    split_sign_mag,
)
from ..resilience import faults as rfaults

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PackedCimWeights:
    """One weight matrix, conditioned once for every macro execution path.

    A registered pytree: jit/vmap/scan slice and trace through it, so a
    stack of packed projections (leading layer axis) drops straight into
    the model zoo's scanned layer stacks.  ``k_dim``/``n_dim`` ride along
    as static metadata (the padded buffers lose the logical shape).
    """

    scale: Array                      # smf_scale output: (1, N) or scalar
    sign: Array                       # (K, N) int8 in {-1, +1}
    mag: Array                        # (K, N) int8 in [0, 127]
    gemm_w: Array                     # (C, L, N) float32 chunked weights
    gemm_planes: Array                # (C, J*L, N) float32 folded planes,
                                      # L-concatenated over distinct j
    pallas_w: Array                   # (Kp, Np) int8, block-padded
    pallas_planes: Array              # (n_j, Kp, Np) int8 folded planes
    k_dim: int                        # static: logical K
    n_dim: int                        # static: logical N
    cfg: CCIMConfig                   # static: the macro config packed FOR
                                      # (plane fold + chunking are cfg-
                                      # specific; use-time mismatch errors)

    def wq(self) -> Array:
        """Reconstruct the raw integer SMF weights (cold-path fidelities)."""
        return self.sign.astype(jnp.int32) * self.mag.astype(jnp.int32)

    def dequantized(self) -> Array:
        """float32 (K, N) dequantized weights (e.g. for the STE backward)."""
        return self.wq().astype(jnp.float32) * jnp.reshape(self.scale, (1, -1))


jax.tree_util.register_dataclass(
    PackedCimWeights,
    data_fields=["scale", "sign", "mag", "gemm_w", "gemm_planes",
                 "pallas_w", "pallas_planes"],
    meta_fields=["k_dim", "n_dim", "cfg"],
)


@dataclasses.dataclass(frozen=True)
class FusedPackedCimWeights:
    """A horizontally fused projection group packed as ONE wide array.

    Several projections that consume the SAME input activation and resolve
    to the SAME deployment-plan entry (QKV, gate/up, the mamba2 input
    projections -- see models.lm.pack_cim_params) concatenate along N and
    pack as a single ``PackedCimWeights``: one activation quantization,
    one macro GEMM and one dequant serve the whole group, which is the
    decode hot path's dominant win at skinny M (7 -> ~3 GEMMs per block).

    ``seg_names``/``seg_dims`` are STATIC metadata: the leaf self-
    describes its per-segment N-offsets, so consumers split the wide
    output back into per-projection results with static slices -- bit-
    identical to the unfused calls (per-channel scales, quantization and
    the fast path's per-column arithmetic are all column-local, and noisy
    serving draws per-segment noise streams, see ccim._fast_gemm_noise).
    """

    packed: PackedCimWeights
    seg_names: Tuple[str, ...]        # static: member projection names
    seg_dims: Tuple[int, ...]         # static: per-segment logical N sizes

    @property
    def n_dim(self) -> int:
        return self.packed.n_dim


jax.tree_util.register_dataclass(
    FusedPackedCimWeights, data_fields=["packed"],
    meta_fields=["seg_names", "seg_dims"])


@dataclasses.dataclass(frozen=True)
class PackedComplexCimWeights:
    """Co-located (Re, Im) weight pair packed once, one shared full-scale.

    Mirrors the complex bit-cell: both components live in the same array
    and share the bitline full-scale, so one pack serves all four real
    sub-MACs of (a+bi)(c+di)."""

    re: PackedCimWeights
    im: PackedCimWeights


jax.tree_util.register_dataclass(
    PackedComplexCimWeights, data_fields=["re", "im"], meta_fields=[])


# ---------------------------------------------------------------------------
# Packing (the write-the-array step; run once per weight matrix)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def pack_quantized_cim_weights(
    wq: Array,                        # (K, N) ints in [-127, 127]
    scale: Array,                     # the smf_scale the ints were made with
    cfg: CCIMConfig = DEFAULT_CONFIG,
) -> PackedCimWeights:
    """Pack already-quantized integer weights (the array-write step).

    jit-compiled with ``cfg`` static: eager and traced callers share one
    fused scale/decompose pipeline, so packs are bit-identical however
    packing is invoked (eager packing used to differ in the last ulp).
    """
    from ..kernels.ccim_matmul.ops import pick_weight_blocks

    K, N = wq.shape
    sign, mag = split_sign_mag(wq)
    planes = fold_dcim_planes(wq, cfg)

    # fast-GEMM layout: K padded to whole ADC conversions, chunked (C, L, N);
    # folded planes concatenate along L into ONE (C, J*L, N) array so the
    # whole DCIM term is a single batched dot at serve time
    C = _pad_to_chunks(K, cfg.acc_len)
    pad_k = C * cfg.acc_len - K
    chunk = lambda v: jnp.pad(v, ((0, pad_k), (0, 0))).reshape(
        C, cfg.acc_len, N)
    gemm_w = chunk(wq).astype(jnp.float32)
    gemm_planes = (jnp.concatenate([chunk(p).astype(jnp.float32)
                                    for p in planes], axis=1) if planes
                   else jnp.zeros((C, 0, N), jnp.float32))

    # Pallas layout: block-padded once (M-independent by construction);
    # the pad geometry follows the config's accumulate length, and an
    # all-analog split (n_dcim_products=0) simply has zero folded planes
    _, _, Np, Kp = pick_weight_blocks(K, N, cfg.acc_len)
    blockpad = lambda v: jnp.pad(v, ((0, Kp - K), (0, Np - N))).astype(jnp.int8)
    pallas_w = blockpad(wq)
    pallas_planes = (jnp.stack([blockpad(p) for p in planes]) if planes
                     else jnp.zeros((0, Kp, Np), jnp.int8))

    return PackedCimWeights(
        scale=scale,
        sign=sign.astype(jnp.int8),
        mag=mag.astype(jnp.int8),
        gemm_w=gemm_w,
        gemm_planes=gemm_planes,
        pallas_w=pallas_w,
        pallas_planes=pallas_planes,
        k_dim=K,
        n_dim=N,
        cfg=cfg,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "per_channel"))
def pack_cim_weights(
    w: Array,                         # (K, N) float weights
    cfg: CCIMConfig = DEFAULT_CONFIG,
    per_channel: bool = True,
) -> PackedCimWeights:
    """Quantize + decompose a float weight matrix once (PTQ array write).

    Matches ``cim_matmul``'s weight conditioning exactly (same scale, same
    rounding), so packed and unpacked execution are bit-identical.
    jit-compiled by default (cfg static) -- see pack_quantized_cim_weights.
    """
    w = w.astype(jnp.float32)
    sw = (smf_scale(w, axis=0, keepdims=True, cfg=cfg) if per_channel
          else smf_scale(w, cfg=cfg))
    return pack_quantized_cim_weights(quantize_smf(w, sw, cfg), sw, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def pack_complex_cim_weights(
    w_re: Array, w_im: Array,         # (K, N) float weights
    cfg: CCIMConfig = DEFAULT_CONFIG,
) -> PackedComplexCimWeights:
    """Pack a co-located complex weight pair with one shared full-scale
    (Re and Im share the array's bitlines in silicon)."""
    w_re = w_re.astype(jnp.float32)
    w_im = w_im.astype(jnp.float32)
    sw = smf_scale(jnp.maximum(jnp.abs(w_re), jnp.abs(w_im)), axis=0,
                   keepdims=True, cfg=cfg)
    return PackedComplexCimWeights(
        re=pack_quantized_cim_weights(quantize_smf(w_re, sw, cfg), sw, cfg),
        im=pack_quantized_cim_weights(quantize_smf(w_im, sw, cfg), sw, cfg),
    )


# ---------------------------------------------------------------------------
# Packed execution (the serve-many step)
# ---------------------------------------------------------------------------


def _prepacked_kernel_supported(cfg: CCIMConfig) -> bool:
    """Configs the GENERALIZED prepacked Pallas kernel can serve: the D/A
    split, ADC width and accumulate length ride in as static meta, so any
    deployment-plan design point qualifies -- the remaining constraints
    are the int8 storage format (7 magnitude bits, folded plane values
    <= 7 for splits up to top-6) and a block-divisible accumulate length.
    """
    d = DEFAULT_CONFIG
    return (cfg.n_mag_bits == d.n_mag_bits
            and cfg.n_dcim_products <= 6
            and cfg.acc_len in (8, 16, 32, 64))


def pack_compatible(packed_cfg: CCIMConfig, cfg: CCIMConfig) -> bool:
    """True when weights packed under ``packed_cfg`` can be SERVED under
    ``cfg`` without repacking.

    Besides trivial equality, the one relaxation is an *analog subset*: a
    serving config with NO DCIM products whose quantization
    (``n_mag_bits``) and chunk geometry (``acc_len``) match the pack.
    Pack-time layout depends only on those two knobs plus the plane fold,
    and a zero-product serving config never reads the folded planes (the
    DCIM dot is skipped entirely) while ``adc_bits`` only enters the
    runtime conversion epilogue.  This is what lets a speculative DRAFT
    plan (all-analog, cheap conversions) serve the SAME packed arrays its
    hybrid VERIFY plan uses -- one pack, two speed/accuracy operating
    points, the software twin of both splits sharing every bit-cell of
    the 2D array in silicon.
    """
    if packed_cfg == cfg:
        return True
    return (cfg.n_dcim_products == 0
            and dataclasses.replace(
                cfg, n_dcim_products=packed_cfg.n_dcim_products,
                adc_bits=packed_cfg.adc_bits) == packed_cfg)


def packed_cim_matmul_int(
    x_q: Array,                       # (M, K) ints in [-127, 127]
    packed: PackedCimWeights,
    macro: Optional[MacroInstance] = None,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    fidelity: str = "fast",
    *,
    use_pallas: Optional[bool] = None,
    noise_segments: Optional[Tuple[int, ...]] = None,
    chunk_block: Optional[int] = None,
) -> Array:
    """Integer GEMM against prepacked weights; bit-identical to
    ``cim_matmul_int(x_q, packed.wq(), ...)`` for every fidelity.

    ``noise_segments`` (with a matching tuple of keys as ``noise_key``)
    draws one analog-noise stream per fused projection segment, keeping
    fused execution bit-identical to the unfused per-projection calls.
    ``chunk_block`` overrides the fast path's tuned scan block (the
    autotuner forces candidates through it; results are invariant).
    """
    M, K = x_q.shape
    assert K == packed.k_dim, (K, packed.k_dim)
    if not pack_compatible(packed.cfg, cfg):
        raise ValueError(
            "PackedCimWeights were packed for a different CCIMConfig than "
            "they are being served with (plane fold and chunk layout are "
            f"config-specific): packed for {packed.cfg}, serving {cfg}. "
            "Re-pack the weights for the serving config, or serve an "
            "all-analog subset (n_dcim_products=0, same n_mag_bits and "
            "acc_len), which never touches the folded planes.")
    # the Pallas kernel implements the NOMINAL macro only: with a fault
    # model armed (resilience/faults), the drifted conversion epilogue
    # exists solely in the XLA fast path, so route there
    if (fidelity == "fast" and noise_key is None
            and _prepacked_kernel_supported(cfg)
            and not rfaults.active()):
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        if use_pallas:
            from ..kernels.ccim_matmul.ops import ccim_matmul_int_prepacked
            x_bits = tuple(_dcim_by_j(cfg))
            # analog-subset serving of a hybrid pack: no activation bit
            # planes, so hand the kernel a zero-plane weight operand
            planes = (packed.pallas_planes if packed.cfg == cfg
                      else packed.pallas_planes[:len(x_bits)])
            return ccim_matmul_int_prepacked(
                x_q, packed.pallas_w, planes,
                k_dim=packed.k_dim, n_dim=packed.n_dim,
                acc_len=cfg.acc_len, x_bits=x_bits,
                dcim_lsb=cfg.dcim_lsb, adc_bits=cfg.adc_bits,
                use_pallas=True)
    if fidelity == "fast":
        C = packed.gemm_w.shape[0]
        pad = C * cfg.acc_len - K
        xq = jnp.pad(x_q, ((0, 0), (0, pad))).reshape(M, C, cfg.acc_len)
        return hybrid_mac_fast_gemm_prepacked(
            xq, packed.gemm_w, packed.gemm_planes, noise_key, cfg,
            noise_segments=noise_segments, chunk_block=chunk_block,
        ) * cfg.dcim_lsb
    # cold-path fidelities reconstruct the raw ints (one O(K*N) multiply,
    # dwarfed by their own per-bit-product work)
    return cim_matmul_int(x_q, packed.wq(), macro, cfg, noise_key, fidelity,
                          use_pallas=use_pallas,
                          noise_segments=noise_segments)


def packed_cim_matmul(
    x: Array,                         # (M, K) float activations
    packed: PackedCimWeights,
    cfg: CCIMConfig = DEFAULT_CONFIG,
    noise_key: Optional[Array] = None,
    macro: Optional[MacroInstance] = None,
    fidelity: str = "fast",
    use_pallas: Optional[bool] = None,
    noise_segments: Optional[Tuple[int, ...]] = None,
    chunk_block: Optional[int] = None,
) -> Array:
    """float (M,K) @ packed -> (M,N): per-row activation quantization is
    the ONLY conditioning left on the hot path (weights sit in the array)."""
    sx = smf_scale(x, axis=-1, keepdims=True, cfg=cfg)
    xq = quantize_smf(x, sx, cfg)
    y_int = packed_cim_matmul_int(xq, packed, macro, cfg, noise_key, fidelity,
                                  use_pallas=use_pallas,
                                  noise_segments=noise_segments,
                                  chunk_block=chunk_block)
    return y_int.astype(jnp.float32) * sx * jnp.reshape(packed.scale, (1, -1))


# ---------------------------------------------------------------------------
# The engine handle (what model configs carry instead of a bare CCIMConfig)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CimEngine:
    """Execution-policy handle: macro config + fidelity + kernel routing.

    One engine serves both operand kinds -- ``matmul`` dispatches on
    whether the weight is raw floats or a ``PackedCimWeights`` -- so model
    code (``models.layers._dense``) stays a one-liner and serving stacks
    can swap packed weights in without touching the layers.
    """

    cfg: CCIMConfig = DEFAULT_CONFIG
    fidelity: str = "fast"
    use_pallas: Optional[bool] = None
    macro: Optional[MacroInstance] = None

    def pack(self, w: Array, per_channel: bool = True) -> PackedCimWeights:
        return pack_cim_weights(w, self.cfg, per_channel)

    def pack_complex(self, w_re: Array, w_im: Array) -> PackedComplexCimWeights:
        return pack_complex_cim_weights(w_re, w_im, self.cfg)

    def matmul(self, x: Array, w, noise_key: Optional[Array] = None,
               noise_segments: Optional[Tuple[int, ...]] = None) -> Array:
        """(..., K) @ w -> (..., N) with STE gradients; w raw, packed or a
        fused projection group (``noise_segments`` then carries the static
        per-segment N sizes matching a tuple of per-segment noise keys)."""
        from .qat import cim_linear, cim_linear_packed
        if isinstance(w, FusedPackedCimWeights):
            segs = w.seg_dims if noise_key is not None else None
            return cim_linear_packed(x, w.packed, noise_key, self.cfg,
                                     self.fidelity, self.use_pallas, segs)
        if isinstance(w, PackedCimWeights):
            return cim_linear_packed(x, w, noise_key, self.cfg, self.fidelity,
                                     self.use_pallas, noise_segments)
        return cim_linear(x, w, noise_key, self.cfg, self.fidelity,
                          self.use_pallas, noise_segments)

    def matmul_int(self, x_q: Array, w,
                   noise_key: Optional[Array] = None) -> Array:
        if isinstance(w, PackedCimWeights):
            return packed_cim_matmul_int(
                x_q, w, self.macro, self.cfg, noise_key, self.fidelity,
                use_pallas=self.use_pallas)
        return cim_matmul_int(x_q, w, self.macro, self.cfg, noise_key,
                              self.fidelity, use_pallas=self.use_pallas)
