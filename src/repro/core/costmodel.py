"""Analytic area / latency / energy model of the C-CIM macro and baselines.

Anchored constants come straight from the paper; derived quantities are
computed from structure so the benchmarks can *check* the paper's headline
ratios rather than hard-coding them:

  paper-measured:  0.0365 mm^2 active area, 64 kb, 1.80 Mb/mm^2,
                   35.0 TOPS/W, unit cap 48 aF @ 0.29 x 0.35 um,
                   7b SAR ADC (CDAC LSB = 16 C), 2.96 % UC mismatch,
                   DNL 0.33 LSB rms, VREFSR = 350 mV, VREFAD = 700 mV.
  paper-claimed:   vs best-of(dup-weight, sequential): -35 % area,
                   -54 % latency, -24 % power (Fig. S1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .ccim import CCIMConfig, DEFAULT_CONFIG

# ---------------------------------------------------------------------------
# Paper-anchored constants (28nm prototype)
# ---------------------------------------------------------------------------

MACRO_AREA_MM2 = 0.0365          # measured active area (Fig. 4/7)
MACRO_CAPACITY_BITS = 64 * 1024  # 64 kb
UNIT_CAP_F = 48e-18              # M7-M7 fringe
UNIT_CAP_AREA_UM2 = 0.29 * 0.35  # per unit cap, on M7 (over the array)
FOUNDRY_MIN_MOM_F = 2e-15        # 2 fF minimum foundry MOM (40x larger)
VREFSR = 0.35                    # V, sampling reference
VREFAD = 0.70                    # V, ADC reference (2x, balances 0x40 sample)
TOPS_PER_W_MEASURED = 35.0
N_COMPLEX_UNITS = 8
F_CLK_HZ = 100e6                 # conversion-rate assumption for latency accounting

# 28nm logic/SRAM density assumptions (public-domain ballpark, used only for
# the *relative* baseline comparison, never for headline numbers):
SRAM_6T_BIT_UM2 = 0.35           # 28nm 6T + DWL + write circuit overhead
LOGIC_GATE_UM2 = 1.0             # NAND2-equivalent incl. wiring
DCIM_GATES_PER_UNIT = 2          # custom counting logic (paper Fig. 9)
ADC_GATES = 120                  # SAR logic + comparator, per ADC
ADCS_PER_COMPLEX_UNIT = 2        # Re and Im output lanes


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    area_mm2: float
    latency_cycles_per_cmac: float   # per 16-element complex MAC, all lanes
    energy_pj_per_conv: float
    power_rel: float                 # relative power at iso-throughput

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _array_caps(cfg: CCIMConfig) -> float:
    """Total unit-cap count of one 2-D array (after split-DAC reduction)."""
    nb = cfg.n_mag_bits
    total = 0.0
    for j in range(nb):
        for k in range(nb):
            if (j, k) in cfg.dcim_products:
                continue
            units = 2.0 ** (j + k)
            if cfg.use_split_dac:
                # split-DAC: low section behind attenuation cap -> effective
                # physical units ~ sqrt of the ideal count
                units = min(units, 2.0 ** math.ceil((j + k) / 2))
            total += units
    return total


def _adc_caps(cfg: CCIMConfig) -> float:
    return cfg.adc_lsb_units * (2 ** cfg.adc_bits - 1)


E_GATE_PJ = 0.1e-3        # 28nm gate switching @ low V, pJ (0.1 fJ)
E_COMPARATOR_PJ = 0.005   # per decision
E_DRIVERS_PJ = 0.75       # WL/input drivers + VREFSR switching + clocking
                          # per conversion AT THE PROTOTYPE acc_len=16 --
                          # CALIBRATED so the derived efficiency lands at
                          # the measured 35.0 TOPS/W.  Half of it scales
                          # with the rows driven (WL/input drivers), half
                          # is fixed per conversion (clocking, refs), so
                          # non-prototype accumulate lengths amortize the
                          # fixed part -- the knob the deployment planner
                          # (repro.plan) sweeps.
_DRIVERS_ROW_FRACTION = 0.5
_PROTO_ACC_LEN = 16


def _drivers_pj(acc_len: int) -> float:
    row = E_DRIVERS_PJ * _DRIVERS_ROW_FRACTION * acc_len / _PROTO_ACC_LEN
    return row + E_DRIVERS_PJ * (1.0 - _DRIVERS_ROW_FRACTION)


def energy_per_conversion_pj(cfg: CCIMConfig = DEFAULT_CONFIG) -> Dict[str, float]:
    """CV^2-style energy accounting for one ADC conversion (one unit)."""
    c_array = _array_caps(cfg) * UNIT_CAP_F * cfg.acc_len
    c_adc = _adc_caps(cfg) * UNIT_CAP_F
    e_array = c_array * VREFSR**2 * 1e12            # pJ
    # SAR CDAC switching energy ~ C V^2 (upper bound over codes)
    e_adc = c_adc * VREFAD**2 * 1e12
    # DCIM: counting logic + adder tree, ~#bit-products * gates * E_gate
    n_dcim_ops = cfg.n_dcim_products * cfg.acc_len
    e_dcim = n_dcim_ops * 8 * E_GATE_PJ
    e_comparator = cfg.adc_bits * E_COMPARATOR_PJ
    e_drivers = _drivers_pj(cfg.acc_len)
    total = e_array + e_adc + e_dcim + e_comparator + e_drivers
    return dict(array=e_array, adc=e_adc, dcim=e_dcim,
                comparator=e_comparator, drivers=e_drivers, total=total)


def tops_per_watt(cfg: CCIMConfig = DEFAULT_CONFIG) -> float:
    """Derived energy efficiency; compare against the measured 35.0 TOPS/W.

    OPs per conversion per unit: acc_len complex MACs = acc_len * 8 real ops
    (4 mul + 4 add), with Re and Im lanes produced in parallel by 2 hybrid
    paths per complex unit (each path = 2 sub-MAC banks merged on the array).
    """
    e = energy_per_conversion_pj(cfg)
    # one complex unit: Re lane + Im lane each need 2 real-MAC conversions
    # -> 4 conversions' worth of array+ADC per 16 complex MACs
    e_cmac_pj = 4 * e["total"]
    ops = cfg.acc_len * 8.0
    return ops / e_cmac_pj  # TOPS/W == ops/pJ


def macro_area_breakdown(cfg: CCIMConfig = DEFAULT_CONFIG) -> Dict[str, float]:
    """mm^2 components of THIS WORK.  The 48aF M7-M7 fringe caps sit ABOVE
    the SRAM/DCIM/ADC stack (Fig. 4 cross-section): only cap area exceeding
    the under-layer footprint costs silicon."""
    a_sram = MACRO_CAPACITY_BITS * SRAM_6T_BIT_UM2 * 1e-6            # mm^2
    n_gates_dcim = (cfg.n_dcim_products * cfg.acc_len * DCIM_GATES_PER_UNIT
                    * 4 * N_COMPLEX_UNITS)            # 4 sub-MAC banks
    a_dcim = n_gates_dcim * LOGIC_GATE_UM2 * 1e-6
    a_adc_logic = (N_COMPLEX_UNITS * ADCS_PER_COMPLEX_UNIT * ADC_GATES
                   * LOGIC_GATE_UM2 * 1e-6)
    a_under = a_sram + a_dcim + a_adc_logic
    a_caps_m7 = (
        (_array_caps(cfg) * cfg.acc_len * 4
         + _adc_caps(cfg) * ADCS_PER_COMPLEX_UNIT)
        * N_COMPLEX_UNITS * UNIT_CAP_AREA_UM2 * 1e-6
    )
    a_caps_extra = max(0.0, a_caps_m7 - a_under)      # only overflow costs area
    a_ctrl = 0.15 * a_under                           # clocks, refs, drivers
    total = a_under + a_caps_extra + a_ctrl
    return dict(sram=a_sram, caps_extra=a_caps_extra, caps_on_m7=a_caps_m7,
                dcim=a_dcim, adc=a_adc_logic, ctrl=a_ctrl, total=total)


# ---------------------------------------------------------------------------
# The three designs of Fig. S1
# ---------------------------------------------------------------------------


def cost_this_work(cfg: CCIMConfig = DEFAULT_CONFIG) -> CostBreakdown:
    a = macro_area_breakdown(cfg)["total"]
    e = energy_per_conversion_pj(cfg)["total"]
    # Re & Im lanes in parallel, one array pass: 1 conversion latency
    return CostBreakdown(area_mm2=a, latency_cycles_per_cmac=1.0,
                         energy_pj_per_conv=4 * e, power_rel=1.0)


def cost_duplicated(cfg: CCIMConfig = DEFAULT_CONFIG) -> CostBreakdown:
    """Baseline (a) [3]: duplicate complex weights -> parallel partials.

    1.5x weight storage (W_re, W_im, and a pre-rotated copy), plus doubled
    compute banks; latency 1 pass but on 2 independent macros.
    """
    b = macro_area_breakdown(cfg)
    a = 1.5 * b["sram"] + b["caps_extra"] * 2 + b["dcim"] * 2 + b["adc"] * 2 \
        + 0.15 * (1.5 * b["sram"] + 2 * (b["dcim"] + b["adc"]))
    e = energy_per_conversion_pj(cfg)["total"]
    # extra bank burns static + duplicated write energy: ~1.3x conversion E
    return CostBreakdown(area_mm2=a, latency_cycles_per_cmac=1.0,
                         energy_pj_per_conv=4 * e * 1.32, power_rel=1.32)


def cost_sequential(cfg: CCIMConfig = DEFAULT_CONFIG) -> CostBreakdown:
    """Baseline (b): one weight copy, 4 sub-MACs sequenced (2.2x latency).

    Needs operand staging registers + orchestration FSM; partial-product
    registers add energy per pass.
    """
    b = macro_area_breakdown(cfg)
    a_extra_ctrl = 0.10 * b["sram"]
    a = b["sram"] + b["caps_extra"] + b["dcim"] + b["adc"] + a_extra_ctrl \
        + 0.15 * (b["sram"] + b["dcim"] + b["adc"])
    e = energy_per_conversion_pj(cfg)["total"]
    # 2.2x latency (paper), ~1.18x energy (register traffic + leakage dwell)
    return CostBreakdown(area_mm2=a, latency_cycles_per_cmac=2.2,
                         energy_pj_per_conv=4 * e * 1.18, power_rel=1.18)


def figS1_comparison(cfg: CCIMConfig = DEFAULT_CONFIG) -> Dict[str, Dict[str, float]]:
    """This work vs the two prior approaches; paper: -35% / -54% / -24%.

    The paper's quoted savings are consistent with: area & power measured
    against the duplicated-weight design (1.5x storage + duplicated
    periphery -> ~1.54x area, 1.32x power) and latency against the
    sequential design (2.2x): 1-1/1.54 = 35%, 1-1/2.2 = 54.5%,
    1-1/1.32 = 24.2%.  We report both columns so the reader can audit.
    """
    tw, dup, seq = cost_this_work(cfg), cost_duplicated(cfg), cost_sequential(cfg)
    return dict(
        this_work=tw.as_dict(), duplicated=dup.as_dict(), sequential=seq.as_dict(),
        savings=dict(
            area_pct_vs_duplicated=100 * (1 - tw.area_mm2 / dup.area_mm2),
            latency_pct_vs_sequential=100
            * (1 - tw.latency_cycles_per_cmac / seq.latency_cycles_per_cmac),
            power_pct_vs_duplicated=100 * (1 - tw.power_rel / dup.power_rel),
            area_pct_vs_sequential=100 * (1 - tw.area_mm2 / seq.area_mm2),
            paper=dict(area_pct=35.0, latency_pct=54.0, power_pct=24.0),
        ),
    )


def density_mb_per_mm2() -> float:
    """Measured density: 64 kb / 0.0365 mm^2 = 1.80 Mb/mm^2."""
    return MACRO_CAPACITY_BITS / 1e6 / MACRO_AREA_MM2


def adc_dnl_lsb_rms(cfg: CCIMConfig = DEFAULT_CONFIG) -> float:
    """Paper's conservative sizing rule: DNL = sigma_u * sqrt(2^N - 1)."""
    return cfg.sigma_unit * math.sqrt(2.0 ** cfg.adc_bits - 1)


# ---------------------------------------------------------------------------
# Per-MAC macro cost summary (consumed by the deployment planner, repro.plan)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MacroCost:
    """Deployment-facing cost of running ONE projection on one macro config.

    area_mm2_per_kb    silicon to hold 1 kb of weights at this design's
                       density (weight-stationary: array area scales with
                       the weights parked on it).
    latency_cyc_per_mac conversions per real MAC (1 conversion covers
                       ``acc_len`` MACs; the all-digital adder tree is
                       pipelined at the same conversion rate).
    energy_pj_per_mac  conversion energy amortized over ``acc_len`` MACs.
    """

    area_mm2_per_kb: float
    latency_cyc_per_mac: float
    energy_pj_per_mac: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _digital_macro_cost(cfg: CCIMConfig) -> MacroCost:
    """All-digital CIM [11]: every one of n_mag_bits^2 bit-products in
    counting logic, no capacitor array and no ADC -- the accuracy ceiling
    and the cost ceiling the hybrid macro is measured against."""
    nb2 = cfg.n_mag_bits ** 2
    a_sram = MACRO_CAPACITY_BITS * SRAM_6T_BIT_UM2 * 1e-6
    n_gates = nb2 * cfg.acc_len * DCIM_GATES_PER_UNIT * 4 * N_COMPLEX_UNITS
    a_dcim = n_gates * LOGIC_GATE_UM2 * 1e-6
    area = (a_sram + a_dcim) * 1.15                    # + ctrl, as elsewhere
    e_dcim = nb2 * cfg.acc_len * 8 * E_GATE_PJ
    e_total = e_dcim + _drivers_pj(cfg.acc_len)
    return MacroCost(
        area_mm2_per_kb=area / (MACRO_CAPACITY_BITS / 1024 / 8),
        latency_cyc_per_mac=1.0 / cfg.acc_len,
        energy_pj_per_mac=e_total / cfg.acc_len,
    )


def macro_cost(cfg: CCIMConfig = DEFAULT_CONFIG,
               fidelity: str = "fast") -> MacroCost:
    """Cost summary of one macro design point, per MAC / per weight-kb.

    ``fidelity`` follows the planner's vocabulary: "fast" (the hybrid or
    all-analog macro described by ``cfg``) or "exact" (all-digital CIM).
    With defaults this reproduces the paper's headline operating point:
    the figS1 ratios (-35% area / -54% latency / -24% power vs the best
    prior approach) and ~35 TOPS/W -- regression-tested in
    tests/test_plan.py so planner cost numbers stay anchored.
    """
    if fidelity == "exact":
        return _digital_macro_cost(cfg)
    if fidelity not in ("fast", "fast_broadcast", "bit_true"):
        raise ValueError(f"no cost model for fidelity {fidelity!r}")
    area = macro_area_breakdown(cfg)["total"]
    e = energy_per_conversion_pj(cfg)["total"]
    # weight kb held by one macro: capacity scales with magnitude bits + sign
    bits_per_weight = cfg.n_mag_bits + 1
    kb = MACRO_CAPACITY_BITS / 1024 / 8 * 8 / bits_per_weight
    return MacroCost(
        area_mm2_per_kb=area / kb,
        latency_cyc_per_mac=1.0 / cfg.acc_len,
        energy_pj_per_mac=e / cfg.acc_len,
    )
