"""Online analog-health watchdog: a debounced GREEN/AMBER/RED machine.

The serving stack already measures the signals that degrade first when
the analog substrate drifts -- it just never *acted* on them:

  ADC clip rate      obs ring counter ``CTR_ADC_CLIP`` (taps in the
                     packed GEMM's conversion epilogue).  Gain/offset
                     drift pushes accumulates past the SAR range, so the
                     clip-per-token rate rises well before logits are
                     visibly wrong.
  acceptance rate    speculative serving's drafted-vs-accepted counters.
                     The draft plan is all-analog, so capacitor drift
                     hits the draft hardest and acceptance collapses --
                     a free, output-level drift detector (fidelity never
                     degrades; the verify pass still gates every token).
  golden probe       a seeded known-input GEMM through the REAL packed
                     weights, compared against the digital reference
                     recorded at deployment (``GoldenProbe``).  Catches
                     what rate signals cannot: slow offset drift that
                     never clips, and stuck-at cells corrupting the
                     stored weights themselves.

``Watchdog.observe`` folds one measurement window into the state
machine.  Both directions are debounced: a breach must persist for
``debounce`` consecutive windows to escalate (one clipped outlier window
is not a failing die), and recovery needs ``recover`` consecutive clean
windows to step back down one level (burst faults flap; the ladder must
not).  Escalation can jump straight to RED; recovery is always one
level at a time.

The watchdog runs ON THE HOST at segment boundaries of the guarded
serve loop (failover.GuardedServer): it reads counters the device
already maintains, so the compiled loop body gains no host callbacks --
the RES-HOST-SYNC lint walks the jaxpr to prove it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

GREEN, AMBER, RED = "GREEN", "AMBER", "RED"
_LEVEL = {GREEN: 0, AMBER: 1, RED: 2}
_STATE = {v: k for k, v in _LEVEL.items()}


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Thresholds and debounce for the health state machine.

    Clip thresholds are per-token rates ABOVE the clean baseline
    (``Watchdog(baseline_clip_rate=...)``): a plan tuned near the SAR
    range clips a little when healthy, and that floor must not count as
    drift.  Probe thresholds are ratios of the probe's rel-RMS over the
    clean quantization floor measured at deployment -- the fast path is
    never bit-equal to the digital reference (ACIM residual rounding),
    so the floor, not zero, is the healthy reference.
    """

    clip_rate_amber: float = 0.05     # excess ADC clips per emitted token
    clip_rate_red: float = 0.50
    accept_amber: float = 0.50        # speculative acceptance below these
    accept_red: float = 0.20
    probe_amber: float = 3.0          # probe rel-RMS / clean floor above
    probe_red: float = 10.0
    debounce: int = 2                 # consecutive breaches to escalate
    recover: int = 4                  # consecutive clean windows per step-down
    probe_every: int = 1              # run the golden probe every N windows

    def __post_init__(self):
        if self.debounce < 1 or self.recover < 1 or self.probe_every < 1:
            raise ValueError("debounce/recover/probe_every must be >= 1")


@dataclasses.dataclass
class HealthSample:
    """One observation window, with the raw per-signal classification."""
    n_tokens: int                     # cumulative tokens at window end
    n_iter: int                       # cumulative loop iterations
    clip_rate: Optional[float]        # excess clips per token this window
    accept_rate: Optional[float]      # acceptance this window (spec only)
    probe_ratio: Optional[float]      # probe rms / clean floor
    raw: str                          # worst un-debounced level
    state: str                        # machine state AFTER this window
    reasons: List[str]

    def to_dict(self) -> Dict:
        rnd = lambda v: None if v is None else round(float(v), 5)
        return dict(n_tokens=self.n_tokens, n_iter=self.n_iter,
                    clip_rate=rnd(self.clip_rate),
                    accept_rate=rnd(self.accept_rate),
                    probe_ratio=rnd(self.probe_ratio),
                    raw=self.raw, state=self.state, reasons=self.reasons)


class Watchdog:
    """Debounced health-state machine over windowed serve telemetry."""

    def __init__(self, cfg: WatchdogConfig = WatchdogConfig(),
                 baseline_clip_rate: float = 0.0):
        self.cfg = cfg
        self.baseline_clip_rate = float(baseline_clip_rate)
        self.state = GREEN
        self.history: List[HealthSample] = []
        self._hot = 0                 # consecutive windows above state
        self._cool = 0                # consecutive windows below state
        self._pending = 0             # level the hot streak argues for

    # -- classification -------------------------------------------------

    def _classify(self, clip_rate, accept_rate, probe_ratio):
        c = self.cfg
        level, reasons = 0, []

        def breach(val, amber, red, name, below=False):
            nonlocal level
            if val is None or val != val:
                return
            hit = 0
            if below:
                hit = 2 if val < red else (1 if val < amber else 0)
            else:
                hit = 2 if val > red else (1 if val > amber else 0)
            if hit:
                reasons.append(f"{name}={val:.4g} ({_STATE[hit]})")
                level = max(level, hit)

        breach(clip_rate, c.clip_rate_amber, c.clip_rate_red, "clip_rate")
        breach(accept_rate, c.accept_amber, c.accept_red, "accept_rate",
               below=True)
        breach(probe_ratio, c.probe_amber, c.probe_red, "probe_ratio")
        return level, reasons

    # -- the state machine ----------------------------------------------

    def observe(self, *, n_tokens: int, n_iter: int,
                clip_rate: Optional[float] = None,
                accept_rate: Optional[float] = None,
                probe_ratio: Optional[float] = None) -> str:
        """Fold one measurement window in; returns the (possibly new)
        debounced state.  ``clip_rate`` should already be per-token for
        the window; the clean baseline is subtracted here."""
        if clip_rate is not None and clip_rate == clip_rate:
            clip_rate = max(0.0, clip_rate - self.baseline_clip_rate)
        raw, reasons = self._classify(clip_rate, accept_rate, probe_ratio)
        cur = _LEVEL[self.state]
        if raw > cur:
            # escalation streak: must argue for at least the same level
            # each window (a RED window refreshes an AMBER streak's count
            # -- it is still "above current state")
            self._pending = max(self._pending, raw) if self._hot else raw
            self._hot += 1
            self._cool = 0
            if self._hot >= self.cfg.debounce:
                self.state = _STATE[self._pending]
                self._hot = self._pending = 0
        elif raw < cur:
            self._cool += 1
            self._hot = self._pending = 0
            if self._cool >= self.cfg.recover:
                self.state = _STATE[cur - 1]   # one level at a time
                self._cool = 0
        else:
            self._hot = self._cool = self._pending = 0
        self.history.append(HealthSample(
            n_tokens=n_tokens, n_iter=n_iter, clip_rate=clip_rate,
            accept_rate=accept_rate, probe_ratio=probe_ratio,
            raw=_STATE[raw], state=self.state, reasons=reasons))
        return self.state

    def observe_snapshot(self, snap, probe_ratio: Optional[float] = None
                         ) -> str:
        """Offline variant: classify one whole-workload ``ObsSnapshot``
        (obs/rings.py) as a single window -- the false-positive tests
        drive clean serve reports through exactly this path."""
        tokens = snap.counters.get("tokens", 0)
        clip = snap.counters.get("adc_clip", 0)
        clip_rate = clip / tokens if tokens else None
        acc = snap.acceptance_rate
        return self.observe(
            n_tokens=tokens, n_iter=snap.n_iter, clip_rate=clip_rate,
            accept_rate=None if acc != acc else acc,
            probe_ratio=probe_ratio)

    # -- reporting ------------------------------------------------------

    @property
    def detection(self) -> Optional[HealthSample]:
        """First window the debounced state left GREEN (None if never)."""
        return next((s for s in self.history if s.state != GREEN), None)

    def to_dict(self) -> Dict:
        return dict(state=self.state,
                    baseline_clip_rate=round(self.baseline_clip_rate, 6),
                    windows=[s.to_dict() for s in self.history])


class GoldenProbe:
    """Known-input probe GEMM against the deployment-time digital
    reference.

    Built ONCE at deployment from one real packed projection: a seeded
    activation batch, the exact-fidelity reference output, and the clean
    fast-path rel-RMS floor (nonzero -- ACIM residual rounding).  Each
    call runs the fast path as currently served -- under whatever fault
    model ``fault`` emulates at clock ``t`` -- and returns the rel-RMS
    ratio over the clean floor, the unit ``WatchdogConfig.probe_*``
    thresholds are written in.

    The probe executable is jitted once with ``t`` as a TRACED argument
    (the fault context is armed around the trace), so repeated probes at
    different clocks never retrace.  ``serve_packed`` lets the harness
    probe a stuck-at-faulted pack against the clean pack's reference --
    the deployment-time recording is exactly what makes silent weight
    corruption visible.
    """

    def __init__(self, packed, *, fault=None, serve_packed=None,
                 m: int = 4, seed: int = 0):
        import jax
        import jax.numpy as jnp
        from ..core.engine import packed_cim_matmul_int
        from ..plan.profiler import rel_rms
        from . import faults as rfaults

        self.packed = packed
        serve = serve_packed if serve_packed is not None else packed
        cfg = packed.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                 0x50524F42)  # "PROB"
        self.xq = jax.random.randint(key, (m, packed.k_dim), -127, 128,
                                     jnp.int32)
        self.ref = np.asarray(
            packed_cim_matmul_int(self.xq, packed, None, cfg,
                                  fidelity="exact"), np.float64)
        self._rel_rms = rel_rms

        def fwd(t):
            if fault is not None:
                with rfaults.inject(fault):
                    with rfaults.clock(t):
                        return packed_cim_matmul_int(self.xq, serve, None,
                                                     cfg, fidelity="fast")
            return packed_cim_matmul_int(self.xq, serve, None, cfg,
                                         fidelity="fast")

        self._fwd = jax.jit(fwd)
        clean = np.asarray(
            packed_cim_matmul_int(self.xq, packed, None, cfg,
                                  fidelity="fast"), np.float64)
        self.clean_floor = max(float(rel_rms(clean, self.ref)), 1e-9)

    def __call__(self, t: int = 0) -> float:
        """rel-RMS of the served fast path at clock ``t`` over the clean
        floor (1.0 == healthy)."""
        import jax.numpy as jnp
        y = np.asarray(self._fwd(jnp.int32(t)), np.float64)
        return float(self._rel_rms(y, self.ref)) / self.clean_floor


def first_packed_leaf(params):
    """The first PackedCimWeights leaf of a params tree (probe target);
    None when the tree holds no packed weights."""
    import jax
    from ..core.engine import FusedPackedCimWeights, PackedCimWeights

    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(
            x, (PackedCimWeights, FusedPackedCimWeights)))
    for leaf in leaves:
        if isinstance(leaf, FusedPackedCimWeights):
            leaf = leaf.packed
        if isinstance(leaf, PackedCimWeights):
            # scanned layer stacks pack with a leading depth axis; the
            # probe wants one physical array -- layer 0's
            if leaf.sign.ndim == 3:
                leaf = jax.tree_util.tree_map(lambda a: a[0], leaf)
            return leaf
    return None
