"""Graceful plan-degradation failover over one packed weight set.

The pack-compatibility property (``core.engine.pack_compatible``) that
powered plan-cascade speculative drafting also defines the RECOVERY
space when the analog substrate degrades: from one hybrid
``PackedCimWeights`` pack, three execution modes are servable with zero
repacks and zero recompiles-at-failover-time --

  analog    the all-analog shadow (``plan.derive_draft_plan``): cheapest
            conversions, fully exposed to capacitor/ADC drift;
  hybrid    the deployed mixed D/A plan: the paper's design point, with
            ~half the product mass in exact counting logic;
  digital   the entry-wise ``fidelity="exact"`` plan: every projection
            reconstructs the integer weights (``packed.wq()``) and MACs
            them exactly -- quantization is the only remaining error, so
            it is immune to EVERY conversion-path fault (stuck-at cell
            faults live in the shared array and survive, as in silicon).

``derive_ladder`` orders these as a degradation ladder; escalation
raises fidelity (and conversion cost), never lowers it.  In speculative
mode the first escalation instead retargets the DRAFT: the all-analog
draft plan -- the most drift-exposed component -- is swapped for
self-speculation (draft == verify plan), which keeps the round shapes
and ``draft_k`` constant so the loop carry still transfers, while
removing the analog exposure that collapses acceptance.

``GuardedServer`` drives the ladder: every rung gets its own
pack-compatible scheduler over the SAME params, all segment executables
are compiled UP FRONT (``n_compiles`` is the census the bench asserts
zero-recompile-at-failover with), and the workload runs as budget-
bounded device-resident segments (``scheduler._lower_segment``).  At
each segment boundary -- the only host syncs -- the driver reads the
obs counters, optionally runs the golden probe, feeds the watchdog, and
on AMBER/RED switches which rung's executable the NEXT segment uses.
The carry transfers across rungs unchanged: cache shapes, slot state
and result buffers are plan-independent, so failover is literally "call
a different precompiled function on the same state".
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..launch.scheduler import (ContinuousBatchingScheduler, Request,
                                ServeReport, _i32)
from ..obs import rings as obs_rings
from ..obs.rings import ObsConfig
from ..plan.draft import derive_draft_plan
from ..plan.plan import DeploymentPlan, PlanEntry
from . import faults as rfaults
from .watchdog import (GREEN, RED, GoldenProbe, Watchdog, WatchdogConfig,
                       first_packed_leaf)


def derive_exact_entry(entry: PlanEntry) -> PlanEntry:
    """The exact-fidelity sibling of one plan entry: same CCIMConfig (so
    ``packed.cfg == cfg`` and the pack guard passes -- zero repacks),
    float entries untouched (they were never on the macro)."""
    if entry.fidelity == "float":
        return entry
    return PlanEntry(cfg=entry.cfg, fidelity="exact", label="digital")


def derive_exact_plan(plan: DeploymentPlan) -> DeploymentPlan:
    """Entry-wise exact (all-digital) sibling of a deployment plan."""
    return DeploymentPlan.from_dict(
        {p: derive_exact_entry(e) for p, e in plan.entries},
        default=derive_exact_entry(plan.default))


@dataclasses.dataclass(frozen=True)
class Rung:
    """One ladder position: a serve plan (plus, in speculative mode, the
    draft plan) -- all rungs of one ladder serve the SAME pack."""
    label: str
    plan: DeploymentPlan
    draft_plan: Optional[DeploymentPlan] = None


def derive_ladder(plan: DeploymentPlan, *, speculative: bool = False
                  ) -> Tuple[List[Rung], int]:
    """The pack-compatible degradation ladder for a deployment plan.

    Returns ``(rungs, start)`` -- rungs ordered cheapest to most exact,
    ``start`` the deployed plan's own position (serving begins there;
    rungs below it exist for per-rung cost measurement and are never
    escalated INTO).  Non-speculative: analog -> hybrid -> digital.
    Speculative: analog-draft -> self-draft (draft disabled by drafting
    with the verify plan itself -- same shapes, same draft_k, so the
    carry transfers) -> digital.
    """
    dig = derive_exact_plan(plan)
    if speculative:
        return [Rung("spec/analog-draft", plan, derive_draft_plan(plan)),
                Rung("spec/self-draft", plan, plan),
                Rung("digital", dig, dig)], 0
    return [Rung("analog", derive_draft_plan(plan)),
            Rung("hybrid", plan),
            Rung("digital", dig)], 1


@dataclasses.dataclass
class FailoverAction:
    """One ladder move, stamped with where in the workload it happened."""
    n_iter: int
    n_tokens: int
    from_rung: int
    to_rung: int
    state: str
    reasons: List[str]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ResilienceLog:
    """What the guarded run did: ladder moves, watchdog windows, census."""
    rung_labels: List[str]
    start_rung: int
    final_rung: int
    actions: List[FailoverAction]
    n_segments: int
    segment_iters: int
    n_compiles: int                   # all incurred BEFORE serving started
    watchdog: Optional[Dict] = None   # Watchdog.to_dict()

    @property
    def detection_tokens(self) -> Optional[int]:
        """Tokens emitted when the watchdog first left GREEN."""
        if not self.watchdog:
            return None
        w = next((s for s in self.watchdog["windows"]
                  if s["state"] != GREEN), None)
        return None if w is None else w["n_tokens"]

    def to_dict(self) -> Dict:
        return dict(rung_labels=self.rung_labels, start_rung=self.start_rung,
                    final_rung=self.final_rung,
                    actions=[a.to_dict() for a in self.actions],
                    n_segments=self.n_segments,
                    segment_iters=self.segment_iters,
                    n_compiles=self.n_compiles,
                    detection_tokens=self.detection_tokens,
                    watchdog=self.watchdog)


class GuardedServer:
    """Watchdog-guarded serving over a failover ladder of schedulers.

    One instance owns one scheduler per rung (same params, same slot
    geometry, pack-compatible plans) and drives the workload in budget-
    bounded segments.  ``fault`` arms a ``FaultModel`` while the rung
    executables are LOWERED, so injected drift follows the device
    iteration clock inside each compiled segment; the digital rung's
    exact path contains no conversion epilogue, so it is naturally
    immune -- escalation genuinely restores fidelity rather than merely
    re-measuring it.

    All compiles happen in ``compile_for`` (or lazily on first ``run``);
    ``n_compiles`` counts them, and no code path below ``run`` can add
    more -- the zero-recompile-failover census the bench asserts.
    """

    def __init__(self, params, cfg, *, slots: int, prompt_len: int,
                 max_new_cap: int, temperature: float = 0.0, seed: int = 0,
                 pad_token: int = 0, draft_k: int = 0, paged=None,
                 prefill_chunk: Optional[int] = None,
                 prefix_sharing: bool = True,
                 obs: Optional[ObsConfig] = None,
                 ladder: Optional[List[Rung]] = None,
                 start_rung: Optional[int] = None,
                 watchdog: Optional[Watchdog] = None,
                 probe: Optional[GoldenProbe] = None,
                 fault: Optional[rfaults.FaultModel] = None,
                 segment_iters: int = 32):
        if segment_iters < 1:
            raise ValueError(f"segment_iters {segment_iters} < 1")
        plan = cfg.cim_plan
        if plan is None:
            from ..core.ccim import DEFAULT_CONFIG
            plan = DeploymentPlan.uniform(PlanEntry(
                cfg=cfg.cim_cfg or DEFAULT_CONFIG,
                fidelity=cfg.cim_fidelity))
        if ladder is None:
            ladder, default_start = derive_ladder(
                plan, speculative=draft_k > 0)
        else:
            default_start = 0
        self.ladder = ladder
        self.start_rung = (start_rung if start_rung is not None
                           else default_start)
        if not (0 <= self.start_rung < len(ladder)):
            raise ValueError(f"start_rung {self.start_rung} outside ladder "
                             f"of {len(ladder)}")
        self.obs = obs if obs is not None else ObsConfig()
        self.watchdog = watchdog
        self.probe = probe
        self.fault = fault
        self.segment_iters = segment_iters
        self._params = params
        self.n_compiles = 0
        self._exes: Dict[Tuple[int, int], object] = {}
        self._scheds: List[ContinuousBatchingScheduler] = []
        for rung in ladder:
            rcfg = dataclasses.replace(cfg, cim_plan=rung.plan)
            self._scheds.append(ContinuousBatchingScheduler(
                params, rcfg, slots, prompt_len, max_new_cap,
                temperature=temperature, seed=seed, pad_token=pad_token,
                draft_k=draft_k, draft_plan=rung.draft_plan, paged=paged,
                prefill_chunk=prefill_chunk, prefix_sharing=prefix_sharing,
                obs=self.obs))

    def scheduler(self, rung: Optional[int] = None
                  ) -> ContinuousBatchingScheduler:
        return self._scheds[self.start_rung if rung is None else rung]

    def _armed(self):
        return (rfaults.inject(self.fault) if self.fault is not None
                else contextlib.nullcontext())

    def compile_for(self, n_requests: int):
        """Precompile EVERY rung's segment executable for a queue length
        -- failover later is a dictionary lookup, never a compile.  The
        fault model (if any) is armed around lowering, baking the drift
        schedule against the device clock into each executable."""
        with self._armed():
            for i in range(len(self.ladder)):
                if (i, n_requests) not in self._exes:
                    self._exes[(i, n_requests)] = (
                        self._scheds[i].compile_segment(n_requests))
                    self.n_compiles += 1

    def run(self, requests: Sequence[Request],
            arrival_iters: Optional[Sequence[int]] = None
            ) -> Tuple[ServeReport, ResilienceLog]:
        """Serve to completion under the watchdog.  Returns the familiar
        ``ServeReport`` (token-identical to the start rung's plain
        ``run`` while the watchdog stays GREEN) plus the resilience log.
        """
        n = len(requests)
        self.compile_for(n)
        compiles_at_start = self.n_compiles
        sched0 = self._scheds[self.start_rung]
        sched0._check(requests)
        q_toks, q_meta, q_pins = sched0._stage(requests, arrival_iters)
        carry = jax.block_until_ready(
            sched0._init_carry(n, with_obs=True))
        rung = self.start_rung
        worst = 0                      # monotone: sticky degradation
        actions: List[FailoverAction] = []
        prev = dict(tokens=0, clip=0, drafted=0, accepted=0)
        n_segments = 0
        budget = 0
        t0 = time.time()
        while True:
            budget += self.segment_iters
            carry = self._exes[(rung, n)](
                self._params, carry, _i32(budget), q_toks, q_meta, q_pins)
            n_segments += 1
            # ONE host sync per segment: the scalar health leaves (plus
            # occupancy masks for the done test)
            st = carry["st"]
            occ = st["live"] | st["pending"]
            if "filling" in st:
                occ = occ | st["filling"]
            h = jax.device_get(dict(
                n_iter=carry["n_iter"], q_head=carry["q_head"],
                occupied=occ.any(), ctr=carry["obs"]["ctr"],
                n_drafted=carry["n_drafted"],
                n_accepted=carry["n_accepted"]))
            n_iter = int(h["n_iter"])
            ctr = np.asarray(h["ctr"])
            tokens = int(ctr[obs_rings.CTR_TOKENS])
            clip = int(ctr[obs_rings.CTR_ADC_CLIP])
            drafted, accepted = int(h["n_drafted"]), int(h["n_accepted"])
            done = (not bool(h["occupied"])) and int(h["q_head"]) >= n

            if self.watchdog is not None:
                tok_d = tokens - prev["tokens"]
                clip_d = clip - prev["clip"]
                dr_d = drafted - prev["drafted"]
                ac_d = accepted - prev["accepted"]
                prev = dict(tokens=tokens, clip=clip, drafted=drafted,
                            accepted=accepted)
                probe_ratio = None
                if (self.probe is not None and (n_segments - 1)
                        % self.watchdog.cfg.probe_every == 0):
                    probe_ratio = self.probe(t=n_iter)
                state = self.watchdog.observe(
                    n_tokens=tokens, n_iter=n_iter,
                    clip_rate=(clip_d / tok_d if tok_d > 0 else None),
                    accept_rate=(ac_d / dr_d if dr_d > 0 else None),
                    probe_ratio=probe_ratio)
                level = 2 if state == RED else (0 if state == GREEN else 1)
                if level > worst:
                    worst = level
                    last = len(self.ladder) - 1
                    target = last if worst >= 2 else min(rung + 1, last)
                    if target != rung:
                        actions.append(FailoverAction(
                            n_iter=n_iter, n_tokens=tokens, from_rung=rung,
                            to_rung=target, state=state,
                            reasons=list(self.watchdog.history[-1].reasons)))
                        rung = target
            if done:
                break
        wall = time.time() - t0

        res_out = np.asarray(carry["res_out"])
        res_n = np.asarray(carry["res_n"])
        res_iter = np.asarray(carry["res_iter"])
        res_first = np.asarray(carry["res_first"])
        n_iter = int(carry["n_iter"])
        from ..launch.scheduler import FinishedRequest
        done_reqs = [FinishedRequest(
            rid=r.rid, tokens=res_out[i, :res_n[i]].copy(),
            latency_s=wall * int(res_iter[i]) / max(n_iter, 1),
            finish_iter=int(res_iter[i]), first_iter=int(res_first[i]))
            for i, r in enumerate(requests)]
        report = ServeReport(
            finished=done_reqs, wall_s=wall, n_steps=int(carry["n_steps"]),
            n_admits=int(carry["n_admits"]), slots=sched0.slots,
            n_drafted=int(carry["n_drafted"]),
            n_accepted=int(carry["n_accepted"]),
            n_pf=int(np.asarray(carry["n_pf"])) if "n_pf" in carry else 0,
            peak_blocks=(int(np.asarray(carry["peak_blocks"]))
                         if "peak_blocks" in carry else 0))
        report.obs = obs_rings.harvest_obs(
            self.obs, jax.device_get(carry["obs"]), n_iter=n_iter,
            wall_s=wall, slots=sched0.slots, n_steps=report.n_steps,
            n_drafted=report.n_drafted, n_accepted=report.n_accepted,
            paged=sched0.paged is not None)
        assert self.n_compiles == compiles_at_start, (
            "guarded serve compiled mid-run")   # the census invariant
        log = ResilienceLog(
            rung_labels=[r.label for r in self.ladder],
            start_rung=self.start_rung, final_rung=rung, actions=actions,
            n_segments=n_segments, segment_iters=self.segment_iters,
            n_compiles=self.n_compiles,
            watchdog=(self.watchdog.to_dict() if self.watchdog is not None
                      else None))
        return report, log


def default_probe(params, *, fault=None, serve_params=None,
                  m: int = 4, seed: int = 0) -> Optional[GoldenProbe]:
    """Golden probe over the first packed projection of ``params`` (the
    deployment-time reference); ``serve_params`` (e.g. a stuck-at-faulted
    pack) supplies the leaf actually probed.  None when the tree holds no
    packed weights (float serving has no analog substrate to watch)."""
    ref = first_packed_leaf(params)
    if ref is None:
        return None
    serve = (first_packed_leaf(serve_params)
             if serve_params is not None else None)
    return GoldenProbe(ref, fault=fault, serve_packed=serve, m=m, seed=seed)
