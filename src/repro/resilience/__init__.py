"""Resilience: seeded fault injection, drift watchdog, plan failover.

Only ``faults`` is imported eagerly -- ``core.ccim`` imports it at load
time, and ``failover`` imports the scheduler (which imports core), so an
eager import of the full package would cycle.  ``watchdog``/``failover``
resolve lazily on first attribute access.
"""
from . import faults  # noqa: F401


def __getattr__(name):
    if name in ("watchdog", "failover"):
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
