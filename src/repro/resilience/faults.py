"""Deterministic analog-substrate fault injection (the chaos half).

The paper's 0.435% RMS is a nominal-conditions number: a deployed
charge-domain macro degrades under capacitor mismatch drift, ADC
offset/gain drift and SRAM bit-cell faults.  This module emulates those
degradations DETERMINISTICALLY (seeded, schedulable) so the watchdog and
failover ladder can be tested in closed loop, exactly the way the obs
rings made telemetry testable.

Two injection surfaces, matching where faults live in silicon:

  weights   ``apply_weight_faults`` -- stuck-at sign/magnitude bit-cells.
            A pure host-side transform of the packed params tree: the
            faulted integer weights are RE-packed through the normal
            pack pipeline, so every serving path (fast GEMM, Pallas,
            exact ``wq()`` reconstruction) sees the SAME faulted cells,
            as they would in silicon.  No trace-time flag involved.
  epilogue  per-column capacitor gain/offset drift, ADC conversion
            offset and clip escalation, applied inside the analog
            conversion epilogue of ``core.ccim.hybrid_mac_fast_gemm_
            prepacked``.  These exist ONLY while an ``inject()`` context
            is open *at trace time* -- the same static-flag mechanism as
            ``obs.taps``: with no context open, not one extra op is
            traced and fault-free serving lowers byte-identical
            StableHLO (fingerprint-gated in benchmarks/resilience_bench
            and the RES-OFF-PATH cimlint rule).

Time. Drift is scheduled against an iteration clock ``t``: a concrete
int for one-shot measurements, or a TRACED scalar (the scheduler loop's
``n_iter``, bound via ``clock()``) so severity evolves mid-stream inside
ONE compiled executable -- mid-workload drift needs no retrace, no
recompile, preserving the serving stack's static-executable contract.

Every draw is keyed from ``FaultModel.seed`` alone (plus static shapes/
paths), never from global state: the same model produces the same fault
pattern in eager, jit, scan and across processes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from typing import Iterator, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array

FAULT_SCHEDULES = ("step", "ramp", "burst")
STUCK_MODES = ("mag_msb", "sign")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One seeded, schedulable fault scenario (hashable, static).

    Severity ``s(t)`` in [0, 1] follows ``schedule`` from ``onset``;
    every analog amplitude below scales linearly with it.  Stuck-at
    cell faults are time-invariant (a failed cell stays failed).
    """

    seed: int = 0
    # -- SRAM bit-cell faults (weights; applied via apply_weight_faults)
    stuck_frac: float = 0.0        # fraction of cells faulted
    stuck_mode: str = "mag_msb"    # "mag_msb": magnitude MSB stuck at 1;
                                   # "sign": sign bit flipped
    # -- capacitor-array drift (per output column, analog epilogue)
    gain_amp: float = 0.0          # relative per-column gain error amplitude
    offset_lsb: float = 0.0        # per-column conversion offset, ADC LSBs
    # -- ADC drift (analog epilogue)
    adc_offset_lsb: float = 0.0    # global conversion offset, ADC LSBs
    adc_clip_bits: float = 0.0     # clip escalation: effective SAR range
                                   # shrinks by up to this many bits
    # -- schedule
    schedule: str = "step"         # step | ramp | burst
    onset: int = 0                 # iteration the fault switches on
    period: int = 64               # ramp rise length / burst period, iters
    duty: float = 0.5              # burst: on-fraction of each period

    def __post_init__(self):
        if self.schedule not in FAULT_SCHEDULES:
            raise ValueError(f"schedule {self.schedule!r} not in "
                             f"{FAULT_SCHEDULES}")
        if self.stuck_mode not in STUCK_MODES:
            raise ValueError(f"stuck_mode {self.stuck_mode!r} not in "
                             f"{STUCK_MODES}")
        if not (0.0 <= self.stuck_frac <= 1.0):
            raise ValueError(f"stuck_frac {self.stuck_frac} outside [0, 1]")
        if self.period < 1:
            raise ValueError(f"period {self.period} < 1")

    @property
    def touches_epilogue(self) -> bool:
        """True when the model perturbs the analog conversion epilogue
        (zero-amplitude models trace no extra conversion ops)."""
        return any(v != 0.0 for v in (self.gain_amp, self.offset_lsb,
                                      self.adc_offset_lsb,
                                      self.adc_clip_bits))

    def severity(self, t) -> Array:
        """Schedule value s(t) in [0, 1]; ``t`` concrete or traced."""
        tf = jnp.asarray(t, jnp.float32)
        on = jnp.float32(self.onset)
        if self.schedule == "step":
            return (tf >= on).astype(jnp.float32)
        if self.schedule == "ramp":
            return jnp.clip((tf - on) / jnp.float32(self.period), 0.0, 1.0)
        # burst: full severity for the first duty*period of each period
        phase = jnp.mod(tf - on, jnp.float32(self.period))
        live = (tf >= on) & (phase < self.duty * self.period)
        return live.astype(jnp.float32)

    def column_patterns(self, n: int) -> Tuple[Array, Array]:
        """Deterministic per-column (gain, offset) unit patterns, shape
        (n,) each in [-1, 1] -- the frozen mismatch signature of one
        capacitor array.  Depends only on (seed, n)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 0x44524946)  # "DRIF"
        kg, ko = jax.random.split(key)
        gain = jax.random.uniform(kg, (n,), jnp.float32, -1.0, 1.0)
        off = jax.random.uniform(ko, (n,), jnp.float32, -1.0, 1.0)
        return gain, off

    @classmethod
    def parse(cls, spec: str) -> "FaultModel":
        """Build from a CLI spec: comma-separated ``key=value`` pairs,
        e.g. ``schedule=ramp,gain_amp=0.3,onset=32,period=64,seed=7``.
        Unknown keys error with the known field list."""
        fields = {f.name: f.type for f in dataclasses.fields(cls)}
        kw = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"fault spec item {part!r} is not "
                                 "key=value")
            k, v = part.split("=", 1)
            if k not in fields:
                raise ValueError(f"unknown fault field {k!r}; known: "
                                 f"{sorted(fields)}")
            kw[k] = v if k in ("schedule", "stuck_mode") else (
                int(v) if k in ("seed", "onset", "period") else float(v))
        return cls(**kw)


@dataclasses.dataclass
class _Site:
    """One open injection frame: the model plus its current clock.  The
    clock may be rebound to a traced scalar (``clock()``) while tracing
    a loop body."""
    model: FaultModel
    t: Union[int, Array]


# stack of open injection frames (innermost last); trace-time only,
# exactly like obs.taps._STACK
_STACK: List[_Site] = []


def active() -> bool:
    """True while some ``inject()`` frame is open whose model perturbs
    the conversion epilogue (trace-time check; plain Python bool)."""
    return bool(_STACK) and _STACK[-1].model.touches_epilogue


def site() -> Optional[_Site]:
    """The innermost open injection frame (None when inactive)."""
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def inject(model: FaultModel, t: Union[int, Array] = 0) -> Iterator[_Site]:
    """Arm ``model`` for everything traced inside this context.

    ``t`` seeds the clock; kernels traced while the context is open bake
    fault ops whose severity is ``model.severity(t)``.  Pass a traced
    scalar (or rebind later with ``clock``) for in-executable schedules.
    """
    s = _Site(model, t)
    _STACK.append(s)
    try:
        yield s
    finally:
        _STACK.pop()


@contextlib.contextmanager
def clock(t: Union[int, Array]) -> Iterator[None]:
    """Rebind the innermost frame's clock for the enclosed trace region.

    The scheduler wraps its loop body with ``clock(carry['n_iter'])``
    when lowering the guarded (segmented) serve loop, so drift severity
    follows the DEVICE iteration counter -- one executable covers the
    whole schedule.  A no-op (no ops traced, no state touched) when no
    injection frame is open, so the fault-free lowering is untouched.
    """
    if not _STACK:
        yield
        return
    frame = _STACK[-1]
    old = frame.t
    frame.t = t
    try:
        yield
    finally:
        frame.t = old


def epilogue_terms(n_cols: int):
    """The fault terms the analog conversion epilogue folds in; called
    by ``core.ccim`` ONLY under ``active()``.

    Returns ``(gain, offset_lsb, adc_off_lsb, range_scale)``:

      gain         (n_cols,) multiplicative error on the analog partial
      offset_lsb   (n_cols,) additive conversion offset, in ADC LSBs
      adc_off_lsb  scalar global ADC offset, in ADC LSBs
      range_scale  scalar in (0, 1]: effective SAR range multiplier
                   (2**-(sev*adc_clip_bits) -- clip escalation)

    All four are severity-scaled by the frame's clock, so inside a loop
    trace they evolve with the device iteration counter.
    """
    frame = _STACK[-1]
    m = frame.model
    sev = m.severity(frame.t)
    gcol, ocol = m.column_patterns(n_cols)
    gain = 1.0 + sev * m.gain_amp * gcol
    off = sev * m.offset_lsb * ocol
    adc_off = sev * m.adc_offset_lsb
    range_scale = jnp.exp2(-sev * m.adc_clip_bits)
    return gain, off, adc_off, range_scale


# ---------------------------------------------------------------------------
# Weight-side stuck-at faults (host transform; no trace-time flag)
# ---------------------------------------------------------------------------


def _leaf_key(model: FaultModel, path_tag: int) -> Array:
    k = jax.random.fold_in(jax.random.PRNGKey(model.seed),
                           0x53545543)  # "STUC"
    return jax.random.fold_in(k, path_tag)


def stuck_mask(model: FaultModel, shape: Tuple[int, ...],
               path_tag: int) -> Array:
    """Deterministic boolean fault map for one (K, N) cell array."""
    return jax.random.bernoulli(_leaf_key(model, path_tag),
                                model.stuck_frac, shape)


def faulted_wq(model: FaultModel, sign: Array, mag: Array,
               path_tag: int, n_mag_bits: int = 7) -> Array:
    """Apply stuck-at cell faults to raw signed-magnitude storage and
    return the faulted integer weights."""
    mask = stuck_mask(model, sign.shape, path_tag)
    sign = sign.astype(jnp.int32)
    mag = mag.astype(jnp.int32)
    if model.stuck_mode == "mag_msb":
        msb = 1 << (n_mag_bits - 1)
        mag = jnp.where(mask, mag | msb, mag)
    else:                                  # "sign": cell flips polarity
        sign = jnp.where(mask, -sign, sign)
    return sign * mag


def apply_weight_faults(model: FaultModel, params):
    """Pure transform of a (packed) params tree: every PackedCimWeights
    leaf gets ``stuck_frac`` of its bit-cells faulted, deterministically
    keyed by (seed, leaf path), and is RE-packed from the faulted ints --
    so the fast-GEMM copies, Pallas tiles and ``wq()`` reconstruction all
    agree on the faulted array contents, exactly like silicon where every
    execution path reads the same cells.  Non-packed leaves pass through
    untouched (stuck-at faults are a property of the CIM array).
    """
    # function-level import: core.ccim imports this module at load time
    from ..core.engine import (FusedPackedCimWeights, PackedCimWeights,
                               pack_quantized_cim_weights)

    if model.stuck_frac <= 0.0:
        return params

    def tag(path) -> int:
        return zlib.crc32("/".join(str(p) for p in path).encode())

    def fix(path, leaf):
        if isinstance(leaf, FusedPackedCimWeights):
            return dataclasses.replace(leaf, packed=fix(path, leaf.packed))
        if isinstance(leaf, PackedCimWeights):
            wq = faulted_wq(model, leaf.sign, leaf.mag, tag(path),
                            n_mag_bits=leaf.cfg.n_mag_bits)
            repack = lambda w, s: pack_quantized_cim_weights(
                w, s, leaf.cfg)
            if wq.ndim == 3:      # scanned layer stack: (layers, K, N),
                repack = jax.vmap(repack)   # packed like models.lm does
            return repack(wq, leaf.scale)
        return leaf

    return jax.tree_util.tree_map_with_path(
        fix, params,
        is_leaf=lambda x: isinstance(x, (PackedCimWeights,
                                         FusedPackedCimWeights)))
