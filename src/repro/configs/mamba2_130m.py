"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD, state=128."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
)
SMOKE = CONFIG.reduced(n_heads=0, n_kv_heads=0, d_head=0, d_ff=0)
