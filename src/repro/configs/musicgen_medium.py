"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens
(codec frontend is a STUB: the backbone consumes token ids / precomputed
frame embeddings per the brief)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_head=64, d_ff=6144, vocab_size=2048,
    frontend="encodec_stub", act="gelu",
)
SMOKE = CONFIG.reduced()
