"""Gemma2-9B [arXiv:2408.00118]: local+global alternating, logit softcap."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv_heads=8, d_head=256, d_ff=14336, vocab_size=256000,
    layer_pattern="local_global", sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0, act="gelu", tie_embeddings=True,
)
SMOKE = CONFIG.reduced(n_kv_heads=2)
