"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: 128e top-2 MoE
with a dense residual branch. ~480B total parameters."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_head=128, d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, moe_d_ff=4864,
)
SMOKE = CONFIG.reduced(top_k=2)
