"""PaliGemma-3B [arXiv:2407.07726]: SigLIP frontend (STUB: precomputed
patch embeddings per the brief) + gemma decoder, prefix-LM attention."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, d_head=256, d_ff=16384, vocab_size=257216,
    prefix_lm=True, frontend="siglip_stub", n_frontend_tokens=256,
    act="gelu", tie_embeddings=True,
)
SMOKE = CONFIG.reduced(n_kv_heads=1)
