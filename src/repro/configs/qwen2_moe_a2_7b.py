"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed top-4 + 4 shared."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_head=128, d_ff=0, vocab_size=151936,
    n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, shared_expert_d_ff=5632,
)
SMOKE = CONFIG.reduced(top_k=2)
