"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + ONE shared attention
block invoked every 6 layers (weight co-location showcase)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, shared_attn_period=6, ssm_chunk=128,
)
SMOKE = CONFIG.reduced()
