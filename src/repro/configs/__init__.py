"""Architecture registry: --arch <id> resolution for launchers/tests."""
from importlib import import_module

_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "qwen3-14b": "qwen3_14b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma2-9b": "gemma2_9b",
    "mamba2-130m": "mamba2_130m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "arctic-480b": "arctic_480b",
    "paligemma-3b": "paligemma_3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "musicgen-medium": "musicgen_medium",
}

ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False):
    mod = import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG
