"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--cim]

Continuous-batching-shaped loop: a fixed decode batch, per-slot stop
handling, greedy or temperature sampling.  Exercised by
tests/test_serve.py and examples/cim_serve.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..data import DataConfig, batch_at
from ..models import lm


def serve(arch: str, smoke: bool = True, batch: int = 4, prompt_len: int = 32,
          gen: int = 16, cim: bool = False, temperature: float = 0.0,
          seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                      global_batch=batch, seed=seed,
                      n_frontend_tokens=cfg.n_frontend_tokens
                      if cfg.family == "vlm" else 0,
                      d_model=cfg.d_model)
    key = jax.random.PRNGKey(seed)
    params, _ = lm.init(key, cfg)
    b = batch_at(dcfg, 0)
    tokens = jnp.asarray(b["tokens"])
    fe = (jnp.asarray(b["frontend_embs"]).astype(jnp.bfloat16)
          if "frontend_embs" in b else None)

    max_seq = prompt_len + gen + (fe.shape[1] if fe is not None else 0)
    cache = lm.init_cache(cfg, batch, max_seq)
    prefill = jax.jit(lambda p, t, c, f: lm.prefill(p, cfg, t, c, f),
                      donate_argnums=(2,))
    decode = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c),
                     donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, tokens, cache, fe)
    out = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(gen):
        out.append(np.asarray(tok))
        logits, cache = decode(params, tok, cache)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen_tokens = np.concatenate(out, axis=1)
    print(f"[serve] {arch}: batch {batch}, prompt {prompt_len}, "
          f"generated {gen} tokens in {dt:.2f}s "
          f"({batch*gen/dt:.1f} tok/s)")
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cim", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen, cim=args.cim,
          temperature=args.temperature)


if __name__ == "__main__":
    main()
