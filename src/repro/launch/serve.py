"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--cim] [--no-pack]

Continuous-batching-shaped loop: a fixed decode batch, per-slot stop
handling, greedy or temperature sampling.  Exercised by
tests/test_serve.py, tests/test_engine.py and examples/cim_serve.py.

Serving dataflow under --cim (weight-stationary, like the silicon):

  pack     : every projection is quantized + bit-plane-decomposed ONCE
             (lm.pack_cim_params), off the token loop -- the array write.
  prefill  : one batched forward over the prompt fills the KV cache.
  decode   : activation-only quantization per token; generated tokens are
             collected ON DEVICE and transferred once at the end (the old
             per-token np.asarray forced a host sync every step and
             serialized the whole loop against the device).

``--no-pack`` keeps the legacy per-call weight conditioning -- the
pre-refactor baseline benchmarks compare against; tokens are bit-identical
either way.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..data import DataConfig, batch_at
from ..models import lm


def serve(arch: str, smoke: bool = True, batch: int = 4, prompt_len: int = 32,
          gen: int = 16, cim: bool = False, temperature: float = 0.0,
          seed: int = 0, pack: bool = True, return_stats: bool = False):
    """Returns generated tokens (batch, gen); with ``return_stats=True``,
    returns (tokens, stats) where stats separates compile / pack /
    prefill / decode time -- prefill and decode steps are AOT-compiled up
    front, so every throughput number is pure execution."""
    cfg = get_config(arch, smoke=smoke)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    pack = pack and cim
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                      global_batch=batch, seed=seed,
                      n_frontend_tokens=cfg.n_frontend_tokens
                      if cfg.family == "vlm" else 0,
                      d_model=cfg.d_model)
    key = jax.random.PRNGKey(seed)
    params, _ = lm.init(key, cfg)
    b = batch_at(dcfg, 0)
    tokens = jnp.asarray(b["tokens"])
    fe = (jnp.asarray(b["frontend_embs"]).astype(jnp.bfloat16)
          if "frontend_embs" in b else None)

    t_pack = 0.0
    if pack:
        t0 = time.time()
        params = jax.block_until_ready(
            jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params))
        t_pack = time.time() - t0

    max_seq = prompt_len + gen + (fe.shape[1] if fe is not None else 0)
    cache = lm.init_cache(cfg, batch, max_seq)
    # AOT-compile both steps so every reported time is pure execution
    # (trace+compile otherwise dominates prefill_s at smoke scale and any
    # PR touching compile time would show a phantom throughput change);
    # lowering with the pre-prefill cache is sound -- cache shapes are
    # static across the whole generation.
    t0 = time.time()
    prefill = jax.jit(lambda p, t, c, f: lm.prefill(p, cfg, t, c, f),
                      donate_argnums=(2,)
                      ).lower(params, tokens, cache, fe).compile()
    tok0 = jnp.zeros((batch, 1), jnp.int32)
    decode = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c),
                     donate_argnums=(2,)).lower(params, tok0, cache).compile()
    t_compile = time.time() - t0

    t0 = time.time()
    logits, cache = prefill(params, tokens, cache, fe)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]                      # device-side; one transfer at the end
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen_tokens = np.asarray(jnp.concatenate(out, axis=1))
    t_decode = time.time() - t0

    decode_steps = gen - 1
    decode_tok_s = (batch * decode_steps / t_decode
                    if decode_steps and t_decode > 0 else float("nan"))
    stats = dict(
        arch=arch, batch=batch, prompt_len=prompt_len, gen=gen,
        cim=cim, packed=pack,
        compile_s=round(t_compile, 4),
        pack_s=round(t_pack, 4),
        prefill_s=round(t_prefill, 4),
        decode_s=round(t_decode, 4),
        decode_tok_s=round(decode_tok_s, 2),
        prefill_tok_s=round(batch * prompt_len / t_prefill, 2)
        if t_prefill > 0 else float("nan"),
    )
    mode = ("cim-packed" if pack else "cim-unpacked") if cim else "fp"
    print(f"[serve] {arch} ({mode}): batch {batch}, prompt {prompt_len}, "
          f"gen {gen} | compile {t_compile:.2f}s, pack {t_pack:.2f}s, "
          f"prefill {t_prefill:.2f}s, decode {t_decode:.2f}s "
          f"({decode_tok_s:.1f} tok/s)")
    if return_stats:
        return gen_tokens, stats
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cim", action="store_true")
    ap.add_argument("--no-pack", dest="pack", action="store_false",
                    help="legacy per-call weight conditioning (baseline)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen, cim=args.cim,
          temperature=args.temperature, pack=args.pack)


if __name__ == "__main__":
    main()
