"""Serving drivers: fixed-batch lock-step loop + continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--cim] [--no-pack] [--continuous]

``serve`` is the LOCK-STEP driver: one fixed batch is prefilled together
and decodes in lock step for exactly ``gen`` tokens -- there is no
per-request stop handling here, and a short request occupies its slot
until the whole batch ends.  It is the baseline the continuous-batching
scheduler (launch/scheduler.py, ``serve_continuous`` below) is measured
against: the scheduler tracks per-slot EOS/max-new-tokens on device and
refills freed slots from a request queue mid-stream.

Serving dataflow under --cim (weight-stationary, like the silicon):

  pack     : every projection is quantized + bit-plane-decomposed ONCE
             (lm.pack_cim_params), off the token loop -- the array write.
  prefill  : one batched forward over the prompt fills the KV cache.
  decode   : activation-only quantization per token; generated tokens are
             collected ON DEVICE and transferred once at the end.

``--no-pack`` keeps the legacy per-call weight conditioning -- the
pre-refactor baseline benchmarks compare against; tokens are bit-identical
either way.  Exercised by tests/test_train_serve.py,
tests/test_scheduler.py, tests/test_engine.py and examples/cim_serve.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config
from ..data import DataConfig, batch_at
from ..models import lm
from ..obs import REGISTRY, ObsConfig, get_tracer, set_trace_path, span
from .paging import PagedLayout
from .scheduler import (ContinuousBatchingScheduler, mixed_length_requests,
                        sampling_key)


def serve(arch: str, smoke: bool = True, batch: int = 4, prompt_len: int = 32,
          gen: int = 16, cim: bool = False, temperature: float = 0.0,
          seed: int = 0, pack: bool = True, return_stats: bool = False,
          plan=None, noise_seed=None, fuse: bool = True,
          metrics: bool = False):
    """Returns generated tokens (batch, gen); with ``return_stats=True``,
    returns (tokens, stats) where stats separates compile / pack /
    prefill / decode time -- prefill and decode steps are AOT-compiled up
    front, so every throughput number is pure execution.

    ``metrics=True`` records pack/compile/prefill/decode spans through
    the obs tracer (obs/trace.py), publishes the run's totals into the
    process metrics registry (``repro.obs.REGISTRY`` -- Prometheus text
    via ``export_prometheus()``), and attaches the registry snapshot as
    ``stats["metrics"]``.  The lock-step driver has no device rings --
    those are a scheduler feature (``serve_continuous(metrics=True)``).

    ``plan`` (a repro.plan.DeploymentPlan) serves each projection under
    its own macro config/fidelity (implies cim); plans are static, so the
    AOT-compiled prefill/decode executables serve the mixed-fidelity model
    with zero recompiles.  ``noise_seed`` turns on deterministic analog-
    noise emulation (cfg.cim_noise_seed) -- packed and unpacked serving
    stay bit-identical under it.  ``fuse`` (default on) enables horizontal
    projection fusion (cfg.cim_fuse): plan-compatible QKV / gate-up /
    mamba-input projections execute as one wide macro GEMM each, tokens
    bit-identical to the unfused path (``fuse=False`` is the A/B baseline).
    """
    cfg = get_config(arch, smoke=smoke)
    if plan is not None:
        cim = True
        cfg = dataclasses.replace(cfg, cim_plan=plan)
    if not fuse:
        cfg = dataclasses.replace(cfg, cim_fuse=False)
    if noise_seed is not None:
        if not cim:
            raise ValueError(
                "noise_seed emulates the macro's analog noise and needs "
                "cim=True (or a plan); without it serving would silently "
                "run noise-free")
        cfg = dataclasses.replace(cfg, cim_noise_seed=noise_seed)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    pack = pack and cim
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                      global_batch=batch, seed=seed,
                      n_frontend_tokens=cfg.n_frontend_tokens
                      if cfg.family == "vlm" else 0,
                      d_model=cfg.d_model)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    # sampling draws from its own stream -- the decode loop used to split
    # the params-init key, so init and sampling consumed the same PRNG
    # stream (regression-tested in tests/test_scheduler.py)
    skey = sampling_key(seed)
    b = batch_at(dcfg, 0)
    tokens = jnp.asarray(b["tokens"])
    fe = (jnp.asarray(b["frontend_embs"]).astype(jnp.bfloat16)
          if "frontend_embs" in b else None)

    t_pack = 0.0
    if pack:
        t0 = time.time()
        # pack_cim_params is jit-compiled internally (eager == jit packs
        # are bit-identical); under a plan each projection packs for its
        # own entry's macro config
        with span("serve.pack", arch=arch):
            params = jax.block_until_ready(lm.pack_cim_params(params, cfg))
        t_pack = time.time() - t0

    n_frontend = fe.shape[1] if fe is not None else 0
    max_seq = prompt_len + gen + n_frontend
    cache = lm.init_cache(cfg, batch, max_seq)
    # AOT-compile both steps so every reported time is pure execution
    # (trace+compile otherwise dominates prefill_s at smoke scale and any
    # PR touching compile time would show a phantom throughput change);
    # lowering with the pre-prefill cache is sound -- cache shapes are
    # static across the whole generation.
    t0 = time.time()
    with span("serve.compile", arch=arch):
        prefill = jax.jit(lambda p, t, c, f: lm.prefill(p, cfg, t, c, f),
                          donate_argnums=(2,)
                          ).lower(params, tokens, cache, fe).compile()
        tok0 = jnp.zeros((batch, 1), jnp.int32)
        decode = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c),
                         donate_argnums=(2,)
                         ).lower(params, tok0, cache).compile()
    t_compile = time.time() - t0

    def sample(logits):
        """One token per row: greedy at temperature 0, else categorical.
        The key split happens only when sampling -- a greedy run must not
        pay per-token split dispatches inside the timed decode loop."""
        nonlocal skey
        if temperature > 0:
            skey, sub = jax.random.split(skey)
            return jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        return jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

    t0 = time.time()
    with span("serve.prefill", arch=arch):
        logits, cache = prefill(params, tokens, cache, fe)
        # the first generated token goes through the same sampler as the
        # rest (it used to be unconditionally greedy while later tokens
        # sampled)
        tok = sample(logits)
        tok.block_until_ready()
    t_prefill = time.time() - t0

    out = [tok]                      # device-side; one transfer at the end
    t0 = time.time()
    with span("serve.decode", arch=arch, steps=gen - 1):
        for i in range(gen - 1):
            logits, cache = decode(params, tok, cache)
            tok = sample(logits)
            out.append(tok)
        gen_tokens = np.asarray(jnp.concatenate(out, axis=1))
    t_decode = time.time() - t0

    decode_steps = gen - 1
    decode_tok_s = (batch * decode_steps / t_decode
                    if decode_steps and t_decode > 0 else float("nan"))
    # the prefill forward covers frontend embeddings too (vlm prepends
    # n_frontend_tokens) -- count the true prefill length, not just text
    prefill_len = prompt_len + n_frontend
    stats = dict(
        arch=arch, batch=batch, prompt_len=prompt_len, gen=gen,
        cim=cim, packed=pack,
        compile_s=round(t_compile, 4),
        pack_s=round(t_pack, 4),
        prefill_s=round(t_prefill, 4),
        decode_s=round(t_decode, 4),
        decode_tok_s=round(decode_tok_s, 2),
        prefill_tok_s=round(batch * prefill_len / t_prefill, 2)
        if t_prefill > 0 else float("nan"),
    )
    if metrics:
        REGISTRY.counter(
            "serve_tokens_total",
            "tokens emitted by the serving drivers").inc(batch * gen)
        REGISTRY.gauge("serve_decode_tok_s",
                       "lock-step decode throughput").set(decode_tok_s)
        REGISTRY.histogram(
            "serve_decode_step_seconds", "mean decode-step latency",
            buckets=(0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0)).observe_many(
            [t_decode / decode_steps] * decode_steps if decode_steps else [])
        stats["metrics"] = REGISTRY.snapshot()
        stats["spans"] = get_tracer().drain()
    mode = ("cim-packed" if pack else "cim-unpacked") if cim else "fp"
    print(f"[serve] {arch} ({mode}): batch {batch}, prompt {prompt_len}, "
          f"gen {gen} | compile {t_compile:.2f}s, pack {t_pack:.2f}s, "
          f"prefill {t_prefill:.2f}s, decode {t_decode:.2f}s "
          f"({decode_tok_s:.1f} tok/s)")
    if return_stats:
        return gen_tokens, stats
    return gen_tokens


def serve_speculative(arch: str, smoke: bool = True, batch: int = 2,
                      prompt_len: int = 16, gen: int = 48, draft_k: int = 8,
                      draft_adc_bits=None, draft_plan=None,
                      temperature: float = 0.0, seed: int = 0, plan=None,
                      cim: bool = True, pack: bool = True, fuse: bool = True,
                      compare_baseline: bool = True,
                      return_stats: bool = False, metrics: bool = False):
    """Plan-cascade speculative lock-step driver: ONE AOT dispatch per
    draft/verify ROUND instead of one per token.

    The draft plan (``plan.draft_plan_for_model``: the all-analog shadow
    of the serving plan, or ``draft_plan`` verbatim) serves from the SAME
    packed weights as the verify plan -- no second pack, no recompiles.
    Each round drafts ``draft_k`` tokens under the draft config, rolls the
    cache positions back, verifies all k+1 positions in one wide skinny-M
    forward under the deployed config, and accepts the longest agreeing
    prefix plus a correction token.  Because the whole round is one
    executable, the per-dispatch overhead that dominates ``serve``'s
    decode loop at smoke scale is amortized over every accepted token --
    that, plus the analog draft skipping the DCIM plane dot, is the
    speedup.

    Greedy output is bit-identical to ``serve`` (asserted when
    ``compare_baseline``); temperature>0 uses standard rejection sampling,
    so it matches the verify model in distribution (not bitwise -- the
    baseline consumes its key stream once per token, this driver once per
    draft/uniform/resample event).

    Returns tokens (batch, gen); with ``return_stats=True``, (tokens,
    stats) including acceptance_rate, tokens_per_round and (when
    ``compare_baseline``) ``decode_speedup_speculative``.
    """
    cfg = get_config(arch, smoke=smoke)
    if plan is not None:
        cim = True
        cfg = dataclasses.replace(cfg, cim_plan=plan)
    if not fuse:
        cfg = dataclasses.replace(cfg, cim_fuse=False)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    pack = pack and cim
    if draft_plan is None:
        from ..plan import draft_plan_for_model
        draft_plan = draft_plan_for_model(cfg, draft_adc_bits)
    dcfg = dataclasses.replace(cfg, cim_plan=draft_plan) if cim else cfg
    K = draft_k

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                    global_batch=batch, seed=seed, d_model=cfg.d_model)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    tokens = jnp.asarray(batch_at(dc, 0)["tokens"])
    t_pack = 0.0
    if pack:
        t0 = time.time()
        params = jax.block_until_ready(lm.pack_cim_params(params, cfg))
        t_pack = time.time() - t0

    # live rows can overshoot the target by up to one block per round and
    # verify probes K rows past the frontier -- size the cache for both
    cache = lm.init_cache(cfg, batch, prompt_len + gen + 2 * K + 1)

    def round_fn(params, last0, cache, key, live):
        pos0 = cache["pos"]
        last, d_toks, d_logits = last0, [], []
        for _ in range(K):
            logits, cache = lm.decode_step(params, dcfg, last, cache,
                                           live=live)
            key, sub = jax.random.split(key)
            if temperature > 0:
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature).astype(jnp.int32)
                d_logits.append(logits[:, -1])
            else:
                tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            d_toks.append(tok)
            last = tok[:, None]
        drafts = jnp.stack(d_toks, axis=1)                  # (B, K)
        vtoks = jnp.concatenate([last0, drafts], axis=1)    # (B, K+1)
        cache = dict(cache, pos=pos0)                       # rollback
        vlogits, cache = lm.verify_step(params, cfg, vtoks, cache)
        cand = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)
        if temperature <= 0:
            v_arg = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)
            match = (v_arg[:, :K] == drafts).astype(jnp.int32)
            n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            corr = v_arg
        else:
            dlg = jnp.stack(d_logits, axis=1)
            p_d = jax.nn.softmax(dlg / temperature, axis=-1)
            p_v = jax.nn.softmax(vlogits / temperature, axis=-1)
            pd_tok = jnp.take_along_axis(p_d, drafts[..., None], -1)[..., 0]
            pv_tok = jnp.take_along_axis(
                p_v[:, :K], drafts[..., None], -1)[..., 0]
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, drafts.shape)
            acc = (u * pd_tok < pv_tok).astype(jnp.int32)
            n_acc = jnp.sum(jnp.cumprod(acc, axis=1), axis=1)
            pv_n = jnp.take_along_axis(p_v, n_acc[:, None, None], 1)[:, 0]
            pd_ext = jnp.concatenate(
                [p_d, jnp.zeros_like(p_d[:, :1])], axis=1)
            pd_n = jnp.take_along_axis(pd_ext, n_acc[:, None, None], 1)[:, 0]
            res = jnp.maximum(pv_n - pd_n, 0.0)
            tot = jnp.sum(res, axis=-1, keepdims=True)
            res = jnp.where(tot > 0, res / jnp.maximum(tot, 1e-38), pv_n)
            key, sub = jax.random.split(key)
            corr = jax.random.categorical(
                sub, jnp.log(jnp.maximum(res, 1e-38)))[:, None].astype(
                jnp.int32)
        cols = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
        emitted = jnp.where(cols == n_acc[:, None], corr, cand)
        n_emit = jnp.where(live, n_acc + 1, 0)
        new_last = jnp.where(
            live[:, None],
            jnp.take_along_axis(emitted, n_acc[:, None], axis=1), last0)
        cache = dict(cache, pos=pos0 + n_emit)
        return emitted, n_emit, new_last, cache, key

    t0 = time.time()
    prefill = jax.jit(lambda p, t, c: lm.prefill(p, cfg, t, c),
                      donate_argnums=(2,)
                      ).lower(params, tokens, cache).compile()
    tok0 = jnp.zeros((batch, 1), jnp.int32)
    key0 = sampling_key(seed)
    live0 = jnp.ones((batch,), jnp.bool_)
    round_exe = jax.jit(round_fn, donate_argnums=(2,)).lower(
        params, tok0, cache, key0, live0).compile()
    t_compile = time.time() - t0

    t0 = time.time()
    logits, cache = prefill(params, tokens, cache)
    key = key0
    if temperature > 0:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
    else:
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    tok.block_until_ready()
    t_prefill = time.time() - t0

    target = gen - 1                      # decode tokens after the first
    first = np.asarray(tok)[:, 0]
    rows = [[int(t)] for t in first]
    counts = np.zeros(batch, np.int64)
    n_rounds = n_drafted = n_accepted = 0
    t0 = time.time()
    while counts.min() < target:
        live = jnp.asarray(counts < target)
        emitted, n_emit, tok, cache, key = round_exe(
            params, tok, cache, key, live)
        em, ne = np.asarray(emitted), np.asarray(n_emit)
        for b in range(batch):
            rows[b].extend(em[b, :ne[b]].tolist())
        n_drafted += K * int((counts < target).sum())
        n_accepted += int(np.maximum(ne - 1, 0).sum())
        counts += ne
        n_rounds += 1
    t_decode = time.time() - t0
    gen_tokens = np.asarray([r[:gen] for r in rows], dtype=np.int64)

    decode_tok_s = (batch * target / t_decode if t_decode > 0
                    else float("nan"))
    stats = dict(
        arch=arch, batch=batch, prompt_len=prompt_len, gen=gen,
        cim=cim, packed=pack, draft_k=K,
        draft_plan=draft_plan.summary()["<default>"],
        compile_s=round(t_compile, 4), pack_s=round(t_pack, 4),
        prefill_s=round(t_prefill, 4), decode_s=round(t_decode, 4),
        decode_tok_s=round(decode_tok_s, 2), n_rounds=n_rounds,
        n_drafted=n_drafted, n_accepted=n_accepted,
        acceptance_rate=round(n_accepted / n_drafted, 4) if n_drafted
        else float("nan"),
        tokens_per_round=round(batch * target / n_rounds, 2) if n_rounds
        else float("nan"),
    )
    if metrics:
        REGISTRY.counter("serve_tokens_total",
                         "tokens emitted by the serving drivers").inc(
            batch * gen)
        REGISTRY.counter("serve_drafted_total",
                         "speculative draft tokens proposed").inc(n_drafted)
        REGISTRY.counter("serve_accepted_total",
                         "speculative draft tokens accepted").inc(n_accepted)
        REGISTRY.gauge("serve_decode_tok_s",
                       "lock-step decode throughput").set(decode_tok_s)
        stats["metrics"] = REGISTRY.snapshot()
        stats["spans"] = get_tracer().drain()
    print(f"[serve-spec] {arch} (k={K}, draft {stats['draft_plan']}): "
          f"batch {batch}, gen {gen} | decode {t_decode:.2f}s "
          f"({decode_tok_s:.1f} tok/s), acceptance "
          f"{stats['acceptance_rate']:.0%}")
    if compare_baseline:
        base_toks, base = serve(arch, smoke=smoke, batch=batch,
                                prompt_len=prompt_len, gen=gen, cim=cim,
                                temperature=temperature, seed=seed,
                                pack=pack, return_stats=True, plan=plan,
                                fuse=fuse)
        if temperature <= 0:
            np.testing.assert_array_equal(
                gen_tokens, base_toks,
                err_msg="speculative greedy decode changed tokens vs the "
                        "non-speculative baseline")
            stats["tokens_match_baseline"] = True
        stats["baseline_decode_tok_s"] = base["decode_tok_s"]
        stats["decode_speedup_speculative"] = round(
            decode_tok_s / base["decode_tok_s"], 2)
        print(f"[serve-spec] speedup vs non-speculative: "
              f"{stats['decode_speedup_speculative']:.2f}x"
              + (" (tokens identical)" if temperature <= 0 else ""))
    if return_stats:
        return gen_tokens, stats
    return gen_tokens


def serve_continuous(arch: str, smoke: bool = True, slots: int = 2,
                     prompt_len: int = 16, n_requests: int = 8,
                     stop_lengths=(4, 16, 8, 12), cim: bool = False,
                     pack: bool = True, temperature: float = 0.0,
                     seed: int = 0, compare_lockstep: bool = True,
                     repeats: int = 1, plan=None, fuse: bool = True,
                     draft_k: int = 0, draft_plan=None, draft_adc_bits=None,
                     paged: PagedLayout | None = None,
                     prefill_chunk: int | None = None,
                     prefix_sharing: bool = True,
                     adaptive_draft_k: bool = False,
                     metrics: bool | ObsConfig = False):
    """Continuous-batching driver: a mixed-length request queue served
    from a fixed pool of ``slots`` decode slots (launch/scheduler.py).

    Returns (tokens_by_rid, stats).  With ``compare_lockstep=True`` the
    same requests also run through the lock-step wave baseline on the SAME
    compiled executables and the per-request tokens are asserted
    bit-identical -- the scheduler may only reorder work, never change it.
    ``repeats`` reruns both drivers and keeps each one's best run for the
    headline numbers plus the per-run median (``tok_s_median``) for stable
    ratios -- host scheduler noise at smoke scale otherwise swamps any
    single-draw comparison.  ``plan`` serves a mixed-fidelity
    DeploymentPlan through the unchanged scheduler (implies cim).

    ``draft_k > 0`` turns on plan-cascade speculative rounds in the
    scheduler (``draft_plan`` or the derived all-analog shadow of the
    serving plan, same packed weights).  Greedy tokens stay bit-identical
    to the non-speculative lock-step baseline, so the parity assert is
    kept; at temperature > 0 speculative sampling is only
    distribution-identical and the lock-step comparison is skipped.

    ``metrics`` (True or an ObsConfig) compiles the scheduler's device-
    resident telemetry rings into the serve loop (launch/scheduler.py):
    the harvested snapshot lands in ``stats["telemetry"]`` and the
    process registry (``repro.obs.REGISTRY``), and the pack/compile/
    workload phases are span-traced.  Tokens are bit-identical with
    metrics on or off -- the rings only read values the loop already
    computes.
    """
    obs = (metrics if isinstance(metrics, ObsConfig)
           else (ObsConfig() if metrics else None))
    if draft_k and temperature > 0:
        compare_lockstep = False
    compare_contiguous = paged is not None and compare_lockstep
    if paged is not None:
        compare_lockstep = False    # lock-step baseline is contiguous-only
    cfg = get_config(arch, smoke=smoke)
    if plan is not None:
        cim = True
        cfg = dataclasses.replace(cfg, cim_plan=plan)
    if not fuse:
        cfg = dataclasses.replace(cfg, cim_fuse=False)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    pack = pack and cim
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    t_pack = 0.0
    if pack:
        t0 = time.time()
        with span("serve.pack", arch=arch):
            params = jax.block_until_ready(lm.pack_cim_params(params, cfg))
        t_pack = time.time() - t0

    if draft_k and draft_plan is None:
        from ..plan import draft_plan_for_model
        draft_plan = draft_plan_for_model(cfg, draft_adc_bits)

    requests = mixed_length_requests(n_requests, prompt_len, cfg.vocab_size,
                                     stop_lengths=stop_lengths, seed=seed)
    t0 = time.time()
    sched = ContinuousBatchingScheduler(
        params, cfg, slots=slots, prompt_len=prompt_len,
        max_new_cap=max(stop_lengths), temperature=temperature, seed=seed,
        draft_k=draft_k, draft_plan=draft_plan, paged=paged,
        prefill_chunk=prefill_chunk, prefix_sharing=prefix_sharing,
        adaptive_draft_k=adaptive_draft_k, obs=obs)
    with span("serve.compile", arch=arch, n_queue=n_requests):
        sched.compile_for(n_requests, lockstep=compare_lockstep)
    t_compile = time.time() - t0

    with span("serve.workload", arch=arch, n_requests=n_requests,
              repeats=repeats):
        runs = [sched.run(requests) for _ in range(repeats)]
    for other in runs[1:]:
        got, want = other.tokens_by_rid(), runs[0].tokens_by_rid()
        for rid in want:
            np.testing.assert_array_equal(got[rid], want[rid])
    report = max(runs, key=lambda r: r.tok_s)
    stats = dict(arch=arch, slots=slots, prompt_len=prompt_len,
                 n_requests=n_requests, stop_lengths=list(stop_lengths),
                 cim=cim, packed=pack, compile_s=round(t_compile, 4),
                 pack_s=round(t_pack, 4), repeats=repeats,
                 tok_s_median=round(
                     statistics.median(r.tok_s for r in runs), 2),
                 continuous=report.summary())
    if draft_k:
        stats["draft_k"] = draft_k
        stats["draft_plan"] = draft_plan.summary()["<default>"]
        if adaptive_draft_k:
            stats["adaptive_draft_k"] = True
    if paged is not None:
        stats["paged"] = dict(block_size=paged.block_size,
                              n_tbl=paged.n_tbl, n_blocks=paged.n_blocks,
                              prefill_chunk=sched.prefill_chunk,
                              prefix_sharing=prefix_sharing,
                              peak_blocks=report.peak_blocks,
                              kv_bytes_peak=sched.kv_bytes_paged(
                                  report.peak_blocks),
                              kv_bytes_contiguous=sched.kv_bytes_contiguous())
        plan = getattr(sched, "last_prefix_plan", None)
        if plan is not None:
            stats["paged"]["prefix_plan"] = plan.stats()
    if compare_contiguous:
        # paged vs contiguous parity: the paged pool may only change WHERE
        # KV rows live, never a single token
        ref = ContinuousBatchingScheduler(
            params, cfg, slots=slots, prompt_len=prompt_len,
            max_new_cap=max(stop_lengths), temperature=temperature,
            seed=seed, draft_k=draft_k, draft_plan=draft_plan)
        got, want = report.tokens_by_rid(), ref.run(requests).tokens_by_rid()
        for rid in want:
            np.testing.assert_array_equal(
                got[rid], want[rid],
                err_msg=f"request {rid}: paged KV changed tokens vs the "
                        "contiguous scheduler")
        stats["tokens_match_contiguous"] = True
    if compare_lockstep:
        base_runs = [sched.run_lockstep(requests) for _ in range(repeats)]
        base = max(base_runs, key=lambda r: r.tok_s)
        got, want = report.tokens_by_rid(), base.tokens_by_rid()
        for rid in want:
            np.testing.assert_array_equal(
                got[rid], want[rid],
                err_msg=f"request {rid}: continuous batching changed tokens "
                        "vs the lock-step baseline")
        base_median = statistics.median(r.tok_s for r in base_runs)
        stats["lockstep"] = base.summary()
        stats["lockstep_tok_s_median"] = round(base_median, 2)
        stats["tokens_match_lockstep"] = True
        stats["speedup_vs_lockstep"] = round(
            stats["tok_s_median"] / base_median, 2) if base_median > 0 \
            else float("nan")
    if obs is not None and report.obs is not None:
        report.obs.register(REGISTRY)
        REGISTRY.gauge("serve_decode_tok_s",
                       "continuous-batching throughput").set(report.tok_s)
        stats["telemetry"] = report.obs.to_dict()
        stats["metrics"] = REGISTRY.snapshot()
        stats["spans"] = get_tracer().drain()
    mode = ("cim-packed" if pack else "cim-unpacked") if cim else "fp"
    if draft_k:
        mode += f"+spec-k{draft_k}"
        if adaptive_draft_k:
            mode += "-adaptive"
    if paged is not None:
        mode += f"+paged-bs{paged.block_size}"
    line = (f"[serve-cb] {arch} ({mode}): {n_requests} reqs x "
            f"stops{tuple(stop_lengths)} over {slots} slots | "
            f"{report.tok_s:.1f} tok/s, occupancy {report.occupancy:.0%}")
    if compare_lockstep:
        line += (f" | lock-step {stats['lockstep']['tok_s']:.1f} tok/s "
                 f"({stats['speedup_vs_lockstep']:.2f}x, tokens identical)")
    print(line)
    return report.tokens_by_rid(), stats


def serve_guarded(arch: str, smoke: bool = True, slots: int = 2,
                  prompt_len: int = 16, n_requests: int = 8,
                  stop_lengths=(4, 16, 8, 12), temperature: float = 0.0,
                  seed: int = 0, plan=None, fuse: bool = True,
                  draft_k: int = 0, paged: PagedLayout | None = None,
                  prefill_chunk: int | None = None,
                  prefix_sharing: bool = True, fault=None, watchdog=True,
                  probe: bool = True, segment_iters: int = 8,
                  start_rung: int | None = None):
    """Watchdog-guarded continuous serving with plan-degradation failover.

    The chaos-engineering driver: the workload runs through
    ``resilience.failover.GuardedServer`` -- one pack-compatible
    scheduler per ladder rung over ONE packed weight set, executed as
    budget-bounded device-resident segments with health read at each
    segment boundary (ADC clip rate, speculative acceptance, golden
    probe).  ``fault`` (a ``resilience.faults.FaultModel``, or its
    ``FaultModel.parse`` spec string like
    ``"gain_amp=0.5,schedule=ramp,onset=8,period=32"``) arms
    deterministic analog fault injection inside the compiled loop, so
    detection and failover can be demonstrated end-to-end.  Fault-free
    guarded serving emits tokens bit-identical to the plain scheduler
    and lowers byte-identical StableHLO (tests/test_resilience.py).

    Returns (tokens_by_rid, stats); ``stats["resilience"]`` carries the
    ladder / watchdog log (``ResilienceLog.to_dict``).
    """
    from ..resilience.failover import GuardedServer, default_probe
    from ..resilience.faults import FaultModel
    from ..resilience.watchdog import Watchdog, WatchdogConfig

    if isinstance(fault, str):
        fault = FaultModel.parse(fault)
    if watchdog is True:
        watchdog = Watchdog()
    elif isinstance(watchdog, WatchdogConfig):
        watchdog = Watchdog(watchdog)
    elif watchdog is False:
        watchdog = None
    cfg = get_config(arch, smoke=smoke)
    if plan is not None:
        cfg = dataclasses.replace(cfg, cim_plan=plan)
    if not fuse:
        cfg = dataclasses.replace(cfg, cim_fuse=False)
    # resilience is a macro feature: the ladder degrades between analog /
    # hybrid / digital executions of one packed weight set
    cfg = dataclasses.replace(cfg, cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    t0 = time.time()
    with span("serve.pack", arch=arch):
        params = jax.block_until_ready(lm.pack_cim_params(params, cfg))
    t_pack = time.time() - t0

    gp = default_probe(params, fault=fault) if probe else None
    server = GuardedServer(
        params, cfg, slots=slots, prompt_len=prompt_len,
        max_new_cap=max(stop_lengths), temperature=temperature, seed=seed,
        draft_k=draft_k, paged=paged, prefill_chunk=prefill_chunk,
        prefix_sharing=prefix_sharing, watchdog=watchdog, probe=gp,
        fault=fault, segment_iters=segment_iters, start_rung=start_rung)
    requests = mixed_length_requests(n_requests, prompt_len, cfg.vocab_size,
                                     stop_lengths=stop_lengths, seed=seed)
    t0 = time.time()
    with span("serve.compile", arch=arch, n_queue=n_requests):
        server.compile_for(n_requests)
    t_compile = time.time() - t0
    with span("serve.workload", arch=arch, n_requests=n_requests):
        report, log = server.run(requests)

    stats = dict(arch=arch, slots=slots, prompt_len=prompt_len,
                 n_requests=n_requests, stop_lengths=list(stop_lengths),
                 draft_k=draft_k, segment_iters=segment_iters,
                 fault=None if fault is None else dataclasses.asdict(fault),
                 pack_s=round(t_pack, 4), compile_s=round(t_compile, 4),
                 n_compiles=server.n_compiles,
                 continuous=report.summary(),
                 resilience=log.to_dict())
    state = watchdog.state if watchdog is not None else "(no watchdog)"
    line = (f"[serve-guarded] {arch}: {n_requests} reqs over {slots} slots "
            f"| {report.tok_s:.1f} tok/s | health {state}, serving rung "
            f"'{log.rung_labels[log.final_rung]}'")
    if log.actions:
        line += f", {len(log.actions)} failover action(s)"
    if fault is not None and log.detection_tokens is not None:
        line += f" | fault detected at {log.detection_tokens} tokens"
    print(line)
    return report.tokens_by_rid(), stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--batch", type=int, default=4,
                    help="lock-step batch / continuous slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cim", action="store_true")
    ap.add_argument("--no-pack", dest="pack", action="store_false",
                    help="legacy per-call weight conditioning (baseline)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a mixed-length queue")
    ap.add_argument("--requests", type=int, default=8,
                    help="(--continuous) queued request count")
    ap.add_argument("--speculative", action="store_true",
                    help="plan-cascade speculative decoding (analog draft "
                         "/ deployed verify from one packed weight set)")
    ap.add_argument("--draft-k", type=int, default=8,
                    help="draft block length per speculative round")
    ap.add_argument("--draft-adc-bits", type=int, default=None,
                    help="draft plan SAR width (default: smallest "
                         "non-clipping width per entry)")
    ap.add_argument("--adaptive-draft-k", action="store_true",
                    help="feed measured acceptance back into draft depth")
    ap.add_argument("--paged-blocks", type=int, default=0,
                    help="(--continuous) KV pool size in blocks; 0 keeps "
                         "the contiguous per-slot layout")
    ap.add_argument("--block-size", type=int, default=16,
                    help="(--paged-blocks) tokens per KV block")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="(--paged-blocks) prompt tokens prefilled per "
                         "scheduler iteration (default: whole prompt)")
    ap.add_argument("--no-prefix-sharing", dest="prefix_sharing",
                    action="store_false",
                    help="(--paged-blocks) disable shared-prefix reuse")
    ap.add_argument("--watchdog", action="store_true",
                    help="guarded continuous serving: drift watchdog + "
                         "plan-degradation failover ladder over one pack")
    ap.add_argument("--inject-fault", type=str, default=None, metavar="SPEC",
                    help="chaos: arm a deterministic analog FaultModel "
                         "inside the compiled loop, e.g. 'gain_amp=0.5,"
                         "schedule=ramp,onset=8,period=32' (see "
                         "resilience.faults.FaultModel; implies --watchdog)")
    ap.add_argument("--segment-iters", type=int, default=8,
                    help="(--watchdog) scheduler iterations per guarded "
                         "segment between health checks")
    ap.add_argument("--metrics", action="store_true",
                    help="device-resident telemetry rings + metrics registry")
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the Prometheus text exposition here")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="append JSON-lines span traces here")
    args = ap.parse_args()
    metrics = args.metrics or bool(args.metrics_out)
    if args.trace_out:
        set_trace_path(args.trace_out)
    paged = None
    if args.paged_blocks:
        from .paging import cdiv
        max_seq = (args.prompt_len + 16
                   + (args.draft_k if args.speculative else 0))
        paged = PagedLayout(block_size=args.block_size,
                            n_tbl=cdiv(max_seq, args.block_size),
                            n_blocks=args.paged_blocks)
    if args.watchdog or args.inject_fault:
        serve_guarded(args.arch, smoke=args.smoke, slots=args.batch,
                      prompt_len=args.prompt_len, n_requests=args.requests,
                      temperature=args.temperature,
                      draft_k=args.draft_k if args.speculative else 0,
                      paged=paged, prefill_chunk=args.prefill_chunk,
                      prefix_sharing=args.prefix_sharing,
                      fault=args.inject_fault,
                      segment_iters=args.segment_iters)
    elif args.continuous:
        serve_continuous(args.arch, smoke=args.smoke, slots=args.batch,
                         prompt_len=args.prompt_len,
                         n_requests=args.requests, cim=args.cim,
                         pack=args.pack, temperature=args.temperature,
                         draft_k=args.draft_k if args.speculative else 0,
                         draft_adc_bits=args.draft_adc_bits,
                         adaptive_draft_k=args.adaptive_draft_k,
                         paged=paged, prefill_chunk=args.prefill_chunk,
                         prefix_sharing=args.prefix_sharing, metrics=metrics)
    elif args.speculative:
        serve_speculative(args.arch, smoke=args.smoke, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen,
                          draft_k=args.draft_k,
                          draft_adc_bits=args.draft_adc_bits,
                          temperature=args.temperature, cim=args.cim,
                          pack=args.pack, metrics=metrics)
    else:
        serve(args.arch, smoke=args.smoke, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen, cim=args.cim,
              temperature=args.temperature, pack=args.pack, metrics=metrics)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(REGISTRY.export_prometheus())
        print(f"[serve] metrics exposition -> {args.metrics_out}")


if __name__ == "__main__":
    main()
