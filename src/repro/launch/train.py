"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt [--resume] [--fail-at 20] [--cim]

Features exercised here (and by tests/test_train.py):
  * deterministic step-indexed data (skip-ahead on resume),
  * atomic checkpoints + keep-last-k + resume-from-latest,
  * failure injection (--fail-at) to prove restart-correctness,
  * WSD or cosine schedule per the arch config,
  * CIM execution mode (--cim): projections through the emulated macro,
  * mesh-aware sharding when >1 device is available.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt
from ..configs import ARCHS, get_config
from ..data import DataConfig, batch_at
from ..models import lm
from ..optim import init_opt_state
from .specs import make_train_step


def train(arch: str, smoke: bool = True, steps: int = 50,
          ckpt_dir: str = "", resume: bool = False, fail_at: int = -1,
          ckpt_every: int = 10, batch: int = 8, seq: int = 64,
          cim: bool = False, log_every: int = 10, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=seed,
                      n_frontend_tokens=cfg.n_frontend_tokens
                      if cfg.family == "vlm" else 0,
                      d_model=cfg.d_model)
    step_fn, ocfg = make_train_step(cfg)
    ocfg = dataclasses.replace(ocfg, total_steps=steps,
                               warmup=max(1, steps // 10))

    key = jax.random.PRNGKey(seed)
    params, axes = lm.init(key, cfg)
    opt_state = init_opt_state(params, ocfg)
    start = 0
    if resume and ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state = ckpt.restore(ckpt_dir, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = ckpt.load_meta(ckpt_dir)["step"]
        print(f"[train] resumed from step {start}")

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    for step in range(start, steps):
        if step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        b = batch_at(dcfg, step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if "frontend_embs" in b:
            b["frontend_embs"] = b["frontend_embs"].astype(jnp.bfloat16)
        t0 = time.time()
        params, opt_state, metrics = jit_step(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({time.time()-t0:.2f}s)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state},
                      meta={"arch": arch, "loss": loss})
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--cim", action="store_true")
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, ckpt_dir=args.ckpt_dir, resume=args.resume,
          fail_at=args.fail_at, cim=args.cim)


if __name__ == "__main__":
    main()
