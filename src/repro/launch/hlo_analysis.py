"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a
scanned-layers model under-reports FLOPs by ~n_layers and collective bytes
by every scan trip.  This parser rebuilds the call graph (while bodies with
``backend_config={"known_trip_count":{"n":...}}``, fusions, to_apply
computations), propagates multipliers from ENTRY, and aggregates:

  * dot_flops            -- 2 * prod(result dims) * prod(contracting dims)
  * collective bytes     -- result bytes per op kind (all-reduce/all-gather/
                            reduce-scatter/all-to-all/collective-permute)
  * traffic_bytes        -- operand+result bytes of materialising ops
                            (a first-order HBM-traffic model: fusions count
                            only their boundary tensors -- that is the point
                            of fusion)

Everything is PER-DEVICE: the compiled module is the SPMD-partitioned one.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_CALL_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose result (and operands) hit HBM; fused interiors excluded by
# construction because we only see the fusion boundary
_TRAFFIC_OPS = {"fusion", "dot", "convolution", "copy", "dynamic-slice",
                "dynamic-update-slice", "slice", "concatenate", "pad",
                "reduce", "broadcast", "transpose", "reshape", "convert",
                "gather", "scatter", "iota", "select", "add", "multiply",
                "subtract", "divide", "tanh", "exponential", "sort",
                "custom-call", "reduce-window", "rng-bit-generator",
                "cholesky", "triangular-solve"} | set(COLLECTIVES)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


class HloModule:
    def __init__(self):
        self.comps: Dict[str, List[dict]] = defaultdict(list)
        self.symtab: Dict[str, Dict[str, str]] = defaultdict(dict)
        self.entry: Optional[str] = None


def parse(hlo_text: str) -> HloModule:
    mod = HloModule()
    comp = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or line.startswith("HloModule"):
            continue
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            comp = mc.group(2)
            if mc.group(1):
                mod.entry = comp
            # params: "name: type, name: type"
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*((?:\(.*?\)|[a-z0-9]+"
                                  r"\[[0-9,]*\](?:\{[^}]*\})?))",
                                  mc.group(3)):
                mod.symtab[comp][pm.group(1)] = pm.group(2)
            continue
        if line == "}" or comp is None:
            if line == "}":
                comp = None
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rtype, opcode, rest = mo.groups()
        mod.symtab[comp][name] = rtype
        # operands: inside the first balanced paren chunk
        depth, i = 1, 0
        for i, ch in enumerate(rest):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                break
        args, attrs = rest[:i], rest[i + 1:]
        rec = dict(name=name, rtype=rtype, opcode=opcode, args=args,
                   attrs=attrs)
        if opcode == "while":
            tm = _TRIP_RE.search(attrs)
            rec["trip"] = int(tm.group(1)) if tm else 1
        mod.comps[comp].append(rec)
    return mod


def _multipliers(mod: HloModule) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    if mod.entry is None:
        return mult
    mult[mod.entry] = 1.0
    # relaxation over the acyclic call graph
    order = [mod.entry]
    seen = {mod.entry}
    i = 0
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for comp, ops in mod.comps.items():
        for op in ops:
            factor = float(op.get("trip", 1)) if op["opcode"] == "while" else 1.0
            callees = _CALL_RE.findall(op["attrs"])
            bm = _BRANCH_RE.search(op["attrs"])
            if bm:
                callees += [c.strip().lstrip("%")
                            for c in bm.group(1).split(",")]
            for c in callees:
                # trip count applies to the while body AND condition
                f = factor if op["opcode"] == "while" else 1.0
                edges[comp].append((c, f))
    while i < len(order):
        comp = order[i]
        i += 1
        for callee, f in edges.get(comp, ()):  # propagate
            mult[callee] += mult[comp] * f
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    # note: accumulation above assumes each comp fully processed before its
    # callees are visited; HLO call graphs from jax are trees (unique
    # callers), so this holds.
    return mult


def analyse(hlo_text: str) -> dict:
    mod = parse(hlo_text)
    mult = _multipliers(mod)
    dot_flops = 0.0
    dot_traffic = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0 for k in COLLECTIVES}
    traffic = 0.0
    for comp, ops in mod.comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for op in ops:
            oc = op["opcode"]
            if oc == "dot":
                dims = _shape_dims(op["rtype"]) or []
                out_elems = 1
                for d in dims:
                    out_elems *= d
                cm = _CONTRACT_RE.search(op["attrs"])
                k = 1
                operands = _OPERAND_RE.findall(op["args"])
                if cm and operands:
                    lhs_t = mod.symtab[comp].get(operands[0])
                    lhs_dims = _shape_dims(lhs_t or "") or []
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                dot_flops += 2.0 * out_elems * k * m
                # dot-boundary HBM traffic: lhs + rhs + result, once per use
                db = _shape_bytes(op["rtype"])
                for operand in operands[:2]:
                    t = mod.symtab[comp].get(operand)
                    if t:
                        db += _shape_bytes(t)
                dot_traffic += db * m
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVES and not oc.endswith("-done"):
                b = _shape_bytes(op["rtype"])
                coll[base] += b * m
                coll_counts[base] += 1
            if base in _TRAFFIC_OPS:
                b = _shape_bytes(op["rtype"])
                for operand in _OPERAND_RE.findall(op["args"])[:8]:
                    t = mod.symtab[comp].get(operand)
                    if t:
                        b += _shape_bytes(t)
                traffic += b * m
    return dict(
        dot_flops=dot_flops,
        # first-order HBM model: matmul operand/result movement (XLA CPU
        # barely fuses, so the all-ops proxy overcounts ~10-30x vs TPU;
        # dot boundaries are fusion-stable)
        dot_traffic_bytes=dot_traffic,
        collective_bytes={k: int(v) for k, v in coll.items()},
        collective_counts=coll_counts,
        collective_total_bytes=int(sum(coll.values())),
        traffic_bytes=traffic,
    )
