"""(architecture x input-shape) cells: ShapeDtypeStruct input specs, step
functions, and sharding trees for the dry-run / train / serve launchers.

The 4 assigned LM shapes:
    train_4k      seq 4096,   global_batch 256   -> train_step
    prefill_32k   seq 32768,  global_batch 32    -> prefill (serve)
    decode_32k    seq 32768,  global_batch 128   -> serve_step (1 new token)
    long_500k     seq 524288, global_batch 1     -> serve_step; SSM/hybrid only
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed import sharding as shd
from ..models import lm
from ..models.config import ModelConfig
from ..optim import OptConfig, adamw_update, init_opt_state

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

FSDP_PARAM_THRESHOLD = 50e9  # ZeRO-3 only where params+moments cannot fit
# otherwise (<= ~15B): TP/16 + replicated-over-data moments stays < 16 GB/dev
# and avoids the activation-sized FSDP all-reduces XLA CPU SPMD emits


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic context handling: SSM/hybrid only.

    (All 10 archs are decoders, so decode shapes always apply.)"""
    if shape_name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            f"{cfg.name} is a full-attention decoder; 500k-token context "
            "requires sub-quadratic attention (run for SSM/hybrid only). "
            "Skip recorded per DESIGN.md §4.")
    return True, ""


# ---------------------------------------------------------------------------
# parameter / optimizer shapes and shardings
# ---------------------------------------------------------------------------


def param_shapes_and_axes(cfg: ModelConfig):
    """ShapeDtypeStruct tree (no allocation) + logical-axes tree."""
    shapes = jax.eval_shape(lambda k: lm.init(k, cfg)[0],
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    _, axes = lm.init(jax.random.PRNGKey(0), cfg.reduced())
    assert (jax.tree.structure(shapes)
            == jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple))), \
        "axes tree drifted from params tree"
    return shapes, axes


def param_count(shapes) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig, shapes) -> int:
    """MoE: only top_k routed experts (+everything else) are active/token."""
    total = param_count(shapes)
    if not cfg.n_experts:
        return total
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def use_fsdp(cfg: ModelConfig, shapes) -> bool:
    return param_count(shapes) >= FSDP_PARAM_THRESHOLD


# ---------------------------------------------------------------------------
# per-kind input specs + shardings
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh):
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    dp = shd.dp_axes(mesh)
    rules = {"batch": dp}
    out_shapes: Dict[str, Any] = {}
    out_spec: Dict[str, Any] = {}
    n_front = cfg.n_frontend_tokens if cfg.family in ("vlm",) else 0
    if info["kind"] in ("train", "prefill"):
        s_text = S - n_front
        out_shapes["tokens"] = _sds((B, s_text), jnp.int32)
        out_spec["tokens"] = shd.spec_for(
            (B, s_text), ("batch", "seq"), mesh, overrides=rules)
        if n_front:
            out_shapes["frontend_embs"] = _sds((B, n_front, cfg.d_model),
                                               jnp.bfloat16)
            out_spec["frontend_embs"] = shd.spec_for(
                (B, n_front, cfg.d_model), ("batch", "front", "embed"), mesh,
                overrides=rules)
    else:  # decode
        out_shapes["token"] = _sds((B, 1), jnp.int32)
        out_spec["token"] = shd.spec_for(
            (B, 1), ("batch", "seq"), mesh, overrides=rules)
    return out_shapes, out_spec


# decode/prefill cache logical axes (shape-aware relocation gives split-KV
# for few-kv-head archs and sequence-parallel caches for batch==1):
_CACHE_AXES = {
    "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    "shared_k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    "shared_v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
    "ssm": ("layers", "batch", "ssm_heads", "head_dim", "state"),
    "conv_x": ("layers", "batch", "conv", "ssm_inner"),
    "conv_bc": ("layers", "batch", "conv", "state2"),
    "pos": ("batch",),   # per-slot positions; tiny -> kept replicated below
}


def cache_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                dtype=jnp.bfloat16):
    """ShapeDtypeStructs + PartitionSpecs for the decode/prefill cache."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    dp = shd.dp_axes(mesh)
    rules = {"batch": dp, "kv_heads": "model", "ssm_heads": "model",
             "ssm_inner": "model"}
    shapes = jax.eval_shape(partial(lm.init_cache, cfg, B, S, dtype))
    spec = {
        k: (P() if k == "pos" else shd.spec_for(
            shapes[k].shape, _CACHE_AXES[k], mesh, overrides=rules))
        for k in shapes
    }
    return shapes, spec


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, ocfg: Optional[OptConfig] = None):
    ocfg = ocfg or OptConfig(
        schedule=cfg.lr_schedule if cfg.lr_schedule in ("wsd", "cosine")
        else "cosine",
        moment_dtype="bfloat16" if cfg.n_experts >= 64 else "float32",
    )

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return lm.lm_loss(p, cfg, batch["tokens"],
                              batch.get("frontend_embs"))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = adamw_update(params, grads, opt_state, ocfg)
        return params, opt_state, {"loss": loss}

    return train_step, ocfg


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return lm.prefill(params, cfg, batch["tokens"], cache,
                          batch.get("frontend_embs"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, batch, cache):
        return lm.decode_step(params, cfg, batch["token"], cache)
    return serve_step


# ---------------------------------------------------------------------------
# the full lowering bundle for one (arch x shape x mesh) cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellSpec:
    fn: Any                     # jittable step
    arg_shapes: tuple           # ShapeDtypeStruct trees
    in_shardings: tuple         # NamedSharding trees
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def build_cell(cfg: ModelConfig, shape_name: str, mesh: Mesh,
               fsdp: Optional[bool] = None) -> CellSpec:
    info = SHAPES[shape_name]
    p_shapes, axes = param_shapes_and_axes(cfg)
    fsdp = use_fsdp(cfg, p_shapes) if fsdp is None else fsdp
    p_spec = shd.param_specs(p_shapes, axes, mesh, fsdp=fsdp)
    p_shard = shd.named(mesh, p_spec)
    b_shapes, b_spec = batch_specs(cfg, shape_name, mesh)
    b_shard = shd.named(mesh, b_spec)
    meta = dict(arch=cfg.name, shape=shape_name, kind=info["kind"],
                fsdp=fsdp, params=param_count(p_shapes),
                active_params=active_param_count(cfg, p_shapes),
                seq=info["seq"], batch=info["batch"])

    if info["kind"] == "train":
        step, ocfg = make_train_step(cfg)
        o_shapes = jax.eval_shape(partial(init_opt_state, cfg=ocfg), p_shapes)
        o_spec = {"step": P(), "m": p_spec, "v": p_spec}
        o_shard = shd.named(mesh, o_spec)
        return CellSpec(
            fn=step,
            arg_shapes=(p_shapes, o_shapes, b_shapes),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
            meta=meta)

    c_shapes, c_spec = cache_specs(cfg, shape_name, mesh)
    c_shard = shd.named(mesh, c_spec)
    if info["kind"] == "prefill":
        step = make_prefill_step(cfg)
    else:
        step = make_decode_step(cfg)
    return CellSpec(
        fn=step,
        arg_shapes=(p_shapes, b_shapes, c_shapes),
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(None, c_shard),
        donate_argnums=(2,),
        meta=meta)
