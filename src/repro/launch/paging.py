"""Paged KV-cache bookkeeping: geometry, host allocator, prefix planner.

The scheduler's paged mode (launch/scheduler.py) keeps ONE global KV pool
per layer -- ``(n_blocks, block_size, heads, head_dim)`` -- and per-slot
block tables instead of per-slot contiguous ``max_seq`` regions.  This
module holds everything about paging that does NOT need to live inside
the AOT-compiled device loop:

  PagedLayout          static geometry (block size, table width, pool size)
                       shared by the scheduler, lm.init_paged_cache and the
                       benchmarks' resident-bytes accounting.

  BlockAllocator       host-side reference allocator: alloc / free /
                       refcounts / copy-on-write over the same invariants
                       the device-side allocator maintains (no double
                       free, no leak, no aliasing of live blocks).  The
                       device loop cannot run hypothesis; this object can
                       (tests/test_paging.py), and the device-side
                       admission/harvest arithmetic is a restriction of
                       this model (alloc at admit, free at harvest,
                       ref-pinned prefix sharing -- CoW degenerates to
                       "recompute the partial tail block", see
                       plan_prefix_sharing).

  plan_prefix_sharing  the host side of prefix caching.  The workload is
                       staged up front and admitted in queue order, so the
                       hash -> block-chain map can be resolved BEFORE the
                       loop runs: each request gets (share_src,
                       n_shared_blocks) -- copy that many table entries
                       from the earlier request -- and every materializing
                       request gets per-block pin counts so a donor's
                       blocks survive the donor's own harvest until the
                       last sharer frees them.  No device hash table, no
                       host round-trip, and the refcount algebra closes:
                       every block's refcount returns to zero when the
                       queue drains (asserted in tests/test_paging.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static paged-cache geometry.

    ``n_tbl`` is the per-slot block-table width: every slot can address at
    most ``n_tbl`` blocks, sized for the worst case prompt + decode budget
    + speculative headroom.  ``n_blocks`` is the global pool size; block 0
    is reserved as the TRASH block (harvested slots' tables point at it,
    so a dead slot's frozen-position decode writes land somewhere no live
    slot ever reads -- the paged analogue of dead rows writing into their
    own private region).
    """
    block_size: int
    n_tbl: int                    # per-slot table width (blocks)
    n_blocks: int                 # global pool size, incl. the trash block

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size {self.block_size} < 1")
        if self.n_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is trash)")

    @property
    def tokens_per_slot(self) -> int:
        return self.n_tbl * self.block_size

    def blocks_for(self, n_tokens: int) -> int:
        return cdiv(n_tokens, self.block_size)

    def kv_bytes(self, cfg, n_blocks: Optional[int] = None,
                 dtype_bytes: int = 2) -> int:
        """Resident KV bytes for ``n_blocks`` pool blocks (default: the
        whole pool) under ``cfg``'s layer/head geometry -- the number the
        serve benchmark reports per row."""
        nb = self.n_blocks if n_blocks is None else n_blocks
        per_row = cfg.padded_kv_heads * cfg.head_dim * dtype_bytes
        n_kv_layers = 0
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            n_kv_layers += 2 * cfg.n_layers                  # k + v
        if cfg.family == "hybrid" and cfg.shared_attn_period:
            n_kv_layers += 2 * (cfg.n_layers // cfg.shared_attn_period)
        return nb * self.block_size * per_row * n_kv_layers


def contiguous_kv_bytes(cfg, slots: int, max_seq: int,
                        dtype_bytes: int = 2) -> int:
    """KV bytes of the contiguous per-slot layout (the baseline)."""
    layout = PagedLayout(block_size=max_seq, n_tbl=1, n_blocks=2)
    return layout.kv_bytes(cfg, n_blocks=slots, dtype_bytes=dtype_bytes)


# ---------------------------------------------------------------------------
# host-side reference allocator (property-tested invariants)
# ---------------------------------------------------------------------------


class BlockAllocator:
    """Refcounted free-list block allocator with copy-on-write.

    This is the HOST model of the device-side allocator: a free list over
    ``n_blocks`` blocks (block 0 reserved), integer refcounts, and the
    three operations the serving loop composes:

      alloc(n)            -> n fresh blocks, each at refcount 1
      share(blocks)       -> refcount += 1 on an existing chain (a prefix
                             hit: the new sequence references the donor's
                             blocks instead of recomputing them)
      free(blocks)        -> refcount -= 1; blocks return to the free
                             list at zero

    plus ``write(owner_blocks, i)`` modelling a write into block i of a
    chain: if the block is shared (refcount > 1) it is COPIED first
    (copy-on-write) so the writer gets a private block and the other
    referents keep the original.  The device loop never needs the copy --
    admission only shares FULL immutable prompt blocks and recomputes the
    partial tail (see plan_prefix_sharing) -- but the allocator supports
    it so the property tests cover the general contract the design
    depends on.

    Invariants (checked by ``check()`` and property-tested):
      * refcounts are never negative; free() on a free block raises
        (double free)
      * a block is on the free list iff its refcount is zero (no leak:
        freeing the last reference always returns the block)
      * alloc never returns a block with a live reference (no aliasing)
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.n_blocks = n_blocks
        self.ref = np.zeros(n_blocks, np.int64)
        self.ref[0] = 1                       # trash block: never allocated
        self._free = list(range(n_blocks - 1, 0, -1))   # pop() -> lowest id

    # -- core ops --------------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise MemoryError(f"alloc({n}): only {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self.ref[b] == 0, f"free-list block {b} had refs"
            self.ref[b] = 1
        return out

    def share(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if not (0 < b < self.n_blocks):
                raise ValueError(f"share: bad block id {b}")
            if self.ref[b] == 0:
                raise ValueError(f"share: block {b} is free (stale chain)")
            self.ref[b] += 1

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if not (0 < b < self.n_blocks):
                raise ValueError(f"free: bad block id {b}")
            if self.ref[b] == 0:
                raise ValueError(f"double free of block {b}")
            self.ref[b] -= 1
            if self.ref[b] == 0:
                self._free.append(b)

    def write(self, chain: List[int], i: int) -> int:
        """Write into ``chain[i]``; copy-on-write if the block is shared.
        Returns the (possibly new) block id and updates ``chain`` in
        place."""
        b = chain[i]
        if self.ref[b] <= 1:
            return b                           # exclusive: write in place
        (nb,) = self.alloc(1)                  # copy: writer goes private
        self.ref[b] -= 1                       # drop the shared reference
        if self.ref[b] == 0:                   # (cannot happen: ref was >1)
            self._free.append(b)
        chain[i] = nb
        return nb

    # -- invariant check -------------------------------------------------

    def check(self) -> None:
        assert self.ref[0] >= 1, "trash block lost its pin"
        assert (self.ref >= 0).all(), "negative refcount"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free-list entry"
        for b in range(1, self.n_blocks):
            on_free = b in free_set
            assert on_free == (self.ref[b] == 0), (
                f"block {b}: ref={self.ref[b]} on_free={on_free}")


# ---------------------------------------------------------------------------
# host-side prefix-sharing planner
# ---------------------------------------------------------------------------


def _block_hash(prev: bytes, tokens: np.ndarray) -> bytes:
    """Chain hash of one FULL block given the hash of the chain before it.

    Chaining makes the hash identify the whole prefix, not just the
    block's own tokens -- two requests share block j only if their first
    (j+1) blocks are identical, which is exactly the condition for the
    cached KV rows to be bit-identical (attention-family KV at position p
    depends on every token <= p).
    """
    h = hashlib.sha1()
    h.update(prev)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class PrefixPlan:
    """Per-request sharing decisions for one staged workload.

    share_src[i]        queue index of the request whose recorded block
                        table request i copies its shared chain from
                        (-1: no sharing).  Always < i, so the donor is
                        admitted -- and prefilled -- first; the device
                        loop additionally gates i's admission on the
                        donor's prefill being COMPLETE.
    n_shared_blocks[i]  how many leading table entries to copy.  Capped
                        at (prompt_len_i - 1) // block_size: only FULL
                        blocks are shared, and at least one prompt token
                        is always recomputed so admission produces the
                        request's first-token logits.  The partial tail
                        block is RECOMPUTED rather than copied -- the
                        degenerate (and bit-exact) form of copy-on-write:
                        the divergent block never aliases the donor's.
    pin_counts[i, j]    extra refcount to place on request i's j-th table
                        entry when i materializes it (the number of LATER
                        requests whose shared chain includes that block,
                        directly or transitively).  Pinning at
                        materialization time -- not at each sharer's admit
                        -- is what lets a donor be harvested before its
                        sharers finish without freeing the shared blocks.
    """
    share_src: np.ndarray          # (N,) int32
    n_shared_blocks: np.ndarray    # (N,) int32
    pin_counts: np.ndarray         # (N, n_tbl) int32

    @property
    def n_shared_tokens(self) -> int:
        return int(np.sum(self.n_shared_blocks))

    def stats(self) -> dict:
        """Plan-level sharing summary for telemetry / bench rows."""
        shared = self.share_src >= 0
        return dict(n_requests=int(self.share_src.shape[0]),
                    shared_requests=int(np.sum(shared)),
                    shared_blocks=int(np.sum(self.n_shared_blocks)),
                    pinned_blocks=int(np.sum(self.pin_counts > 0)),
                    max_chain_depth=int(self.n_shared_blocks.max(initial=0)))


def plan_prefix_sharing(prompts: Sequence[np.ndarray], block_size: int,
                        n_tbl: int, enable: bool = True) -> PrefixPlan:
    """Resolve block-granular prefix sharing for a staged request queue.

    One pass in admission order: hash each request's full prompt blocks
    as a chain, look up the longest previously-seen chain prefix, and
    record (donor, depth).  A second pass converts "how many chains pass
    through this block" into pin counts for whichever request materializes
    the block first.
    """
    n = len(prompts)
    share_src = np.full(n, -1, np.int32)
    n_shared = np.zeros(n, np.int32)
    pins = np.zeros((n, n_tbl), np.int32)
    if not enable:
        return PrefixPlan(share_src, n_shared, pins)

    first_holder: Dict[bytes, Tuple[int, int]] = {}  # hash -> (req, depth)
    refs: Dict[bytes, int] = {}                      # hash -> chains through
    chains: List[List[bytes]] = []
    for i, toks in enumerate(prompts):
        toks = np.asarray(toks)
        nb_cap = min((len(toks) - 1) // block_size, n_tbl)
        chain, h = [], b""
        for j in range(nb_cap):
            h = _block_hash(h, toks[j * block_size:(j + 1) * block_size])
            chain.append(h)
        chains.append(chain)
        depth = 0
        for j, hj in enumerate(chain):
            if hj in first_holder:
                depth = j + 1
            else:
                break
        if depth:
            src, _ = first_holder[chain[depth - 1]]
            share_src[i] = src
            n_shared[i] = depth
        for j, hj in enumerate(chain):
            refs[hj] = refs.get(hj, 0) + 1
            if hj not in first_holder:
                first_holder[hj] = (i, j)
    for h, (i, j) in first_holder.items():
        pins[i, j] = refs[h] - 1      # later sharers; own ref comes from alloc
    return PrefixPlan(share_src, n_shared, pins)
