"""Roofline analysis over the dry-run artifacts (TPU v5e targets).

Terms per (arch x shape x mesh) cell, all PER-DEVICE seconds (the dry-run
records trip-count-aware, SPMD-partitioned per-device numbers -- see
hlo_analysis.py):

  compute    = dot_FLOPs_dev / 197e12 FLOP/s
  memory     = traffic_bytes_dev / 819e9 B/s
  collective = collective_bytes_dev / 50e9 B/s (per ICI link)

plus MODEL_FLOPS (6*N_active*D train, 2*N_active*D inference) and the
useful-compute ratio MODEL_FLOPS / executed_FLOPs, which exposes remat
recompute + emulation overheads.  roofline_frac = useful-per-device-FLOPs
/ peak at the bottleneck-implied step time.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "results", "dryrun")


def model_flops(meta: dict, kind: str) -> float:
    """6*N*D for training, 2*N_active*D for inference (D = tokens)."""
    n_act = meta["active_params"]
    if kind == "train":
        return 6.0 * n_act * meta["seq"] * meta["batch"]
    if kind == "prefill":
        return 2.0 * n_act * meta["seq"] * meta["batch"]
    return 2.0 * n_act * meta["batch"]   # decode: one token per sequence


def analyse(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    meta = rec["meta"]
    mf = model_flops(meta, meta["kind"])
    flops_dev = rec["flops"] or 0.0          # per-device, trip-aware
    bytes_dev = rec["hlo_bytes"] or 0.0
    coll_dev = rec["collectives"]["total_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bound = max(terms, key=terms.get)
    t_total = max(terms.values())
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    mfu = (mf / chips / PEAK_FLOPS) / t_total if t_total else 0.0
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=meta["kind"],
        compute_s=t_compute, memory_s=t_memory, collective_s=t_coll,
        bound=bound,
        model_flops=mf,
        useful_ratio=useful,
        roofline_frac=mfu,
        memory_gb_per_dev=_mem_gb(rec),
    )


def _mem_gb(rec) -> Optional[float]:
    m = rec.get("memory") or {}
    vals = [v for k, v in m.items()
            if v and k in ("argument_bytes", "temp_bytes")]
    return round(sum(vals) / 2**30, 2) if vals else None


def load_all(result_dir: str = RESULT_DIR):
    recs = []
    for p in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def table(result_dir: str = RESULT_DIR, mesh: str = "16x16") -> str:
    rows = []
    hdr = (f"{'arch':17s} {'shape':12s} {'bound':10s} {'compute_s':>10s} "
           f"{'memory_s':>9s} {'coll_s':>9s} {'useful':>7s} {'roofl%':>7s} "
           f"{'GB/dev':>7s}")
    rows.append(hdr)
    recs = load_all(result_dir)
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9))
    for rec in recs:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(f"{rec['arch']:17s} {rec['shape']:12s} SKIP "
                        "(full attention; sub-quadratic-only shape)")
            continue
        a = analyse(rec)
        if a is None:
            rows.append(f"{rec['arch']:17s} {rec['shape']:12s} FAILED")
            continue
        rows.append(
            f"{a['arch']:17s} {a['shape']:12s} {a['bound']:10s} "
            f"{a['compute_s']:10.4f} {a['memory_s']:9.4f} "
            f"{a['collective_s']:9.4f} {a['useful_ratio']:7.2f} "
            f"{100*a['roofline_frac']:7.1f} "
            f"{a['memory_gb_per_dev'] or 0:7.1f}")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(table(mesh=mesh))
