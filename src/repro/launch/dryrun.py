import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
#   Placeholder host devices exist ONLY for the dry-run; smoke tests and
#   benches see 1 device (this env var is set nowhere else).

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell we record:
  * memory_analysis()      -- per-device bytes: proves the cell fits HBM
  * cost_analysis()        -- HLO FLOPs / bytes for the roofline terms
  * collective byte counts -- parsed from the partitioned HLO text
and write JSON to results/dryrun/. Any sharding mismatch, OOM-at-compile or
unsupported collective is a bug in the framework and fails the cell.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from ..configs import ARCHS, get_config
from . import hlo_analysis
from .mesh import make_production_mesh
from .specs import SHAPES, applicable, build_cell

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "results", "dryrun")


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    rec = dict(arch=arch, shape=shape,
               mesh="2x16x16" if multi_pod else "16x16")
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    t0 = time.time()
    hlo = hlo_analysis.analyse(compiled.as_text())
    t_analyse = time.time() - t0
    rec.update(
        status="ok",
        meta=cell.meta,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        analyse_s=round(t_analyse, 1),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(mem, "generated_code_size_in_bytes",
                                         None),
        ),
        # trip-count-aware, per-device (see hlo_analysis.py)
        flops=hlo["dot_flops"],
        hlo_bytes=hlo["dot_traffic_bytes"],
        hlo_bytes_all_ops=hlo["traffic_bytes"],
        collectives={"bytes": hlo["collective_bytes"],
                     "counts": hlo["collective_counts"],
                     "total_bytes": hlo["collective_total_bytes"]},
        # raw XLA numbers for reference (while bodies counted once!)
        xla_cost=dict(flops=cost.get("flops"),
                      bytes_accessed=cost.get("bytes accessed")),
    )
    print(f"[dryrun] {arch} x {shape} x {rec['mesh']}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"flops={rec['flops']:.3e} coll={rec['collectives']['total_bytes']:.3e}B")
    print(f"  memory: {rec['memory']}")
    return rec


def _cell_path(arch, shape, multi_pod):
    mesh = "2x16x16" if multi_pod else "16x16"
    return os.path.join(RESULT_DIR, f"{arch}__{shape}__{mesh}.json")


def run_all(force: bool = False, timeout: int = 3600):
    os.makedirs(RESULT_DIR, exist_ok=True)
    failures = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mp in (False, True):
                path = _cell_path(arch, shape, mp)
                if os.path.exists(path) and not force:
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", path]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, timeout=timeout,
                                   capture_output=True, text=True)
                if r.returncode != 0:
                    failures.append((arch, shape, mp, r.stdout[-2000:] +
                                     r.stderr[-2000:]))
                    print(f"[dryrun] FAIL {arch} x {shape} mp={mp}")
                    print(r.stderr[-2000:])
                else:
                    print(r.stdout.strip().splitlines()[-2]
                          if r.stdout.strip() else "")
    print(f"[dryrun] done, {len(failures)} failures")
    for a, s, mp, _ in failures:
        print("  FAIL:", a, s, "multi_pod" if mp else "single")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()
    if args.all:
        failures = run_all(force=args.force)
        sys.exit(1 if failures else 0)
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
    else:
        print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
