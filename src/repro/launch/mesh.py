"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; the multi-pod mesh adds a 2-pod outer axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests (axis names preserved)."""
    return jax.make_mesh((1, 1), ("data", "model"))
