"""Continuous-batching serving scheduler over a fixed pool of decode slots.

The paper's macro is weight-stationary: weights are written once and
activations stream.  The serving-system analogue is a fixed pool of decode
slots over prepacked weights -- one compiled decode step serves the pool
forever, and the scheduler's only job is keeping the slots full.  The
lock-step loop in launch/serve.py wastes exactly what the macro's
single-conversion trick saves: a finished sequence burns a slot (a
conversion) until the slowest request ends.  Here every step advances only
live slots, and a freed slot is refilled from the request queue through
``lm.prefill_into_slot`` without recompiling anything.

The entire serve loop is DEVICE-RESIDENT.  The request queue (prompts +
per-request budgets/stop tokens) is staged into device buffers up front,
and one AOT-compiled ``lax.while_loop`` runs a ``lax.switch`` until the
queue is drained:

  harvest : some slot finished (EOS or max-new-tokens, tracked by the
            on-device ``live`` mask; finishes are parked in a ``pending``
            mask) -> copy its output row into the per-request result
            buffer and free the slot (paged mode: decrement its blocks'
            refcounts and point its table at the trash block).
  admit   : a slot is free and the queue head is admissible -> reset the
            slot and arm it.  Contiguous mode prefills the whole prompt
            here (``lm.prefill_into_slot``); paged mode only ALLOCATES
            (grab blocks off the device free list, copy the shared-prefix
            chain from the donor's recorded table, place pin refcounts)
            and marks the slot ``filling`` -- the prompt itself streams in
            through the prefill branch.
  prefill : (paged only) advance ONE filling slot by one
            ``prefill_chunk``-token chunk (``lm.prefill_chunk_into_slot``).
            Chunked admission interleaves with decode steps, so a long
            prompt can no longer stall the whole pool for its full
            prefill; the final chunk samples the request's first token.
  step    : one pooled decode step; only live slots advance.

The host syncs with the device exactly ONCE per workload -- there is no
per-token (or even per-request) host round-trip, which is what lets the
scheduler's fewer-wasted-slot-steps advantage survive dispatch latency
even at smoke scale on CPU.  (``run_instrumented`` deliberately trades
that away: it drives the SAME compiled iteration body one switch at a
time to put a host timestamp on every iteration -- TTFT and per-step
latency percentiles for the serve benchmark -- while ``run`` keeps the
pure loop for throughput numbers.)

PAGED mode (``paged=PagedLayout(...)``) replaces the per-slot contiguous
``max_seq`` KV regions with global per-layer block pools and per-slot
block tables (lm.init_paged_cache).  The allocator lives INSIDE the loop:
a ``(n_blocks,)`` refcount vector doubles as the free list (free <=>
ref==0; an argsort puts free blocks first in id order), admission grants
``max_blk`` blocks eagerly (prompt span + decode budget + speculative
headroom -- no mid-flight growth, so admission is the only place that can
run out), and harvest decrements.  Shared prompt prefixes are planned on
the host (paging.plan_prefix_sharing): a sharer copies the donor's
leading table entries instead of recomputing them, donors carry pin
refcounts for every chain that passes through their blocks, and the
refcount algebra returns every block to zero when the queue drains.
Tokens are BIT-IDENTICAL to the contiguous scheduler (and to a solo run):
the attention validity horizon does not care where KV rows physically
live, and the chunked/shared prefill paths recompute exactly the rows
whose values the single-shot path would have produced.

Determinism contract (tested in tests/test_scheduler.py): a request's
tokens depend only on (params, prompt, rid) -- NOT on which slot it ran
in, what shared the pool with it, or when it was admitted.  Sampling keys
are folded per request id (``fold_in(sampling_key(seed), rid)``) and each
slot consumes its own key stream one split per generated token, so even
temperature sampling is bit-identical to a solo run.

Mixed-fidelity deployment plans (repro.plan) serve through this scheduler
UNCHANGED: a plan is static metadata on the ModelConfig, resolved inside
``lm.prefill_into_slot``/``lm.decode_step`` at trace time, so the pool's
AOT-compiled loop already embeds every projection's own macro config --
zero recompiles across decode steps, per-layer D/A splits and all
(tests/test_plan.py).  Caveat: deterministic noise emulation
(cfg.cim_noise_seed) draws per POOL ROW, so noisy tokens depend on slot
assignment -- like silicon, where each slot maps to a physical macro bank
-- and the scheduler's slot-independence contract holds only noise-free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig
from ..obs import taps
from ..obs import rings as obs_rings
from ..obs.rings import ObsConfig, ObsSnapshot
from ..resilience import faults as rfaults
from .paging import PagedLayout, cdiv, contiguous_kv_bytes, plan_prefix_sharing


def sampling_key(seed: int) -> jax.Array:
    """Sampling PRNG stream, deliberately distinct from the params-init
    stream: serve.py used to feed PRNGKey(seed) to BOTH ``lm.init`` and
    the decode-loop sampler (regression-tested in tests/test_scheduler.py)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), 0x53414D50)  # "SAMP"


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``rid`` seeds the request's sampling
    stream and must be unique within a run.  ``stop_token < 0`` disables
    EOS detection (the request runs to ``max_new_tokens``)."""
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    stop_token: int = -1


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray            # (n,) generated tokens, stop token incl.
    latency_s: float              # arrival (run start) -> completion
    finish_iter: int              # loop iteration the request finished at
    first_iter: int = 0           # loop iteration its first token appeared
    ttft_s: float = float("nan")  # measured only by run_instrumented


@dataclasses.dataclass
class ServeReport:
    finished: List[FinishedRequest]
    wall_s: float
    n_steps: int                  # pooled decode steps (rounds, if spec)
    n_admits: int
    slots: int
    n_drafted: int = 0            # draft tokens proposed (speculative mode)
    n_accepted: int = 0           # draft tokens accepted by verify
    n_pf: int = 0                 # chunked-prefill iterations (paged mode)
    peak_blocks: int = 0          # peak live pool blocks (paged mode)
    obs: Optional[ObsSnapshot] = None   # harvested device rings (obs mode)

    @property
    def total_tokens(self) -> int:
        return sum(len(f.tokens) for f in self.finished)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify pass accepted."""
        return (self.n_accepted / self.n_drafted if self.n_drafted
                else float("nan"))

    @property
    def tokens_per_step(self) -> float:
        """Emitted tokens per pooled step: ~1*occupancy lock-free decode,
        up to (draft_k+1)*slots when every draft block is accepted."""
        return (self.total_tokens / self.n_steps if self.n_steps
                else float("nan"))

    @property
    def tok_s(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else float("nan")

    @property
    def occupancy(self) -> float:
        """Useful-token fraction of the slot-steps spent (admits each
        yield one token; every pooled step spends ``slots`` slot-steps)."""
        slot_steps = self.slots * self.n_steps + self.n_admits + self.n_pf
        return self.total_tokens / slot_steps if slot_steps else float("nan")

    def latency_percentiles(self) -> Dict[str, float]:
        lats = sorted(f.latency_s for f in self.finished)
        if not lats:
            return {"p50_s": float("nan"), "p95_s": float("nan")}
        pick = lambda q: lats[min(len(lats) - 1, int(q * (len(lats) - 1) + 0.5))]
        return {"p50_s": pick(0.50), "p95_s": pick(0.95)}

    def ttft_percentiles(self) -> Dict[str, float]:
        """Time-to-first-token percentiles; NaN unless the report came
        from ``run_instrumented`` (the pure device loop has no per-event
        clock to read without paying the sync it removes)."""
        ts = sorted(f.ttft_s for f in self.finished
                    if not np.isnan(f.ttft_s))
        if not ts:
            return {"ttft_p50_s": float("nan"), "ttft_p95_s": float("nan")}
        pick = lambda q: ts[min(len(ts) - 1, int(q * (len(ts) - 1) + 0.5))]
        return {"ttft_p50_s": pick(0.50), "ttft_p95_s": pick(0.95)}

    def summary(self) -> Dict:
        out = dict(total_tokens=self.total_tokens,
                   wall_s=round(self.wall_s, 4),
                   tok_s=round(self.tok_s, 2),
                   occupancy=round(self.occupancy, 4),
                   n_steps=self.n_steps, n_admits=self.n_admits,
                   slots=self.slots,
                   **{k: round(v, 4) for k, v in
                      self.latency_percentiles().items()})
        if self.n_drafted:
            out.update(n_drafted=self.n_drafted,
                       n_accepted=self.n_accepted,
                       acceptance_rate=round(self.acceptance_rate, 4),
                       tokens_per_step=round(self.tokens_per_step, 4))
        if self.n_pf or self.peak_blocks:
            out.update(n_pf=self.n_pf, peak_blocks=self.peak_blocks)
        return out

    def tokens_by_rid(self) -> Dict[int, np.ndarray]:
        return {f.rid: f.tokens for f in self.finished}


def _i32(v) -> jax.Array:
    return jnp.asarray(v, jnp.int32)


# q_meta column layout (one row per staged request):
#   0 rid  1 max_new  2 stop  3 prompt_len  4 share_src  5 n_shared_blocks
#   6 arrival_iter  7 max_blk
_QM_COLS = 8


class ContinuousBatchingScheduler:
    """Fixed-slot continuous batching, fully device-resident.

    ``params`` may hold prepacked CIM weights (lm.pack_cim_params) -- the
    scheduler never touches weights, so pack-once/serve-many carries
    straight through.  ``max_new_cap`` bounds every request's
    max_new_tokens and sizes the on-device output buffers; ``prompt_len``
    is the static MAXIMUM prompt length (contiguous mode: also the exact
    length -- shorter prompts must be padded by the caller; paged mode:
    shorter prompts are fine, the scheduler pads the staging buffer and
    tracks true lengths per request).

    Request latencies are exact at the workload level (one wall clock
    around the device loop) and attributed per request by its finish
    iteration: latency_i = wall * finish_iter_i / total_iters.  This is an
    estimate -- admit iterations cost more than step iterations -- but the
    loop never leaves the device, so there is no per-event host timestamp
    to read without paying the sync the design removes.  Use
    ``run_instrumented`` when you need real TTFT / per-iteration numbers.

    ``paged=PagedLayout(...)`` switches the KV cache to the global block
    pool + per-slot table layout with on-device alloc/free, host-planned
    shared-prefix reuse (``prefix_sharing``, attention families only --
    SSM/conv recurrent state is not positional and cannot be shared) and
    chunked prefill (``prefill_chunk`` tokens per scheduler iteration,
    default: whole prompt in one chunk).  Paged tokens are bit-identical
    to contiguous-mode tokens.

    ``draft_k > 0`` turns on plan-cascade speculative decoding: each step
    branch becomes one atomic draft-K/verify/accept ROUND (see
    ``spec_step``), drafting under ``draft_plan`` (an all-analog shadow of
    the serving plan -- ``plan.derive_draft_plan`` -- served from the SAME
    packed weights) and verifying under the deployed config.  Rounds are
    atomic per loop iteration, so harvest/admit still interleave between
    rounds and the determinism contract is unchanged: a request's tokens
    depend only on (params, prompt, rid); greedy output is bit-identical
    to the non-speculative scheduler, temperature sampling is
    distribution-identical (rejection sampling) and stays pool-vs-solo
    bit-identical at EQUAL draft_k.  Restricted to positional-KV families
    (attention); SSM/conv recurrences cannot roll back a rejected block.

    ``adaptive_draft_k=True`` feeds the measured acceptance rate (an EMA
    over spec rounds) back into the next round's draft depth over the
    rung ladder {K, K/2, K/4}: high acceptance keeps deep drafts, low
    acceptance stops paying for blocks the verifier rejects.  Greedy
    tokens are invariant to the rung (accept-longest-prefix + correction
    reproduces the argmax chain at any K); temperature sampling stays
    distribution-correct but the pool-vs-solo bit-equality holds only at
    FIXED draft_k (the rung schedule depends on poolmates' acceptance).

    ``obs=ObsConfig(...)`` threads fixed-size telemetry rings through
    the loop carry (obs/rings.py): per-request admit/first-token/finish
    iteration stamps, per-iteration occupancy/token samples, and scalar
    counters (ADC clips via obs/taps.py, prefix hits, free-list
    low-water mark), all written with saturating masked scatters so the
    loop still syncs the host exactly once.  Telemetry is a STATIC flag
    compiling a SEPARATE executable: with ``obs=None`` the lowered
    serve loop is byte-identical to the pre-telemetry program
    (``loop_hlo_text`` exposes the text; serve_bench gates its sha256),
    and with obs on the emitted tokens are bit-identical -- the rings
    only read values the loop already computes (tests/test_obs.py).
    """

    def __init__(self, params, cfg: ModelConfig, slots: int, prompt_len: int,
                 max_new_cap: int, temperature: float = 0.0, seed: int = 0,
                 pad_token: int = 0, draft_k: int = 0, draft_plan=None,
                 paged: Optional[PagedLayout] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_sharing: bool = True,
                 adaptive_draft_k: bool = False,
                 obs: Optional[ObsConfig] = None):
        if cfg.family == "vlm":
            raise NotImplementedError(
                "scheduler is text-only for now (no per-request frontends)")
        if draft_k and cfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                "speculative decoding needs positional KV rollback; the "
                f"{cfg.family!r} family carries recurrent SSM/conv state "
                "that a rejected draft block cannot roll back")
        if draft_k < 0 or draft_k > 31:
            raise ValueError(f"draft_k {draft_k} outside [0, 31] (k+1 must "
                             "stay on the skinny-M verify path)")
        if adaptive_draft_k and not draft_k:
            raise ValueError("adaptive_draft_k needs draft_k > 0")
        self.cfg, self.slots = cfg, slots
        self.obs = obs
        self.prompt_len, self.cap = prompt_len, max_new_cap
        self.temperature, self.pad_token = temperature, pad_token
        self._base_key = sampling_key(seed)
        # speculative rounds write draft/verify KV rows up to pos + draft_k
        # before rollback, so the cache keeps that much extra headroom
        self.max_seq = prompt_len + max_new_cap + draft_k
        self._params = params
        self.draft_k = draft_k
        self.adaptive_draft_k = adaptive_draft_k
        rungs: List[int] = []
        for k in (draft_k, draft_k // 2, draft_k // 4):
            k = max(1, k)
            if k not in rungs:
                rungs.append(k)
        self._rungs = rungs if adaptive_draft_k else [draft_k]
        self.draft_cfg = (dataclasses.replace(cfg, cim_plan=draft_plan)
                          if draft_plan is not None else cfg)

        self.paged = paged
        self.prefix_sharing = prefix_sharing
        if paged is not None:
            C = prefill_chunk if prefill_chunk is not None else prompt_len
            if not (1 <= C <= prompt_len):
                raise ValueError(f"prefill_chunk {C} outside [1, {prompt_len}]")
            if paged.n_tbl >= paged.n_blocks:
                raise ValueError(
                    f"table width {paged.n_tbl} >= pool size {paged.n_blocks}"
                    " (one slot could hold more blocks than exist)")
            self.prefill_chunk = C
            self._p_pad = cdiv(prompt_len, C) * C
            need = max(self._p_pad, prompt_len + max_new_cap - 1 + draft_k)
            if paged.tokens_per_slot < need:
                raise ValueError(
                    f"paged layout addresses {paged.tokens_per_slot} tokens "
                    f"per slot < worst-case need {need} (prompt span + "
                    "decode budget + draft headroom)")
        else:
            self.prefill_chunk = prompt_len
            self._p_pad = prompt_len
        self._loops: Dict[int, object] = {}    # queue length -> executable
        self._iters: Dict[int, object] = {}    # queue length -> one-iter exe

        def sample(logits, keys):
            """logits (R, V) f32, keys (R, 2) -> (R,) int32 tokens."""
            if temperature <= 0:
                return jnp.argmax(logits, -1).astype(jnp.int32)
            return jax.vmap(lambda l, k: jax.random.categorical(
                k, l / temperature))(logits, keys).astype(jnp.int32)

        def arm_slot(params, st, slot, prompt, rid, max_new, stop):
            """Reset + prefill ``slot`` with one request and sample its
            first token.  A request can finish ON that token; the event is
            parked in the pending mask like any step finish."""
            logits, cache = lm.prefill_into_slot(params, cfg, prompt,
                                                 st["cache"], slot)
            k_next, k_use = jax.random.split(
                jax.random.fold_in(self._base_key, rid))
            tok = sample(logits[:, -1], k_use[None])[0]
            fin0 = (tok == stop) | (max_new <= 1)
            st = dict(st, cache=cache)
            st["last_tok"] = st["last_tok"].at[slot, 0].set(tok)
            st["out"] = (st["out"].at[slot].set(self.pad_token)
                         .at[slot, 0].set(tok))
            st["n_gen"] = st["n_gen"].at[slot].set(1)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            st["stop"] = st["stop"].at[slot].set(stop)
            st["keys"] = st["keys"].at[slot].set(k_next)
            st["live"] = st["live"].at[slot].set(~fin0)
            st["pending"] = st["pending"].at[slot].set(fin0)
            return st

        def step(params, st):
            """One pooled decode step; finishes land in pending."""
            live = st["live"]
            logits, cache = lm.decode_step(params, cfg, st["last_tok"],
                                           st["cache"], live=live)
            splits = jax.vmap(jax.random.split)(st["keys"])      # (B,2,2)
            tok = sample(logits[:, -1], splits[:, 1])
            tok = jnp.where(live, tok, jnp.int32(self.pad_token))
            keys = jnp.where(live[:, None], splits[:, 0], st["keys"])
            ar = jnp.arange(self.slots)
            idx = jnp.minimum(st["n_gen"], self.cap - 1)
            row = st["out"][ar, idx]
            out = st["out"].at[ar, idx].set(jnp.where(live, tok, row))
            n_gen = st["n_gen"] + live.astype(jnp.int32)
            finished = live & ((tok == st["stop"]) | (n_gen >= st["max_new"]))
            return dict(st, cache=cache, last_tok=tok[:, None], out=out,
                        n_gen=n_gen, keys=keys, live=live & ~finished,
                        pending=st["pending"] | finished)

        def spec_step(params, st, K: int):
            """One speculative ROUND as a single pooled step: draft K
            tokens under the draft-plan config (same packed weights), roll
            the per-slot positions back, verify all K+1 positions in ONE
            wide forward (M = slots*(K+1) stays on the skinny-M prepacked
            kernels), then accept the longest agreeing prefix plus a
            correction/bonus token.  Emits a VARIABLE 1..K+1 tokens per
            slot; the whole round compiles into one loop iteration, so
            per-step dispatch overhead is amortized over every accepted
            token.  Returns (state, n_drafted, n_accepted).

            Rollback is positional: draft and verify writes land at rows
            >= the committed ``cache["pos"]``, which the attention
            validity horizon masks until pos is advanced past them -- so
            "rolling back" a rejected suffix is just not advancing pos
            over it, and the next round's writes overwrite those rows.
            Paged caches change NOTHING here: the table is untouched
            mid-round (admission pre-allocated ``draft_k`` rows of
            headroom), so rollback never frees or re-allocates a block,
            and non-live slots' draft/verify writes are redirected to the
            trash block (they may alias shared or mid-prefill blocks).
            """
            live = st["live"]
            pos0 = st["cache"]["pos"]
            cache, keys, last = st["cache"], st["keys"], st["last_tok"]
            d_toks, d_logits = [], []
            for _ in range(K):
                logits, cache = lm.decode_step(params, self.draft_cfg, last,
                                               cache, live=live)
                splits = jax.vmap(jax.random.split)(keys)
                dtok = sample(logits[:, -1], splits[:, 1])
                dtok = jnp.where(live, dtok, jnp.int32(self.pad_token))
                keys = jnp.where(live[:, None], splits[:, 0], keys)
                d_toks.append(dtok)
                if temperature > 0:
                    d_logits.append(logits[:, -1])
                last = dtok[:, None]
            drafts = jnp.stack(d_toks, axis=1)                  # (B, K)
            vtoks = jnp.concatenate([st["last_tok"], drafts], axis=1)
            cache = dict(cache, pos=pos0)   # rollback before verify
            vlogits, cache = lm.verify_step(params, cfg, vtoks, cache,
                                            live=live)

            # verify position i gives the distribution of the token AFTER
            # prefix [last, d_1..d_i]; cand pads drafts to K+1 columns so
            # the correction token can be placed at column n_acc
            cand = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)
            if temperature <= 0:
                v_arg = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)
                match = (v_arg[:, :K] == drafts).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                corr = v_arg              # correction at ANY column is its argmax
            else:
                # standard rejection sampling: accept d_i with probability
                # min(1, p_verify(d_i)/p_draft(d_i)); on first rejection,
                # resample from the normalized residual max(p_v - p_d, 0).
                # When all K drafts are accepted the padded zero row makes
                # the residual collapse to p_v[:, K] -- the bonus draw.
                dlg = jnp.stack(d_logits, axis=1)               # (B, K, V)
                p_d = jax.nn.softmax(dlg / temperature, axis=-1)
                p_v = jax.nn.softmax(vlogits / temperature, axis=-1)
                pd_tok = jnp.take_along_axis(
                    p_d, drafts[..., None], -1)[..., 0]
                pv_tok = jnp.take_along_axis(
                    p_v[:, :K], drafts[..., None], -1)[..., 0]
                splits = jax.vmap(jax.random.split)(keys)
                u = jax.vmap(lambda k: jax.random.uniform(k, (K,)))(
                    splits[:, 1])
                keys = jnp.where(live[:, None], splits[:, 0], keys)
                acc = (u * pd_tok < pv_tok).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(acc, axis=1), axis=1)
                pv_n = jnp.take_along_axis(
                    p_v, n_acc[:, None, None], axis=1)[:, 0]
                pd_ext = jnp.concatenate(
                    [p_d, jnp.zeros_like(p_d[:, :1])], axis=1)
                pd_n = jnp.take_along_axis(
                    pd_ext, n_acc[:, None, None], axis=1)[:, 0]
                res = jnp.maximum(pv_n - pd_n, 0.0)
                tot = jnp.sum(res, axis=-1, keepdims=True)
                res = jnp.where(tot > 0, res / jnp.maximum(tot, 1e-38),
                                pv_n)
                splits = jax.vmap(jax.random.split)(keys)
                corr = jax.vmap(lambda r, k: jax.random.categorical(
                    k, jnp.log(jnp.maximum(r, 1e-38))))(
                    res, splits[:, 1]).astype(jnp.int32)[:, None]
                keys = jnp.where(live[:, None], splits[:, 0], keys)

            cols = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
            emitted = jnp.where(cols == n_acc[:, None], corr, cand)
            # clamp by the per-request budget, then truncate at the first
            # stop token INSIDE the emitted block (stop included)
            allowed = jnp.maximum(st["max_new"] - st["n_gen"], 0)
            n_emit = jnp.minimum(n_acc + 1, allowed)
            is_stop = (emitted == st["stop"][:, None]) & (cols < n_emit[:, None])
            has_stop = jnp.any(is_stop, axis=1)
            n_emit = jnp.where(has_stop, jnp.argmax(is_stop, axis=1) + 1,
                               n_emit)
            n_emit = jnp.where(live, n_emit, 0)

            ar = jnp.arange(self.slots)
            out = st["out"]
            for j in range(K + 1):
                idx = jnp.minimum(st["n_gen"] + j, self.cap - 1)
                cur = out[ar, idx]
                out = out.at[ar, idx].set(
                    jnp.where(j < n_emit, emitted[:, j], cur))
            n_gen = st["n_gen"] + n_emit
            finished = live & (has_stop | (n_gen >= st["max_new"]))
            new_last = jnp.take_along_axis(
                emitted, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
            new_last = jnp.where(n_emit > 0, new_last, st["last_tok"][:, 0])
            # committed rows [0, pos0 + n_emit): the old frontier token
            # plus every emitted token except the new frontier
            cache = dict(cache, pos=pos0 + n_emit)
            st = dict(st, cache=cache, last_tok=new_last[:, None], out=out,
                      n_gen=n_gen, keys=keys, live=live & ~finished,
                      pending=st["pending"] | finished)
            return (st, jnp.sum(jnp.where(live, K, 0)).astype(jnp.int32),
                    jnp.sum(jnp.where(live, n_acc, 0)).astype(jnp.int32))

        self._sample = sample
        self._arm_slot, self._step_fn = arm_slot, step
        self._spec_step = spec_step
        self._lockstep_exes = None

    # -- KV footprint accounting ---------------------------------------

    def kv_bytes_contiguous(self, dtype_bytes: int = 2) -> int:
        """KV bytes the contiguous layout would hold resident for this
        pool (slots * max_seq regions) -- the baseline the paged pool's
        peak-block footprint is compared against."""
        return contiguous_kv_bytes(self.cfg, self.slots, self.max_seq,
                                   dtype_bytes=dtype_bytes)

    def kv_bytes_paged(self, n_blocks: Optional[int] = None,
                       dtype_bytes: int = 2) -> int:
        """KV bytes of ``n_blocks`` pool blocks (default: the whole
        pool).  Pass a report's ``peak_blocks`` for the peak-resident
        number the serve benchmark gates on."""
        if self.paged is None:
            raise ValueError("kv_bytes_paged on a contiguous scheduler")
        return self.paged.kv_bytes(self.cfg, n_blocks=n_blocks,
                                   dtype_bytes=dtype_bytes)

    def _lockstep_executables(self):
        """Lock-step baseline executables: batch-1 admit + drain-N-steps
        (run_lockstep), compiled lazily against the same pool state."""
        if self._lockstep_exes is None:
            state = self._init_state()
            p0 = _i32(np.zeros((1, self.prompt_len)))
            z = _i32(0)
            admit = (jax.jit(self._arm_slot, donate_argnums=(1,))
                     .lower(self._params, state, z, p0, z, z, z).compile())

            def drain(params, st, n):
                return jax.lax.fori_loop(
                    0, n, lambda _, s: self._step_fn(params, s), st)

            drain = (jax.jit(drain, donate_argnums=(1,))
                     .lower(self._params, state, z).compile())
            self._lockstep_exes = (admit, drain)
        return self._lockstep_exes

    # -- device-resident serve loop ------------------------------------

    def _occupied(self, st):
        occ = st["live"] | st["pending"]
        if self.paged is not None:
            occ = occ | st["filling"]
        return occ

    def _step_once(self, params, c, q_toks, q_meta, q_pins, n_queue: int):
        """ONE scheduler iteration: pick a branch, run it, bump n_iter.
        The while_loop body (``_build_loop``) and the host-stepped
        instrumented runner (``run_instrumented``) share this function,
        so instrumenting never measures a different program.  Returns
        (carry, branch, continue?)."""
        cfg, paged = self.cfg, self.paged

        def harvest(c):
            st = c["st"]
            slot = jnp.argmax(st["pending"])
            qidx = st["occupant"][slot]
            c = dict(c)
            c["res_out"] = c["res_out"].at[qidx].set(st["out"][slot])
            c["res_n"] = c["res_n"].at[qidx].set(st["n_gen"][slot])
            c["res_iter"] = c["res_iter"].at[qidx].set(c["n_iter"])
            if self.obs is not None:
                o = obs_rings.ring_push(c["obs"], obs_rings.EV_FINISH,
                                        q_meta[qidx, 0], c["n_iter"])
                c["obs"] = dict(o, tick_tok=jnp.zeros((), jnp.int32))
            st = dict(st, pending=st["pending"].at[slot].set(False))
            if paged is not None:
                # free the slot's grant: one ref off each of its first
                # n_alloc table entries (shared entries included -- the
                # donor pinned one ref per chain through them), then park
                # the table on the trash block
                tbl_row = st["cache"]["table"][slot]
                j = jnp.arange(paged.n_tbl, dtype=jnp.int32)
                tgt = jnp.where(j < st["n_alloc"][slot], tbl_row,
                                paged.n_blocks)
                st["ref"] = st["ref"].at[tgt].add(-1, mode="drop")
                st["n_alloc"] = st["n_alloc"].at[slot].set(0)
                st["cache"] = dict(st["cache"],
                                   table=st["cache"]["table"].at[slot].set(0))
            c["st"] = st
            return c

        def admit_contiguous(c):
            st, qidx = c["st"], c["q_head"]
            slot = jnp.argmin(self._occupied(st))
            prompt = jax.lax.dynamic_slice(q_toks, (qidx, 0),
                                           (1, self.prompt_len))
            rid, max_new, stop = (q_meta[qidx, 0], q_meta[qidx, 1],
                                  q_meta[qidx, 2])
            upd = {}
            if self.obs is not None:
                with taps.collect() as fr:
                    st = self._arm_slot(params, st, slot, prompt, rid,
                                        max_new, stop)
                # the whole-prompt prefill samples the first token here:
                # admit and first-token land on the same iteration stamp
                o = obs_rings.ring_push(c["obs"], obs_rings.EV_ADMIT,
                                        rid, c["n_iter"])
                o = obs_rings.ring_push(o, obs_rings.EV_FIRST, rid,
                                        c["n_iter"])
                o = obs_rings.ctr_add(o, obs_rings.CTR_ADC_CLIP,
                                      taps.drain_sum(fr, "adc_clip"))
                upd["obs"] = dict(o, tick_tok=jnp.ones((), jnp.int32))
            else:
                st = self._arm_slot(params, st, slot, prompt, rid, max_new,
                                    stop)
            st = dict(st, occupant=st["occupant"].at[slot].set(qidx))
            return dict(c, st=st, q_head=qidx + 1,
                        n_admits=c["n_admits"] + 1,
                        res_first=c["res_first"].at[qidx].set(c["n_iter"]),
                        **upd)

        def admit_paged(c):
            """Grant blocks + arm the slot; the prompt streams in through
            the prefill branch.  The free list is the refcount vector
            itself: argsort(free-first, by id) makes the grant
            deterministic, and the admission gate already guaranteed
            enough zeros exist."""
            st, qidx = c["st"], c["q_head"]
            bs, n_tbl, NB = paged.block_size, paged.n_tbl, paged.n_blocks
            slot = jnp.argmin(self._occupied(st))
            rid, max_new, stop = (q_meta[qidx, 0], q_meta[qidx, 1],
                                  q_meta[qidx, 2])
            src = jnp.clip(q_meta[qidx, 4], 0, n_queue - 1)
            n_sh, max_blk = q_meta[qidx, 5], q_meta[qidx, 7]
            pins = q_pins[qidx]                              # (n_tbl,)
            ar_nb = jnp.arange(NB, dtype=jnp.int32)
            order = jnp.argsort(
                jnp.where(st["ref"] == 0, ar_nb, NB + ar_nb)).astype(jnp.int32)
            j = jnp.arange(n_tbl, dtype=jnp.int32)
            fresh = order[jnp.clip(j - n_sh, 0, NB - 1)]
            shared = c["req_tables"][src]
            tbl_row = jnp.where(
                j < n_sh, shared,
                jnp.where(j < max_blk, fresh, 0)).astype(jnp.int32)
            # fresh blocks come up at ref 1 (+ pins for later chains that
            # pass through them); shared blocks were pre-pinned by their
            # materializer, so the sharer adds nothing here
            is_fresh = (j >= n_sh) & (j < max_blk)
            tgt = jnp.where(is_fresh, tbl_row, NB)
            ref = st["ref"].at[tgt].add(
                jnp.where(is_fresh, 1 + pins, 0), mode="drop")
            used = jnp.sum((ref > 0).astype(jnp.int32)) - 1  # - trash pin
            cache = lm.reset_slot(st["cache"], slot)
            # a sharer starts its chunk walk at the last chunk boundary
            # inside the shared region: the few recomputed rows write
            # values bit-identical to what the donor already materialized
            s0 = (n_sh * bs) // self.prefill_chunk * self.prefill_chunk
            cache = dict(cache,
                         table=cache["table"].at[slot].set(tbl_row),
                         pos=cache["pos"].at[slot].set(s0))
            k0 = jax.random.fold_in(self._base_key, rid)
            st = dict(st, cache=cache, ref=ref,
                      filling=st["filling"].at[slot].set(True),
                      live=st["live"].at[slot].set(False),
                      pending=st["pending"].at[slot].set(False),
                      n_gen=st["n_gen"].at[slot].set(0),
                      max_new=st["max_new"].at[slot].set(max_new),
                      stop=st["stop"].at[slot].set(stop),
                      out=st["out"].at[slot].set(self.pad_token),
                      keys=st["keys"].at[slot].set(k0),
                      occupant=st["occupant"].at[slot].set(qidx),
                      n_alloc=st["n_alloc"].at[slot].set(max_blk))
            upd = {}
            if self.obs is not None:
                o = obs_rings.ring_push(c["obs"], obs_rings.EV_ADMIT,
                                        rid, c["n_iter"])
                o = obs_rings.ctr_add(o, obs_rings.CTR_PREFIX_BLOCKS, n_sh)
                o = obs_rings.ctr_add(o, obs_rings.CTR_SHARED_ADMITS,
                                      (n_sh > 0).astype(jnp.int32))
                upd["obs"] = dict(o, tick_tok=jnp.zeros((), jnp.int32))
            return dict(c, st=st, q_head=qidx + 1,
                        n_admits=c["n_admits"] + 1,
                        req_tables=c["req_tables"].at[qidx].set(tbl_row),
                        peak_blocks=jnp.maximum(c["peak_blocks"], used),
                        **upd)

        def prefill_chunk(c):
            """Advance the first filling slot by one chunk; the final
            chunk samples the first token exactly as arm_slot would
            (same key split, same logits row) and flips the slot live."""
            st = c["st"]
            C = self.prefill_chunk
            slot = jnp.argmax(st["filling"])
            qidx = st["occupant"][slot]
            plen = q_meta[qidx, 3]
            start = st["cache"]["pos"][slot]
            chunk = jax.lax.dynamic_slice(q_toks, (qidx, start), (1, C))
            if self.obs is not None:
                with taps.collect() as fr:
                    logits, cache = lm.prefill_chunk_into_slot(
                        params, cfg, chunk, st["cache"], slot)
                clip = taps.drain_sum(fr, "adc_clip")
            else:
                logits, cache = lm.prefill_chunk_into_slot(
                    params, cfg, chunk, st["cache"], slot)
            done = (start + C) >= plen
            row = jnp.clip(plen - 1 - start, 0, C - 1)
            lg = jax.lax.dynamic_slice(
                logits, (0, row, 0), (1, 1, logits.shape[-1]))[:, 0]
            k_next, k_use = jax.random.split(st["keys"][slot])
            tok = self._sample(lg, k_use[None])[0]
            fin0 = (tok == st["stop"][slot]) | (st["max_new"][slot] <= 1)
            # the final chunk ran to the padded span; commit pos = plen so
            # decode writes land right after the true prompt (the span's
            # padding rows sit beyond the validity horizon until decode
            # overwrites them)
            pos_new = jnp.where(done, plen, start + C)
            cache = dict(cache, pos=cache["pos"].at[slot].set(pos_new))
            st = dict(
                st, cache=cache,
                last_tok=st["last_tok"].at[slot, 0].set(
                    jnp.where(done, tok, st["last_tok"][slot, 0])),
                out=st["out"].at[slot, 0].set(
                    jnp.where(done, tok, st["out"][slot, 0])),
                n_gen=st["n_gen"].at[slot].set(
                    jnp.where(done, 1, 0)),
                keys=st["keys"].at[slot].set(
                    jnp.where(done, k_next, st["keys"][slot])),
                live=st["live"].at[slot].set(done & ~fin0),
                pending=st["pending"].at[slot].set(done & fin0),
                filling=st["filling"].at[slot].set(~done))
            upd = {}
            if self.obs is not None:
                # first-token stamp at EXACTLY the site that sets
                # res_first: the final chunk samples the first token
                o = obs_rings.ring_push(c["obs"], obs_rings.EV_FIRST,
                                        q_meta[qidx, 0], c["n_iter"],
                                        do=done)
                o = obs_rings.ctr_add(o, obs_rings.CTR_ADC_CLIP, clip)
                upd["obs"] = dict(o, tick_tok=done.astype(jnp.int32))
            return dict(c, st=st, last_pf=jnp.bool_(True),
                        n_pf=c["n_pf"] + 1,
                        pf_done=c["pf_done"].at[qidx].set(
                            c["pf_done"][qidx] | done),
                        res_first=c["res_first"].at[qidx].set(
                            jnp.where(done, c["n_iter"],
                                      c["res_first"][qidx])),
                        **upd)

        def step_core(c):
            upd = (dict(last_pf=jnp.bool_(False)) if paged is not None
                   else {})
            if self.draft_k:
                if len(self._rungs) > 1:
                    ema = c["acc_ema"]
                    R = len(self._rungs)
                    idx = jnp.where(ema > 0.8, 0,
                                    jnp.where(ema > 0.4, min(1, R - 1),
                                              R - 1))
                    st, drafted, accepted = taps.switch(
                        idx,
                        [lambda s, k=k: self._spec_step(params, s, k)
                         for k in self._rungs],
                        c["st"])
                else:
                    st, drafted, accepted = self._spec_step(
                        params, c["st"], self.draft_k)
                rate = (accepted.astype(jnp.float32)
                        / jnp.maximum(drafted, 1).astype(jnp.float32))
                ema = jnp.where(drafted > 0,
                                0.8 * c["acc_ema"] + 0.2 * rate,
                                c["acc_ema"])
                return dict(c, st=st, n_steps=c["n_steps"] + 1,
                            n_drafted=c["n_drafted"] + drafted,
                            n_accepted=c["n_accepted"] + accepted,
                            acc_ema=ema, **upd)
            return dict(c, st=self._step_fn(params, c["st"]),
                        n_steps=c["n_steps"] + 1, **upd)

        def step(c):
            if self.obs is None:
                return step_core(c)
            n_gen0 = jnp.sum(c["st"]["n_gen"])
            with taps.collect() as fr:
                c2 = step_core(c)
            # n_gen is monotone across a decode step / spec round, so
            # the delta is exactly the tokens this iteration emitted
            # (variable 1..K+1 per live slot in spec mode)
            tok = jnp.sum(c2["st"]["n_gen"]) - n_gen0
            o = obs_rings.ctr_add(c2["obs"], obs_rings.CTR_ADC_CLIP,
                                  taps.drain_sum(fr, "adc_clip"))
            return dict(c2, obs=dict(o, tick_tok=tok))

        st = c["st"]
        if self.obs is not None:
            # pre-branch occupancy: the decoders that waited (or ran)
            # through this iteration, for the stall/occupancy samples
            live0 = jnp.sum(st["live"].astype(jnp.int32))
            drafted0, accepted0 = c["n_drafted"], c["n_accepted"]
        qh = jnp.minimum(c["q_head"], n_queue - 1)
        arrived = q_meta[qh, 6] <= c["n_iter"]
        can_admit = ((c["q_head"] < n_queue)
                     & ~jnp.all(self._occupied(st)) & arrived)
        if paged is not None:
            n_sh = q_meta[qh, 5]
            src = jnp.clip(q_meta[qh, 4], 0, n_queue - 1)
            free_cnt = jnp.sum((st["ref"] == 0).astype(jnp.int32))
            can_admit &= (n_sh == 0) | c["pf_done"][src]
            can_admit &= free_cnt >= (q_meta[qh, 7] - n_sh)
            # prefill/step alternation: a filling slot always progresses,
            # but never starves live decoders for more than one iteration
            want_pf = (jnp.any(st["filling"])
                       & (~jnp.any(st["live"]) | ~c["last_pf"]))
            branch = jnp.where(
                jnp.any(st["pending"]), 0,
                jnp.where(can_admit, 1, jnp.where(want_pf, 2, 3)))
            c = jax.lax.switch(branch,
                               [harvest, admit_paged, prefill_chunk, step], c)
        else:
            branch = jnp.where(jnp.any(st["pending"]), 0,
                               jnp.where(can_admit, 1, 2))
            c = jax.lax.switch(branch, [harvest, admit_contiguous, step], c)
        if self.obs is not None:
            free = (jnp.sum((c["st"]["ref"] == 0).astype(jnp.int32))
                    if paged is not None else jnp.zeros((), jnp.int32))
            c = dict(c, obs=obs_rings.iter_tick(
                c["obs"], c["n_iter"], branch, live0,
                c["n_drafted"] - drafted0, c["n_accepted"] - accepted0,
                free))
        c = dict(c, n_iter=c["n_iter"] + 1)
        cont = jnp.any(self._occupied(c["st"])) | (c["q_head"] < n_queue)
        return c, branch, cont

    def _lower_loop(self, n_queue: int):
        """Lower (don't compile) the whole-workload loop for a queue of
        n_queue requests.

        Metrics OFF: this function is required to produce StableHLO text
        byte-identical to the pre-telemetry scheduler -- the sha256 of
        ``loop_hlo_text`` is the zero-overhead-when-off gate in
        benchmarks/serve_bench.py, so every telemetry hook below is a
        Python-level conditional, never a traced-then-unused value.

        Metrics ON: the telemetry rings enter as their own donated
        argument (the only carry members that appear unchanged in shape
        among the outputs, so donation actually aliases -- the
        OBS-RING-DONATION lint checks this) and leave as ``out["obs"]``
        for ``harvest_obs``.
        """
        def serve_body(params, carry, q_toks, q_meta, q_pins):
            def body(c):
                return self._step_once(params, c, q_toks, q_meta, q_pins,
                                       n_queue)[0]

            def cond(c):
                return (jnp.any(self._occupied(c["st"]))
                        | (c["q_head"] < n_queue))

            c = jax.lax.while_loop(cond, body, carry)
            out = dict(res_out=c["res_out"], res_n=c["res_n"],
                       res_iter=c["res_iter"], res_first=c["res_first"],
                       n_iter=c["n_iter"], n_steps=c["n_steps"],
                       n_admits=c["n_admits"], n_drafted=c["n_drafted"],
                       n_accepted=c["n_accepted"])
            if self.paged is not None:
                out.update(n_pf=c["n_pf"], peak_blocks=c["peak_blocks"])
            if self.obs is not None:
                out["obs"] = c["obs"]
            return out

        carry = self._init_carry(n_queue, with_obs=False)
        qt = _i32(np.zeros((n_queue, self._p_pad)))
        qm = _i32(np.zeros((n_queue, _QM_COLS)))
        qp = _i32(np.zeros((n_queue, self._n_pin_cols())))
        if self.obs is not None:
            def serve_loop(params, carry, obs, q_toks, q_meta, q_pins):
                return serve_body(params, dict(carry, obs=obs), q_toks,
                                  q_meta, q_pins)
            return jax.jit(serve_loop, donate_argnums=(2,)).lower(
                self._params, carry, obs_rings.init_obs_state(self.obs),
                qt, qm, qp)

        def serve_loop(params, carry, q_toks, q_meta, q_pins):
            return serve_body(params, carry, q_toks, q_meta, q_pins)

        # no donation: the loop's outputs are only the result buffers, so
        # the input state can't alias anything (XLA would warn and ignore)
        return jax.jit(serve_loop).lower(self._params, carry, qt, qm, qp)

    def loop_hlo_text(self, n_queue: int) -> str:
        """Pre-optimization StableHLO of the serve loop (fingerprint
        input for the zero-overhead-when-off gate, obs/fingerprint.py)."""
        return self._lower_loop(n_queue).as_text()

    # -- segmented (guarded) serve loop --------------------------------

    def _lower_segment(self, n_queue: int):
        """Lower the BUDGET-BOUNDED serve loop the resilience driver runs
        (resilience/failover.GuardedServer).

        Identical to ``_lower_loop``'s body except for two things:

        * the while condition also requires ``n_iter < budget``, and the
          executable returns the FULL carry -- so the host can run the
          workload as a sequence of device-resident segments, reading the
          health counters (and possibly switching to a pack-compatible
          sibling scheduler's executable) at each boundary.  Within a
          segment the one-host-sync contract holds exactly as in ``run``;
          the budget is the watchdog's sampling cadence.
        * the body is traced under ``resilience.faults.clock(n_iter)``:
          with a fault model armed at lower time, the injected drift's
          severity schedule follows the DEVICE iteration counter, so one
          executable covers the whole mid-stream drift scenario -- zero
          retraces, zero recompiles as severity evolves.  With no model
          armed the clock is a Python-level no-op and the segment body
          lowers the exact ops of the plain loop (RES-OFF-PATH gates
          this by fingerprint).

        The telemetry rings stay INLINE in the carry (unlike
        ``_lower_loop``'s separately-donated obs argument): the carry
        round-trips through this executable every segment, so donation
        of the whole carry aliases the rings anyway.
        """
        def seg_loop(params, carry, budget, q_toks, q_meta, q_pins):
            def body(c):
                with rfaults.clock(c["n_iter"]):
                    return self._step_once(params, c, q_toks, q_meta,
                                           q_pins, n_queue)[0]

            def cond(c):
                work = (jnp.any(self._occupied(c["st"]))
                        | (c["q_head"] < n_queue))
                return work & (c["n_iter"] < budget)

            return jax.lax.while_loop(cond, body, carry)

        carry = self._init_carry(n_queue, with_obs=True)
        qt = _i32(np.zeros((n_queue, self._p_pad)))
        qm = _i32(np.zeros((n_queue, _QM_COLS)))
        qp = _i32(np.zeros((n_queue, self._n_pin_cols())))
        return jax.jit(seg_loop, donate_argnums=(1,)).lower(
            self._params, carry, _i32(0), qt, qm, qp)

    def segment_hlo_text(self, n_queue: int) -> str:
        """Pre-optimization StableHLO of the segmented loop (fingerprint
        input for the fault-off-path gate in resilience tests/lint)."""
        return self._lower_segment(n_queue).as_text()

    def compile_segment(self, n_queue: int):
        """Compile (and cache) the segmented loop for a queue length."""
        key = ("seg", n_queue)
        if key not in self._loops:
            self._loops[key] = self._lower_segment(n_queue).compile()
        return self._loops[key]

    def _build_loop(self, n_queue: int):
        """Compile the whole-workload loop for a queue of n_queue requests."""
        return self._lower_loop(n_queue).compile()

    def _build_iter(self, n_queue: int):
        """Compile ONE scheduler iteration (the switch) for the
        instrumented runner.  The carry is donated: the host steps the
        loop, so the pool state round-trips through this executable every
        iteration."""
        def one(params, carry, q_toks, q_meta, q_pins):
            c, branch, cont = self._step_once(params, carry, q_toks,
                                              q_meta, q_pins, n_queue)
            return c, branch, cont

        carry = self._init_carry(n_queue)
        qt = _i32(np.zeros((n_queue, self._p_pad)))
        qm = _i32(np.zeros((n_queue, _QM_COLS)))
        qp = _i32(np.zeros((n_queue, self._n_pin_cols())))
        return (jax.jit(one, donate_argnums=(1,))
                .lower(self._params, carry, qt, qm, qp).compile())

    def _n_pin_cols(self) -> int:
        return self.paged.n_tbl if self.paged is not None else 1

    def _init_state(self) -> Dict:
        B, cap = self.slots, self.cap
        st = dict(
            last_tok=jnp.full((B, 1), self.pad_token, jnp.int32),
            live=jnp.zeros((B,), jnp.bool_),
            n_gen=jnp.zeros((B,), jnp.int32),
            max_new=jnp.zeros((B,), jnp.int32),
            stop=jnp.full((B,), -1, jnp.int32),
            out=jnp.full((B, cap), self.pad_token, jnp.int32),
            keys=jnp.zeros((B, 2), jnp.uint32),
            pending=jnp.zeros((B,), jnp.bool_),
            occupant=jnp.zeros((B,), jnp.int32),
        )
        if self.paged is not None:
            lay = self.paged
            st["cache"] = lm.init_paged_cache(
                self.cfg, B, lay.n_blocks, lay.block_size, lay.n_tbl)
            st["ref"] = jnp.zeros((lay.n_blocks,), jnp.int32).at[0].set(1)
            st["filling"] = jnp.zeros((B,), jnp.bool_)
            st["n_alloc"] = jnp.zeros((B,), jnp.int32)
        else:
            st["cache"] = lm.init_cache(self.cfg, B, self.max_seq)
        return st

    def _init_carry(self, n_queue: int, with_obs: bool = True) -> Dict:
        """``with_obs=False`` builds the obs-less carry the whole-loop
        executable takes (its telemetry rings enter as a separately
        donated argument, see ``_lower_loop``); the single-iteration
        executable keeps them in the carry it round-trips."""
        carry = dict(
            st=self._init_state(), q_head=_i32(0), n_iter=_i32(0),
            n_steps=_i32(0), n_admits=_i32(0), n_drafted=_i32(0),
            n_accepted=_i32(0), acc_ema=jnp.float32(1.0),
            res_out=jnp.full((n_queue, self.cap), self.pad_token, jnp.int32),
            res_n=jnp.zeros((n_queue,), jnp.int32),
            res_iter=jnp.zeros((n_queue,), jnp.int32),
            res_first=jnp.zeros((n_queue,), jnp.int32),
        )
        if self.paged is not None:
            carry.update(
                last_pf=jnp.bool_(False), n_pf=_i32(0),
                peak_blocks=_i32(0),
                pf_done=jnp.zeros((n_queue,), jnp.bool_),
                req_tables=jnp.zeros((n_queue, self.paged.n_tbl),
                                     jnp.int32))
        if self.obs is not None and with_obs:
            carry["obs"] = obs_rings.init_obs_state(self.obs)
        return carry

    # -- host-side staging ---------------------------------------------

    def _check(self, requests: Sequence[Request]):
        for r in requests:
            if r.max_new_tokens > self.cap:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens {r.max_new_tokens} "
                    f"> cap {self.cap}")
            if self.paged is not None:
                plen = len(r.prompt)
                if not (1 <= plen <= self.prompt_len):
                    raise ValueError(
                        f"request {r.rid}: prompt len {plen} outside "
                        f"[1, {self.prompt_len}]")
                if (self.cfg.family in ("ssm", "hybrid")
                        and plen % self.prefill_chunk):
                    raise ValueError(
                        f"request {r.rid}: prompt len {plen} must be a "
                        f"multiple of prefill_chunk {self.prefill_chunk} "
                        f"for the {self.cfg.family!r} family (a garbage "
                        "chunk tail would corrupt the recurrent state)")
                need = max(cdiv(plen, self.prefill_chunk)
                           * self.prefill_chunk,
                           plen + r.max_new_tokens - 1 + self.draft_k)
                if need > self.paged.tokens_per_slot:
                    raise ValueError(
                        f"request {r.rid}: needs {need} addressable tokens"
                        f" > table capacity {self.paged.tokens_per_slot}")
            elif len(r.prompt) != self.prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt len {len(r.prompt)} != "
                    f"scheduler prompt_len {self.prompt_len}")
        if len({r.rid for r in requests}) != len(requests):
            raise ValueError("request rids must be unique within a run")

    def _stage(self, requests: Sequence[Request],
               arrival_iters: Optional[Sequence[int]] = None
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Stage the workload into the (q_toks, q_meta, q_pins) device
        buffers, resolving prefix sharing and per-request block grants."""
        n = len(requests)
        toks = np.full((n, self._p_pad), self.pad_token, np.int32)
        for i, r in enumerate(requests):
            toks[i, :len(r.prompt)] = np.asarray(r.prompt, np.int32)
        arr = (np.zeros(n, np.int64) if arrival_iters is None
               else np.asarray(arrival_iters, np.int64))
        if arrival_iters is not None and len(arr) != n:
            raise ValueError("arrival_iters length != len(requests)")
        meta = np.zeros((n, _QM_COLS), np.int64)
        if self.paged is not None:
            lay, C = self.paged, self.prefill_chunk
            enable = (self.prefix_sharing
                      and self.cfg.family not in ("ssm", "hybrid"))
            plan = plan_prefix_sharing(
                [np.asarray(r.prompt) for r in requests],
                lay.block_size, lay.n_tbl, enable=enable)
            self.last_prefix_plan = plan
            pins = plan.pin_counts.astype(np.int64)
            max_blks = np.zeros(n, np.int64)
            for i, r in enumerate(requests):
                plen = len(r.prompt)
                need = max(cdiv(plen, C) * C,
                           plen + r.max_new_tokens - 1 + self.draft_k)
                max_blks[i] = lay.blocks_for(need)
            # static no-deadlock guarantee: even with every pinned shared
            # block held live by a not-yet-admitted sharer, the pool can
            # satisfy the largest single fresh grant
            n_pinned = int(np.sum(plan.pin_counts > 0))
            worst = int(np.max(max_blks - plan.n_shared_blocks, initial=0))
            if worst + n_pinned > lay.n_blocks - 1:
                raise ValueError(
                    f"paged pool too small: worst-case fresh grant {worst}"
                    f" + {n_pinned} pinned shared blocks > "
                    f"{lay.n_blocks - 1} allocatable blocks")
            for i, r in enumerate(requests):
                meta[i] = [r.rid, r.max_new_tokens, r.stop_token,
                           len(r.prompt), plan.share_src[i],
                           plan.n_shared_blocks[i], arr[i], max_blks[i]]
        else:
            pins = np.zeros((n, 1), np.int64)
            for i, r in enumerate(requests):
                meta[i] = [r.rid, r.max_new_tokens, r.stop_token,
                           len(r.prompt), -1, 0, arr[i], 0]
        return _i32(toks), _i32(meta), _i32(pins)

    def compile_for(self, n_requests: int, lockstep: bool = False,
                    instrumented: bool = False):
        """Pre-compile the serve loop for a queue length (off the clock);
        ``lockstep=True`` also pre-compiles the baseline executables so a
        timed run_lockstep never pays compile, ``instrumented=True`` the
        single-iteration executable run_instrumented steps."""
        if n_requests not in self._loops:
            self._loops[n_requests] = self._build_loop(n_requests)
        if lockstep:
            self._lockstep_executables()
        if instrumented and n_requests not in self._iters:
            self._iters[n_requests] = self._build_iter(n_requests)
        return self._loops[n_requests]

    def run(self, requests: Sequence[Request],
            arrival_iters: Optional[Sequence[int]] = None) -> ServeReport:
        """Serve ``requests`` to completion.  ``arrival_iters`` holds an
        open-loop arrival schedule in LOOP-ITERATION units (the device
        clock): request i is not admitted before iteration
        arrival_iters[i].  Default: everything arrives at t=0."""
        self._check(requests)
        loop = self.compile_for(len(requests))
        q_toks, q_meta, q_pins = self._stage(requests, arrival_iters)
        carry = jax.block_until_ready(
            self._init_carry(len(requests), with_obs=False))
        args = (q_toks, q_meta, q_pins)
        if self.obs is not None:
            args = (jax.block_until_ready(
                obs_rings.init_obs_state(self.obs)),) + args
        t0 = time.time()                    # compile + staging off the clock
        res = jax.block_until_ready(loop(self._params, carry, *args))
        wall = time.time() - t0
        res_out, res_n = np.asarray(res["res_out"]), np.asarray(res["res_n"])
        res_iter, n_iter = np.asarray(res["res_iter"]), int(res["n_iter"])
        res_first = np.asarray(res["res_first"])
        done = [FinishedRequest(
            rid=r.rid, tokens=res_out[i, :res_n[i]].copy(),
            latency_s=wall * int(res_iter[i]) / max(n_iter, 1),
            finish_iter=int(res_iter[i]), first_iter=int(res_first[i]))
            for i, r in enumerate(requests)]
        report = ServeReport(finished=done, wall_s=wall,
                             n_steps=int(res["n_steps"]),
                             n_admits=int(res["n_admits"]), slots=self.slots,
                             n_drafted=int(res["n_drafted"]),
                             n_accepted=int(res["n_accepted"]),
                             n_pf=int(res.get("n_pf", 0)),
                             peak_blocks=int(res.get("peak_blocks", 0)))
        if self.obs is not None:
            report.obs = obs_rings.harvest_obs(
                self.obs, jax.device_get(res["obs"]), n_iter=n_iter,
                wall_s=wall, slots=self.slots,
                n_steps=report.n_steps, n_drafted=report.n_drafted,
                n_accepted=report.n_accepted,
                paged=self.paged is not None)
        return report

    def run_instrumented(self, requests: Sequence[Request],
                         arrival_iters: Optional[Sequence[int]] = None
                         ) -> Tuple[ServeReport, Dict[str, np.ndarray]]:
        """Serve with a host timestamp on EVERY loop iteration: the same
        compiled iteration body the while_loop runs, stepped from the
        host.  Wall time is inflated by one device->host sync per
        iteration, so use ``run`` for throughput and this for latency
        structure: real TTFT per request and the per-iteration duration
        series (whose step-branch percentiles are the serve benchmark's
        decode-stall gate).  Returns (report, timeline) where timeline
        has ``branch`` (the switch index per iteration) and ``iter_s``."""
        self._check(requests)
        n = len(requests)
        self.compile_for(n, instrumented=True)
        it = self._iters[n]
        q_toks, q_meta, q_pins = self._stage(requests, arrival_iters)
        c = jax.block_until_ready(self._init_carry(n))
        branches: List[int] = []
        iter_s: List[float] = []
        t0 = time.time()
        t_prev = t0
        while True:
            c, br, cont = it(self._params, c, q_toks, q_meta, q_pins)
            br, cont = int(br), bool(cont)          # per-iteration sync
            t_now = time.time()
            iter_s.append(t_now - t_prev)
            t_prev = t_now
            branches.append(br)
            if not cont:
                break
        wall = time.time() - t0
        res_out = np.asarray(c["res_out"])
        res_n = np.asarray(c["res_n"])
        res_iter = np.asarray(c["res_iter"])
        res_first = np.asarray(c["res_first"])
        cum = np.cumsum(iter_s)
        at = lambda k: float(cum[min(int(k), len(cum) - 1)])
        done = [FinishedRequest(
            rid=r.rid, tokens=res_out[i, :res_n[i]].copy(),
            latency_s=at(res_iter[i]), finish_iter=int(res_iter[i]),
            first_iter=int(res_first[i]), ttft_s=at(res_first[i]))
            for i, r in enumerate(requests)]
        report = ServeReport(
            finished=done, wall_s=wall, n_steps=int(c["n_steps"]),
            n_admits=int(c["n_admits"]), slots=self.slots,
            n_drafted=int(c["n_drafted"]), n_accepted=int(c["n_accepted"]),
            n_pf=int(c.get("n_pf", 0)),
            peak_blocks=int(c.get("peak_blocks", 0)))
        if self.obs is not None:
            # the instrumented runner's carry keeps the rings inline
            # (the host round-trips it), so harvest reads them directly
            report.obs = obs_rings.harvest_obs(
                self.obs, jax.device_get(c["obs"]),
                n_iter=len(branches), wall_s=wall, slots=self.slots,
                n_steps=report.n_steps, n_drafted=report.n_drafted,
                n_accepted=report.n_accepted,
                paged=self.paged is not None)
        timeline = dict(branch=np.asarray(branches, np.int32),
                        iter_s=np.asarray(iter_s))
        return report, timeline

    def run_lockstep(self, requests: Sequence[Request]) -> ServeReport:
        """Lock-step baseline through the SAME per-slot machinery: waves
        of ``slots`` requests all decode to the wave's longest budget, and
        per-request stop handling is applied post-hoc by truncation -- the
        pre-scheduler serve.py discipline, isolated so the benchmark delta
        is pure scheduling (identical kernels, admit path and step math)."""
        if self.paged is not None:
            raise ValueError("run_lockstep is the contiguous baseline; "
                             "build the scheduler without paged=")
        self._check(requests)
        admit, drain = self._lockstep_executables()
        state = self._init_state()
        done: List[FinishedRequest] = []
        n_steps = n_admits = 0
        t0 = time.time()
        for w0 in range(0, len(requests), self.slots):
            wave = list(requests[w0:w0 + self.slots])
            wave_max = max(r.max_new_tokens for r in wave)
            for slot, r in enumerate(wave):
                # stop=-1, budget=wave_max: every slot decodes the full wave
                state = admit(
                    self._params, state, _i32(slot),
                    _i32(np.asarray(r.prompt)[None, :]), _i32(r.rid),
                    _i32(wave_max), _i32(-1))
                n_admits += 1
            state = drain(self._params, state, _i32(wave_max - 1))
            n_steps += wave_max - 1
            out_h = np.asarray(state["out"])
            t_wave = time.time() - t0
            for slot, r in enumerate(wave):
                toks = out_h[slot, :wave_max]
                n = r.max_new_tokens
                if r.stop_token >= 0:
                    hits = np.nonzero(toks == r.stop_token)[0]
                    if hits.size:
                        n = min(n, int(hits[0]) + 1)
                done.append(FinishedRequest(rid=r.rid, tokens=toks[:n].copy(),
                                            latency_s=t_wave,
                                            finish_iter=n_steps + n_admits))
        return ServeReport(finished=done, wall_s=time.time() - t0,
                           n_steps=n_steps, n_admits=n_admits,
                           slots=self.slots)


def mixed_length_requests(n: int, prompt_len: int, vocab_size: int,
                          stop_lengths: Sequence[int] = (4, 16, 8, 12),
                          seed: int = 0) -> List[Request]:
    """Synthetic mixed-length workload: request i stops after
    ``stop_lengths[i % len]`` tokens.  The interleaving is deliberately
    adversarial for lock-step waves (short and long requests share one)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=int(stop_lengths[i % len(stop_lengths)]))
            for i in range(n)]
