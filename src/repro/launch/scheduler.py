"""Continuous-batching serving scheduler over a fixed pool of decode slots.

The paper's macro is weight-stationary: weights are written once and
activations stream.  The serving-system analogue is a fixed pool of decode
slots over prepacked weights -- one compiled decode step serves the pool
forever, and the scheduler's only job is keeping the slots full.  The
lock-step loop in launch/serve.py wastes exactly what the macro's
single-conversion trick saves: a finished sequence burns a slot (a
conversion) until the slowest request ends.  Here every step advances only
live slots, and a freed slot is refilled from the request queue through
``lm.prefill_into_slot`` without recompiling anything.

The entire serve loop is DEVICE-RESIDENT.  The request queue (prompts +
per-request budgets/stop tokens) is staged into device buffers up front,
and one AOT-compiled ``lax.while_loop`` runs a three-way ``lax.switch``
until the queue is drained:

  harvest : some slot finished (EOS or max-new-tokens, tracked by the
            on-device ``live`` mask; finishes are parked in a ``pending``
            mask) -> copy its output row into the per-request result
            buffer and free the slot.
  admit   : a slot is free and the queue is non-empty -> reset the slot,
            batch-1 prefill the next queued prompt into the pool cache
            (``lm.prefill_into_slot``; the slot index is traced, shapes
            are static), sample the request's first token, arm its
            counters.
  step    : one pooled decode step; only live slots advance.

The host syncs with the device exactly ONCE per workload -- there is no
per-token (or even per-request) host round-trip, which is what lets the
scheduler's fewer-wasted-slot-steps advantage survive dispatch latency
even at smoke scale on CPU.

Determinism contract (tested in tests/test_scheduler.py): a request's
tokens depend only on (params, prompt, rid) -- NOT on which slot it ran
in, what shared the pool with it, or when it was admitted.  Sampling keys
are folded per request id (``fold_in(sampling_key(seed), rid)``) and each
slot consumes its own key stream one split per generated token, so even
temperature sampling is bit-identical to a solo run.

Mixed-fidelity deployment plans (repro.plan) serve through this scheduler
UNCHANGED: a plan is static metadata on the ModelConfig, resolved inside
``lm.prefill_into_slot``/``lm.decode_step`` at trace time, so the pool's
AOT-compiled loop already embeds every projection's own macro config --
zero recompiles across decode steps, per-layer D/A splits and all
(tests/test_plan.py).  Caveat: deterministic noise emulation
(cfg.cim_noise_seed) draws per POOL ROW, so noisy tokens depend on slot
assignment -- like silicon, where each slot maps to a physical macro bank
-- and the scheduler's slot-independence contract holds only noise-free.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from ..models.config import ModelConfig


def sampling_key(seed: int) -> jax.Array:
    """Sampling PRNG stream, deliberately distinct from the params-init
    stream: serve.py used to feed PRNGKey(seed) to BOTH ``lm.init`` and
    the decode-loop sampler (regression-tested in tests/test_scheduler.py)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), 0x53414D50)  # "SAMP"


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``rid`` seeds the request's sampling
    stream and must be unique within a run.  ``stop_token < 0`` disables
    EOS detection (the request runs to ``max_new_tokens``)."""
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    stop_token: int = -1


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    tokens: np.ndarray            # (n,) generated tokens, stop token incl.
    latency_s: float              # arrival (run start) -> completion
    finish_iter: int              # loop iteration the request finished at


@dataclasses.dataclass
class ServeReport:
    finished: List[FinishedRequest]
    wall_s: float
    n_steps: int                  # pooled decode steps (rounds, if spec)
    n_admits: int
    slots: int
    n_drafted: int = 0            # draft tokens proposed (speculative mode)
    n_accepted: int = 0           # draft tokens accepted by verify

    @property
    def total_tokens(self) -> int:
        return sum(len(f.tokens) for f in self.finished)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify pass accepted."""
        return (self.n_accepted / self.n_drafted if self.n_drafted
                else float("nan"))

    @property
    def tokens_per_step(self) -> float:
        """Emitted tokens per pooled step: ~1*occupancy lock-free decode,
        up to (draft_k+1)*slots when every draft block is accepted."""
        return (self.total_tokens / self.n_steps if self.n_steps
                else float("nan"))

    @property
    def tok_s(self) -> float:
        return self.total_tokens / self.wall_s if self.wall_s > 0 else float("nan")

    @property
    def occupancy(self) -> float:
        """Useful-token fraction of the slot-steps spent (admits each
        yield one token; every pooled step spends ``slots`` slot-steps)."""
        slot_steps = self.slots * self.n_steps + self.n_admits
        return self.total_tokens / slot_steps if slot_steps else float("nan")

    def latency_percentiles(self) -> Dict[str, float]:
        lats = sorted(f.latency_s for f in self.finished)
        if not lats:
            return {"p50_s": float("nan"), "p95_s": float("nan")}
        pick = lambda q: lats[min(len(lats) - 1, int(q * (len(lats) - 1) + 0.5))]
        return {"p50_s": pick(0.50), "p95_s": pick(0.95)}

    def summary(self) -> Dict:
        out = dict(total_tokens=self.total_tokens,
                   wall_s=round(self.wall_s, 4),
                   tok_s=round(self.tok_s, 2),
                   occupancy=round(self.occupancy, 4),
                   n_steps=self.n_steps, n_admits=self.n_admits,
                   slots=self.slots,
                   **{k: round(v, 4) for k, v in
                      self.latency_percentiles().items()})
        if self.n_drafted:
            out.update(n_drafted=self.n_drafted,
                       n_accepted=self.n_accepted,
                       acceptance_rate=round(self.acceptance_rate, 4),
                       tokens_per_step=round(self.tokens_per_step, 4))
        return out

    def tokens_by_rid(self) -> Dict[int, np.ndarray]:
        return {f.rid: f.tokens for f in self.finished}


def _i32(v) -> jax.Array:
    return jnp.asarray(v, jnp.int32)


class ContinuousBatchingScheduler:
    """Fixed-slot continuous batching, fully device-resident.

    ``params`` may hold prepacked CIM weights (lm.pack_cim_params) -- the
    scheduler never touches weights, so pack-once/serve-many carries
    straight through.  ``max_new_cap`` bounds every request's
    max_new_tokens and sizes the on-device output buffers; ``prompt_len``
    is the single static prompt length (shorter prompts must be padded by
    the caller -- static shapes are what keep the whole pool on a handful
    of compiled executables).

    Request latencies are exact at the workload level (one wall clock
    around the device loop) and attributed per request by its finish
    iteration: latency_i = wall * finish_iter_i / total_iters.  This is an
    estimate -- admit iterations cost more than step iterations -- but the
    loop never leaves the device, so there is no per-event host timestamp
    to read without paying the sync the design removes.

    ``draft_k > 0`` turns on plan-cascade speculative decoding: each step
    branch becomes one atomic draft-K/verify/accept ROUND (see
    ``spec_step``), drafting under ``draft_plan`` (an all-analog shadow of
    the serving plan -- ``plan.derive_draft_plan`` -- served from the SAME
    packed weights) and verifying under the deployed config.  Rounds are
    atomic per loop iteration, so harvest/admit still interleave between
    rounds and the determinism contract is unchanged: a request's tokens
    depend only on (params, prompt, rid); greedy output is bit-identical
    to the non-speculative scheduler, temperature sampling is
    distribution-identical (rejection sampling) and stays pool-vs-solo
    bit-identical at EQUAL draft_k.  Restricted to positional-KV families
    (attention); SSM/conv recurrences cannot roll back a rejected block.
    """

    def __init__(self, params, cfg: ModelConfig, slots: int, prompt_len: int,
                 max_new_cap: int, temperature: float = 0.0, seed: int = 0,
                 pad_token: int = 0, draft_k: int = 0, draft_plan=None):
        if cfg.family == "vlm":
            raise NotImplementedError(
                "scheduler is text-only for now (no per-request frontends)")
        if draft_k and cfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                "speculative decoding needs positional KV rollback; the "
                f"{cfg.family!r} family carries recurrent SSM/conv state "
                "that a rejected draft block cannot roll back")
        if draft_k < 0 or draft_k > 31:
            raise ValueError(f"draft_k {draft_k} outside [0, 31] (k+1 must "
                             "stay on the skinny-M verify path)")
        self.cfg, self.slots = cfg, slots
        self.prompt_len, self.cap = prompt_len, max_new_cap
        self.temperature, self.pad_token = temperature, pad_token
        self._base_key = sampling_key(seed)
        # speculative rounds write draft/verify KV rows up to pos + draft_k
        # before rollback, so the cache keeps that much extra headroom
        self.max_seq = prompt_len + max_new_cap + draft_k
        self._params = params
        self.draft_k = draft_k
        self.draft_cfg = (dataclasses.replace(cfg, cim_plan=draft_plan)
                          if draft_plan is not None else cfg)
        self._loops: Dict[int, object] = {}    # queue length -> executable

        def sample(logits, keys):
            """logits (R, V) f32, keys (R, 2) -> (R,) int32 tokens."""
            if temperature <= 0:
                return jnp.argmax(logits, -1).astype(jnp.int32)
            return jax.vmap(lambda l, k: jax.random.categorical(
                k, l / temperature))(logits, keys).astype(jnp.int32)

        def arm_slot(params, st, slot, prompt, rid, max_new, stop):
            """Reset + prefill ``slot`` with one request and sample its
            first token.  A request can finish ON that token; the event is
            parked in the pending mask like any step finish."""
            logits, cache = lm.prefill_into_slot(params, cfg, prompt,
                                                 st["cache"], slot)
            k_next, k_use = jax.random.split(
                jax.random.fold_in(self._base_key, rid))
            tok = sample(logits[:, -1], k_use[None])[0]
            fin0 = (tok == stop) | (max_new <= 1)
            st = dict(st, cache=cache)
            st["last_tok"] = st["last_tok"].at[slot, 0].set(tok)
            st["out"] = (st["out"].at[slot].set(self.pad_token)
                         .at[slot, 0].set(tok))
            st["n_gen"] = st["n_gen"].at[slot].set(1)
            st["max_new"] = st["max_new"].at[slot].set(max_new)
            st["stop"] = st["stop"].at[slot].set(stop)
            st["keys"] = st["keys"].at[slot].set(k_next)
            st["live"] = st["live"].at[slot].set(~fin0)
            st["pending"] = st["pending"].at[slot].set(fin0)
            return st

        def step(params, st):
            """One pooled decode step; finishes land in pending."""
            live = st["live"]
            logits, cache = lm.decode_step(params, cfg, st["last_tok"],
                                           st["cache"], live=live)
            splits = jax.vmap(jax.random.split)(st["keys"])      # (B,2,2)
            tok = sample(logits[:, -1], splits[:, 1])
            tok = jnp.where(live, tok, jnp.int32(self.pad_token))
            keys = jnp.where(live[:, None], splits[:, 0], st["keys"])
            ar = jnp.arange(self.slots)
            idx = jnp.minimum(st["n_gen"], self.cap - 1)
            row = st["out"][ar, idx]
            out = st["out"].at[ar, idx].set(jnp.where(live, tok, row))
            n_gen = st["n_gen"] + live.astype(jnp.int32)
            finished = live & ((tok == st["stop"]) | (n_gen >= st["max_new"]))
            return dict(st, cache=cache, last_tok=tok[:, None], out=out,
                        n_gen=n_gen, keys=keys, live=live & ~finished,
                        pending=st["pending"] | finished)

        def spec_step(params, st):
            """One speculative ROUND as a single pooled step: draft K
            tokens under the draft-plan config (same packed weights), roll
            the per-slot positions back, verify all K+1 positions in ONE
            wide forward (M = slots*(K+1) stays on the skinny-M prepacked
            kernels), then accept the longest agreeing prefix plus a
            correction/bonus token.  Emits a VARIABLE 1..K+1 tokens per
            slot; the whole round compiles into one loop iteration, so
            per-step dispatch overhead is amortized over every accepted
            token.  Returns (state, n_drafted, n_accepted).

            Rollback is positional: draft and verify writes land at rows
            >= the committed ``cache["pos"]``, which the attention
            validity horizon masks until pos is advanced past them -- so
            "rolling back" a rejected suffix is just not advancing pos
            over it, and the next round's writes overwrite those rows.
            """
            K = self.draft_k
            live = st["live"]
            pos0 = st["cache"]["pos"]
            cache, keys, last = st["cache"], st["keys"], st["last_tok"]
            d_toks, d_logits = [], []
            for _ in range(K):
                logits, cache = lm.decode_step(params, self.draft_cfg, last,
                                               cache, live=live)
                splits = jax.vmap(jax.random.split)(keys)
                dtok = sample(logits[:, -1], splits[:, 1])
                dtok = jnp.where(live, dtok, jnp.int32(self.pad_token))
                keys = jnp.where(live[:, None], splits[:, 0], keys)
                d_toks.append(dtok)
                if temperature > 0:
                    d_logits.append(logits[:, -1])
                last = dtok[:, None]
            drafts = jnp.stack(d_toks, axis=1)                  # (B, K)
            vtoks = jnp.concatenate([st["last_tok"], drafts], axis=1)
            cache = dict(cache, pos=pos0)   # rollback before verify
            vlogits, cache = lm.verify_step(params, cfg, vtoks, cache)

            # verify position i gives the distribution of the token AFTER
            # prefix [last, d_1..d_i]; cand pads drafts to K+1 columns so
            # the correction token can be placed at column n_acc
            cand = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)
            if temperature <= 0:
                v_arg = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)
                match = (v_arg[:, :K] == drafts).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                corr = v_arg              # correction at ANY column is its argmax
            else:
                # standard rejection sampling: accept d_i with probability
                # min(1, p_verify(d_i)/p_draft(d_i)); on first rejection,
                # resample from the normalized residual max(p_v - p_d, 0).
                # When all K drafts are accepted the padded zero row makes
                # the residual collapse to p_v[:, K] -- the bonus draw.
                dlg = jnp.stack(d_logits, axis=1)               # (B, K, V)
                p_d = jax.nn.softmax(dlg / temperature, axis=-1)
                p_v = jax.nn.softmax(vlogits / temperature, axis=-1)
                pd_tok = jnp.take_along_axis(
                    p_d, drafts[..., None], -1)[..., 0]
                pv_tok = jnp.take_along_axis(
                    p_v[:, :K], drafts[..., None], -1)[..., 0]
                splits = jax.vmap(jax.random.split)(keys)
                u = jax.vmap(lambda k: jax.random.uniform(k, (K,)))(
                    splits[:, 1])
                keys = jnp.where(live[:, None], splits[:, 0], keys)
                acc = (u * pd_tok < pv_tok).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(acc, axis=1), axis=1)
                pv_n = jnp.take_along_axis(
                    p_v, n_acc[:, None, None], axis=1)[:, 0]
                pd_ext = jnp.concatenate(
                    [p_d, jnp.zeros_like(p_d[:, :1])], axis=1)
                pd_n = jnp.take_along_axis(
                    pd_ext, n_acc[:, None, None], axis=1)[:, 0]
                res = jnp.maximum(pv_n - pd_n, 0.0)
                tot = jnp.sum(res, axis=-1, keepdims=True)
                res = jnp.where(tot > 0, res / jnp.maximum(tot, 1e-38),
                                pv_n)
                splits = jax.vmap(jax.random.split)(keys)
                corr = jax.vmap(lambda r, k: jax.random.categorical(
                    k, jnp.log(jnp.maximum(r, 1e-38))))(
                    res, splits[:, 1]).astype(jnp.int32)[:, None]
                keys = jnp.where(live[:, None], splits[:, 0], keys)

            cols = jnp.arange(K + 1, dtype=jnp.int32)[None, :]
            emitted = jnp.where(cols == n_acc[:, None], corr, cand)
            # clamp by the per-request budget, then truncate at the first
            # stop token INSIDE the emitted block (stop included)
            allowed = jnp.maximum(st["max_new"] - st["n_gen"], 0)
            n_emit = jnp.minimum(n_acc + 1, allowed)
            is_stop = (emitted == st["stop"][:, None]) & (cols < n_emit[:, None])
            has_stop = jnp.any(is_stop, axis=1)
            n_emit = jnp.where(has_stop, jnp.argmax(is_stop, axis=1) + 1,
                               n_emit)
            n_emit = jnp.where(live, n_emit, 0)

            ar = jnp.arange(self.slots)
            out = st["out"]
            for j in range(K + 1):
                idx = jnp.minimum(st["n_gen"] + j, self.cap - 1)
                cur = out[ar, idx]
                out = out.at[ar, idx].set(
                    jnp.where(j < n_emit, emitted[:, j], cur))
            n_gen = st["n_gen"] + n_emit
            finished = live & (has_stop | (n_gen >= st["max_new"]))
            new_last = jnp.take_along_axis(
                emitted, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
            new_last = jnp.where(n_emit > 0, new_last, st["last_tok"][:, 0])
            # committed rows [0, pos0 + n_emit): the old frontier token
            # plus every emitted token except the new frontier
            cache = dict(cache, pos=pos0 + n_emit)
            st = dict(st, cache=cache, last_tok=new_last[:, None], out=out,
                      n_gen=n_gen, keys=keys, live=live & ~finished,
                      pending=st["pending"] | finished)
            return (st, jnp.sum(jnp.where(live, K, 0)).astype(jnp.int32),
                    jnp.sum(jnp.where(live, n_acc, 0)).astype(jnp.int32))

        self._arm_slot, self._step_fn = arm_slot, step
        self._spec_step = spec_step
        self._lockstep_exes = None

    def _lockstep_executables(self):
        """Lock-step baseline executables: batch-1 admit + drain-N-steps
        (run_lockstep), compiled lazily against the same pool state."""
        if self._lockstep_exes is None:
            state = self._init_state()
            p0 = _i32(np.zeros((1, self.prompt_len)))
            z = _i32(0)
            admit = (jax.jit(self._arm_slot, donate_argnums=(1,))
                     .lower(self._params, state, z, p0, z, z, z).compile())

            def drain(params, st, n):
                return jax.lax.fori_loop(
                    0, n, lambda _, s: self._step_fn(params, s), st)

            drain = (jax.jit(drain, donate_argnums=(1,))
                     .lower(self._params, state, z).compile())
            self._lockstep_exes = (admit, drain)
        return self._lockstep_exes

    # -- device-resident serve loop ------------------------------------

    def _build_loop(self, n_queue: int):
        """Compile the whole-workload loop for a queue of n_queue requests."""
        cfg, slots, cap, P = self.cfg, self.slots, self.cap, self.prompt_len

        def serve_loop(params, st, q_toks, q_meta):
            # q_toks (N, P) int32; q_meta (N, 3) int32: rid, max_new, stop
            def occupied(st):
                return st["live"] | st["pending"]

            def harvest(c):
                st = c["st"]
                slot = jnp.argmax(st["pending"])
                qidx = st["occupant"][slot]
                c = dict(c)
                c["res_out"] = c["res_out"].at[qidx].set(st["out"][slot])
                c["res_n"] = c["res_n"].at[qidx].set(st["n_gen"][slot])
                c["res_iter"] = c["res_iter"].at[qidx].set(c["n_iter"])
                c["st"] = dict(st, pending=st["pending"].at[slot].set(False))
                return c

            def admit(c):
                st, qidx = c["st"], c["q_head"]
                slot = jnp.argmin(occupied(st))
                prompt = jax.lax.dynamic_slice(q_toks, (qidx, 0), (1, P))
                rid, max_new, stop = (q_meta[qidx, 0], q_meta[qidx, 1],
                                      q_meta[qidx, 2])
                st = self._arm_slot(params, st, slot, prompt, rid, max_new,
                                    stop)
                st = dict(st, occupant=st["occupant"].at[slot].set(qidx))
                return dict(c, st=st, q_head=qidx + 1,
                            n_admits=c["n_admits"] + 1)

            def step(c):
                if self.draft_k:
                    st, drafted, accepted = self._spec_step(params, c["st"])
                    return dict(c, st=st, n_steps=c["n_steps"] + 1,
                                n_drafted=c["n_drafted"] + drafted,
                                n_accepted=c["n_accepted"] + accepted)
                return dict(c, st=self._step_fn(params, c["st"]),
                            n_steps=c["n_steps"] + 1)

            def body(c):
                st = c["st"]
                can_admit = (c["q_head"] < n_queue) & ~jnp.all(occupied(st))
                branch = jnp.where(jnp.any(st["pending"]), 0,
                                   jnp.where(can_admit, 1, 2))
                c = jax.lax.switch(branch, [harvest, admit, step], c)
                return dict(c, n_iter=c["n_iter"] + 1)

            def cond(c):
                return (jnp.any(occupied(c["st"]))
                        | (c["q_head"] < n_queue))

            carry = dict(
                st=st, q_head=_i32(0), n_iter=_i32(0), n_steps=_i32(0),
                n_admits=_i32(0), n_drafted=_i32(0), n_accepted=_i32(0),
                res_out=jnp.full((n_queue, cap), self.pad_token, jnp.int32),
                res_n=jnp.zeros((n_queue,), jnp.int32),
                res_iter=jnp.zeros((n_queue,), jnp.int32),
            )
            c = jax.lax.while_loop(cond, body, carry)
            return dict(res_out=c["res_out"], res_n=c["res_n"],
                        res_iter=c["res_iter"], n_iter=c["n_iter"],
                        n_steps=c["n_steps"], n_admits=c["n_admits"],
                        n_drafted=c["n_drafted"], n_accepted=c["n_accepted"])

        # no donation: the loop's outputs are only the result buffers, so
        # the input state can't alias anything (XLA would warn and ignore)
        state = self._init_state()
        qt = _i32(np.zeros((n_queue, P)))
        qm = _i32(np.zeros((n_queue, 3)))
        return (jax.jit(serve_loop)
                .lower(self._params, state, qt, qm).compile())

    def _init_state(self) -> Dict:
        B, cap = self.slots, self.cap
        return dict(
            cache=lm.init_cache(self.cfg, B, self.max_seq),
            last_tok=jnp.full((B, 1), self.pad_token, jnp.int32),
            live=jnp.zeros((B,), jnp.bool_),
            n_gen=jnp.zeros((B,), jnp.int32),
            max_new=jnp.zeros((B,), jnp.int32),
            stop=jnp.full((B,), -1, jnp.int32),
            out=jnp.full((B, cap), self.pad_token, jnp.int32),
            keys=jnp.zeros((B, 2), jnp.uint32),
            pending=jnp.zeros((B,), jnp.bool_),
            occupant=jnp.zeros((B,), jnp.int32),
        )

    def _check(self, requests: Sequence[Request]):
        for r in requests:
            if len(r.prompt) != self.prompt_len:
                raise ValueError(
                    f"request {r.rid}: prompt len {len(r.prompt)} != "
                    f"scheduler prompt_len {self.prompt_len}")
            if r.max_new_tokens > self.cap:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens {r.max_new_tokens} "
                    f"> cap {self.cap}")
        if len({r.rid for r in requests}) != len(requests):
            raise ValueError("request rids must be unique within a run")

    def compile_for(self, n_requests: int, lockstep: bool = False):
        """Pre-compile the serve loop for a queue length (off the clock);
        ``lockstep=True`` also pre-compiles the baseline executables so a
        timed run_lockstep never pays compile."""
        if n_requests not in self._loops:
            self._loops[n_requests] = self._build_loop(n_requests)
        if lockstep:
            self._lockstep_executables()
        return self._loops[n_requests]

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` (all arriving at t=0) to completion."""
        self._check(requests)
        loop = self.compile_for(len(requests))
        q_toks = _i32(np.stack([np.asarray(r.prompt) for r in requests]))
        q_meta = _i32(np.asarray(
            [[r.rid, r.max_new_tokens, r.stop_token] for r in requests]))
        state = jax.block_until_ready(self._init_state())  # off the clock,
        t0 = time.time()                                   # like lockstep's
        res = jax.block_until_ready(
            loop(self._params, state, q_toks, q_meta))
        wall = time.time() - t0
        res_out, res_n = np.asarray(res["res_out"]), np.asarray(res["res_n"])
        res_iter, n_iter = np.asarray(res["res_iter"]), int(res["n_iter"])
        done = [FinishedRequest(
            rid=r.rid, tokens=res_out[i, :res_n[i]].copy(),
            latency_s=wall * int(res_iter[i]) / max(n_iter, 1),
            finish_iter=int(res_iter[i]))
            for i, r in enumerate(requests)]
        return ServeReport(finished=done, wall_s=wall,
                           n_steps=int(res["n_steps"]),
                           n_admits=int(res["n_admits"]), slots=self.slots,
                           n_drafted=int(res["n_drafted"]),
                           n_accepted=int(res["n_accepted"]))

    def run_lockstep(self, requests: Sequence[Request]) -> ServeReport:
        """Lock-step baseline through the SAME per-slot machinery: waves
        of ``slots`` requests all decode to the wave's longest budget, and
        per-request stop handling is applied post-hoc by truncation -- the
        pre-scheduler serve.py discipline, isolated so the benchmark delta
        is pure scheduling (identical kernels, admit path and step math)."""
        self._check(requests)
        admit, drain = self._lockstep_executables()
        state = self._init_state()
        done: List[FinishedRequest] = []
        n_steps = n_admits = 0
        t0 = time.time()
        for w0 in range(0, len(requests), self.slots):
            wave = list(requests[w0:w0 + self.slots])
            wave_max = max(r.max_new_tokens for r in wave)
            for slot, r in enumerate(wave):
                # stop=-1, budget=wave_max: every slot decodes the full wave
                state = admit(
                    self._params, state, _i32(slot),
                    _i32(np.asarray(r.prompt)[None, :]), _i32(r.rid),
                    _i32(wave_max), _i32(-1))
                n_admits += 1
            state = drain(self._params, state, _i32(wave_max - 1))
            n_steps += wave_max - 1
            out_h = np.asarray(state["out"])
            t_wave = time.time() - t0
            for slot, r in enumerate(wave):
                toks = out_h[slot, :wave_max]
                n = r.max_new_tokens
                if r.stop_token >= 0:
                    hits = np.nonzero(toks == r.stop_token)[0]
                    if hits.size:
                        n = min(n, int(hits[0]) + 1)
                done.append(FinishedRequest(rid=r.rid, tokens=toks[:n].copy(),
                                            latency_s=t_wave,
                                            finish_iter=n_steps + n_admits))
        return ServeReport(finished=done, wall_s=time.time() - t0,
                           n_steps=n_steps, n_admits=n_admits,
                           slots=self.slots)


def mixed_length_requests(n: int, prompt_len: int, vocab_size: int,
                          stop_lengths: Sequence[int] = (4, 16, 8, 12),
                          seed: int = 0) -> List[Request]:
    """Synthetic mixed-length workload: request i stops after
    ``stop_lengths[i % len]`` tokens.  The interleaving is deliberately
    adversarial for lock-step waves (short and long requests share one)."""
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab_size, prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=int(stop_lengths[i % len(stop_lengths)]))
            for i in range(n)]
