"""Jitted public wrapper for the fused complex hybrid-CIM GEMM kernel.

Handles: shared-full-scale complex SMF quantization, K padding to the
accumulate length, (bm,bn,bk) block selection with zero-padding to the
MXU-preferred blocks, CPU fallback (jnp oracle / interpret mode), dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..ccim_matmul.ops import (_pad_to, _pick_block, pick_gemm_blocks,
                               pick_weight_blocks)
from ..ccim_matmul.ops import SKINNY_VMEM_BUDGET
from .kernel import (ACC_LEN, SKINNY_SUBLANE, ccim_complex_matmul_pallas,
                     ccim_complex_matmul_prepacked_pallas,
                     ccim_complex_matmul_prepacked_skinny_pallas)
from .ref import ccim_complex_matmul_ref


def ccim_complex_matmul_int(
    x_re: jax.Array, x_im: jax.Array,        # (M, K) ints in [-127, 127]
    w_re: jax.Array, w_im: jax.Array,        # (K, N) ints -- one co-located copy
    *, use_pallas: bool | None = None, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Integer complex GEMM -> (y_re, y_im) int32 at scale 2^11."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_re.shape
    _, N = w_re.shape
    Kp = _pad_to(K, ACC_LEN)
    if Kp != K:
        pk = Kp - K
        x_re = jnp.pad(x_re, ((0, 0), (0, pk)))
        x_im = jnp.pad(x_im, ((0, 0), (0, pk)))
        w_re = jnp.pad(w_re, ((0, pk), (0, 0)))
        w_im = jnp.pad(w_im, ((0, pk), (0, 0)))
    if not use_pallas:
        return ccim_complex_matmul_ref(x_re, x_im, w_re, w_im)
    bm, bn, bk = pick_gemm_blocks(M, N, Kp)
    Mp, Np, Kpp = _pad_to(M, bm), _pad_to(N, bn), _pad_to(Kp, bk)
    if (Mp, Np, Kpp) != (M, N, Kp):
        x_re = jnp.pad(x_re, ((0, Mp - M), (0, Kpp - Kp)))
        x_im = jnp.pad(x_im, ((0, Mp - M), (0, Kpp - Kp)))
        w_re = jnp.pad(w_re, ((0, Kpp - Kp), (0, Np - N)))
        w_im = jnp.pad(w_im, ((0, Kpp - Kp), (0, Np - N)))
    y_re, y_im = ccim_complex_matmul_pallas(
        x_re.astype(jnp.int8), x_im.astype(jnp.int8),
        w_re.astype(jnp.int8), w_im.astype(jnp.int8),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y_re[:M, :N], y_im[:M, :N]


def ccim_complex_matmul_int_prepacked(
    x_re: jax.Array, x_im: jax.Array,     # (M, K) ints in [-127, 127]
    w_re: jax.Array, w_im: jax.Array,     # (Kp, Np) int8, pack-time padded
    wr_p6: jax.Array, wr_p5: jax.Array,   # (Kp, Np) int8 folded Re planes
    wi_p6: jax.Array, wi_p5: jax.Array,   # (Kp, Np) int8 folded Im planes
    *,
    k_dim: int, n_dim: int,
    use_pallas: bool | None = None, interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Prepacked fused complex macro GEMM: one co-located (Re, Im) weight
    pack serves all four real sub-MACs; only activations are padded and
    decomposed per call."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_re.shape
    assert K == k_dim, (K, k_dim)
    bn, bk, Np, Kp = pick_weight_blocks(k_dim, n_dim)
    assert w_re.shape == (Kp, Np), (w_re.shape, Kp, Np)
    if not use_pallas:
        pk = ((0, 0), (0, Kp - K))
        yr, yi = ccim_complex_matmul_ref(
            jnp.pad(x_re, pk).astype(jnp.int32),
            jnp.pad(x_im, pk).astype(jnp.int32),
            w_re.astype(jnp.int32), w_im.astype(jnp.int32))
        return yr[:, :n_dim], yi[:, :n_dim]
    if (M <= SKINNY_SUBLANE and 4 * Kp * bn <= SKINNY_VMEM_BUDGET
            and bk % SKINNY_SUBLANE == 0):
        # decode-shaped: pad M to the sublane width, keep the four folded
        # planes VMEM-resident across the K-loop (see the skinny kernel)
        px = ((0, SKINNY_SUBLANE - M), (0, Kp - K))
        y_re, y_im = ccim_complex_matmul_prepacked_skinny_pallas(
            jnp.pad(x_re, px).astype(jnp.int8),
            jnp.pad(x_im, px).astype(jnp.int8),
            w_re, w_im, jnp.stack([wr_p6, wr_p5, wi_p6, wi_p5]),
            bn=bn, bk=bk, interpret=interpret,
        )
        return y_re[:M, :n_dim], y_im[:M, :n_dim]
    bm = _pick_block(M, 128)
    Mp = _pad_to(M, bm)
    px = ((0, Mp - M), (0, Kp - K))
    y_re, y_im = ccim_complex_matmul_prepacked_pallas(
        jnp.pad(x_re, px).astype(jnp.int8), jnp.pad(x_im, px).astype(jnp.int8),
        w_re, w_im, wr_p6, wr_p5, wi_p6, wi_p5,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y_re[:M, :n_dim], y_im[:M, :n_dim]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ccim_complex_matmul(
    x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Complex float (M,K) @ (K,N) through the fused macro numerics.

    Re and Im of each operand share one scale (they share the array's
    full-scale in silicon, where both live on the same bitlines).
    """
    xr, xi = jnp.real(x), jnp.imag(x)
    wr, wi = jnp.real(w), jnp.imag(w)
    amax_x = jnp.maximum(
        jnp.max(jnp.maximum(jnp.abs(xr), jnp.abs(xi)), axis=-1, keepdims=True),
        1e-12)
    amax_w = jnp.maximum(
        jnp.max(jnp.maximum(jnp.abs(wr), jnp.abs(wi)), axis=0, keepdims=True),
        1e-12)
    sx, sw = amax_x / 127.0, amax_w / 127.0
    q = lambda v, s: jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int32)
    y_re, y_im = ccim_complex_matmul_int(
        q(xr, sx), q(xi, sx), q(wr, sw), q(wi, sw),
        use_pallas=use_pallas, interpret=interpret,
    )
    scale = sx * sw
    return (y_re * scale + 1j * (y_im * scale)).astype(jnp.complex64)
