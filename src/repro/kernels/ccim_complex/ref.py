"""Pure-jnp oracle for the fused complex CIM kernel: the 4-call reference.

Four independent ideal-analog hybrid GEMMs (one per real sub-MAC of
(a+bi)(c+di)) combined digitally.  Built on the ccim_matmul jnp oracle --
NOT on the fused kernel module -- so the parity test compares two
independent implementations of the same dataflow.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..ccim_matmul.ref import ccim_matmul_ref


def ccim_complex_matmul_ref(
    x_re: jnp.ndarray, x_im: jnp.ndarray,
    w_re: jnp.ndarray, w_im: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """4-pass reference: (M,K)x2 @ (K,N)x2 -> (y_re, y_im) int32 at x2^11."""
    ac = ccim_matmul_ref(x_re, w_re)
    bd = ccim_matmul_ref(x_im, w_im)
    ad = ccim_matmul_ref(x_re, w_im)
    bc = ccim_matmul_ref(x_im, w_re)
    return ac - bd, ad + bc
