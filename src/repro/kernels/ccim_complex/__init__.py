from .kernel import (ccim_complex_matmul_pallas,  # noqa: F401
                     ccim_complex_matmul_prepacked_pallas)
from .ops import (ccim_complex_matmul, ccim_complex_matmul_int,  # noqa: F401
                  ccim_complex_matmul_int_prepacked)
from .ref import ccim_complex_matmul_ref  # noqa: F401
