"""Fused single-pass complex hybrid-CIM GEMM Pallas kernel.

The silicon's headline dataflow (see DESIGN.md §5): Re and Im of each
weight are co-located in one 6T array, so ONE weight residency serves all
four real sub-MACs of (a+bi)(c+di) and the Re/Im outputs are produced with
a single conversion pass.  The kernel mirrors that: per (bm, bn, bk) grid
step it loads the w_re / w_im tiles ONCE, decomposes their MSB bit-planes
ONCE, and emits BOTH the Re and the Im output tiles -- four per-chunk
hybrid y8 streams (ac, bd, ad, bc) combined digitally as

    y_re += 2^11 * sum_c (y8_ac - y8_bd)
    y_im += 2^11 * sum_c (y8_ad + y8_bc)

Each sub-MAC uses the same ideal-analog macro arithmetic as
kernels.ccim_matmul (exact MXU dot + 3 MSB bit-plane dots + 7b mid-tread
ADC per 16-element chunk), so the result is bit-identical to four
independent ccim_matmul passes -- but with one weight fetch instead of
four and one kernel launch instead of four.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACC_LEN = 16
DCIM_LSB = 2048  # 2^11
ADC_HALF = 64    # 7-bit bipolar


def _chunk_dot(x, w):
    """(C, bm, L) x (C, L, bn) -> (C, bm, bn) int32 batched MXU dot."""
    return jax.lax.dot_general(
        x, w,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )


def _msb_planes(v):
    """int32 tile -> (value, signed bit-6 plane, signed bit-5 plane)."""
    s = jnp.where(v < 0, -1, 1)
    m = jnp.abs(v)
    return v, s * ((m >> 6) & 1), s * ((m >> 5) & 1)


def _y8_chunks(x, x6, x5, w, w6, w5):
    """Per-chunk hybrid macro output (C, bm, bn) for one real sub-MAC."""
    exact = _chunk_dot(x, w)
    dcim = 2 * _chunk_dot(x6, w6) + _chunk_dot(x6, w5) + _chunk_dot(x5, w6)
    acim = exact - dcim * DCIM_LSB
    code = jnp.clip(
        jnp.floor_divide(acim + DCIM_LSB // 2, DCIM_LSB), -ADC_HALF, ADC_HALF - 1
    )
    return dcim + code


def _ccim_complex_kernel(
    xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref, acc_re, acc_im,
    *, bk: int, n_k: int,
):
    """One (bm, bn) Re tile AND one Im tile; grid axis 2 walks K in bk steps."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)

    # ONE residency of the co-located (Re, Im) weight tile + ONE bit-plane
    # decomposition, shared by all four sub-MACs below.
    wr, wr6, wr5 = _msb_planes(wr_ref[...].astype(jnp.int32))   # (bk, bn)
    wi, wi6, wi5 = _msb_planes(wi_ref[...].astype(jnp.int32))
    xr, xr6, xr5 = _msb_planes(xr_ref[...].astype(jnp.int32))   # (bm, bk)
    xi, xi6, xi5 = _msb_planes(xi_ref[...].astype(jnp.int32))

    bm, bn = xr.shape[0], wr.shape[1]
    c = bk // ACC_LEN
    to_xc = lambda v: v.reshape(bm, c, ACC_LEN).swapaxes(0, 1)  # (C, bm, L)
    to_wc = lambda v: v.reshape(c, ACC_LEN, bn)                 # (C, L, bn)
    xrc = tuple(map(to_xc, (xr, xr6, xr5)))
    xic = tuple(map(to_xc, (xi, xi6, xi5)))
    wrc = tuple(map(to_wc, (wr, wr6, wr5)))
    wic = tuple(map(to_wc, (wi, wi6, wi5)))

    y_ac = _y8_chunks(*xrc, *wrc)
    y_bd = _y8_chunks(*xic, *wic)
    y_ad = _y8_chunks(*xrc, *wic)
    y_bc = _y8_chunks(*xic, *wrc)
    acc_re[...] += jnp.sum(y_ac - y_bd, axis=0) * DCIM_LSB
    acc_im[...] += jnp.sum(y_ad + y_bc, axis=0) * DCIM_LSB

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        or_ref[...] = acc_re[...]
        oi_ref[...] = acc_im[...]


def _y8_chunks_folded(x, x6, x5, w, wp6, wp5):
    """Per-chunk hybrid output with prepacked folded weight planes:
    dcim = x6 . (s*(2*b6+b5)) + x5 . (s*b6) -- integer-identical to the
    3-dot form in ``_y8_chunks``."""
    exact = _chunk_dot(x, w)
    dcim = _chunk_dot(x6, wp6) + _chunk_dot(x5, wp5)
    acim = exact - dcim * DCIM_LSB
    code = jnp.clip(
        jnp.floor_divide(acim + DCIM_LSB // 2, DCIM_LSB), -ADC_HALF, ADC_HALF - 1
    )
    return dcim + code


def _ccim_complex_kernel_prepacked(
    xr_ref, xi_ref, wr_ref, wi_ref, wr6_ref, wr5_ref, wi6_ref, wi5_ref,
    or_ref, oi_ref, acc_re, acc_im, *, bk: int, n_k: int,
):
    """Prepacked-weight fused complex kernel: the co-located (Re, Im)
    weight tiles AND their folded MSB planes stream in as inputs (packed
    once per deployment), so per-step weight decomposition drops to zero;
    only the activations are decomposed in-kernel.  Bit-identical to
    ``_ccim_complex_kernel`` on the same integer operands."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)

    wr = wr_ref[...].astype(jnp.int32)                          # (bk, bn)
    wi = wi_ref[...].astype(jnp.int32)
    wr6, wr5 = wr6_ref[...].astype(jnp.int32), wr5_ref[...].astype(jnp.int32)
    wi6, wi5 = wi6_ref[...].astype(jnp.int32), wi5_ref[...].astype(jnp.int32)
    xr, xr6, xr5 = _msb_planes(xr_ref[...].astype(jnp.int32))   # (bm, bk)
    xi, xi6, xi5 = _msb_planes(xi_ref[...].astype(jnp.int32))

    bm, bn = xr.shape[0], wr.shape[1]
    c = bk // ACC_LEN
    to_xc = lambda v: v.reshape(bm, c, ACC_LEN).swapaxes(0, 1)  # (C, bm, L)
    to_wc = lambda v: v.reshape(c, ACC_LEN, bn)                 # (C, L, bn)
    xrc = tuple(map(to_xc, (xr, xr6, xr5)))
    xic = tuple(map(to_xc, (xi, xi6, xi5)))
    wrc = tuple(map(to_wc, (wr, wr6, wr5)))
    wic = tuple(map(to_wc, (wi, wi6, wi5)))

    y_ac = _y8_chunks_folded(*xrc, *wrc)
    y_bd = _y8_chunks_folded(*xic, *wic)
    y_ad = _y8_chunks_folded(*xrc, *wic)
    y_bc = _y8_chunks_folded(*xic, *wrc)
    acc_re[...] += jnp.sum(y_ac - y_bd, axis=0) * DCIM_LSB
    acc_im[...] += jnp.sum(y_ad + y_bc, axis=0) * DCIM_LSB

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        or_ref[...] = acc_re[...]
        oi_ref[...] = acc_im[...]


# int8 sublane tile the skinny path pads M to: ONE definition shared with
# the real-valued kernels (the padding contract must match dispatch-wide)
from ..ccim_matmul.kernel import SKINNY_SUBLANE  # noqa: E402


def _ccim_complex_kernel_prepacked_skinny(
    xr_ref, xi_ref, wr_ref, wi_ref, planes_ref, or_ref, oi_ref,
    acc_re, acc_im, *, bk: int, n_k: int,
):
    """Decode-shaped fused complex variant: M padded once to the int8
    sublane width (32) instead of the 128-lane MXU block, and the four
    folded weight planes arrive STACKED as one full-K resident block per N
    tile (sliced in-kernel per k step), so only the co-located (Re, Im)
    weight tiles stream with k -- double-buffered by the Pallas pipeline.
    Bit-identical to ``_ccim_complex_kernel_prepacked``."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)

    k_step = pl.program_id(1)
    wr = wr_ref[...].astype(jnp.int32)                          # (bk, bn)
    wi = wi_ref[...].astype(jnp.int32)
    sl = lambda i: planes_ref[i, pl.ds(k_step * bk, bk), :].astype(jnp.int32)
    wr6, wr5, wi6, wi5 = sl(0), sl(1), sl(2), sl(3)
    xr, xr6, xr5 = _msb_planes(xr_ref[...].astype(jnp.int32))   # (Mp, bk)
    xi, xi6, xi5 = _msb_planes(xi_ref[...].astype(jnp.int32))

    bm, bn = xr.shape[0], wr.shape[1]
    c = bk // ACC_LEN
    to_xc = lambda v: v.reshape(bm, c, ACC_LEN).swapaxes(0, 1)  # (C, Mp, L)
    to_wc = lambda v: v.reshape(c, ACC_LEN, bn)                 # (C, L, bn)
    xrc = tuple(map(to_xc, (xr, xr6, xr5)))
    xic = tuple(map(to_xc, (xi, xi6, xi5)))
    wrc = tuple(map(to_wc, (wr, wr6, wr5)))
    wic = tuple(map(to_wc, (wi, wi6, wi5)))

    y_ac = _y8_chunks_folded(*xrc, *wrc)
    y_bd = _y8_chunks_folded(*xic, *wic)
    y_ad = _y8_chunks_folded(*xrc, *wic)
    y_bc = _y8_chunks_folded(*xic, *wrc)
    acc_re[...] += jnp.sum(y_ac - y_bd, axis=0) * DCIM_LSB
    acc_im[...] += jnp.sum(y_ad + y_bc, axis=0) * DCIM_LSB

    @pl.when(k_step == n_k - 1)
    def _done():
        or_ref[...] = acc_re[...]
        oi_ref[...] = acc_im[...]


@functools.partial(
    jax.jit, static_argnames=("bn", "bk", "interpret")
)
def ccim_complex_matmul_prepacked_skinny_pallas(
    x_re: jax.Array, x_im: jax.Array,     # (Mp, K) int8, Mp % 32 == 0
    w_re: jax.Array, w_im: jax.Array,     # (K, N) int8
    planes: jax.Array,                    # (4, K, N) int8: wr6, wr5, wi6, wi5
    *,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Skinny-M prepacked fused complex CIM GEMM -> (y_re, y_im) int32 at
    x2^11; bit-identical to ``ccim_complex_matmul_prepacked_pallas``."""
    Mp, K = x_re.shape
    K2, N = w_re.shape
    assert K == K2 and x_im.shape == (Mp, K) and w_im.shape == (K, N)
    assert planes.shape == (4, K, N), planes.shape
    assert Mp % SKINNY_SUBLANE == 0, Mp
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    assert bk % ACC_LEN == 0 and bk % SKINNY_SUBLANE == 0, bk

    n_k = K // bk
    kernel = functools.partial(_ccim_complex_kernel_prepacked_skinny,
                               bk=bk, n_k=n_k)
    x_spec = pl.BlockSpec((Mp, bk), lambda j, k: (0, k))
    w_spec = pl.BlockSpec((bk, bn), lambda j, k: (k, j))
    p_spec = pl.BlockSpec((4, K, bn), lambda j, k: (0, 0, j))   # resident
    o_spec = pl.BlockSpec((Mp, bn), lambda j, k: (0, j))
    return pl.pallas_call(
        kernel,
        grid=(N // bn, n_k),
        in_specs=[x_spec, x_spec, w_spec, w_spec, p_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, N), jnp.int32),
            jax.ShapeDtypeStruct((Mp, N), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Mp, bn), jnp.int32),
            pltpu.VMEM((Mp, bn), jnp.int32),
        ],
        interpret=interpret,
    )(x_re, x_im, w_re, w_im, planes)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def ccim_complex_matmul_prepacked_pallas(
    x_re: jax.Array, x_im: jax.Array,     # (M, K) int8
    w_re: jax.Array, w_im: jax.Array,     # (K, N) int8 -- one co-located copy
    wr_p6: jax.Array, wr_p5: jax.Array,   # (K, N) int8 folded Re planes
    wi_p6: jax.Array, wi_p5: jax.Array,   # (K, N) int8 folded Im planes
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Prepacked fused complex CIM GEMM -> (y_re, y_im) int32 at x2^11."""
    M, K = x_re.shape
    K2, N = w_re.shape
    assert K == K2
    assert x_im.shape == (M, K)
    for w in (w_im, wr_p6, wr_p5, wi_p6, wi_p5):
        assert w.shape == (K, N)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % ACC_LEN == 0
    n_k = K // bk

    kernel = functools.partial(_ccim_complex_kernel_prepacked, bk=bk, n_k=n_k)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[x_spec, x_spec] + [w_spec] * 6,
        out_specs=[o_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int32),
            jax.ShapeDtypeStruct((M, N), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
        ],
        interpret=interpret,
    )(x_re, x_im, w_re, w_im, wr_p6, wr_p5, wi_p6, wi_p5)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def ccim_complex_matmul_pallas(
    x_re: jax.Array,          # (M, K) int8, values in [-127, 127]
    x_im: jax.Array,          # (M, K) int8
    w_re: jax.Array,          # (K, N) int8 -- ONE co-located copy
    w_im: jax.Array,          # (K, N) int8
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused complex CIM GEMM -> (y_re, y_im), each (M, N) int32 at x2^11."""
    M, K = x_re.shape
    K2, N = w_re.shape
    assert K == K2
    assert x_im.shape == (M, K) and w_im.shape == (K, N)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % ACC_LEN == 0
    n_k = K // bk

    kernel = functools.partial(_ccim_complex_kernel, bk=bk, n_k=n_k)
    x_spec = pl.BlockSpec((bm, bk), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.int32),
            jax.ShapeDtypeStruct((M, N), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.int32),
        ],
        interpret=interpret,
    )(x_re, x_im, w_re, w_im)
