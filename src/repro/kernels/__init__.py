# Pallas TPU kernels for the macro's compute hot-spots, each as
# <name>/{kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle)}; validated in interpret mode on CPU.
from . import ccim_matmul, int8_matmul  # noqa: F401
