# Pallas TPU kernels for the macro's compute hot-spots, each as
# <name>/{kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
# ref.py (pure-jnp oracle)}; validated in interpret mode on CPU.
# ccim_complex is the fused single-pass complex GEMM (one co-located
# weight residency -> both Re and Im output tiles, see DESIGN.md §5).
from . import ccim_complex, ccim_matmul, int8_matmul, paged_attn  # noqa: F401
