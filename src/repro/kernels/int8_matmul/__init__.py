from .kernel import int8_matmul_pallas  # noqa: F401
from .ops import int8_matmul  # noqa: F401
from .ref import int8_matmul_ref  # noqa: F401
