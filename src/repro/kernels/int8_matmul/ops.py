"""Jitted wrapper: dynamic quantization + the W8A8 Pallas GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import int8_matmul_pallas
from .ref import int8_matmul_ref


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(dim: int, preferred: int) -> int:
    b = min(preferred, dim)
    while dim % b:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def int8_matmul(
    x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """float (M,K)@(K,N) with dynamic per-row/per-col int8 quantization."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    sx = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12) / 127.0
    sw = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12) / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int8)
    if not use_pallas:
        return int8_matmul_ref(xq, wq, sx, sw)
    M, K = xq.shape
    _, N = wq.shape
    bm, bn = _pick_block(M, 128), _pick_block(N, 128)
    bk = _pick_block(K, 512)
    return int8_matmul_pallas(
        xq, wq, sx.astype(jnp.float32), sw.astype(jnp.float32),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
