"""Jitted wrapper: dynamic quantization + the W8A8 Pallas GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import int8_matmul_pallas
from .ref import int8_matmul_ref


# block policy shared with the CIM GEMM: pad up to MXU-preferred blocks
# instead of shrinking to non-lane-aligned divisors (see DESIGN.md §2)
from ..ccim_matmul.ops import _pad_to, _pick_block, _pick_k_block


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def int8_matmul(
    x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """float (M,K)@(K,N) with dynamic per-row/per-col int8 quantization."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    sx = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12) / 127.0
    sw = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12) / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int8)
    if not use_pallas:
        return int8_matmul_ref(xq, wq, sx, sw)
    M, K = xq.shape
    _, N = wq.shape
    bm, bn = _pick_block(M, 128), _pick_block(N, 128)
    bk = _pick_k_block(K, 512)
    Mp, Np, Kp = _pad_to(M, bm), _pad_to(N, bn), _pad_to(K, bk)
    if (Mp, Np, Kp) != (M, N, K):
        # zero products contribute nothing to the int32 accumulator; the
        # padded rows/cols are sliced away before dequant scales matter
        xq = jnp.pad(xq, ((0, Mp - M), (0, Kp - K)))
        wq = jnp.pad(wq, ((0, Kp - K), (0, Np - N)))
        sx = jnp.pad(sx, ((0, Mp - M), (0, 0)), constant_values=1.0)
        sw = jnp.pad(sw, ((0, 0), (0, Np - N)), constant_values=1.0)
    y = int8_matmul_pallas(
        xq, wq, sx.astype(jnp.float32), sw.astype(jnp.float32),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y[:M, :N]
