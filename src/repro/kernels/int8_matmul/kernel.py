"""Pallas TPU kernel: W8A8 integer GEMM with fused per-channel dequant.

The all-digital CIM baseline [11] (and the framework's generic quantized
linear): y = (x_q @ w_q) * sx[m] * sw[n], int8 x int8 -> int32 on the MXU,
dequant fused into the epilogue so the int32 accumulator never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _int8_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        sx = sx_ref[...]                     # (bm, 1) float32
        sw = sw_ref[...]                     # (1, bn) float32
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx * sw


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul_pallas(
    x_q: jax.Array,    # (M, K) int8
    w_q: jax.Array,    # (K, N) int8
    sx: jax.Array,     # (M, 1) float32 per-row scale
    sw: jax.Array,     # (1, N) float32 per-col scale
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_q.shape
    _, N = w_q.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    kernel = functools.partial(_int8_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, sx, sw)
