"""Pure-jnp oracle for the W8A8 GEMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def int8_matmul_ref(x_q, w_q, sx, sw):
    acc = jnp.einsum(
        "mk,kn->mn", x_q.astype(jnp.int32), w_q.astype(jnp.int32)
    )
    return acc.astype(jnp.float32) * sx * sw
