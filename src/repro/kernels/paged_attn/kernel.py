"""Pallas TPU kernel: paged-attention decode read (block-table gather
fused into the attention dot).

One grid step per (batch row, table entry): the scalar-prefetched block
table drives the k/v BlockSpec index maps, so each step DMAs exactly the
(bs, Hkv, Dh) pool block the row's table points at -- the gather never
materializes a dense (B, L, Hkv, Dh) view in HBM, which is the entire
point of the kernel (the XLA fallback in ref.py pays that gather).  The
inner loop is a standard online-softmax accumulation over the row's
blocks (grid axis 1 is innermost, so VMEM scratch carries m/l/acc across
a row's blocks exactly like the flash scan in models.layers).

Skinny-M by construction: decode is M=1 per row, so the query block is a
single (Hq, Dh) tile resident in VMEM for the row's whole block walk.
VMEM working set per step = bs*Hkv*Dh*2 (k+v) + Hq*Dh bytes -- a few KiB
at serving shapes, far under budget; block_size and Dh should be lane
(128) / sublane multiples on real hardware (interpret mode, which CI
exercises, does not care).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(table_ref, len_ref, loc_ref,      # scalar prefetch
                       q_ref, k_ref, v_ref, o_ref,       # blocks
                       m_ref, l_ref, acc_ref,            # VMEM scratch
                       *, bs: int, n_tbl: int, hkv: int, g: int,
                       softcap: Optional[float], window: Optional[int]):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dh = q_ref.shape[-1]
    qg = (q_ref[0].astype(jnp.float32).reshape(hkv, g, dh)) * (dh ** -0.5)
    k = k_ref[0].astype(jnp.float32)                     # (bs, Hkv, Dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("hgd,khd->hgk", qg, k,
                   preferred_element_type=jnp.float32)   # (Hkv, G, bs)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    k_pos = j * bs + jax.lax.iota(jnp.int32, bs)
    q_pos = len_ref[b] - 1
    msk = k_pos <= q_pos
    if window is not None:
        msk_local = msk & (q_pos - k_pos < window)
        msk = jnp.where(loc_ref[0] != 0, msk_local, msk)
    s = jnp.where(msk[None, None, :], s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_prev * corr[..., None] + jnp.einsum(
        "hgk,khd->hgd", p, v, preferred_element_type=jnp.float32)

    @pl.when(j == n_tbl - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(hkv * g, dh).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "window", "interpret"))
def paged_attention_pallas(
    q: jax.Array,                 # (B, Hq, Dh)
    k_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    v_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    table: jax.Array,             # (B, n_tbl) int32
    lengths: jax.Array,           # (B,) int32
    is_local: jax.Array,          # () bool/int (traced ok)
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused block-table-gather decode attention -> (B, Hq, Dh)."""
    B, Hq, Dh = q.shape
    _, bs, Hkv, Dh2 = k_pool.shape
    assert Dh == Dh2 and Hq % Hkv == 0, (q.shape, k_pool.shape)
    n_tbl = table.shape[1]
    G = Hq // Hkv

    kernel = functools.partial(
        _paged_attn_kernel, bs=bs, n_tbl=n_tbl, hkv=Hkv, g=G,
        softcap=softcap, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, n_tbl),
        in_specs=[
            pl.BlockSpec((1, Hq, Dh), lambda b, j, tbl, lens, loc: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Dh),
                         lambda b, j, tbl, lens, loc: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, Dh),
                         lambda b, j, tbl, lens, loc: (tbl[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, Dh),
                               lambda b, j, tbl, lens, loc: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G), jnp.float32),
            pltpu.VMEM((Hkv, G), jnp.float32),
            pltpu.VMEM((Hkv, G, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Dh), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32),
      jnp.asarray(is_local, jnp.int32).reshape(1), q, k_pool, v_pool)
