"""Pure-jnp oracle for the paged-attention decode read path.

Mirrors ``models.layers.plain_attention`` for an S==1 query batch, with
the contiguous KV tensor replaced by (block pool, block table) -- gather
the table into a dense per-row view, then do exactly the plain decode
attention math (f32 scores, optional tanh softcap, -1e30 masking,
softmax, bf16 PV).  The Pallas kernel (kernel.py) must match this oracle;
the XLA fallback IS this oracle.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def paged_gather_kv(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Gather (n_blocks, bs, H, D) pool rows -> (B, n_tbl*bs, H, D).

    ``table`` is (B, n_tbl) int32 block ids; logical position p of row b
    lives at pool[table[b, p // bs], p % bs].
    """
    nb, bs = pool.shape[0], pool.shape[1]
    flat = pool.reshape(nb * bs, *pool.shape[2:])
    B, n_tbl = table.shape
    idx = (table[:, :, None] * bs
           + jnp.arange(bs, dtype=table.dtype)[None, None, :])
    return jnp.take(flat, idx.reshape(B, n_tbl * bs), axis=0)


def paged_attention_ref(
    q: jax.Array,                 # (B, Hq, Dh)
    k_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    v_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    table: jax.Array,             # (B, n_tbl) int32
    lengths: jax.Array,           # (B,) int32 valid kv rows (incl. current)
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    is_local=False,               # scalar bool (traced ok)
) -> jax.Array:
    """Decode attention over a paged KV cache -> (B, Hq, Dh) f32-accurate
    output in q's dtype.  Row b's query sits at position lengths[b]-1 and
    attends k_pos < lengths[b] (ANDed with the sliding window when
    ``is_local``)."""
    B, Hq, Dh = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = Hq // Hkv
    L = table.shape[1] * bs

    k = paged_gather_kv(k_pool, table)          # (B, L, Hkv, Dh)
    v = paged_gather_kv(v_pool, table)
    qg = q.reshape(B, Hkv, G, Dh) * (Dh ** -0.5)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    q_pos = (lengths - 1)[:, None]
    msk = k_pos <= q_pos
    if window is not None:
        msk_local = msk & (q_pos - k_pos < window)
        msk = jnp.where(jnp.asarray(is_local), msk_local, msk)
    s = jnp.where(msk[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v)
    return out.reshape(B, Hq, Dh)
