"""Dispatcher for the paged-attention decode read path.

Same conventions as ccim_matmul.ops: ``use_pallas`` defaults to "am I on
a TPU backend", the Pallas kernel runs in interpret mode off-TPU (CI
covers it that way), and the XLA fallback is the pure-jnp gather oracle
in ref.py.  models.layers routes S==1 paged reads here only when the
kernel path is enabled (TPU, or REPRO_PAGED_ATTN=1 to force interpret
mode) -- on CPU the scheduler's bit-identity contract rides the fallback,
which is exactly plain decode attention over the gathered view.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from .kernel import paged_attention_pallas
from .ref import paged_attention_ref, paged_gather_kv  # noqa: F401


def kernel_enabled() -> bool:
    """Should models.layers route paged decode reads through the Pallas
    kernel?  Default: only on a real TPU backend.  REPRO_PAGED_ATTN=1
    forces it (interpret mode off-TPU, for end-to-end kernel testing);
    REPRO_PAGED_ATTN=0 disables it everywhere."""
    env = os.environ.get("REPRO_PAGED_ATTN", "auto")
    if env == "1":
        return True
    if env == "0":
        return False
    return jax.default_backend() == "tpu"


def paged_attention_decode(
    q: jax.Array,                 # (B, Hq, Dh)
    k_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    v_pool: jax.Array,            # (n_blocks, bs, Hkv, Dh)
    table: jax.Array,             # (B, n_tbl) int32
    lengths: jax.Array,           # (B,) int32 valid kv rows (incl. current)
    is_local=False,               # scalar bool (traced ok)
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = kernel_enabled()
    if not use_pallas:
        return paged_attention_ref(q, k_pool, v_pool, table, lengths,
                                   softcap=softcap, window=window,
                                   is_local=is_local)
    return paged_attention_pallas(
        q, k_pool, v_pool, table, lengths, is_local,
        softcap=softcap, window=window,
        interpret=(not on_tpu) if interpret is None else interpret)
