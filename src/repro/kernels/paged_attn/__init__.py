# Paged-attention decode read path: skinny-M (decode) attention over a
# block-pooled KV cache, the per-slot block-table gather fused into the
# attention dot (kernel.py, PrefetchScalarGridSpec) with a pure-jnp
# gather oracle (ref.py) and a backend-aware dispatcher (ops.py).
from .ops import paged_attention_decode, paged_gather_kv  # noqa: F401
from .ref import paged_attention_ref  # noqa: F401
