"""Persisted block-size autotuning for the CIM GEMM hot paths.

Two tunable schedules feed from one JSON cache:

  fast_gemm     the XLA fast-path scan's chunk block (``chunk_block`` in
                core.ccim.hybrid_mac_fast_gemm_prepacked) -- how many ADC
                conversions each scan step processes.  Pure scheduling:
                int32 partial sums make every block size bit-identical.
  skinny_pallas (bn, bk) for the skinny-M prepacked Pallas kernel
                (kernel.ccim_matmul_prepacked_skinny_pallas) -- only
                meaningful on a TPU backend.

The cache lives at ``benchmarks/TUNING_CACHE.json`` (override with
$REPRO_TUNING_CACHE) and is consulted AT TRACE TIME: lookups are pure
python keyed on static shapes, so serve/scheduler executables bake the
tuned blocks in and decode steps never recompile.  Keys carry the backend,
the op, an M shape-class (gemv <= 8 rows, skinny <= 64, wide above -- decode
batches land in gemv/skinny, prefill/train in wide) and the exact reduction
geometry; anything not in the cache falls back to the built-in heuristics,
so a missing or stale cache only costs performance, never correctness.
Invalidation is by construction: keys are (backend, op, shape, config) and
the file carries a ``version`` -- bump ``_CACHE_VERSION`` when a schedule's
meaning changes and old entries are ignored wholesale.
"""
from __future__ import annotations

import functools
import json
import os
import time
import warnings
from typing import Dict, Iterable, Optional, Tuple

_CACHE_VERSION = 1
_ENV_VAR = "REPRO_TUNING_CACHE"
_DEFAULT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "..", "benchmarks",
    "TUNING_CACHE.json")

# in-memory cache state: loaded once per path, updated by the tuner
_state: Dict[str, object] = {"path": None, "entries": None}


def cache_path() -> str:
    return os.path.abspath(os.environ.get(_ENV_VAR, _DEFAULT_PATH))


def _count(event: str, amount: int = 1) -> None:
    """Tuning-cache outcome counters in the process metrics registry.

    hit / miss label raw key lookups; fallback counts a consumer actually
    settling for the built-in heuristic schedule (the formerly silent
    path); dropped counts illegal entries discarded at load.  Lookups run
    at trace time behind an lru_cache, so counts reflect distinct shapes
    lowered, not decode steps.
    """
    from ...obs.metrics import REGISTRY
    REGISTRY.counter("autotune_cache_events_total",
                     "tuning-cache lookups by outcome",
                     labels={"event": event}).inc(amount)


def cache_summary() -> str:
    """One-line cache-effectiveness report for benchmark logs."""
    from ...obs.metrics import REGISTRY

    def v(ev):
        return int(REGISTRY.counter("autotune_cache_events_total",
                                    labels={"event": ev}).value)
    return (f"tuning cache {cache_path()}: {len(_entries())} entries | "
            f"{v('hit')} hits, {v('miss')} misses, {v('fallback')} "
            f"heuristic fallbacks, {v('dropped')} dropped illegal entries")


def _key_dims(key: str) -> Dict[str, int]:
    """Shape fields encoded in a cache key: K512 -> {'K': 512} etc."""
    dims: Dict[str, int] = {}
    for part in key.split("|"):
        if len(part) > 1 and part[0].isalpha() and part[1:].isdigit():
            dims[part[0]] = int(part[1:])
    return dims


def entry_violation(key: str, entry: dict) -> Optional[str]:
    """Why a cached entry would select an illegal schedule, or None.

    The same legality screen the kernel dispatchers apply (block
    divisibility, sublane/accumulate alignment, the skinny VMEM
    residency budget), run at LOAD time -- a stale or hand-edited
    TUNING_CACHE entry is dropped here instead of steering a dispatch
    into a block shape the kernel would reject (or worse, pad wrong).
    Unknown ops pass: new tunables must not be invalidated by an old
    loader.
    """
    if not isinstance(entry, dict):
        return "entry is not an object"
    parts = key.split("|")
    op = parts[1] if len(parts) > 1 else ""
    dims = _key_dims(key)
    if op == "skinny_pallas":
        from .ops import SKINNY_VMEM_BUDGET
        try:
            bn, bk = int(entry["bn"]), int(entry["bk"])
        except (KeyError, TypeError, ValueError):
            return "missing/non-integer (bn, bk)"
        Kp, Np, L, P = (dims.get(d, 0) for d in "KNLP")
        if bn <= 0 or bk <= 0:
            return f"non-positive blocks ({bn}, {bk})"
        if Np % bn:
            return f"bn {bn} does not divide N {Np}"
        if Kp % bk:
            return f"bk {bk} does not divide K {Kp}"
        if L and bk % L:
            return f"bk {bk} not a multiple of acc_len {L}"
        if bk % 32:
            return f"bk {bk} not a multiple of the int8 sublane (32)"
        if bn % 128:
            return f"bn {bn} not lane-aligned (128)"
        if max(P, 1) * Kp * bn > SKINNY_VMEM_BUDGET:
            return (f"resident planes {max(P, 1)}x{Kp}x{bn} exceed the "
                    f"{SKINNY_VMEM_BUDGET} B skinny VMEM budget")
    elif op == "fast_gemm":
        C = dims.get("C", 0)
        try:
            cb = int(entry["chunk_block"])
        except (KeyError, TypeError, ValueError):
            return "missing/non-integer chunk_block"
        if cb < 1 or (C and cb > C):
            return f"chunk_block {cb} outside [1, {C}]"
    return None


def _entries() -> Dict[str, dict]:
    path = cache_path()
    if _state["entries"] is None or _state["path"] != path:
        entries: Dict[str, dict] = {}
        try:
            with open(path) as f:
                data = json.load(f)
        except FileNotFoundError:
            data = None
        except (OSError, ValueError) as e:
            # Corrupt / partially-written cache: blocks are a perf knob,
            # never a correctness one, so warn once and run on heuristics.
            warnings.warn(f"ignoring unreadable tuning cache {path}: {e}")
            data = None
        if data is not None:
            if (isinstance(data, dict)
                    and isinstance(data.get("entries"), dict)):
                if data.get("version") == _CACHE_VERSION:
                    entries = {k: v for k, v in data["entries"].items()
                               if isinstance(v, dict)}
            else:
                warnings.warn(
                    f"ignoring malformed tuning cache {path}: expected "
                    "{'version': ..., 'entries': {...}}")
        bad = {k: entry_violation(k, v) for k, v in entries.items()}
        bad = {k: why for k, why in bad.items() if why}
        if bad:
            # same rationale as the corrupt-file path: an illegal block
            # is a perf knob gone stale, never worth a wrong dispatch
            warnings.warn(
                f"dropping {len(bad)} illegal tuning cache entr"
                f"{'y' if len(bad) == 1 else 'ies'}: "
                + "; ".join(f"{k} ({why})" for k, why in sorted(bad.items())))
            entries = {k: v for k, v in entries.items() if k not in bad}
            _count("dropped", len(bad))
        _state["path"], _state["entries"] = path, entries
    return _state["entries"]  # type: ignore[return-value]


def lookup(key: str) -> Optional[dict]:
    e = _entries().get(key)
    _count("hit" if e is not None else "miss")
    return e


def update(key: str, entry: dict) -> None:
    _entries()[key] = entry
    tuned_chunk_block.cache_clear()   # fresh entries take effect in-process


def save(path: Optional[str] = None) -> str:
    path = os.path.abspath(path or cache_path())
    with open(path, "w") as f:
        json.dump(dict(version=_CACHE_VERSION, entries=_entries()), f,
                  indent=2, sort_keys=True)
        f.write("\n")
    return path


def shape_class(m: int) -> str:
    """M bucketing: decode steps are gemv/skinny, prefill/train are wide."""
    if m <= 8:
        return "gemv"
    if m <= 64:
        return "skinny"
    return "wide"


def _backend() -> str:
    import jax
    return jax.default_backend()


# ---------------------------------------------------------------------------
# fast-GEMM chunk block (any backend; the XLA serving hot path)
# ---------------------------------------------------------------------------


def chunk_key(M: int, C: int, N: int, acc_len: int) -> str:
    return f"{_backend()}|fast_gemm|{shape_class(M)}|C{C}|N{N}|L{acc_len}"


@functools.lru_cache(maxsize=None)
def tuned_chunk_block(M: int, C: int, N: int, acc_len: int) -> int:
    """Chunk block for an (M, C*acc_len) x (C*acc_len, N) fast GEMM.

    Cache hit -> the tuned block.  Miss -> heuristic: skinny M collapses
    the scan to ONE step (the (C, M, N) partials already fit in cache and
    per-step dispatch dominates), wide M keeps the cache-sized default.
    """
    e = lookup(chunk_key(M, C, N, acc_len))
    if e is not None and "chunk_block" in e:
        return max(1, int(e["chunk_block"]))
    _count("fallback")
    from ...core.ccim import _CHUNK_BLOCK, _SKINNY_M
    return C if M <= _SKINNY_M else _CHUNK_BLOCK


# ---------------------------------------------------------------------------
# skinny-M Pallas kernel blocks (TPU)
# ---------------------------------------------------------------------------


def skinny_key(K: int, N: int, acc_len: int, n_planes: int) -> str:
    return f"{_backend()}|skinny_pallas|K{K}|N{N}|L{acc_len}|P{n_planes}"


def tuned_skinny_blocks(K: int, N: int, acc_len: int,
                        n_planes: int) -> Optional[Tuple[int, int]]:
    """(bn, bk) override for the skinny kernel, or None for the pack-time
    defaults (ops.pick_weight_blocks geometry)."""
    e = lookup(skinny_key(K, N, acc_len, n_planes))
    if e is not None and "bn" in e and "bk" in e:
        return int(e["bn"]), int(e["bk"])
    _count("fallback")
    return None


# ---------------------------------------------------------------------------
# the search (off the serving path; benchmarks/autotune.py drives it)
# ---------------------------------------------------------------------------


def _time_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _chunk_candidates(C: int) -> Tuple[int, ...]:
    cands = {c for c in (2, 4, 8, 16, 32, 64) if c <= C}
    cands.add(C)
    return tuple(sorted(cands))


_CHAIN = 16   # calls per timed executable: amortizes per-dispatch overhead


def autotune_chunk_block(M: int, K: int, N: int, cfg=None, seed: int = 0,
                         iters: int = 5) -> dict:
    """Search the fast-GEMM chunk block for one (M, K, N) shape and record
    the winner in the in-memory cache (call ``save`` to persist).

    Times a CHAIN of data-dependent prepacked serving ops (activation
    quantization included) inside one executable: a single-call timing is
    dominated by per-dispatch overhead that vanishes inside the compiled
    decode loop, which used to crown noise as the winner.  The chain uses
    a float dependency (0.0 * y) on purpose -- an integer one would be
    constant-folded and the whole chain CSE'd into one call.
    """
    import jax
    from ...core.ccim import DEFAULT_CONFIG, _pad_to_chunks
    from ...core.engine import pack_cim_weights, packed_cim_matmul

    cfg = cfg or DEFAULT_CONFIG
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (M, K))
    packed = pack_cim_weights(jax.random.normal(k2, (K, N)), cfg)
    C = _pad_to_chunks(K, cfg.acc_len)

    results = {}
    for cb in _chunk_candidates(C):
        def chain(v, p, cb=cb):
            o = None
            y = v
            for _ in range(_CHAIN):
                o = packed_cim_matmul(y, p, cfg, use_pallas=False,
                                      chunk_block=cb)
                y = v + 0.0 * o[:1, :1]
            return o
        fn = jax.jit(chain)
        results[cb] = round(_time_us(fn, x, packed, iters=iters) / _CHAIN, 1)
    best = min(results, key=results.get)
    entry = dict(chunk_block=int(best), us=results[best],
                 candidates_us={str(c): u for c, u in results.items()},
                 M=M, K=K, N=N)
    update(chunk_key(M, C, N, cfg.acc_len), entry)
    return entry


def autotune_skinny_pallas(M: int, K: int, N: int, cfg=None, seed: int = 0,
                           iters: int = 5) -> Optional[dict]:
    """Search (bn, bk) for the skinny-M prepacked Pallas kernel (TPU only:
    interpret-mode timings would tune the emulator, not the hardware)."""
    if _backend() != "tpu":
        return None
    import jax
    from ...core.ccim import DEFAULT_CONFIG
    from ...core.engine import pack_cim_weights
    from . import ops

    cfg = cfg or DEFAULT_CONFIG
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x_q = jax.random.randint(k1, (M, K), -127, 128).clip(-127, 127)
    packed = pack_cim_weights(jax.random.normal(k2, (K, N)), cfg)
    _, _, Np, Kp = ops.pick_weight_blocks(K, N, cfg.acc_len)
    n_planes = packed.pallas_planes.shape[0]

    results = {}
    for bn in (128, 256, 512):
        for bk in (128, 256, 512, 1024):
            if Np % bn or Kp % bk or bk % cfg.acc_len or bk % 32:
                continue
            import functools as ft
            import jax as _jax
            fn = _jax.jit(ft.partial(
                ops.ccim_matmul_int_prepacked, k_dim=K, n_dim=N,
                acc_len=cfg.acc_len, use_pallas=True, interpret=False,
                skinny_blocks=(bn, bk)))
            results[(bn, bk)] = round(
                _time_us(fn, x_q, packed.pallas_w, packed.pallas_planes,
                         iters=iters), 1)
    if not results:
        return None
    best = min(results, key=results.get)
    entry = dict(bn=best[0], bk=best[1], us=results[best],
                 candidates_us={f"{b[0]}x{b[1]}": u
                                for b, u in results.items()}, M=M)
    # keyed on the PADDED dims: that is what the dispatcher looks up
    # (ops.ccim_matmul_int_prepacked consults tuned_skinny_blocks(Kp, Np))
    update(skinny_key(Kp, Np, cfg.acc_len, n_planes), entry)
    return entry


def autotune_shapes(shapes: Iterable[Tuple[int, int, int]], cfg=None,
                    iters: int = 5) -> Dict[str, dict]:
    """Tune every (M, K, N) in ``shapes`` on the current backend; clears
    the lookup memo so freshly tuned blocks take effect in-process."""
    out = {}
    for (M, K, N) in shapes:
        out[f"fast_gemm {M}x{K}x{N}"] = autotune_chunk_block(
            M, K, N, cfg, iters=iters)
        sk = autotune_skinny_pallas(M, K, N, cfg, iters=iters)
        if sk is not None:
            out[f"skinny_pallas {M}x{K}x{N}"] = sk
    tuned_chunk_block.cache_clear()
    return out
