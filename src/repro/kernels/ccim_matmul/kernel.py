"""Pallas TPU kernel: hybrid DCIM/ACIM quantized GEMM (the macro's numerics).

TPU adaptation of the paper's dataflow (see DESIGN.md §2): the MXU plays the
role of the bit-parallel array.  Per 16-element K-chunk ("one ADC
conversion") we compute

    exact_c = x_c . w_c                       (int8 x int8 -> int32, MXU)
    dcim_c  = 2*x6.w6 + x6.w5 + x5.w6         (3 signed MSB bit-plane dots)
    acim_c  = exact_c - 2^11 * dcim_c         (the analog group's ideal sum)
    code_c  = clip(floor(acim_c/2^11 + 1/2), -64, 63)     (7b SAR ADC)
    y8_c    = dcim_c + code_c                 (post-digital adder)
    out    += 2^11 * sum_c y8_c               (digital partial accumulation)

i.e. the *ideal-analog* bit-true macro arithmetic (mismatch noise is a
training-time emulation feature injected at the jnp level, see core.qat;
the silicon itself has frozen mismatch -- the kernel models the design
arithmetic).  All chunk dots are expressed as one batched dot_general so
the MXU sees (C, bm, 16) x (C, 16, bn).

Block shapes are MXU/VMEM aligned: bm, bn multiples of 128 (lane dim), bk a
multiple of acc_len; VMEM working set = bm*bk + bk*bn (int8) + bm*bn
(int32 scratch) -- 128x512x128 => 128 KiB + 64 KiB well under 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACC_LEN = 16
DCIM_LSB = 2048  # 2^11
ADC_HALF = 64    # 7-bit bipolar


def _chunk_dot(x, w):
    """(C, bm, L) x (C, L, bn) -> (C, bm, bn) int32 batched MXU dot."""
    return jax.lax.dot_general(
        x, w,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )


def _ccim_kernel(x_ref, w_ref, o_ref, acc_ref, *, bk: int, n_k: int):
    """One (bm, bn) output tile; grid axis 2 walks K in bk steps."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)            # (bm, bk)
    w = w_ref[...].astype(jnp.int32)            # (bk, bn)
    bm, bn = x.shape[0], w.shape[1]
    c = bk // ACC_LEN

    # sign / magnitude decomposition (SMF)
    sx = jnp.where(x < 0, -1, 1)
    mx = jnp.abs(x)
    sw = jnp.where(w < 0, -1, 1)
    mw = jnp.abs(w)

    # signed MSB bit-planes (values in {-1, 0, +1})
    x6 = sx * ((mx >> 6) & 1)
    x5 = sx * ((mx >> 5) & 1)
    w6 = sw * ((mw >> 6) & 1)
    w5 = sw * ((mw >> 5) & 1)

    xc = x.reshape(bm, c, ACC_LEN).swapaxes(0, 1)       # (C, bm, L)
    wc = w.reshape(c, ACC_LEN, bn)                      # (C, L, bn)
    exact = _chunk_dot(xc, wc)

    x6c = x6.reshape(bm, c, ACC_LEN).swapaxes(0, 1)
    x5c = x5.reshape(bm, c, ACC_LEN).swapaxes(0, 1)
    w6c = w6.reshape(c, ACC_LEN, bn)
    w5c = w5.reshape(c, ACC_LEN, bn)
    dcim = 2 * _chunk_dot(x6c, w6c) + _chunk_dot(x6c, w5c) + _chunk_dot(x5c, w6c)

    acim = exact - dcim * DCIM_LSB
    code = jnp.clip(
        jnp.floor_divide(acim + DCIM_LSB // 2, DCIM_LSB), -ADC_HALF, ADC_HALF - 1
    )
    y8 = dcim + code
    acc_ref[...] += jnp.sum(y8, axis=0) * DCIM_LSB

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _ccim_kernel_prepacked(*refs, bk: int, n_k: int, acc_len: int,
                           x_bits: tuple, dcim_lsb: int, adc_half: int):
    """Prepacked-weight variant, generalized over the macro's D/A split.

    The folded signed DCIM planes of w arrive as ONE stacked kernel input
    (packed once, off the hot path -- weight-stationary, as bit-cells in
    the silicon array), so the per-step weight work drops to zero.  The
    split itself is STATIC META: ``x_bits`` lists the activation bit-plane
    index each folded weight plane pairs with (the deployment planner's
    per-projection ``n_dcim_products`` choice determines the plane count),
    and ``dcim_lsb``/``adc_half``/``acc_len`` carry the matching ADC
    geometry.  For the 28nm prototype (top-3 split) this is x_bits=(6, 5):

        plane 0 holds s_w * (2*b6(|w|) + b5(|w|))   (pairs with x bit 6)
        plane 1 holds s_w * b6(|w|)                 (pairs with x bit 5)

    and the arithmetic is bit-identical to ``_ccim_kernel``.  With
    x_bits=() (all-analog split) there is NO planes input and every
    bit-product goes through the ADC path.
    """
    if x_bits:
        x_ref, w_ref, planes_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)            # (bm, bk)
    w = w_ref[...].astype(jnp.int32)            # (bk, bn)
    bm, bn = x.shape[0], w.shape[1]
    c = bk // acc_len

    to_xc = lambda v: v.reshape(bm, c, acc_len).swapaxes(0, 1)  # (C, bm, L)
    to_wc = lambda v: v.reshape(c, acc_len, bn)                 # (C, L, bn)
    exact = _chunk_dot(to_xc(x), to_wc(w))

    # activation-side decomposition only (activations stream, as in silicon)
    dcim = jnp.zeros_like(exact)
    if x_bits:
        sx = jnp.where(x < 0, -1, 1)
        mx = jnp.abs(x)
        planes = planes_ref[...].astype(jnp.int32)  # (n_planes, bk, bn)
        for i, j in enumerate(x_bits):
            xj = sx * ((mx >> j) & 1)
            dcim = dcim + _chunk_dot(to_xc(xj), to_wc(planes[i]))

    acim = exact - dcim * dcim_lsb
    code = jnp.clip(
        jnp.floor_divide(acim + dcim_lsb // 2, dcim_lsb),
        -adc_half, adc_half - 1,
    )
    y8 = dcim + code
    acc_ref[...] += jnp.sum(y8, axis=0) * dcim_lsb

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "acc_len", "x_bits",
                              "dcim_lsb", "adc_half", "interpret")
)
def ccim_matmul_prepacked_pallas(
    x_q: jax.Array,           # (M, K) int8, values in [-127, 127]
    w_q: jax.Array,           # (K, N) int8
    planes: jax.Array,        # (n_planes, K, N) int8 folded DCIM planes
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    acc_len: int = ACC_LEN,
    x_bits: tuple = (6, 5),
    dcim_lsb: int = DCIM_LSB,
    adc_half: int = ADC_HALF,
    interpret: bool = False,
) -> jax.Array:
    """Prepacked-weight hybrid-CIM GEMM -> (M, N) int32 at scale dcim_lsb.

    ``x_bits``/``dcim_lsb``/``adc_half``/``acc_len`` are static meta
    describing the packed D/A split (see ``_ccim_kernel_prepacked``); the
    defaults are the 28nm prototype's top-3 split.
    """
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    assert planes.shape == (len(x_bits), K, N), (planes.shape, x_bits)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % acc_len == 0
    n_k = K // bk

    kernel = functools.partial(
        _ccim_kernel_prepacked, bk=bk, n_k=n_k, acc_len=acc_len,
        x_bits=tuple(x_bits), dcim_lsb=dcim_lsb, adc_half=adc_half)
    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))]
    operands = [x_q, w_q]
    if x_bits:
        in_specs.append(pl.BlockSpec((len(x_bits), bk, bn),
                                     lambda i, j, k: (0, k, j)))
        operands.append(planes)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)


SKINNY_SUBLANE = 32   # int8 sublane tile: the skinny path pads M to this


def _ccim_kernel_prepacked_skinny(*refs, bk: int, n_k: int, acc_len: int,
                                  x_bits: tuple, dcim_lsb: int,
                                  adc_half: int):
    """Decode-shaped (skinny-M) prepacked variant.

    Same macro arithmetic as ``_ccim_kernel_prepacked``, different
    schedule, built for M of a decode batch (<= 32 rows):

      * M is padded ONCE to the int8 sublane width (32) instead of the
        128-lane MXU block -- a 4x cut in wasted rows at M=4;
      * the folded DCIM planes for the current N tile arrive as ONE
        full-K resident block (index map ignores the k grid axis), so
        they stay in VMEM across the whole K-loop and are sliced
        in-kernel per k step;
      * only the weight tile streams with k -- the grid's innermost axis
        -- which the Pallas pipeline double-buffers automatically.

    VMEM cost of the residency is n_planes * K * bn int8 bytes; the
    dispatcher (ops.ccim_matmul_int_prepacked) checks the budget and
    falls back to the general kernel when it does not fit.
    """
    if x_bits:
        x_ref, w_ref, planes_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
    k_step = pl.program_id(1)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)            # (Mp, bk)
    w = w_ref[...].astype(jnp.int32)            # (bk, bn)
    bm, bn = x.shape[0], w.shape[1]
    c = bk // acc_len

    to_xc = lambda v: v.reshape(bm, c, acc_len).swapaxes(0, 1)  # (C, Mp, L)
    to_wc = lambda v: v.reshape(c, acc_len, bn)                 # (C, L, bn)
    exact = _chunk_dot(to_xc(x), to_wc(w))

    dcim = jnp.zeros_like(exact)
    if x_bits:
        sx = jnp.where(x < 0, -1, 1)
        mx = jnp.abs(x)
        for i, j in enumerate(x_bits):
            xj = sx * ((mx >> j) & 1)
            # K-resident planes: slice this k step's rows in-register
            pj = planes_ref[i, pl.ds(k_step * bk, bk), :].astype(jnp.int32)
            dcim = dcim + _chunk_dot(to_xc(xj), to_wc(pj))

    acim = exact - dcim * dcim_lsb
    code = jnp.clip(
        jnp.floor_divide(acim + dcim_lsb // 2, dcim_lsb),
        -adc_half, adc_half - 1,
    )
    acc_ref[...] += jnp.sum(dcim + code, axis=0) * dcim_lsb

    @pl.when(k_step == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bn", "bk", "acc_len", "x_bits", "dcim_lsb",
                              "adc_half", "interpret")
)
def ccim_matmul_prepacked_skinny_pallas(
    x_q: jax.Array,           # (Mp, K) int8, Mp a SKINNY_SUBLANE multiple
    w_q: jax.Array,           # (K, N) int8
    planes: jax.Array,        # (n_planes, K, N) int8 folded DCIM planes
    *,
    bn: int = 128,
    bk: int = 512,
    acc_len: int = ACC_LEN,
    x_bits: tuple = (6, 5),
    dcim_lsb: int = DCIM_LSB,
    adc_half: int = ADC_HALF,
    interpret: bool = False,
) -> jax.Array:
    """Skinny-M prepacked hybrid-CIM GEMM -> (Mp, N) int32 at scale
    dcim_lsb; bit-identical to ``ccim_matmul_prepacked_pallas`` (see
    ``_ccim_kernel_prepacked_skinny`` for the schedule)."""
    Mp, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    assert planes.shape == (len(x_bits), K, N), (planes.shape, x_bits)
    assert Mp % SKINNY_SUBLANE == 0, Mp
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    assert bk % acc_len == 0 and bk % SKINNY_SUBLANE == 0, (bk, acc_len)
    n_k = K // bk

    kernel = functools.partial(
        _ccim_kernel_prepacked_skinny, bk=bk, n_k=n_k, acc_len=acc_len,
        x_bits=tuple(x_bits), dcim_lsb=dcim_lsb, adc_half=adc_half)
    # grid: N tiles outer, K inner (sequential accumulation); x streams
    # (Mp, bk), w streams (bk, bn) double-buffered, planes are RESIDENT
    # full-K blocks per N tile (their index map ignores k)
    in_specs = [pl.BlockSpec((Mp, bk), lambda j, k: (0, k)),
                pl.BlockSpec((bk, bn), lambda j, k: (k, j))]
    operands = [x_q, w_q]
    if x_bits:
        in_specs.append(pl.BlockSpec((len(x_bits), K, bn),
                                     lambda j, k: (0, 0, j)))
        operands.append(planes)
    return pl.pallas_call(
        kernel,
        grid=(N // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Mp, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((Mp, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def ccim_matmul_pallas(
    x_q: jax.Array,           # (M, K) int8, values in [-127, 127]
    w_q: jax.Array,           # (K, N) int8
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Hybrid-CIM integer GEMM -> (M, N) int32 at product scale (already x2^11)."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % ACC_LEN == 0
    n_k = K // bk

    kernel = functools.partial(_ccim_kernel, bk=bk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q)
