"""Pure-jnp oracle for the hybrid-CIM GEMM kernel (ideal-analog arithmetic).

Must match core.ccim.hybrid_mac_ideal tiled over K -- and it does, by
construction: both compute y8 = dcim + clip(floor(acim/2^11 + 1/2)) per
16-element chunk.  Kept dependency-free of the kernel module so the test
compares two independent implementations.
"""
from __future__ import annotations

import jax.numpy as jnp

ACC_LEN = 16
DCIM_LSB = 2048
ADC_HALF = 64


def ccim_matmul_ref(x_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """(M, K) int @ (K, N) int -> (M, N) int32 at product scale."""
    M, K = x_q.shape
    _, N = w_q.shape
    assert K % ACC_LEN == 0
    C = K // ACC_LEN
    x = x_q.astype(jnp.int32).reshape(M, C, ACC_LEN)
    w = w_q.astype(jnp.int32).reshape(C, ACC_LEN, N)

    sx, mx = jnp.where(x < 0, -1, 1), jnp.abs(x)
    sw, mw = jnp.where(w < 0, -1, 1), jnp.abs(w)
    x6, x5 = sx * ((mx >> 6) & 1), sx * ((mx >> 5) & 1)
    w6, w5 = sw * ((mw >> 6) & 1), sw * ((mw >> 5) & 1)

    exact = jnp.einsum("mcl,cln->mcn", x, w)
    dcim = (
        2 * jnp.einsum("mcl,cln->mcn", x6, w6)
        + jnp.einsum("mcl,cln->mcn", x6, w5)
        + jnp.einsum("mcl,cln->mcn", x5, w6)
    )
    acim = exact - dcim * DCIM_LSB
    code = jnp.clip(
        jnp.floor_divide(acim + DCIM_LSB // 2, DCIM_LSB), -ADC_HALF, ADC_HALF - 1
    )
    y8 = dcim + code
    return jnp.sum(y8, axis=1) * DCIM_LSB
