from .kernel import ccim_matmul_pallas  # noqa: F401
from .ops import ccim_matmul, ccim_matmul_int  # noqa: F401
from .ref import ccim_matmul_ref  # noqa: F401
