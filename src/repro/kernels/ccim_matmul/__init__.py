from .kernel import ccim_matmul_pallas, ccim_matmul_prepacked_pallas  # noqa: F401
from .ops import (ccim_matmul, ccim_matmul_int,  # noqa: F401
                  ccim_matmul_int_prepacked, pick_weight_blocks)
from .ref import ccim_matmul_ref  # noqa: F401
