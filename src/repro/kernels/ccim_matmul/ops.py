"""Jitted public wrapper for the hybrid-CIM GEMM kernel.

Handles: float->SMF quantization, K padding to the accumulate length,
(bm,bn,bk) block selection, CPU fallback (interpret mode), and dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (ACC_LEN, DCIM_LSB, SKINNY_SUBLANE, ccim_matmul_pallas,
                     ccim_matmul_prepacked_pallas,
                     ccim_matmul_prepacked_skinny_pallas)
from .ref import ccim_matmul_ref

# VMEM budget (bytes) the skinny kernel's plane residency may claim; above
# this the dispatcher keeps the general streaming kernel (16 MiB VMEM on
# current TPUs; leave headroom for the double-buffered w stream + output)
SKINNY_VMEM_BUDGET = 8 * 1024 * 1024


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(dim: int, preferred: int) -> int:
    """Block size for ``dim``; the caller pads ``dim`` up to a multiple.

    Dims at or above the MXU-preferred block always get the full block (the
    ragged remainder is padded with zeros -- numerically free: all-zero
    chunks produce y8 = 0) instead of degrading to tiny non-lane-aligned
    blocks (the old divisor search turned e.g. M=160 into bm=32).  Smaller
    dims round up to the next power of two.
    """
    if dim >= preferred:
        return preferred
    return 1 << max(dim - 1, 0).bit_length()


def _pick_k_block(c: int, preferred: int = 32) -> int:
    """Chunk-count block for the K axis: largest power-of-two block whose
    zero-padding waste stays under 25%.  Unlike M/N, K has no lane-
    alignment constraint beyond the ACC_LEN chunking, so trading block
    size against padded-out MACs is free (e.g. C=33 takes an 8-chunk
    block over padding 33 -> 64 with a 32-chunk one)."""
    b = _pick_block(c, preferred)
    while b > 1 and (-c % b) * 4 > c:
        b //= 2
    return b


def pick_gemm_blocks(M: int, N: int, K: int,
                     acc_len: int = ACC_LEN) -> tuple[int, int, int]:
    """(bm, bn, bk) for an (M, K) x (K, N) macro GEMM; K in acc_len chunks."""
    bm, bn = _pick_block(M, 128), _pick_block(N, 128)
    bk = _pick_k_block(_pad_to(K, acc_len) // acc_len) * acc_len
    return bm, bn, bk


def pick_weight_blocks(K: int, N: int,
                       acc_len: int = ACC_LEN) -> tuple[int, int, int, int]:
    """(bn, bk, Np, Kp) weight-side block selection and padded dims.

    Deliberately M-independent (bm only shapes the activation tile), so a
    weight matrix can be padded ONCE at pack time and reused for every
    activation batch shape -- the weight-stationary contract.  ``acc_len``
    is the packed config's accumulate length (the deployment planner
    assigns non-prototype lengths per projection).
    """
    bn = _pick_block(N, 128)
    bk = _pick_k_block(_pad_to(K, acc_len) // acc_len) * acc_len
    return bn, bk, _pad_to(N, bn), _pad_to(_pad_to(K, acc_len), bk)


def ccim_matmul_int_prepacked(
    x_q: jax.Array,           # (M, K) ints in [-127, 127]
    w_q: jax.Array,           # (Kp, Np) int8, block-padded at pack time
    planes: jax.Array,        # (n_planes, Kp, Np) int8 folded DCIM planes
    *,
    k_dim: int, n_dim: int,
    acc_len: int = ACC_LEN,
    x_bits: tuple = (6, 5),
    dcim_lsb: int = DCIM_LSB,
    adc_bits: int = 7,
    use_pallas: bool | None = None, interpret: bool | None = None,
    skinny_blocks: tuple | None = None,
) -> jax.Array:
    """Prepacked-weight macro GEMM: only the activations are padded and
    decomposed per call.  Bit-identical to ``cim_matmul_int`` (fast
    fidelity, noise-free) on the raw integer weights the pack was built
    from.  The packed D/A split rides in as static meta -- ``x_bits`` (one
    activation bit index per folded plane; the plane COUNT is the plan's
    ``n_dcim_products`` grouped by x bit), ``dcim_lsb``, ``adc_bits`` and
    ``acc_len`` -- so one kernel serves every deployment-plan design point.

    Decode-shaped calls (M <= SKINNY_SUBLANE) route to the skinny-M kernel
    -- M padded to the int8 sublane width instead of the 128-lane MXU
    block, folded planes VMEM-resident across the K-loop -- with (bn, bk)
    from the persisted tuning cache (autotune.tuned_skinny_blocks) when
    available; ``skinny_blocks`` forces a candidate (the autotuner's
    search hook).  All routes are bit-identical.
    """
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_q.shape
    assert K == k_dim, (K, k_dim)
    bn, bk, Np, Kp = pick_weight_blocks(k_dim, n_dim, acc_len)
    assert w_q.shape == (Kp, Np), (w_q.shape, Kp, Np)
    if not use_pallas:
        default = (acc_len == ACC_LEN and tuple(x_bits) == (6, 5)
                   and dcim_lsb == DCIM_LSB and adc_bits == 7)
        if not default:
            raise ValueError(
                "non-prototype D/A splits are served by the generalized "
                "Pallas kernel (interpret mode off-TPU); pass "
                "use_pallas=True")
        xp = jnp.pad(x_q, ((0, 0), (0, Kp - K)))
        return ccim_matmul_ref(xp.astype(jnp.int32),
                               w_q.astype(jnp.int32))[:, :n_dim]
    n_planes = len(x_bits)
    if M <= SKINNY_SUBLANE:
        from . import autotune
        if skinny_blocks is None:
            skinny_blocks = (autotune.tuned_skinny_blocks(
                Kp, Np, acc_len, n_planes) or (bn, bk))
        sbn, sbk = skinny_blocks
        fits = (max(n_planes, 1) * Kp * sbn <= SKINNY_VMEM_BUDGET
                and Np % sbn == 0 and Kp % sbk == 0
                and sbk % acc_len == 0 and sbk % SKINNY_SUBLANE == 0)
        if fits:
            xp = jnp.pad(x_q, ((0, SKINNY_SUBLANE - M), (0, Kp - K)))
            y = ccim_matmul_prepacked_skinny_pallas(
                xp.astype(jnp.int8), w_q, planes,
                bn=sbn, bk=sbk, acc_len=acc_len, x_bits=tuple(x_bits),
                dcim_lsb=dcim_lsb, adc_half=1 << (adc_bits - 1),
                interpret=interpret,
            )
            return y[:M, :n_dim]
    bm = _pick_block(M, 128)
    Mp = _pad_to(M, bm)
    xp = jnp.pad(x_q, ((0, Mp - M), (0, Kp - K)))
    y = ccim_matmul_prepacked_pallas(
        xp.astype(jnp.int8), w_q, planes,
        bm=bm, bn=bn, bk=bk, acc_len=acc_len, x_bits=tuple(x_bits),
        dcim_lsb=dcim_lsb, adc_half=1 << (adc_bits - 1), interpret=interpret,
    )
    return y[:M, :n_dim]


def ccim_matmul_int(
    x_q: jax.Array, w_q: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(M,K) x (K,N) int8-range ints -> int32 macro GEMM (scale 2^11)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_q.shape
    _, N = w_q.shape
    Kp = _pad_to(K, ACC_LEN)
    if Kp != K:
        x_q = jnp.pad(x_q, ((0, 0), (0, Kp - K)))
        w_q = jnp.pad(w_q, ((0, Kp - K), (0, 0)))
    if not use_pallas:
        return ccim_matmul_ref(x_q, w_q)
    bm, bn, bk = pick_gemm_blocks(M, N, Kp)
    Mp, Np, Kpp = _pad_to(M, bm), _pad_to(N, bn), _pad_to(Kp, bk)
    if (Mp, Np, Kpp) != (M, N, Kp):
        x_q = jnp.pad(x_q, ((0, Mp - M), (0, Kpp - Kp)))
        w_q = jnp.pad(w_q, ((0, Kpp - Kp), (0, Np - N)))
    y = ccim_matmul_pallas(
        x_q.astype(jnp.int8), w_q.astype(jnp.int8),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ccim_matmul(
    x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """float GEMM through the (ideal-analog) macro numerics, dequantized."""
    amax_x = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    amax_w = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12)
    sx, sw = amax_x / 127.0, amax_w / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int32)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int32)
    y = ccim_matmul_int(xq, wq, use_pallas=use_pallas, interpret=interpret)
    return y.astype(jnp.float32) * sx * sw
