"""Jitted public wrapper for the hybrid-CIM GEMM kernel.

Handles: float->SMF quantization, K padding to the accumulate length,
(bm,bn,bk) block selection, CPU fallback (interpret mode), and dequant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (ACC_LEN, DCIM_LSB, ccim_matmul_pallas,
                     ccim_matmul_prepacked_pallas)
from .ref import ccim_matmul_ref


def _pad_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(dim: int, preferred: int) -> int:
    """Block size for ``dim``; the caller pads ``dim`` up to a multiple.

    Dims at or above the MXU-preferred block always get the full block (the
    ragged remainder is padded with zeros -- numerically free: all-zero
    chunks produce y8 = 0) instead of degrading to tiny non-lane-aligned
    blocks (the old divisor search turned e.g. M=160 into bm=32).  Smaller
    dims round up to the next power of two.
    """
    if dim >= preferred:
        return preferred
    return 1 << max(dim - 1, 0).bit_length()


def _pick_k_block(c: int, preferred: int = 32) -> int:
    """Chunk-count block for the K axis: largest power-of-two block whose
    zero-padding waste stays under 25%.  Unlike M/N, K has no lane-
    alignment constraint beyond the ACC_LEN chunking, so trading block
    size against padded-out MACs is free (e.g. C=33 takes an 8-chunk
    block over padding 33 -> 64 with a 32-chunk one)."""
    b = _pick_block(c, preferred)
    while b > 1 and (-c % b) * 4 > c:
        b //= 2
    return b


def pick_gemm_blocks(M: int, N: int, K: int) -> tuple[int, int, int]:
    """(bm, bn, bk) for an (M, K) x (K, N) macro GEMM; K in ACC_LEN chunks."""
    bm, bn = _pick_block(M, 128), _pick_block(N, 128)
    bk = _pick_k_block(_pad_to(K, ACC_LEN) // ACC_LEN) * ACC_LEN
    return bm, bn, bk


def pick_weight_blocks(K: int, N: int) -> tuple[int, int, int, int]:
    """(bn, bk, Np, Kp) weight-side block selection and padded dims.

    Deliberately M-independent (bm only shapes the activation tile), so a
    weight matrix can be padded ONCE at pack time and reused for every
    activation batch shape -- the weight-stationary contract.
    """
    bn = _pick_block(N, 128)
    bk = _pick_k_block(_pad_to(K, ACC_LEN) // ACC_LEN) * ACC_LEN
    return bn, bk, _pad_to(N, bn), _pad_to(_pad_to(K, ACC_LEN), bk)


def ccim_matmul_int_prepacked(
    x_q: jax.Array,           # (M, K) ints in [-127, 127]
    w_q: jax.Array,           # (Kp, Np) int8, block-padded at pack time
    w_p6: jax.Array,          # (Kp, Np) int8 folded plane s*(2*b6+b5)
    w_p5: jax.Array,          # (Kp, Np) int8 folded plane s*b6
    *,
    k_dim: int, n_dim: int,
    use_pallas: bool | None = None, interpret: bool | None = None,
) -> jax.Array:
    """Prepacked-weight macro GEMM: only the activations are padded and
    decomposed per call.  Bit-identical to ``ccim_matmul_int`` on the raw
    integer weights the pack was built from."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_q.shape
    assert K == k_dim, (K, k_dim)
    bn, bk, Np, Kp = pick_weight_blocks(k_dim, n_dim)
    assert w_q.shape == (Kp, Np), (w_q.shape, Kp, Np)
    if not use_pallas:
        xp = jnp.pad(x_q, ((0, 0), (0, Kp - K)))
        return ccim_matmul_ref(xp.astype(jnp.int32),
                               w_q.astype(jnp.int32))[:, :n_dim]
    bm = _pick_block(M, 128)
    Mp = _pad_to(M, bm)
    xp = jnp.pad(x_q, ((0, Mp - M), (0, Kp - K)))
    y = ccim_matmul_prepacked_pallas(
        xp.astype(jnp.int8), w_q, w_p6, w_p5,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y[:M, :n_dim]


def ccim_matmul_int(
    x_q: jax.Array, w_q: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """(M,K) x (K,N) int8-range ints -> int32 macro GEMM (scale 2^11)."""
    on_tpu = jax.default_backend() == "tpu"
    if use_pallas is None:
        use_pallas = on_tpu
    if interpret is None:
        interpret = not on_tpu
    M, K = x_q.shape
    _, N = w_q.shape
    Kp = _pad_to(K, ACC_LEN)
    if Kp != K:
        x_q = jnp.pad(x_q, ((0, 0), (0, Kp - K)))
        w_q = jnp.pad(w_q, ((0, Kp - K), (0, 0)))
    if not use_pallas:
        return ccim_matmul_ref(x_q, w_q)
    bm, bn, bk = pick_gemm_blocks(M, N, Kp)
    Mp, Np, Kpp = _pad_to(M, bm), _pad_to(N, bn), _pad_to(Kp, bk)
    if (Mp, Np, Kpp) != (M, N, Kp):
        x_q = jnp.pad(x_q, ((0, Mp - M), (0, Kpp - Kp)))
        w_q = jnp.pad(w_q, ((0, Kpp - Kp), (0, Np - N)))
    y = ccim_matmul_pallas(
        x_q.astype(jnp.int8), w_q.astype(jnp.int8),
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y[:M, :N]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ccim_matmul(
    x: jax.Array, w: jax.Array, *, use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """float GEMM through the (ideal-analog) macro numerics, dequantized."""
    amax_x = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    amax_w = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-12)
    sx, sw = amax_x / 127.0, amax_w / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int32)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int32)
    y = ccim_matmul_int(xq, wq, use_pallas=use_pallas, interpret=interpret)
    return y.astype(jnp.float32) * sx * sw
