from .sharding import (  # noqa: F401
    FSDP_EXTRA,
    TP_RULES,
    dp_axes,
    dp_size,
    named,
    param_specs,
    spec_for,
)
from .compression import (  # noqa: F401
    compressed_psum_mean,
    make_compressed_grad_allreduce,
)
