"""Logical-axis -> mesh-axis sharding rules (GSPMD PartitionSpec trees).

Model params carry logical dimension names (see models/layers.py init
functions).  Rules map those to mesh axes:

  TP   : vocab/heads/kv_heads/ff/ssm dims -> "model"     (Megatron splits)
  EP   : experts -> "model"                              (expert parallel)
  FSDP : embed/moe_ff -> "data"                          (ZeRO-3; required
         for the >=10B configs -- arctic-480b's optimizer state cannot fit
         one chip's HBM share otherwise)
  DP   : batch -> ("pod","data") on the multi-pod mesh   ("pod" = outer DP)
  SP   : batch==1 long-context caches shard sequence over the DP axes

Explicit in_shardings must divide array dims evenly, so assignment is
SHAPE-AWARE: if a rule's home dimension is not divisible by its mesh axis,
the axis is relocated to the largest other divisible unsharded dimension
(e.g. minicpm's vocab=122753 is odd -> the "model" axis moves to the embed
dim; qwen3's 8 kv heads < 16 -> the decode cache shards its sequence dim,
which is exactly split-KV / flash-decoding).  Rules return PartitionSpec
trees consumed by jax.jit in_shardings; GSPMD propagates them through the
program and inserts the collectives the roofline pass audits.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "moe_ff": None,
    "experts": "model",
    "experts_r": None,
    "embed": None,
    "head_embed": None,   # embedding/lm_head D dim: never FSDP (CE locality)
    "head_dim": None,
    "layers": None,
    "ssm_proj": "model",
    "ssm_inner": "model",
    "ssm_heads": None,
    "conv": None,
    "state": None,
}

# ZeRO-3: shard the embed dim of every 2-D+ weight over "data".  For MoE
# expert tensors this means the D (embed) dim -- NOT moe_ff: sharding the
# F dim made XLA's wgrad all-gather activation-sized (B,C,D,E) buffers
# (43 GB/layer on qwen2-moe); with D-over-data the wgrad lowers to the
# textbook partial + reduce-scatter.
FSDP_EXTRA = {"embed": "data"}

# Semantics-aware fallback when a rule's home dim is indivisible: the mesh
# axis moves to a NAMED alternative dim (never a blind relocation -- see
# spec_for docstring).  qwen2-moe: 60 experts don't divide a 16-way model
# axis -> shard each expert's FF dim instead (Megatron within-expert TP).
PARAM_FALLBACKS: Dict[str, Tuple[str, ...]] = {
    "experts": ("moe_ff",),
    "ssm_inner": ("ssm_heads",),
}

# 1-D params (norm scales etc.) stay replicated: sharding tiny vectors only
# costs collectives.
_REPLICATE_1D = True


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _size(ax, sizes) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes[a]
        return n
    return sizes[ax]


def spec_for(shape: Sequence[int], axes: Tuple[str, ...], mesh: Mesh, *,
             fsdp: bool = False,
             overrides: Optional[Dict[str, Optional[str]]] = None,
             relocate: bool = True) -> P:
    """Shape-aware spec: rules first, optional relocation fallback.

    relocate=True  (caches / activations): a failed axis moves to the
        largest divisible free dim -- for KV caches this yields split-KV
        decode (few kv heads -> shard sequence) and sequence-parallel
        caches for batch==1.  Positional tensors have no contracting
        semantics, so any dim is safe to shard.
    relocate=False (params): a failed TP dim REPLICATES instead.  Moving a
        weight shard onto a matmul's contracting dim would turn every use
        into a full activation all-reduce (measured: 80 GB/step/device on
        mamba2 before this rule); replicating a few-MB projection or even a
        500 MB embedding is strictly cheaper.
    """
    rules = dict(TP_RULES)
    if fsdp:
        rules.update(FSDP_EXTRA)
    if overrides:
        rules.update(overrides)
    sizes = _axis_sizes(mesh)
    nd = len(shape)
    if nd == 1 and _REPLICATE_1D:
        return P(None)
    assign: list = [None] * nd
    used = set()
    wanted = []
    for i, name in enumerate(axes):
        ax = rules.get(name)
        if ax is None or ax in used:
            continue
        if shape[i] % _size(ax, sizes) == 0:
            assign[i] = ax
            used.add(ax)
        else:
            wanted.append(ax)
    if relocate:
        for ax in wanted:      # relocate to largest divisible free dim
            if ax in used:
                continue
            cands = [j for j in range(nd)
                     if assign[j] is None and axes[j] != "layers"
                     and shape[j] % _size(ax, sizes) == 0 and shape[j] > 1]
            if cands:
                j = max(cands, key=lambda j: shape[j])
                assign[j] = ax
                used.add(ax)
    else:
        # params: only NAMED fallbacks (semantics-aware)
        for i, name in enumerate(axes):
            ax = rules.get(name)
            if ax is None or ax in used:
                continue
            for alt in PARAM_FALLBACKS.get(name, ()):
                if alt not in axes:
                    continue
                j = axes.index(alt)
                if assign[j] is None and shape[j] % _size(ax, sizes) == 0:
                    assign[j] = ax
                    used.add(ax)
                    break
    return P(*assign)


def param_specs(shapes_tree, axes_tree, mesh: Mesh, *, fsdp: bool = False,
                overrides: Optional[Dict[str, Optional[str]]] = None):
    """Same-structure tree of PartitionSpec for (shapes, logical axes)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(s, str) for s in x)
    flat_s, tdef = jax.tree.flatten(shapes_tree)
    flat_a = tdef.flatten_up_to(
        jax.tree.map(lambda a: a, axes_tree, is_leaf=is_axes))
    specs = [spec_for(s.shape, a, mesh, fsdp=fsdp, overrides=overrides,
                      relocate=False)
             for s, a in zip(flat_s, flat_a)]
    return tdef.unflatten(specs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel mesh axes: ('pod','data') when a pod axis exists."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    return _size(dp_axes(mesh), _axis_sizes(mesh))
