"""int8 error-feedback gradient compression for slow inter-pod links.

On a 2-pod mesh the "pod" axis crosses data-center-network (or optical
ICI) links an order of magnitude slower than in-pod ICI.  1-bit/8-bit
compressed all-reduce with error feedback (Seide et al. 2014; signSGD
variants) cuts that traffic 4x vs bf16 with negligible convergence impact
when the quantization residual is fed back into the next step.

The collective is explicit (shard_map + psum) because its semantics --
quantize THEN sum THEN dequantize, residual kept local -- must not be
re-associated by the compiler.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level (curried form supported)
    shard_map = jax.shard_map
except AttributeError:  # older jax: experimental module, f-first only
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    def shard_map(f=None, **kwargs):
        if f is None:  # used as @shard_map(mesh=..., ...) decorator
            return lambda fn: _shard_map_impl(fn, **kwargs)
        return _shard_map_impl(f, **kwargs)

Array = jax.Array


def _quantize(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(x: Array, err: Array, axis_name: str
                         ) -> Tuple[Array, Array]:
    """Mean-reduce ``x`` over ``axis_name`` in int8 with error feedback.

    Returns (mean, new_err). new_err is the local quantization residual to
    be added into next step's input (carried in the optimizer state).
    """
    n = (jax.lax.axis_size(axis_name) if hasattr(jax.lax, "axis_size")
         else jax.lax.psum(1, axis_name))  # older jax: no lax.axis_size
    xc = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = _quantize(xc)
    new_err = xc - q.astype(jnp.float32) * scale
    # sum int32 partial sums and the per-shard scales (scales differ ->
    # sum q*scale products; send q int8 + one scalar)
    total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    return total / n, new_err.astype(err.dtype)


def make_compressed_grad_allreduce(mesh: Mesh, axis_name: str = "data"):
    """Tree-level compressed mean-all-reduce over the DP axis.

    grads are expected sharded with batch-derived partial values per DP
    shard (i.e. from a per-shard loss); returns the DP-mean.
    """

    def _one(g, e):
        spec = P(*(None,) * g.ndim)

        @partial(
            shard_map, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec))
        def _run(gl, el):
            return compressed_psum_mean(gl, el, axis_name)

        return _run(g, e)

    def allreduce(grads, err_state):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        out = [_one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    return allreduce
