"""Quickstart: the hybrid D/A complex-CIM macro in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CCIMConfig, cim_matmul, complex_cim_matmul,
                        contribution_table, fabricate, hybrid_mac_bit_true)
from repro.core.costmodel import density_mb_per_mm2, figS1_comparison

cfg = CCIMConfig()  # the 28nm prototype: 8b SMF, top-3 DCIM, 7b SAR, 48aF UC
print(f"DCIM group: {cfg.dcim_products} -> "
      f"{100*np.sort(contribution_table(cfg).ravel())[-3:].sum():.1f}% of "
      "output contribution (paper: 'half')")
print(f"Memory density: {density_mb_per_mm2():.2f} Mb/mm^2 (paper: 1.80)\n")

# --- fabricate a die (frozen mismatch) and run one 16-element complex MAC --
key = jax.random.PRNGKey(0)
macro = fabricate(key, cfg)
k1, k2, k3 = jax.random.split(key, 3)
x = jax.random.randint(k1, (4, 16), -127, 128).clip(-127, 127)
w = jax.random.randint(k2, (4, 16), -127, 128).clip(-127, 127)
out = hybrid_mac_bit_true(x, w, macro, cfg, noise_key=k3)
print("one conversion per row:  y8 =", np.asarray(out["y8"]))
print("exact / 2^11          =", np.asarray(out["exact"]) // 2048)
print("DCIM part (exact)     =", np.asarray(out["dcim"]),
      " ADC code =", np.asarray(out["adc_code"]), "\n")

# --- float GEMM through the macro (tiled into 16-element conversions) -----
xf = jax.random.normal(k1, (8, 256))
wf = jax.random.normal(k2, (256, 32))
y = cim_matmul(xf, wf, cfg, noise_key=k3)
rel = float(jnp.linalg.norm(y - xf @ wf) / jnp.linalg.norm(xf @ wf))
print(f"cim_matmul  (8x256)@(256x32): rel err {rel:.4f}")

# --- complex MAC: ONE co-located weight array serves all 4 sub-products ---
xc = (jax.random.normal(k1, (8, 64)) + 1j * jax.random.normal(k2, (8, 64))
      ).astype(jnp.complex64)
wc = (jax.random.normal(k2, (64, 8)) - 0.5j * jax.random.normal(k3, (64, 8))
      ).astype(jnp.complex64)
yc = complex_cim_matmul(xc, wc, cfg, noise_key=k3)
ref = xc @ wc
print(f"complex_cim_matmul rel err "
      f"{float(jnp.linalg.norm(yc-ref)/jnp.linalg.norm(ref)):.4f}")

# --- why this beats duplicated-weight / sequential complex CIM ------------
s = figS1_comparison(cfg)["savings"]
print(f"\nvs prior approaches: area -{s['area_pct_vs_duplicated']:.0f}% "
      f"(paper -35%), latency -{s['latency_pct_vs_sequential']:.0f}% "
      f"(paper -54%), power -{s['power_pct_vs_duplicated']:.0f}% "
      f"(paper -24%)")

# --- the D/A split as a deployment knob: plan -> pack -> serve ------------
# Profile each projection's noise sensitivity, knapsack-search a per-
# projection CCIMConfig assignment (digital where it hurts, cheap analog
# splits where it doesn't), then serve the planned model -- each weight
# matrix packed once under ITS OWN macro config, zero recompiles.
import dataclasses

from repro import plan as P
from repro.configs import get_config
from repro.kernels.ccim_matmul import autotune
from repro.launch.serve import serve
from repro.models import lm

mcfg = get_config("minicpm-2b", smoke=True)
params, _ = lm.init(jax.random.PRNGKey(0), mcfg)
toks = P.calibration_batch(mcfg, batch=1, seq_len=16)
cands = [P.digital_candidate(), P.prototype_candidate(),
         P.make_candidate("hybrid3/adc8/L32",
                          dataclasses.replace(cfg, acc_len=32, adc_bits=8))]
res = P.pareto_search(params, mcfg, toks, candidates=cands)  # profile+search
print("\ndeployment plan (projection -> design point):")
for site, label in res.assignment.items():
    print(f"  {site:10s} -> {label}")
print(f"planned rms {res.measured_rms:.4f} (budget {res.budget_measured:.4f}"
      f" = the global prototype config), modeled cost "
      f"{res.cost['combined']:.3f} vs {res.cost_budget_plan['combined']:.3f}"
      " global / 1.0 all-digital")

# Autotune the decode GEMM schedules once per machine: the winners persist
# in benchmarks/TUNING_CACHE.json and serving consults them at trace time
# (every candidate is bit-identical -- tuning can only change speed).
autotune.autotune_chunk_block(2, mcfg.d_model, 2 * mcfg.d_ff, iters=2)
autotune.save()

# Serve the planned model: pack once under the plan (plan-compatible
# QKV / gate-up groups fuse into single wide macro GEMMs -- fuse=True is
# the default, shown explicitly; tokens are bit-identical either way),
# then decode through the AOT-compiled step with tuned blocks.
tokens = serve("minicpm-2b", batch=2, prompt_len=16, gen=8, plan=res.plan,
               pack=True, fuse=True)
print("served tokens through the planned model:", tokens[0])

# --- the D/A split as a LATENCY knob: speculative decoding ----------------
# Derive the plan's all-analog shadow (same n_mag_bits/acc_len, no DCIM
# planes -- pack-compatible, so it serves the SAME packed weights), draft
# k tokens per round under it, verify all k+1 positions in one wide
# skinny-M forward under the deployed plan, accept/resample.  Greedy
# output is bit-identical to the non-speculative serve above (asserted
# inside serve_speculative); acceptance depends on how far the draft SAR
# is narrowed below its no-clip width.
from repro.launch.serve import serve_speculative

draft = P.derive_draft_plan(res.plan)     # conservative: no-clip widths
print("\ndraft plan (default entry):", draft.default.label)
spec_tokens, spec = serve_speculative(
    "minicpm-2b", batch=2, prompt_len=16, gen=8, draft_k=4, plan=res.plan,
    draft_plan=draft, return_stats=True)
print(f"speculative decode: {spec['decode_speedup_speculative']}x vs "
      f"non-speculative, acceptance {spec['acceptance_rate']:.0%}, "
      "tokens identical")

# --- paged KV serving: block pool, shared prefixes, chunked prefill -------
# The continuous-batching scheduler can swap its per-slot contiguous KV
# regions for a global block pool with per-slot block tables (vLLM's
# layout, allocator folded into the one device-resident serve loop):
# mixed-length prompts stop paying for the context limit, identical
# system prompts share refcounted blocks, and long prompts prefill in
# chunks interleaved with decode so admission never stalls the pool.
# Tokens are bit-identical to the contiguous scheduler -- see
# examples/cim_serve.py for a running pool and DESIGN.md §11 for the
# allocator/pinning/rollback semantics.
from repro.launch.paging import PagedLayout  # noqa: F401  (see cim_serve.py)

# --- telemetry: what did the serve loop actually do? ----------------------
# metrics=True compiles a SEPARATE executable whose while-loop carry
# threads fixed-size event/iteration rings (tokens stay bit-identical;
# the metrics-off program is byte-identical to a build without the
# telemetry code).  The harvested rings land in the stats dict next to
# a Prometheus-style registry snapshot and the span trace of this very
# pack/compile/serve sequence.
import json

from repro.launch.serve import serve_continuous
from repro.obs import REGISTRY

_, st = serve_continuous("minicpm-2b", n_requests=4, slots=2, prompt_len=16,
                         stop_lengths=(4, 8, 6, 8), metrics=True)
tel = st["telemetry"]
print(f"\ntelemetry: {tel['counters']['tokens']} tokens over "
      f"{tel['n_iter']} loop iterations, occupancy "
      f"{tel['occupancy_mean']:.2f}, ttft p50 {tel['ttft_p50_iters']:.0f} "
      "iters")
print("per-request spans:", json.dumps(tel["spans"][0]))
print(REGISTRY.export_prometheus().splitlines()[0], "...")
