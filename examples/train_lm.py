"""End-to-end driver: train the FULL mamba2-130m (~170M params incl.
embeddings) for a few hundred steps on synthetic data with checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--smoke]

(--smoke trains the reduced config instead -- seconds instead of tens of
minutes on one CPU core.)
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    _, _, losses = train(
        "mamba2-130m", smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, resume=True, ckpt_every=50,
        log_every=10)
    print(f"first-10 mean loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")


if __name__ == "__main__":
    main()
