"""Serve a small LM with every projection running through the emulated
C-CIM macro (PTQ inference on the paper's hardware), batched requests.

The CIM run uses the prepacked-weight engine: every projection is
quantized + bit-plane-decomposed ONCE before prefill (the array write),
and the decode loop runs activation-only quantization -- so the numbers
below separate the one-time pack cost from the steady-state decode rate
instead of folding everything into one misleading wall-clock figure.

The last section serves a mixed-length request queue through the
continuous-batching scheduler (launch/scheduler.py): per-slot EOS /
max-new-tokens tracking on device, freed slots refilled mid-stream from
the queue, packed weights throughout -- vs the lock-step loop that holds
every slot until the slowest request ends.

  PYTHONPATH=src python examples/cim_serve.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve, serve_continuous

print("=== fp (bf16) serving ===")
fp, fp_stats = serve("musicgen-medium", smoke=True, batch=4, prompt_len=32,
                     gen=12, return_stats=True)
print("tokens:\n", fp)

print("\n=== C-CIM macro serving (8b SMF, hybrid DCIM/ACIM + 7b ADC, "
      "prepacked weights) ===")
cim, cim_stats = serve("musicgen-medium", smoke=True, batch=4, prompt_len=32,
                       gen=12, cim=True, return_stats=True)
print("tokens:\n", cim)

print(f"\none-time weight pack (array write): {cim_stats['pack_s']:.2f}s")
print(f"steady-state decode: fp {fp_stats['decode_tok_s']:.1f} tok/s, "
      f"CIM {cim_stats['decode_tok_s']:.1f} tok/s")
print(f"prefill: fp {fp_stats['prefill_s']:.2f}s, "
      f"CIM {cim_stats['prefill_s']:.2f}s")

agree = float((fp == cim).mean())
print(f"\ntoken agreement fp vs CIM: {100*agree:.0f}% "
      "(greedy decode; quantized execution may diverge after a few tokens)")

print("\n=== continuous batching: mixed-length queue on packed CIM "
      "weights ===")
toks, cb = serve_continuous("musicgen-medium", smoke=True, slots=2,
                            prompt_len=16, n_requests=8,
                            stop_lengths=(4, 16, 8, 12), cim=True,
                            repeats=2)
cont, lock = cb["continuous"], cb["lockstep"]
print(f"8 requests (stops 4/16/8/12) over 2 slots:")
print(f"  continuous: {cont['tok_s']:.1f} tok/s, "
      f"occupancy {cont['occupancy']:.0%}, "
      f"latency p50 {cont['p50_s']*1e3:.0f}ms / p95 {cont['p95_s']*1e3:.0f}ms")
print(f"  lock-step : {lock['tok_s']:.1f} tok/s, "
      f"occupancy {lock['occupancy']:.0%}, "
      f"latency p50 {lock['p50_s']*1e3:.0f}ms / p95 {lock['p95_s']*1e3:.0f}ms")
print(f"  speedup {cb['speedup_vs_lockstep']:.2f}x, per-request tokens "
      "bit-identical to the lock-step plan")

print("\n=== paged KV: block pool + shared prefixes + chunked prefill ===")
import numpy as np

from repro.launch.paging import PagedLayout
toks_pg, pg = serve_continuous("musicgen-medium", smoke=True, slots=2,
                               prompt_len=16, n_requests=8,
                               stop_lengths=(4, 16, 8, 12), cim=True,
                               repeats=2,
                               paged=PagedLayout(block_size=4, n_tbl=10,
                                                 n_blocks=48),
                               prefill_chunk=8)
for rid, want in toks.items():
    np.testing.assert_array_equal(toks_pg[rid], want)
print("same queue on a 48-block pool (block_size=4, 8-token prefill "
      "chunks):")
print(f"  paged: {pg['continuous']['tok_s']:.1f} tok/s, peak "
      f"{pg['paged']['peak_blocks']} blocks resident "
      f"({pg['paged']['kv_bytes_peak']/1024:.0f}KiB vs "
      f"{pg['paged']['kv_bytes_contiguous']/1024:.0f}KiB contiguous "
      "reservation)")
print("  tokens bit-identical to the contiguous scheduler above "
      "(asserted)")
