"""Serve a small LM with every projection running through the emulated
C-CIM macro (PTQ inference on the paper's hardware), batched requests.

  PYTHONPATH=src python examples/cim_serve.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.launch.serve import serve

print("=== fp (bf16) serving ===")
fp = serve("musicgen-medium", smoke=True, batch=4, prompt_len=32, gen=12)
print("tokens:\n", fp)

print("\n=== C-CIM macro serving (8b SMF, hybrid DCIM/ACIM + 7b ADC) ===")
cim = serve("musicgen-medium", smoke=True, batch=4, prompt_len=32, gen=12,
            cim=True)
print("tokens:\n", cim)

agree = float((fp == cim).mean())
print(f"\ntoken agreement fp vs CIM: {100*agree:.0f}% "
      "(greedy decode; quantized execution may diverge after a few tokens)")
