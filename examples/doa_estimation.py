"""DOA estimation on the complex-CIM macro (paper Fig. S3 application).

MUSIC over an 8-sensor ULA; the complex covariance and spectrum
projections run through the emulated macro, the eigendecomposition stays
in the digital backend.  Paper claim: < 4% RMSE vs fp32 software.

  PYTHONPATH=src python examples/doa_estimation.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.figS3_doa import _estimate, _music_spectrum, _steering

import jax
import jax.numpy as jnp

true_doa = [-24.0, 17.0]
n_sensors, n_snap = 8, 64
rng = np.random.default_rng(1)
A = _steering(n_sensors, true_doa)
S = (rng.standard_normal((2, n_snap)) + 1j * rng.standard_normal((2, n_snap)))
N = (rng.standard_normal((n_sensors, n_snap)) +
     1j * rng.standard_normal((n_sensors, n_snap))) * 0.05
X = jnp.asarray(A @ S + N, jnp.complex64)

grid = np.arange(-60.0, 60.5, 0.5)
key = jax.random.PRNGKey(0)
p_sw = _music_spectrum(X, 2, grid, cim=False, key=key)
p_cim = _music_spectrum(X, 2, grid, cim=True, key=key)

est_sw = _estimate(p_sw, grid, 2)
est_cim = _estimate(p_cim, grid, 2)
print(f"true DOA:          {true_doa}")
print(f"software MUSIC:    {est_sw}")
print(f"C-CIM MUSIC:       {est_cim}")
err = np.sqrt(np.mean((np.array(est_cim) - np.array(true_doa)) ** 2))
print(f"C-CIM RMSE: {err:.2f} deg  ({100*err/120:.2f}% of FOV; paper <4%)")

# ascii spectrum
p = np.asarray(p_cim)
p = p / p.max()
print("\nMUSIC spectrum (C-CIM):")
for i in range(0, len(grid), 8):
    bar = "#" * int(40 * p[i])
    print(f"{grid[i]:+6.1f} deg |{bar}")
