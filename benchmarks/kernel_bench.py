"""Kernel micro-benchmarks (interpret-mode correctness + host timing) and
the fast-vs-bit-true emulation fidelity/speed trade (the TPU adaptation:
a handful of matmuls instead of 49 bit-plane products -- see DESIGN.md §2).

Includes the old-vs-new comparison for this repo's two GEMM hot paths:
the matmul-ized fast-fidelity GEMM vs the legacy elementwise-broadcast
implementation, and the complex GEMM (fused/matmul-ized vs broadcast
4-pass).  Rows are also accumulated into BENCH_kernels.json via
common.record for the perf trajectory.  Host timings use min-of-iters
(robust to scheduler noise on small shared machines).
"""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, record, time_us, write_bench_json
from repro.core import (DEFAULT_CONFIG, cim_matmul, fabricate,
                        pack_cim_weights, pack_complex_cim_weights)
from repro.core.ccim import cim_matmul_int
from repro.core.complex_mac import complex_cim_matmul, complex_cim_matmul_int
from repro.kernels.ccim_matmul import ccim_matmul_ref
from repro.kernels.ccim_complex import (ccim_complex_matmul_int,
                                        ccim_complex_matmul_ref)
from repro.kernels.int8_matmul import int8_matmul

# Decode-shape regression gate (see ISSUE 5): the prepacked serving path
# must beat per-call weight conditioning AT SERVING SHAPES, not just at
# 256x1024x256.  The pre-overhaul row was 0.98x -- the skinny-M chunk
# schedule is what buys the margin -- so CI fails if it regresses back
# below this floor.  Waiver: host-timer noise on tiny kernels is real;
# the floor is set ~15% under the measured steady-state speedup rather
# than at the speedup itself.
DECODE_SPEEDUP_FLOOR = 1.05


def _rand_q(key, shape):
    return jax.random.randint(key, shape, -127, 128).clip(-127, 127)


def run(seed: int = 0):
    cfg = DEFAULT_CONFIG
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    M, K, N = 64, 512, 64
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    macro = fabricate(key, cfg)

    f_bit = jax.jit(lambda a, b: cim_matmul(a, b, cfg, noise_key=key,
                                            macro=macro, fidelity="bit_true"))
    f_fast = jax.jit(lambda a, b: cim_matmul(a, b, cfg, noise_key=key,
                                             fidelity="fast"))
    us_bit = time_us(f_bit, x, w, iters=2, warmup=1)
    us_fast = time_us(f_fast, x, w, iters=2, warmup=1)
    y_bit, y_fast = f_bit(x, w), f_fast(x, w)
    ref = x @ w
    fs = float(jnp.abs(x).max() * jnp.abs(w).max() * K)
    emit("kern.bit_true_emulation", us_bit,
         f"max FS-rel err {float(jnp.abs(y_bit-ref).max())/fs:.4f}")
    emit("kern.fast_emulation", us_fast,
         f"max FS-rel err {float(jnp.abs(y_fast-ref).max())/fs:.4f}; "
         f"{us_bit/us_fast:.1f}x faster than bit-true")
    record("bit_true_emulation", (M, K, N), us_bit)
    record("fast_emulation", (M, K, N), us_fast, us_bit / us_fast,
           "vs bit_true oracle")

    # ---- fast-fidelity GEMM: matmul-ized (new) vs broadcast (old) --------
    M2, K2, N2 = 256, 1024, 256
    qx2 = _rand_q(k1, (M2, K2))
    qw2 = _rand_q(k2, (K2, N2))
    f_bcast = jax.jit(lambda a, b: cim_matmul_int(
        a, b, None, cfg, None, "fast_broadcast"))
    f_mm = jax.jit(lambda a, b: cim_matmul_int(
        a, b, None, cfg, None, "fast", use_pallas=False))
    us_bcast = time_us(f_bcast, qx2, qw2, iters=3, warmup=1, reduce="min")
    us_mm = time_us(f_mm, qx2, qw2, iters=8, warmup=2, reduce="min")
    assert (np.asarray(f_bcast(qx2, qw2)) == np.asarray(f_mm(qx2, qw2))).all()
    emit("kern.fast_gemm_broadcast", us_bcast,
         f"{M2}x{K2}x{N2} legacy elementwise-broadcast fast path")
    emit("kern.fast_gemm_matmulized", us_mm,
         f"bit-identical; {us_bcast/us_mm:.1f}x faster than broadcast")
    record("fast_gemm_broadcast", (M2, K2, N2), us_bcast)
    record("fast_gemm_matmulized", (M2, K2, N2), us_mm, us_bcast / us_mm,
           "vs broadcast fast path (bit-identical)")

    # ---- prepacked weights: decode-shaped float GEMM (M small) -----------
    # serving decode re-runs the SAME weight matrix every token; packing
    # amortizes quantize+decompose, leaving activation-only work per call.
    # The skinny-M chunk schedule (scan collapsed to one step, consulted
    # from the tuning cache) is what makes packing actually WIN here --
    # the pre-overhaul prepacked row was 0.98x at this shape.
    Md, Kd, Nd = 4, 1024, 256
    xd = jax.random.normal(k1, (Md, Kd))
    wd = jax.random.normal(k2, (Kd, Nd))
    packed = jax.jit(lambda v: pack_cim_weights(v, cfg))(wd)
    f_unp = jax.jit(lambda a, b: cim_matmul(a, b, cfg, use_pallas=False))
    f_pk = jax.jit(lambda a, p: cim_matmul(a, p, cfg, use_pallas=False))
    us_unp = time_us(f_unp, xd, wd, iters=16, warmup=4, reduce="min")
    us_pk = time_us(f_pk, xd, packed, iters=16, warmup=4, reduce="min")
    assert (np.asarray(f_unp(xd, wd)) == np.asarray(f_pk(xd, packed))).all()
    emit("kern.decode_gemm_unpacked", us_unp,
         f"{Md}x{Kd}x{Nd} per-call weight conditioning (legacy)")
    emit("kern.decode_gemm_prepacked", us_pk,
         f"bit-identical; {us_unp/us_pk:.1f}x faster with packed weights")
    record("decode_gemm_unpacked", (Md, Kd, Nd), us_unp)
    record("decode_gemm_prepacked", (Md, Kd, Nd), us_pk, us_unp / us_pk,
           "vs per-call weight conditioning (bit-identical); skinny-M "
           f"chunk schedule; CI floor {DECODE_SPEEDUP_FLOOR}x")
    if us_unp / us_pk < DECODE_SPEEDUP_FLOOR:
        raise SystemExit(
            f"decode-shape prepacked regression: {us_unp / us_pk:.2f}x < "
            f"{DECODE_SPEEDUP_FLOOR}x floor at {Md}x{Kd}x{Nd} (packing "
            "must beat per-call conditioning at serving shapes)")

    # ---- horizontal fusion at decode shape: one wide GEMM vs 3 skinny ----
    # the serving hot path's QKV/gate-up collapse (models.layers): same
    # x rows, three N=256 projections fused into one N=768 call
    w3s = [jax.random.normal(k, (Kd, Nd)) for k in jax.random.split(k2, 3)]
    pk3 = [jax.jit(lambda v: pack_cim_weights(v, cfg))(w) for w in w3s]
    pk_f = jax.jit(lambda v: pack_cim_weights(v, cfg))(
        jnp.concatenate(w3s, axis=1))
    f_sep = jax.jit(lambda a, p0, p1, p2: jnp.concatenate(
        [cim_matmul(a, p0, cfg, use_pallas=False),
         cim_matmul(a, p1, cfg, use_pallas=False),
         cim_matmul(a, p2, cfg, use_pallas=False)], axis=1))
    f_fus = jax.jit(lambda a, p: cim_matmul(a, p, cfg, use_pallas=False))
    us_sep = time_us(f_sep, xd, *pk3, iters=16, warmup=4, reduce="min")
    us_fus = time_us(f_fus, xd, pk_f, iters=16, warmup=4, reduce="min")
    assert (np.asarray(f_sep(xd, *pk3))
            == np.asarray(f_fus(xd, pk_f))).all()
    emit("kern.decode_gemm_fused_qkv", us_fus,
         f"{Md}x{Kd}x{3 * Nd} fused vs 3 skinny calls "
         f"({us_sep/us_fus:.2f}x, bit-identical)")
    record("decode_gemm_3x_unfused", (Md, Kd, 3 * Nd), us_sep,
           None, "three per-projection prepacked calls (QKV-shaped)")
    record("decode_gemm_fused_qkv", (Md, Kd, 3 * Nd), us_fus,
           us_sep / us_fus, "one wide fused GEMM vs 3 skinny calls "
           "(bit-identical per segment)")

    # ---- skinny-M prepacked Pallas kernel at decode shape ----------------
    # on TPU this is a real compiled timing; elsewhere interpret mode only
    # proves bit-parity (see common.record parity_only)
    on_tpu = jax.default_backend() == "tpu"
    qxd = _rand_q(k1, (Md, Kd))
    f_sk = jax.jit(lambda a, p: cim_matmul_int(
        a, p, None, cfg, None, "fast", use_pallas=True))
    ok_sk = (np.asarray(f_sk(qxd, packed))
             == np.asarray(cim_matmul_int(qxd, packed.wq(), None, cfg, None,
                                          "fast", use_pallas=False))).all()
    if on_tpu:
        us_sk = time_us(f_sk, qxd, packed, iters=16, warmup=4, reduce="min")
        emit("kern.decode_skinny_pallas", us_sk,
             f"{Md}x{Kd}x{Nd} skinny-M prepacked kernel (compiled)")
        record("decode_skinny_pallas", (Md, Kd, Nd), us_sk, None,
               "M padded to sublane 32, planes VMEM-resident"
               + ("" if ok_sk else "; MISMATCH"))
    else:
        emit("kern.decode_skinny_pallas", 0.0,
             "interpret-mode parity: "
             + ("bit-identical" if ok_sk else "MISMATCH"))
        record("decode_skinny_pallas", (Md, Kd, Nd), None, None,
               "skinny-M prepacked kernel vs fast-GEMM reference: "
               + ("bit-identical" if ok_sk else "MISMATCH"),
               parity_only=True)
    assert ok_sk, "skinny-M prepacked kernel diverged from the reference"

    # ---- complex GEMM: matmul-ized 4-pass (new) vs broadcast 4-pass ------
    kk = jax.random.split(key, 4)
    cxr, cxi = _rand_q(kk[0], (M2, K2)), _rand_q(kk[1], (M2, K2))
    cwr, cwi = _rand_q(kk[2], (K2, N2)), _rand_q(kk[3], (K2, N2))
    f_cbcast = jax.jit(lambda a, b, c, d: complex_cim_matmul_int(
        a, b, c, d, None, cfg, None, "fast_broadcast"))
    f_cmm = jax.jit(lambda a, b, c, d: complex_cim_matmul_int(
        a, b, c, d, None, cfg, None, "fast", use_pallas=False))
    us_cb = time_us(f_cbcast, cxr, cxi, cwr, cwi, iters=2, warmup=1,
                    reduce="min")
    us_cm = time_us(f_cmm, cxr, cxi, cwr, cwi, iters=6, warmup=2,
                    reduce="min")
    emit("kern.complex_gemm_broadcast", us_cb,
         f"{M2}x{K2}x{N2} complex, 4 broadcast sub-MAC passes")
    emit("kern.complex_gemm_matmulized", us_cm,
         f"bit-identical; {us_cb/us_cm:.1f}x faster than broadcast")
    record("complex_gemm_broadcast", (M2, K2, N2), us_cb)
    record("complex_gemm_matmulized", (M2, K2, N2), us_cm, us_cb / us_cm,
           "vs broadcast 4-pass (bit-identical)")

    # ---- fused single-pass complex kernel ---------------------------------
    # TPU: compiled timing of the fused kernel vs the 4-pass GEMM.  Other
    # backends: interpret mode only proves bit-parity -- the row records
    # us=null (a 0.0 here used to read as infinite speedup).
    Mc, Kc, Nc = 16, 64, 16
    fxr, fxi = _rand_q(kk[0], (Mc, Kc)), _rand_q(kk[1], (Mc, Kc))
    fwr, fwi = _rand_q(kk[2], (Kc, Nc)), _rand_q(kk[3], (Kc, Nc))
    yr, yi = ccim_complex_matmul_int(fxr, fxi, fwr, fwi,
                                     use_pallas=True, interpret=not on_tpu)
    rr, ri = ccim_complex_matmul_ref(fxr, fxi, fwr, fwi)
    ok = (np.asarray(yr) == np.asarray(rr)).all() and (
        np.asarray(yi) == np.asarray(ri)).all()
    if on_tpu:
        f_cf = jax.jit(lambda a, b, c, d: ccim_complex_matmul_int(
            a, b, c, d, use_pallas=True))
        us_cf = time_us(f_cf, cxr, cxi, cwr, cwi, iters=8, warmup=2,
                        reduce="min")
        # parity at the TIMED shape too: 16x64x16 routes through the
        # skinny kernel, 256x1024x256 through the general multi-tile grid
        br, bi = f_cf(cxr, cxi, cwr, cwi)
        gr, gi = ccim_complex_matmul_ref(cxr, cxi, cwr, cwi)
        ok = ok and (np.asarray(br) == np.asarray(gr)).all() and (
            np.asarray(bi) == np.asarray(gi)).all()
        emit("kern.complex_fused_kernel", us_cf,
             f"{M2}x{K2}x{N2} fused Re+Im single-pass (compiled); "
             f"{us_cm/us_cf:.2f}x vs 4-pass GEMM")
        record("complex_fused_kernel", (M2, K2, N2), us_cf, us_cm / us_cf,
               "vs matmul-ized 4-pass (bit-identical)"
               + ("" if ok else "; MISMATCH"))
    else:
        emit("kern.complex_fused_parity", 0.0,
             f"fused Re+Im kernel vs 4-call ref: "
             f"{'bit-identical' if ok else 'MISMATCH'}")
        record("complex_fused_kernel", (Mc, Kc, Nc), None, None,
               "vs 4-call reference: "
               + ("bit-identical" if ok else "MISMATCH"), parity_only=True)
    assert ok, "fused complex kernel diverged from the 4-call reference"

    # ---- decode-shaped fused complex kernel (skinny-M prepacked) ---------
    Mcd, Kcd, Ncd = 4, 256, 128
    czr = jax.random.normal(kk[0], (Kcd, Ncd))
    czi = jax.random.normal(kk[1], (Kcd, Ncd))
    cpk = jax.jit(lambda a, b: pack_complex_cim_weights(a, b, cfg))(czr, czi)
    cxz = (jax.random.normal(kk[2], (Mcd, Kcd))
           + 1j * jax.random.normal(kk[3], (Mcd, Kcd))).astype(jnp.complex64)
    f_cd = jax.jit(lambda a, p: complex_cim_matmul(a, p, cfg,
                                                   use_pallas=True))
    f_cr = jax.jit(lambda a, p: complex_cim_matmul(a, p, cfg,
                                                   use_pallas=False))
    ok_cd = (np.asarray(f_cd(cxz, cpk)) == np.asarray(f_cr(cxz, cpk))).all()
    if on_tpu:
        us_cd = time_us(f_cd, cxz, cpk, iters=16, warmup=4, reduce="min")
        # A/B against the 4-pass prepacked GEMM at the SAME decode shape:
        # the real-valued skinny row above has had this ratio since PR 5,
        # the complex twin only recorded raw us
        us_cr = time_us(f_cr, cxz, cpk, iters=16, warmup=4, reduce="min")
        emit("kern.decode_complex_fused_prepacked", us_cd,
             f"{Mcd}x{Kcd}x{Ncd} skinny-M fused complex (compiled); "
             f"{us_cr/us_cd:.2f}x vs 4-pass prepacked GEMM")
        record("decode_complex_fused_prepacked", (Mcd, Kcd, Ncd), us_cd,
               us_cr / us_cd, "vs 4-pass prepacked GEMM at decode shape "
               "(bit-identical)" + ("" if ok_cd else "; MISMATCH"))
    else:
        emit("kern.decode_complex_fused_prepacked", 0.0,
             "interpret-mode parity: "
             + ("bit-identical" if ok_cd else "MISMATCH"))
        record("decode_complex_fused_prepacked", (Mcd, Kcd, Ncd), None,
               None, "skinny-M prepacked fused complex kernel vs 4-pass "
               "reference: " + ("bit-identical" if ok_cd else "MISMATCH"),
               parity_only=True)
    assert ok_cd, "skinny fused complex kernel diverged from the reference"

    qx = _rand_q(k1, (M, K)).astype(jnp.int8)
    qw = _rand_q(k2, (K, N)).astype(jnp.int8)
    f_ref = jax.jit(ccim_matmul_ref)
    us_ref = time_us(f_ref, qx, qw, iters=3)
    emit("kern.ccim_ref_oracle", us_ref, f"{M}x{K}x{N} int GEMM (jnp oracle)")
    record("ccim_ref_oracle", (M, K, N), us_ref)
    f_i8 = jax.jit(lambda a, b: int8_matmul(a, b, use_pallas=False))
    us_i8 = time_us(f_i8, x, w, iters=3)
    emit("kern.int8_w8a8", us_i8, "all-digital CIM baseline [11] numerics")
    record("int8_w8a8", (M, K, N), us_i8)

    path = write_bench_json()
    print(f"# wrote {path}")


if __name__ == "__main__":
    run()
