"""Kernel micro-benchmarks (interpret-mode correctness + host timing) and
the fast-vs-bit-true emulation fidelity/speed trade (the TPU adaptation:
2 matmuls instead of 49 bit-plane products -- see DESIGN.md §2)."""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_us
from repro.core import DEFAULT_CONFIG, cim_matmul, fabricate
from repro.kernels.ccim_matmul import ccim_matmul_ref
from repro.kernels.int8_matmul import int8_matmul


def run(seed: int = 0):
    cfg = DEFAULT_CONFIG
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    M, K, N = 64, 512, 64
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    macro = fabricate(key, cfg)

    f_bit = jax.jit(lambda a, b: cim_matmul(a, b, cfg, noise_key=key,
                                            macro=macro, fidelity="bit_true"))
    f_fast = jax.jit(lambda a, b: cim_matmul(a, b, cfg, noise_key=key,
                                             fidelity="fast"))
    us_bit = time_us(f_bit, x, w, iters=2, warmup=1)
    us_fast = time_us(f_fast, x, w, iters=2, warmup=1)
    y_bit, y_fast = f_bit(x, w), f_fast(x, w)
    ref = x @ w
    fs = float(jnp.abs(x).max() * jnp.abs(w).max() * K)
    emit("kern.bit_true_emulation", us_bit,
         f"max FS-rel err {float(jnp.abs(y_bit-ref).max())/fs:.4f}")
    emit("kern.fast_emulation", us_fast,
         f"max FS-rel err {float(jnp.abs(y_fast-ref).max())/fs:.4f}; "
         f"{us_bit/us_fast:.1f}x faster than bit-true (2 vs 49 matmuls)")

    qx = jax.random.randint(k1, (M, K), -127, 128).clip(-127, 127).astype(jnp.int8)
    qw = jax.random.randint(k2, (K, N), -127, 128).clip(-127, 127).astype(jnp.int8)
    f_ref = jax.jit(ccim_matmul_ref)
    us_ref = time_us(f_ref, qx, qw, iters=3)
    emit("kern.ccim_ref_oracle", us_ref, f"{M}x{K}x{N} int GEMM (jnp oracle)")
    f_i8 = jax.jit(lambda a, b: int8_matmul(a, b, use_pallas=False))
    us_i8 = time_us(f_i8, x, w, iters=3)
    emit("kern.int8_w8a8", us_i8, "all-digital CIM baseline [11] numerics")


if __name__ == "__main__":
    run()
