"""Serving benchmark: prepacked-weight CIM decode vs the legacy per-call
weight-conditioning path (and the fp/bf16 reference), the
continuous-batching scheduler vs the lock-step loop on a mixed-length
workload, and plan-cascade speculative decoding (analog draft / deployed
verify from one packed weight set), written to BENCH_serve.json for the
per-PR perf trajectory.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke

Measures pure-execution decode tok/s and prefill time (serve AOT-compiles
both steps, so jit compile never pollutes a throughput number) plus the
one-time pack cost.  The packed and unpacked CIM runs must emit
bit-identical tokens: packing is a caching transform of the weight
conditioning, not an approximation -- the benchmark asserts this before
recording any number.

Every serve-level RATIO is computed from the per-variant MEDIAN of
``repeats`` runs, not a single draw: at smoke scale host scheduler noise
swings single-run tok/s by 10-30%, which once produced a committed
fusion speedup of 1.02x while the kernel benchmark showed 1.31x for the
same fused shape.  Each row records the median and the raw per-run
values so the spread is visible in the JSON.

The continuous-batching rows (fp, packed-CIM, and a packed-unfused A/B)
report aggregate tok/s, slot occupancy and p50/p95 request latency for a
mixed-length queue against the lock-step wave baseline running on the
SAME compiled executables; serve_continuous asserts per-request tokens
are bit-identical between the two plans.

The speculative section is the acceptance-vs-D/A-split study: the draft
plan is the all-analog shadow of the serving plan (same packed weights),
and narrowing its SAR below the no-clip width drafts faster but clips
large accumulates, so the verify pass rejects more.  Each sweep point
records acceptance rate, tokens per scheduler step and tok/s; the
headline row is the serve-level lock-step driver, whose greedy tokens
are asserted bit-identical to the non-speculative baseline.
"""
import argparse
import json
import os
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

# absolute floor for the serve-level speculative/non-speculative decode
# ratio (the PR's acceptance gate), checked in addition to the committed-
# baseline-relative tolerance
_SPEC_SPEEDUP_FLOOR = 1.5
# the committed sweep point is the conservative no-clip draft; acceptance
# may not drop more than this (absolute) below the committed value
_ACCEPTANCE_SLACK = 0.05


def _median_rate(row: dict) -> float:
    """Median decode rate of a bench row (old baselines lack the field)."""
    return row.get("decode_tok_s_median", row.get("decode_tok_s", 0.0))


def check_regression(new: dict, baseline_path: str,
                     tolerance: float = 0.10) -> None:
    """CI gate: fail if a serving hot path regressed vs the committed
    BENCH_serve.json baseline.

    All gates compare RATIOS, not raw tok/s: CI machines are not the
    machine the baseline was committed on, and absolute tok/s comparisons
    across hosts would gate on hardware, not code.  Ratios cancel host
    speed (both sides run in the same process on the same box) and still
    catch exactly what matters -- one path losing ground relative to
    another.  Three gates:

      packed/fp decode ratio   >= (1 - tolerance) * committed ratio
      speculative speedup      >= max(_SPEC_SPEEDUP_FLOOR,
                                      (1 - tolerance) * committed)
      acceptance rate          >= committed - _ACCEPTANCE_SLACK on the
                                 conservative sweep point (acceptance is
                                 a pure function of the plan cascade, not
                                 host speed, so it gets an absolute gate)
    """
    try:
        with open(baseline_path) as f:
            base = json.load(f)
        base_ratio = (_median_rate(base["cim_packed"])
                      / _median_rate(base["fp"]))
    except (OSError, KeyError, ValueError, ZeroDivisionError):
        print("# no usable baseline -- regression gate skipped")
        return
    new_ratio = (_median_rate(new["cim_packed"])
                 / _median_rate(new["fp"]))
    print(f"# regression gate: packed/fp decode ratio {new_ratio:.3f} "
          f"(baseline {base_ratio:.3f}, tolerance -{tolerance:.0%})")
    if new_ratio < (1.0 - tolerance) * base_ratio:
        raise SystemExit(
            f"cim_packed decode regressed: packed/fp ratio {new_ratio:.3f} "
            f"is >{tolerance:.0%} below the committed baseline "
            f"{base_ratio:.3f} ({baseline_path})")

    spec = new.get("speculative", {})
    base_spec = base.get("speculative", {})
    speedup = spec.get("serve_level", {}).get("decode_speedup_speculative")
    if speedup is not None:
        floor = _SPEC_SPEEDUP_FLOOR
        committed = base_spec.get("serve_level", {}).get(
            "decode_speedup_speculative")
        if committed:
            floor = max(floor, (1.0 - tolerance) * committed)
        print(f"# regression gate: speculative decode speedup "
              f"{speedup:.2f}x (floor {floor:.2f}x)")
        if speedup < floor:
            raise SystemExit(
                f"speculative decode speedup {speedup:.2f}x fell below the "
                f"floor {floor:.2f}x (absolute {_SPEC_SPEEDUP_FLOOR}x / "
                f"committed-relative)")
        acc = spec.get("sweep", [{}])[0].get("acceptance_rate")
        base_acc = base_spec.get("sweep", [{}])[0].get("acceptance_rate")
        if acc is not None and base_acc is not None:
            print(f"# regression gate: conservative-draft acceptance "
                  f"{acc:.3f} (committed {base_acc:.3f}, "
                  f"slack {_ACCEPTANCE_SLACK})")
            if acc < base_acc - _ACCEPTANCE_SLACK:
                raise SystemExit(
                    f"draft acceptance on the conservative sweep point "
                    f"dropped to {acc:.3f} (committed {base_acc:.3f}): the "
                    f"plan cascade got lossier without a plan change")


def run(arch: str = "minicpm-2b", smoke: bool = True, batch: int = 2,
        prompt_len: int = 16, gen: int = 48, repeats: int = 3,
        draft_k: int = 8, path: str = _BENCH_JSON, gate: bool = False) -> dict:
    from repro.launch.serve import serve, serve_continuous, serve_speculative

    def measure(cim: bool, pack: bool, fuse: bool = True):
        """Median-of-repeats decode rate; tokens asserted deterministic."""
        runs = [serve(arch, smoke=smoke, batch=batch, prompt_len=prompt_len,
                      gen=gen, cim=cim, pack=pack, fuse=fuse,
                      return_stats=True)
                for _ in range(repeats)]
        toks = runs[0][0]
        for t, _ in runs[1:]:
            assert (t == toks).all(), "greedy serving must be deterministic"
        rates = sorted(s["decode_tok_s"] for _, s in runs)
        stats = max((s for _, s in runs), key=lambda s: s["decode_tok_s"])
        stats = dict(stats, decode_tok_s_median=statistics.median(rates),
                     decode_tok_s_runs=rates)
        return toks, stats

    _, fp = measure(cim=False, pack=False)
    tok_u, unpacked = measure(cim=True, pack=False, fuse=False)
    tok_p, packed = measure(cim=True, pack=True)
    assert (tok_u == tok_p).all(), \
        "packed+fused CIM serving diverged from the unpacked unfused path"
    # fusion A/B on the same packed weights: tokens must also be identical
    tok_nf, packed_unfused = measure(cim=True, pack=True, fuse=False)
    assert (tok_nf == tok_p).all(), \
        "fused serving changed tokens vs the unfused packed path"

    # all ratios from the per-variant medians (single draws at smoke scale
    # are dominated by host scheduler noise, not the code under test)
    pack_speedup = (packed_unfused["decode_tok_s_median"]
                    / unpacked["decode_tok_s_median"])
    fusion_speedup = (packed["decode_tok_s_median"]
                      / packed_unfused["decode_tok_s_median"])
    total_speedup = (packed["decode_tok_s_median"]
                     / unpacked["decode_tok_s_median"])

    # continuous batching vs lock-step on a mixed-length queue; token
    # parity with the lock-step plan is asserted inside serve_continuous.
    # The packed_unfused row is the fusion A/B at the continuous-batching
    # level (same scheduler, cfg.cim_fuse off).
    cb = {}
    cb_tokens = {}
    cb_repeats = max(repeats, 3)
    for mode, cim, fuse in (("fp", False, True), ("cim_packed", True, True),
                            ("cim_packed_unfused", True, False)):
        toks, st = serve_continuous(arch, smoke=smoke, slots=batch,
                                    prompt_len=prompt_len,
                                    n_requests=4 * batch,
                                    stop_lengths=(4, 16, 8, 12), cim=cim,
                                    pack=cim, fuse=fuse, repeats=cb_repeats)
        cb_tokens[mode] = toks
        cb[mode] = dict(continuous=st["continuous"], lockstep=st["lockstep"],
                        tok_s_median=st["tok_s_median"],
                        lockstep_tok_s_median=st["lockstep_tok_s_median"],
                        tokens_match_lockstep=st["tokens_match_lockstep"],
                        speedup_vs_lockstep=st["speedup_vs_lockstep"])
    for rid, want in cb_tokens["cim_packed"].items():
        np.testing.assert_array_equal(
            cb_tokens["cim_packed_unfused"][rid], want,
            err_msg=f"request {rid}: fusion changed continuous-batching "
                    "tokens")
    cb["fusion_speedup"] = round(
        cb["cim_packed"]["tok_s_median"]
        / cb["cim_packed_unfused"]["tok_s_median"], 2)
    cb["fused_tokens_bit_identical"] = True

    # --- plan-cascade speculative decoding -------------------------------
    # Headline: the serve-level lock-step driver (one AOT dispatch per
    # draft/verify round); greedy tokens asserted bit-identical to the
    # non-speculative baseline inside serve_speculative.  Median-of-repeats
    # on both sides of the ratio.
    spec_runs = [serve_speculative(arch, smoke=smoke, batch=batch,
                                   prompt_len=prompt_len, gen=gen,
                                   draft_k=draft_k, return_stats=True)[1]
                 for _ in range(repeats)]
    spec_med = statistics.median(s["decode_tok_s"] for s in spec_runs)
    base_med = statistics.median(s["baseline_decode_tok_s"]
                                 for s in spec_runs)
    serve_level = dict(
        spec_runs[0], decode_tok_s_median=round(spec_med, 2),
        baseline_decode_tok_s_median=round(base_med, 2),
        decode_speedup_speculative=round(spec_med / base_med, 2))

    # Acceptance-vs-D/A-split sweep through the continuous-batching
    # scheduler: the draft plan's SAR width is the aggressiveness axis
    # (None = per-entry no-clip width; narrower widths clip large analog
    # accumulates, so verify rejects more and tokens/step shrinks).
    nonspec_med = cb["cim_packed"]["tok_s_median"]
    sweep = []
    for bits in (None, 7, 6, 5):
        _, st = serve_continuous(arch, smoke=smoke, slots=batch,
                                 prompt_len=prompt_len,
                                 n_requests=4 * batch,
                                 stop_lengths=(4, 16, 8, 12), cim=True,
                                 pack=True, draft_k=draft_k,
                                 draft_adc_bits=bits, repeats=cb_repeats)
        cont = st["continuous"]
        sweep.append(dict(
            draft_plan=st["draft_plan"], draft_k=draft_k,
            acceptance_rate=cont["acceptance_rate"],
            tokens_per_step=cont["tokens_per_step"],
            tok_s_median=st["tok_s_median"],
            speedup_vs_nonspec_cb=round(st["tok_s_median"] / nonspec_med, 2),
            tokens_match_lockstep=st["tokens_match_lockstep"]))

    result = dict(
        config=dict(arch=arch, smoke=smoke, batch=batch,
                    prompt_len=prompt_len, gen=gen, repeats=repeats,
                    draft_k=draft_k),
        fp=fp,
        cim_unpacked=unpacked,          # pre-refactor baseline dataflow
        cim_packed_unfused=packed_unfused,   # packing alone, no fusion
        cim_packed=packed,              # packed + fused + tuned (hot path)
        packed_tokens_bit_identical=True,
        fused_tokens_bit_identical=True,
        decode_speedup_packed_vs_unpacked=round(pack_speedup, 2),
        decode_speedup_fusion=round(fusion_speedup, 2),
        decode_speedup_vs_prerefactor=round(total_speedup, 2),
        continuous_batching=cb,
        speculative=dict(serve_level=serve_level, sweep=sweep),
    )
    if gate:
        check_regression(result, path)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# decode tok/s (median of {repeats}): "
          f"fp {fp['decode_tok_s_median']:.1f}, "
          f"cim unpacked {unpacked['decode_tok_s_median']:.1f}, "
          f"cim packed {packed['decode_tok_s_median']:.1f} "
          f"({total_speedup:.2f}x total: {pack_speedup:.2f}x packing, "
          f"{fusion_speedup:.2f}x fusion; pack cost {packed['pack_s']}s)")
    for mode in ("fp", "cim_packed", "cim_packed_unfused"):
        row = cb[mode]
        print(f"# continuous batching ({mode}): "
              f"{row['tok_s_median']} tok/s (median) at "
              f"{row['continuous']['occupancy']:.0%} occupancy vs lock-step "
              f"{row['lockstep_tok_s_median']} ({row['speedup_vs_lockstep']}x,"
              f" tokens identical)")
    print(f"# cb fusion speedup (median): {cb['fusion_speedup']}x")
    print(f"# speculative (serve-level, k={draft_k}): "
          f"{serve_level['decode_tok_s_median']} tok/s vs baseline "
          f"{serve_level['baseline_decode_tok_s_median']} "
          f"({serve_level['decode_speedup_speculative']}x, acceptance "
          f"{serve_level['acceptance_rate']:.0%}, tokens identical)")
    for pt in sweep:
        print(f"# speculative sweep {pt['draft_plan']}: acceptance "
              f"{pt['acceptance_rate']:.2f}, {pt['tokens_per_step']} tok/step,"
              f" {pt['tok_s_median']} tok/s "
              f"({pt['speedup_vs_nonspec_cb']}x vs non-spec cb)")
    print(f"# wrote {path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--draft-k", type=int, default=8,
                    help="draft block length for the speculative rows")
    ap.add_argument("--check-regression", dest="gate", action="store_true",
                    help="fail if packed decode regressed >10%% vs the "
                         "committed BENCH_serve.json (packed/fp ratio), the "
                         "speculative speedup fell below its floor, or "
                         "draft acceptance dropped on the committed sweep "
                         "point")
    args = ap.parse_args()
    run(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
        args.repeats, args.draft_k, gate=args.gate)


if __name__ == "__main__":
    main()
