"""Serving benchmark: prepacked-weight CIM decode vs the legacy per-call
weight-conditioning path (and the fp/bf16 reference), the
continuous-batching scheduler vs the lock-step loop on a mixed-length
workload, and plan-cascade speculative decoding (analog draft / deployed
verify from one packed weight set), written to BENCH_serve.json for the
per-PR perf trajectory.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke

Measures pure-execution decode tok/s and prefill time (serve AOT-compiles
both steps, so jit compile never pollutes a throughput number) plus the
one-time pack cost.  The packed and unpacked CIM runs must emit
bit-identical tokens: packing is a caching transform of the weight
conditioning, not an approximation -- the benchmark asserts this before
recording any number.

Every serve-level RATIO is computed from the per-variant MEDIAN of
``repeats`` runs, not a single draw: at smoke scale host scheduler noise
swings single-run tok/s by 10-30%, which once produced a committed
fusion speedup of 1.02x while the kernel benchmark showed 1.31x for the
same fused shape.  Each row records the median and the raw per-run
values so the spread is visible in the JSON.

The continuous-batching rows (fp, packed-CIM, and a packed-unfused A/B)
report aggregate tok/s, slot occupancy and p50/p95 request latency for a
mixed-length queue against the lock-step wave baseline running on the
SAME compiled executables; serve_continuous asserts per-request tokens
are bit-identical between the two plans.

The speculative section is the acceptance-vs-D/A-split study: the draft
plan is the all-analog shadow of the serving plan (same packed weights),
and narrowing its SAR below the no-clip width drafts faster but clips
large accumulates, so the verify pass rejects more.  Each sweep point
records acceptance rate, tokens per scheduler step and tok/s; the
headline row is the serve-level lock-step driver, whose greedy tokens
are asserted bit-identical to the non-speculative baseline.
"""
import argparse
import json
import os
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

# absolute floor for the serve-level speculative/non-speculative decode
# ratio (the PR's acceptance gate), checked in addition to the committed-
# baseline-relative tolerance
_SPEC_SPEEDUP_FLOOR = 1.5
# the committed sweep point is the conservative no-clip draft; acceptance
# may not drop more than this (absolute) below the committed value
_ACCEPTANCE_SLACK = 0.05
# multi-tenant paged-KV acceptance gate: the paged scheduler must either
# beat the contiguous pool on throughput outright, or shrink peak
# resident KV bytes at near-iso throughput (both ratios are same-host,
# same-process, so they cancel machine speed)
_PAGED_TOK_S_FLOOR = 1.3
_PAGED_KV_REDUCTION_FLOOR = 2.0
# near-iso throughput bar for the KV-reduction arm of the gate: the
# measured paged/contiguous ratio at smoke scale swings 0.9-1.4x run to
# run on a noisy host (median ~1.0), so the floor sits below the
# observed spread rather than on top of it
_PAGED_ISO_TOK_S = 0.8
# chunked prefill bounds per-iteration admission work, so the paged
# pool's worst-iteration/median-decode-step stall factor may not exceed
# the contiguous pool's (whole-prompt admits) by more than this slack
_STALL_RATIO_SLACK = 1.25
# device-resident telemetry overhead gate: the metrics-on serve loop may
# not fall below this fraction of the metrics-off throughput (best of
# interleaved warm repeats, same process/host so the ratio cancels
# machine speed and one-sided scheduler noise)
_OBS_OVERHEAD_FLOOR = 0.95
# Prometheus exposition written next to BENCH_serve.json (uploaded as a
# CI artifact)
_METRICS_PROM = os.path.join(os.path.dirname(__file__), "..",
                             "OBS_metrics.prom")


def _median_rate(row: dict) -> float:
    """Median decode rate of a bench row (old baselines lack the field)."""
    return row.get("decode_tok_s_median", row.get("decode_tok_s", 0.0))


def check_regression(new: dict, baseline_path: str,
                     tolerance: float = 0.10) -> None:
    """CI gate: fail if a serving hot path regressed vs the committed
    BENCH_serve.json baseline.

    All gates compare RATIOS, not raw tok/s: CI machines are not the
    machine the baseline was committed on, and absolute tok/s comparisons
    across hosts would gate on hardware, not code.  Ratios cancel host
    speed (both sides run in the same process on the same box) and still
    catch exactly what matters -- one path losing ground relative to
    another.  Three gates:

      packed/fp decode ratio   >= (1 - tolerance) * committed ratio
      speculative speedup      >= max(_SPEC_SPEEDUP_FLOOR,
                                      (1 - tolerance) * committed)
      acceptance rate          >= committed - _ACCEPTANCE_SLACK on the
                                 conservative sweep point (acceptance is
                                 a pure function of the plan cascade, not
                                 host speed, so it gets an absolute gate)
    """
    try:
        with open(baseline_path) as f:
            base = json.load(f)
        base_ratio = (_median_rate(base["cim_packed"])
                      / _median_rate(base["fp"]))
    except (OSError, KeyError, ValueError, ZeroDivisionError):
        print("# no usable baseline -- regression gate skipped")
        return
    new_ratio = (_median_rate(new["cim_packed"])
                 / _median_rate(new["fp"]))
    print(f"# regression gate: packed/fp decode ratio {new_ratio:.3f} "
          f"(baseline {base_ratio:.3f}, tolerance -{tolerance:.0%})")
    if new_ratio < (1.0 - tolerance) * base_ratio:
        raise SystemExit(
            f"cim_packed decode regressed: packed/fp ratio {new_ratio:.3f} "
            f"is >{tolerance:.0%} below the committed baseline "
            f"{base_ratio:.3f} ({baseline_path})")

    spec = new.get("speculative", {})
    base_spec = base.get("speculative", {})
    speedup = spec.get("serve_level", {}).get("decode_speedup_speculative")
    if speedup is not None:
        floor = _SPEC_SPEEDUP_FLOOR
        committed = base_spec.get("serve_level", {}).get(
            "decode_speedup_speculative")
        if committed:
            floor = max(floor, (1.0 - tolerance) * committed)
        print(f"# regression gate: speculative decode speedup "
              f"{speedup:.2f}x (floor {floor:.2f}x)")
        if speedup < floor:
            raise SystemExit(
                f"speculative decode speedup {speedup:.2f}x fell below the "
                f"floor {floor:.2f}x (absolute {_SPEC_SPEEDUP_FLOOR}x / "
                f"committed-relative)")
        acc = spec.get("sweep", [{}])[0].get("acceptance_rate")
        base_acc = base_spec.get("sweep", [{}])[0].get("acceptance_rate")
        if acc is not None and base_acc is not None:
            print(f"# regression gate: conservative-draft acceptance "
                  f"{acc:.3f} (committed {base_acc:.3f}, "
                  f"slack {_ACCEPTANCE_SLACK})")
            if acc < base_acc - _ACCEPTANCE_SLACK:
                raise SystemExit(
                    f"draft acceptance on the conservative sweep point "
                    f"dropped to {acc:.3f} (committed {base_acc:.3f}): the "
                    f"plan cascade got lossier without a plan change")

    mt = new.get("multi_tenant")
    if mt is not None:
        if not mt.get("token_parity"):
            raise SystemExit("multi-tenant paged token parity failed")
        r_tok, r_kv = mt["paged_vs_contiguous_tok_s"], mt["kv_reduction"]
        print(f"# regression gate: paged/contiguous tok/s {r_tok:.2f}x, "
              f"peak-KV reduction {r_kv:.2f}x (need >= "
              f"{_PAGED_TOK_S_FLOOR}x tok/s OR >= "
              f"{_PAGED_KV_REDUCTION_FLOOR}x KV at >= "
              f"{_PAGED_ISO_TOK_S}x tok/s)")
        if not (r_tok >= _PAGED_TOK_S_FLOOR
                or (r_kv >= _PAGED_KV_REDUCTION_FLOOR
                    and r_tok >= _PAGED_ISO_TOK_S)):
            raise SystemExit(
                f"paged KV pool misses its acceptance gate: "
                f"{r_tok:.2f}x tok/s, {r_kv:.2f}x KV reduction")
        s_pg = mt["paged"]["stall_factor"]
        s_ct = mt["contiguous"]["stall_factor"]
        print(f"# regression gate: admission stall factor paged "
              f"{s_pg:.2f} vs contiguous {s_ct:.2f} "
              f"(slack {_STALL_RATIO_SLACK}x)")
        if s_pg > _STALL_RATIO_SLACK * s_ct:
            raise SystemExit(
                f"chunked prefill stopped bounding admission stalls: "
                f"paged worst-iteration factor {s_pg:.2f} > "
                f"{_STALL_RATIO_SLACK}x contiguous {s_ct:.2f}")
        base_mt = base.get("multi_tenant")
        if base_mt is not None:
            # committed-relative gates: kv_reduction is deterministic
            # (pure block accounting) so it always gets one; the tok/s
            # ratio only when the committed win is throughput-mode --
            # in KV-reduction mode the absolute near-iso bar above
            # already governs it and a committed 0.95x would otherwise
            # ratchet a noise floor into the gate
            keys = ["kv_reduction"]
            if (base_mt.get("paged_vs_contiguous_tok_s") or 0) \
                    >= _PAGED_TOK_S_FLOOR:
                keys.append("paged_vs_contiguous_tok_s")
            for key in keys:
                commit = base_mt.get(key)
                if commit and mt[key] < (1.0 - tolerance) * commit:
                    raise SystemExit(
                        f"multi-tenant {key} regressed: {mt[key]:.2f} is "
                        f">{tolerance:.0%} below committed {commit:.2f}")

    tel = new.get("telemetry")
    if tel is not None:
        from repro.obs import host_matches
        ratio = tel.get("on_off_tok_s_ratio")
        print(f"# regression gate: telemetry on/off tok/s ratio "
              f"{ratio:.3f} (floor {_OBS_OVERHEAD_FLOOR})")
        if ratio is not None and ratio < _OBS_OVERHEAD_FLOOR:
            raise SystemExit(
                f"device-resident telemetry overhead blew its budget: "
                f"metrics-on throughput is {ratio:.3f}x metrics-off "
                f"(floor {_OBS_OVERHEAD_FLOOR}x)")
        base_tel = base.get("telemetry")
        base_fps = (base_tel or {}).get("fingerprints_metrics_off", {})
        if base_fps and host_matches(tel.get("host"),
                                     (base_tel or {}).get("host")):
            moved = {k: (v, tel["fingerprints_metrics_off"].get(k))
                     for k, v in base_fps.items()
                     if tel["fingerprints_metrics_off"].get(k) != v}
            print(f"# regression gate: metrics-off HLO fingerprints "
                  f"{'MOVED: ' + str(sorted(moved)) if moved else 'stable'} "
                  f"({len(base_fps)} variants, host-matched)")
            if moved:
                raise SystemExit(
                    f"metrics-off serve loop stopped lowering "
                    f"byte-identically on a matching host -- some code "
                    f"path now pays for telemetry while it is off: "
                    f"{moved}")
        elif base_fps:
            print("# regression gate: metrics-off HLO fingerprints "
                  "skipped (baseline host differs -- StableHLO is only "
                  "comparable for a fixed backend/jax version)")


def multi_tenant_trace(n_requests: int, max_prompt: int, vocab: int,
                       block_size: int, n_tenants: int = 3, seed: int = 0,
                       arrival_rate: float = 0.5):
    """Open-loop multi-tenant workload: ``n_tenants`` tenants each with a
    shared system prompt (a block-aligned prefix, so the paged scheduler
    can deduplicate it), per-request tails of mixed length, per-request
    decode budgets, and Poisson arrivals (exponential inter-arrival times
    in scheduler-iteration units).  Prompts top out at ``max_prompt`` --
    the service's STATIC context limit is larger (run_multi_tenant's
    ``prompt_len``), which is the realistic serving shape: a contiguous
    pool must reserve and prefill the context limit for every slot, while
    actual traffic is mostly chat-sized with one long-prompt stressor
    (request 0).  Returns (requests, arrival_iters)."""
    import numpy as np
    from repro.launch.scheduler import Request

    rng = np.random.default_rng(seed)
    pre_blocks = [3, 1, 2, 4, 2, 3][:n_tenants]
    prefixes = [rng.integers(0, vocab, nb * block_size, dtype=np.int32)
                for nb in pre_blocks]
    reqs, arrivals = [], []
    t = 0.0
    for i in range(n_requests):
        tenant = i % n_tenants            # round-robin keeps tenants mixed
        pre = prefixes[tenant]
        if i == 0:                        # one long-prompt request: the
            tail = max_prompt - len(pre)  # admission-stall stressor
        else:                             # the rest are chat-sized
            tail = int(rng.integers(
                1, min(max_prompt - len(pre), 3 * block_size) + 1))
        prompt = np.concatenate(
            [pre, rng.integers(0, vocab, tail, dtype=np.int32)])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(4, 13))))
        arrivals.append(int(t))
        t += rng.exponential(1.0 / arrival_rate)
    return reqs, arrivals


def run_multi_tenant(arch: str = "minicpm-2b", smoke: bool = True,
                     slots: int = 3, prompt_len: int = 128,
                     max_prompt: int = 64, n_requests: int = 10,
                     block_size: int = 8, prefill_chunk: int = 16,
                     repeats: int = 3, seed: int = 0) -> dict:
    """Paged vs contiguous KV on the multi-tenant trace.

    Three runs of the SAME workload: the contiguous pool (prompts padded
    to the static length -- all a contiguous layout can do with mixed
    lengths), the paged pool single-shot without sharing (the parity
    reference), and the paged pool with chunked prefill + shared-prefix
    reuse (the candidate).  Token parity between the two paged runs is
    asserted bit-exactly; throughput comes from the pure device loop
    (median of ``repeats``) and latency structure (TTFT, per-iteration
    stall factor) from the instrumented runner stepping the identical
    compiled iteration."""
    import statistics as _stats

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.launch.paging import PagedLayout, cdiv
    from repro.launch.scheduler import ContinuousBatchingScheduler, Request
    from repro.models import lm

    cfg = get_config(arch, smoke=smoke)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    cap = 12
    reqs, arrivals = multi_tenant_trace(n_requests, max_prompt,
                                        cfg.vocab_size, block_size,
                                        seed=seed)
    # the contiguous pool can only serve mixed lengths by padding every
    # prompt to the static context limit -- full-length prefill AND
    # full-length KV reservation per slot
    padded = [Request(rid=r.rid,
                      prompt=np.concatenate(
                          [r.prompt, np.zeros(prompt_len - len(r.prompt),
                                              np.int32)]),
                      max_new_tokens=r.max_new_tokens,
                      stop_token=r.stop_token) for r in reqs]
    n_tbl = cdiv(prompt_len + cap, block_size)
    lay = PagedLayout(block_size=block_size, n_tbl=n_tbl,
                      n_blocks=2 * slots * cdiv(max_prompt + cap,
                                                block_size) + 8)

    contig = ContinuousBatchingScheduler(
        params, cfg, slots=slots, prompt_len=prompt_len, max_new_cap=cap,
        seed=seed)
    paged = ContinuousBatchingScheduler(
        params, cfg, slots=slots, prompt_len=prompt_len, max_new_cap=cap,
        seed=seed, paged=lay, prefill_chunk=prefill_chunk,
        prefix_sharing=True)
    paged_ref = ContinuousBatchingScheduler(
        params, cfg, slots=slots, prompt_len=prompt_len, max_new_cap=cap,
        seed=seed, paged=lay, prefix_sharing=False)

    # bit-exact parity: chunked + prefix-shared vs single-shot unshared
    want = paged_ref.run(reqs).tokens_by_rid()
    got = paged.run(reqs).tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"request {rid}: chunked/shared paged prefill changed "
                    "tokens vs single-shot paged")

    pg_runs = [paged.run(reqs, arrivals) for _ in range(repeats)]
    ct_runs = [contig.run(padded, arrivals) for _ in range(repeats)]
    pg_tok = _stats.median(r.tok_s for r in pg_runs)
    ct_tok = _stats.median(r.tok_s for r in ct_runs)
    pg_rep, pg_tl = paged.run_instrumented(reqs, arrivals)
    ct_rep, ct_tl = contig.run_instrumented(padded, arrivals)

    def stall(timeline, step_branch):
        """Worst-iteration / median-decode-step duration ratio: how long
        the slowest single iteration (a long-prompt admit, in the
        contiguous pool) starves every live decoder."""
        it = timeline["iter_s"]
        steps = it[timeline["branch"] == step_branch]
        med = float(np.median(steps)) if steps.size else float("nan")
        p95 = float(np.percentile(it, 95))
        return p95 / med if med and med > 0 else float("nan")

    peak = max(r.peak_blocks for r in pg_runs + [pg_rep])
    kv_paged = paged.kv_bytes_paged(peak)
    kv_contig = contig.kv_bytes_contiguous()
    out = dict(
        config=dict(arch=arch, slots=slots, prompt_len=prompt_len,
                    max_prompt=max_prompt, n_requests=n_requests,
                    block_size=block_size, n_blocks=lay.n_blocks,
                    prefill_chunk=prefill_chunk, repeats=repeats),
        token_parity=True,
        paged=dict(pg_rep.summary(), tok_s_median=round(pg_tok, 2),
                   **{k: round(v, 4) for k, v in
                      pg_rep.ttft_percentiles().items()},
                   stall_factor=round(stall(pg_tl, 3), 2)),
        contiguous=dict(ct_rep.summary(), tok_s_median=round(ct_tok, 2),
                        **{k: round(v, 4) for k, v in
                           ct_rep.ttft_percentiles().items()},
                        stall_factor=round(stall(ct_tl, 2), 2)),
        paged_vs_contiguous_tok_s=round(pg_tok / ct_tok, 2) if ct_tok
        else float("nan"),
        kv_bytes_paged_peak=kv_paged,
        kv_bytes_contiguous=kv_contig,
        kv_reduction=round(kv_contig / kv_paged, 2) if kv_paged
        else float("nan"),
    )
    print(f"# multi-tenant ({arch}, {n_requests} reqs, {slots} slots, "
          f"P<={prompt_len}): paged {pg_tok:.1f} tok/s vs contiguous "
          f"{ct_tok:.1f} ({out['paged_vs_contiguous_tok_s']}x), KV "
          f"{kv_paged / 1024:.0f}KiB peak vs {kv_contig / 1024:.0f}KiB "
          f"({out['kv_reduction']}x smaller), ttft p95 "
          f"{out['paged']['ttft_p95_s']}s vs {out['contiguous']['ttft_p95_s']}s,"
          f" stall {out['paged']['stall_factor']} vs "
          f"{out['contiguous']['stall_factor']}")
    return out


def run_telemetry(arch: str = "minicpm-2b", smoke: bool = True,
                  slots: int = 3, prompt_len: int = 128,
                  max_prompt: int = 64, n_requests: int = 10,
                  block_size: int = 8, prefill_chunk: int = 16,
                  repeats: int = 3, seed: int = 0,
                  prom_path: str = _METRICS_PROM) -> dict:
    """Device-resident telemetry section: zero-overhead-when-off proof.

    Runs the multi-tenant Poisson trace through the SAME paged scheduler
    twice -- metrics off and metrics on -- and records (a) the sha256
    StableHLO fingerprints of all three metrics-OFF serve-loop variants
    (the byte-identity artifact --check-regression gates on), (b) the
    on/off throughput ratio (the <=5%% overhead budget), (c) bit-exact
    token parity, and (d) the ring-derived TTFT against the instrumented
    runner's host-observed first_iter -- the rings must not merely look
    plausible, they must agree exactly with the per-iteration ground
    truth.  The metrics-on run's registry snapshot is exported as a
    Prometheus text exposition (the CI artifact)."""
    import statistics as _stats

    import jax
    from repro.configs import get_config
    from repro.launch.paging import PagedLayout, cdiv
    from repro.launch.scheduler import ContinuousBatchingScheduler
    from repro.models import lm
    from repro.obs import (REGISTRY, ObsConfig, host_fingerprint,
                           scheduler_fingerprint)
    from repro.obs.fingerprint import VARIANTS

    cfg = get_config(arch, smoke=smoke)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    cap = 12
    reqs, arrivals = multi_tenant_trace(n_requests, max_prompt,
                                        cfg.vocab_size, block_size,
                                        seed=seed)
    lay = PagedLayout(block_size=block_size,
                      n_tbl=cdiv(prompt_len + cap, block_size),
                      n_blocks=2 * slots * cdiv(max_prompt + cap,
                                                block_size) + 8)
    kw = dict(slots=slots, prompt_len=prompt_len, max_new_cap=cap,
              seed=seed, paged=lay, prefill_chunk=prefill_chunk,
              prefix_sharing=True)
    off = ContinuousBatchingScheduler(params, cfg, **kw)
    on = ContinuousBatchingScheduler(params, cfg, obs=ObsConfig(), **kw)

    # metrics-off serve loops must lower byte-identically forever: hash
    # the pre-optimization StableHLO of every variant (small queue -- the
    # fingerprint covers the program, not the workload size)
    def variant(name):
        if name == "paged":
            return off
        kw2 = dict(slots=2, prompt_len=16, max_new_cap=4, seed=seed)
        if name == "speculative":
            kw2["draft_k"] = 2
        return ContinuousBatchingScheduler(params, cfg, **kw2)
    fps = {name: scheduler_fingerprint(variant(name), 2)
           for name in VARIANTS}

    # one warm run each (pays compile outside the timed window), then
    # interleave the timed repeats so host drift hits both sides alike;
    # gate on best-of-repeats -- noise on a shared host only ever
    # subtracts throughput, so max-of-N estimates each loop's true rate
    off_runs = [off.run(reqs, arrivals)]
    on_runs = [on.run(reqs, arrivals)]
    for _ in range(repeats):
        off_runs.append(off.run(reqs, arrivals))
        on_runs.append(on.run(reqs, arrivals))
    want = off_runs[0].tokens_by_rid()
    for r in off_runs[1:] + on_runs:
        got = r.tokens_by_rid()
        for rid in want:
            np.testing.assert_array_equal(
                got[rid], want[rid],
                err_msg=f"request {rid}: telemetry rings changed tokens")
    off_med = _stats.median(r.tok_s for r in off_runs[1:])
    on_med = _stats.median(r.tok_s for r in on_runs[1:])
    off_best = max(r.tok_s for r in off_runs[1:])
    on_best = max(r.tok_s for r in on_runs[1:])
    ratio = on_best / off_best if off_best else float("nan")

    # ring truth: TTFT read back from the device event ring must equal
    # the instrumented runner's host-stepped first_iter, request by request
    ri, _ = on.run_instrumented(reqs, arrivals)
    ring_ttft = on_runs[0].obs.ttft_iters
    inst_ttft = {f.rid: f.first_iter for f in ri.finished}
    assert ring_ttft == inst_ttft, \
        f"ring TTFT diverged from instrumented: {ring_ttft} vs {inst_ttft}"

    snap = max(on_runs, key=lambda r: r.tok_s).obs
    snap.register(REGISTRY)
    from repro.kernels.ccim_matmul.autotune import cache_summary
    tuning = cache_summary()
    with open(prom_path, "w") as f:
        f.write(REGISTRY.export_prometheus())
    out = dict(
        fingerprints_metrics_off=fps,
        host=host_fingerprint(),
        tok_s_off_median=round(off_med, 2),
        tok_s_on_median=round(on_med, 2),
        tok_s_off_best=round(off_best, 2),
        tok_s_on_best=round(on_best, 2),
        on_off_tok_s_ratio=round(ratio, 3),
        tokens_bit_identical=True,
        ring_ttft_matches_instrumented=True,
        tuning_cache=tuning,
        snapshot=snap.to_dict(),
        prom_path=os.path.relpath(prom_path,
                                  os.path.join(os.path.dirname(__file__),
                                               "..")),
    )
    print(f"# telemetry: on/off tok/s ratio {ratio:.3f} "
          f"(best {on_best:.1f}/{off_best:.1f}, median {on_med:.1f}/"
          f"{off_med:.1f}), tokens identical, ring TTFT == "
          f"instrumented; metrics-off fingerprints "
          f"{ {k: v[:12] for k, v in fps.items()} }")
    print(f"# telemetry: Prometheus exposition -> {prom_path}")
    print(f"# {tuning}")
    return out


def run(arch: str = "minicpm-2b", smoke: bool = True, batch: int = 2,
        prompt_len: int = 16, gen: int = 48, repeats: int = 3,
        draft_k: int = 8, path: str = _BENCH_JSON, gate: bool = False,
        multi_tenant: bool = True) -> dict:
    from repro.launch.serve import serve, serve_continuous, serve_speculative

    def measure(cim: bool, pack: bool, fuse: bool = True):
        """Median-of-repeats decode rate; tokens asserted deterministic."""
        runs = [serve(arch, smoke=smoke, batch=batch, prompt_len=prompt_len,
                      gen=gen, cim=cim, pack=pack, fuse=fuse,
                      return_stats=True)
                for _ in range(repeats)]
        toks = runs[0][0]
        for t, _ in runs[1:]:
            assert (t == toks).all(), "greedy serving must be deterministic"
        rates = sorted(s["decode_tok_s"] for _, s in runs)
        stats = max((s for _, s in runs), key=lambda s: s["decode_tok_s"])
        stats = dict(stats, decode_tok_s_median=statistics.median(rates),
                     decode_tok_s_runs=rates)
        return toks, stats

    _, fp = measure(cim=False, pack=False)
    tok_u, unpacked = measure(cim=True, pack=False, fuse=False)
    tok_p, packed = measure(cim=True, pack=True)
    assert (tok_u == tok_p).all(), \
        "packed+fused CIM serving diverged from the unpacked unfused path"
    # fusion A/B on the same packed weights: tokens must also be identical
    tok_nf, packed_unfused = measure(cim=True, pack=True, fuse=False)
    assert (tok_nf == tok_p).all(), \
        "fused serving changed tokens vs the unfused packed path"

    # all ratios from the per-variant medians (single draws at smoke scale
    # are dominated by host scheduler noise, not the code under test)
    pack_speedup = (packed_unfused["decode_tok_s_median"]
                    / unpacked["decode_tok_s_median"])
    fusion_speedup = (packed["decode_tok_s_median"]
                      / packed_unfused["decode_tok_s_median"])
    total_speedup = (packed["decode_tok_s_median"]
                     / unpacked["decode_tok_s_median"])

    # continuous batching vs lock-step on a mixed-length queue; token
    # parity with the lock-step plan is asserted inside serve_continuous.
    # The packed_unfused row is the fusion A/B at the continuous-batching
    # level (same scheduler, cfg.cim_fuse off).
    cb = {}
    cb_tokens = {}
    cb_repeats = max(repeats, 3)
    for mode, cim, fuse in (("fp", False, True), ("cim_packed", True, True),
                            ("cim_packed_unfused", True, False)):
        toks, st = serve_continuous(arch, smoke=smoke, slots=batch,
                                    prompt_len=prompt_len,
                                    n_requests=4 * batch,
                                    stop_lengths=(4, 16, 8, 12), cim=cim,
                                    pack=cim, fuse=fuse, repeats=cb_repeats)
        cb_tokens[mode] = toks
        cb[mode] = dict(continuous=st["continuous"], lockstep=st["lockstep"],
                        tok_s_median=st["tok_s_median"],
                        lockstep_tok_s_median=st["lockstep_tok_s_median"],
                        tokens_match_lockstep=st["tokens_match_lockstep"],
                        speedup_vs_lockstep=st["speedup_vs_lockstep"])
    for rid, want in cb_tokens["cim_packed"].items():
        np.testing.assert_array_equal(
            cb_tokens["cim_packed_unfused"][rid], want,
            err_msg=f"request {rid}: fusion changed continuous-batching "
                    "tokens")
    cb["fusion_speedup"] = round(
        cb["cim_packed"]["tok_s_median"]
        / cb["cim_packed_unfused"]["tok_s_median"], 2)
    cb["fused_tokens_bit_identical"] = True

    # --- plan-cascade speculative decoding -------------------------------
    # Headline: the serve-level lock-step driver (one AOT dispatch per
    # draft/verify round); greedy tokens asserted bit-identical to the
    # non-speculative baseline inside serve_speculative.  Median-of-repeats
    # on both sides of the ratio.
    spec_runs = [serve_speculative(arch, smoke=smoke, batch=batch,
                                   prompt_len=prompt_len, gen=gen,
                                   draft_k=draft_k, return_stats=True)[1]
                 for _ in range(repeats)]
    spec_med = statistics.median(s["decode_tok_s"] for s in spec_runs)
    base_med = statistics.median(s["baseline_decode_tok_s"]
                                 for s in spec_runs)
    serve_level = dict(
        spec_runs[0], decode_tok_s_median=round(spec_med, 2),
        baseline_decode_tok_s_median=round(base_med, 2),
        decode_speedup_speculative=round(spec_med / base_med, 2))

    # Acceptance-vs-D/A-split sweep through the continuous-batching
    # scheduler: the draft plan's SAR width is the aggressiveness axis
    # (None = per-entry no-clip width; narrower widths clip large analog
    # accumulates, so verify rejects more and tokens/step shrinks).
    nonspec_med = cb["cim_packed"]["tok_s_median"]
    sweep = []
    for bits in (None, 7, 6, 5):
        _, st = serve_continuous(arch, smoke=smoke, slots=batch,
                                 prompt_len=prompt_len,
                                 n_requests=4 * batch,
                                 stop_lengths=(4, 16, 8, 12), cim=True,
                                 pack=True, draft_k=draft_k,
                                 draft_adc_bits=bits, repeats=cb_repeats)
        cont = st["continuous"]
        sweep.append(dict(
            draft_plan=st["draft_plan"], draft_k=draft_k,
            acceptance_rate=cont["acceptance_rate"],
            tokens_per_step=cont["tokens_per_step"],
            tok_s_median=st["tok_s_median"],
            speedup_vs_nonspec_cb=round(st["tok_s_median"] / nonspec_med, 2),
            tokens_match_lockstep=st["tokens_match_lockstep"]))

    try:
        from .common import bench_header
    except ImportError:
        from common import bench_header
    result = dict(
        **bench_header(),
        config=dict(arch=arch, smoke=smoke, batch=batch,
                    prompt_len=prompt_len, gen=gen, repeats=repeats,
                    draft_k=draft_k),
        fp=fp,
        cim_unpacked=unpacked,          # pre-refactor baseline dataflow
        cim_packed_unfused=packed_unfused,   # packing alone, no fusion
        cim_packed=packed,              # packed + fused + tuned (hot path)
        packed_tokens_bit_identical=True,
        fused_tokens_bit_identical=True,
        decode_speedup_packed_vs_unpacked=round(pack_speedup, 2),
        decode_speedup_fusion=round(fusion_speedup, 2),
        decode_speedup_vs_prerefactor=round(total_speedup, 2),
        continuous_batching=cb,
        speculative=dict(serve_level=serve_level, sweep=sweep),
    )
    if multi_tenant:
        result["multi_tenant"] = run_multi_tenant(
            arch, smoke=smoke, repeats=max(repeats, 3))
    # 8 timed repeats: the overhead gate compares best-of-N of two
    # ~100ms loops, and small N lets one lucky draw on either side move
    # the ratio past the floor (observed swing at N=3-5: 0.93-1.01 for
    # a true ratio of ~1.0)
    result["telemetry"] = run_telemetry(arch, smoke=smoke,
                                        repeats=max(repeats, 8))
    if gate:
        check_regression(result, path)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# decode tok/s (median of {repeats}): "
          f"fp {fp['decode_tok_s_median']:.1f}, "
          f"cim unpacked {unpacked['decode_tok_s_median']:.1f}, "
          f"cim packed {packed['decode_tok_s_median']:.1f} "
          f"({total_speedup:.2f}x total: {pack_speedup:.2f}x packing, "
          f"{fusion_speedup:.2f}x fusion; pack cost {packed['pack_s']}s)")
    for mode in ("fp", "cim_packed", "cim_packed_unfused"):
        row = cb[mode]
        print(f"# continuous batching ({mode}): "
              f"{row['tok_s_median']} tok/s (median) at "
              f"{row['continuous']['occupancy']:.0%} occupancy vs lock-step "
              f"{row['lockstep_tok_s_median']} ({row['speedup_vs_lockstep']}x,"
              f" tokens identical)")
    print(f"# cb fusion speedup (median): {cb['fusion_speedup']}x")
    print(f"# speculative (serve-level, k={draft_k}): "
          f"{serve_level['decode_tok_s_median']} tok/s vs baseline "
          f"{serve_level['baseline_decode_tok_s_median']} "
          f"({serve_level['decode_speedup_speculative']}x, acceptance "
          f"{serve_level['acceptance_rate']:.0%}, tokens identical)")
    for pt in sweep:
        print(f"# speculative sweep {pt['draft_plan']}: acceptance "
              f"{pt['acceptance_rate']:.2f}, {pt['tokens_per_step']} tok/step,"
              f" {pt['tok_s_median']} tok/s "
              f"({pt['speedup_vs_nonspec_cb']}x vs non-spec cb)")
    print(f"# wrote {path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--draft-k", type=int, default=8,
                    help="draft block length for the speculative rows")
    ap.add_argument("--check-regression", dest="gate", action="store_true",
                    help="fail if packed decode regressed >10%% vs the "
                         "committed BENCH_serve.json (packed/fp ratio), the "
                         "speculative speedup fell below its floor, draft "
                         "acceptance dropped on the committed sweep point, "
                         "the paged KV pool missed its multi-tenant "
                         "throughput/footprint/stall gates, a metrics-off "
                         "serve-loop HLO fingerprint moved on a matching "
                         "host, or telemetry overhead exceeded its budget")
    ap.add_argument("--multi-tenant", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="include the paged-vs-contiguous multi-tenant "
                         "trace section")
    args = ap.parse_args()
    run(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
        args.repeats, args.draft_k, gate=args.gate,
        multi_tenant=args.multi_tenant)


if __name__ == "__main__":
    main()
