"""Serving benchmark: prepacked-weight CIM decode vs the legacy per-call
weight-conditioning path (and the fp/bf16 reference), plus the
continuous-batching scheduler vs the lock-step loop on a mixed-length
workload, written to BENCH_serve.json for the per-PR perf trajectory.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke

Measures pure-execution decode tok/s and prefill time (serve AOT-compiles
both steps, so jit compile never pollutes a throughput number) plus the
one-time pack cost.  The packed and unpacked
CIM runs must emit bit-identical tokens: packing is a caching transform
of the weight conditioning, not an approximation -- the benchmark asserts
this before recording any number.

The continuous-batching rows (fp and packed-CIM) report aggregate tok/s,
slot occupancy and p50/p95 request latency for a mixed-length queue
(stop lengths 4/16/8/12 over 4x the slot count) against the lock-step
wave baseline running on the SAME compiled executables.  serve_continuous
asserts per-request tokens are bit-identical between the two plans, so a
scheduler regression fails the benchmark (and CI) outright.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def check_regression(new: dict, baseline_path: str,
                     tolerance: float = 0.10) -> None:
    """CI gate: fail if the packed-CIM decode rate regressed >10% vs the
    committed BENCH_serve.json baseline.

    The gate compares the packed/fp RATIO, not raw tok/s: CI machines are
    not the machine the baseline was committed on, and absolute tok/s
    comparisons across hosts would gate on hardware, not code.  The ratio
    cancels host speed (fp runs in the same process on the same box) and
    still catches exactly what matters -- the CIM hot path losing ground
    relative to the native matmul path.
    """
    try:
        with open(baseline_path) as f:
            base = json.load(f)
        base_ratio = (base["cim_packed"]["decode_tok_s"]
                      / base["fp"]["decode_tok_s"])
    except (OSError, KeyError, ValueError, ZeroDivisionError):
        print("# no usable baseline -- regression gate skipped")
        return
    new_ratio = new["cim_packed"]["decode_tok_s"] / new["fp"]["decode_tok_s"]
    print(f"# regression gate: packed/fp decode ratio {new_ratio:.3f} "
          f"(baseline {base_ratio:.3f}, tolerance -{tolerance:.0%})")
    if new_ratio < (1.0 - tolerance) * base_ratio:
        raise SystemExit(
            f"cim_packed decode regressed: packed/fp ratio {new_ratio:.3f} "
            f"is >{tolerance:.0%} below the committed baseline "
            f"{base_ratio:.3f} ({baseline_path})")


def run(arch: str = "minicpm-2b", smoke: bool = True, batch: int = 2,
        prompt_len: int = 16, gen: int = 48, repeats: int = 2,
        path: str = _BENCH_JSON, gate: bool = False) -> dict:
    from repro.launch.serve import serve, serve_continuous

    def best(cim: bool, pack: bool, fuse: bool = True):
        """Best-of-repeats steady decode rate (robust to scheduler noise)."""
        runs = [serve(arch, smoke=smoke, batch=batch, prompt_len=prompt_len,
                      gen=gen, cim=cim, pack=pack, fuse=fuse,
                      return_stats=True)
                for _ in range(repeats)]
        toks = runs[0][0]
        for t, _ in runs[1:]:
            assert (t == toks).all(), "greedy serving must be deterministic"
        return toks, max((s for _, s in runs), key=lambda s: s["decode_tok_s"])

    _, fp = best(cim=False, pack=False)
    tok_u, unpacked = best(cim=True, pack=False, fuse=False)
    tok_p, packed = best(cim=True, pack=True)
    assert (tok_u == tok_p).all(), \
        "packed+fused CIM serving diverged from the unpacked unfused path"
    # fusion A/B on the same packed weights: tokens must also be identical
    tok_nf, packed_unfused = best(cim=True, pack=True, fuse=False)
    assert (tok_nf == tok_p).all(), \
        "fused serving changed tokens vs the unfused packed path"

    # decode_speedup_packed_vs_unpacked keeps its historical meaning
    # (packing ALONE, both sides unfused); fusion and the total vs the
    # pre-refactor baseline are separate fields
    pack_speedup = (packed_unfused["decode_tok_s"]
                    / unpacked["decode_tok_s"])
    fusion_speedup = (packed["decode_tok_s"]
                      / packed_unfused["decode_tok_s"])
    total_speedup = packed["decode_tok_s"] / unpacked["decode_tok_s"]

    # continuous batching vs lock-step on a mixed-length queue; token
    # parity with the lock-step plan is asserted inside serve_continuous
    cb = {}
    for mode, cim in (("fp", False), ("cim_packed", True)):
        _, st = serve_continuous(arch, smoke=smoke, slots=batch,
                                 prompt_len=prompt_len, n_requests=4 * batch,
                                 stop_lengths=(4, 16, 8, 12), cim=cim,
                                 pack=cim, repeats=max(repeats, 3))
        cb[mode] = dict(continuous=st["continuous"], lockstep=st["lockstep"],
                        tokens_match_lockstep=st["tokens_match_lockstep"],
                        speedup_vs_lockstep=st["speedup_vs_lockstep"])

    result = dict(
        config=dict(arch=arch, smoke=smoke, batch=batch,
                    prompt_len=prompt_len, gen=gen, repeats=repeats),
        fp=fp,
        cim_unpacked=unpacked,          # pre-refactor baseline dataflow
        cim_packed_unfused=packed_unfused,   # packing alone, no fusion
        cim_packed=packed,              # packed + fused + tuned (hot path)
        packed_tokens_bit_identical=True,
        fused_tokens_bit_identical=True,
        decode_speedup_packed_vs_unpacked=round(pack_speedup, 2),
        decode_speedup_fusion=round(fusion_speedup, 2),
        decode_speedup_vs_prerefactor=round(total_speedup, 2),
        continuous_batching=cb,
    )
    if gate:
        check_regression(result, path)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# decode tok/s: fp {fp['decode_tok_s']}, "
          f"cim unpacked {unpacked['decode_tok_s']}, "
          f"cim packed {packed['decode_tok_s']} "
          f"({total_speedup:.2f}x total: {pack_speedup:.2f}x packing, "
          f"{fusion_speedup:.2f}x fusion; pack cost {packed['pack_s']}s)")
    for mode, row in cb.items():
        print(f"# continuous batching ({mode}): "
              f"{row['continuous']['tok_s']} tok/s at "
              f"{row['continuous']['occupancy']:.0%} occupancy vs lock-step "
              f"{row['lockstep']['tok_s']} ({row['speedup_vs_lockstep']}x, "
              f"tokens identical)")
    print(f"# wrote {path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--check-regression", dest="gate", action="store_true",
                    help="fail if packed decode regressed >10%% vs the "
                         "committed BENCH_serve.json (packed/fp ratio)")
    args = ap.parse_args()
    run(args.arch, args.smoke, args.batch, args.prompt_len, args.gen,
        args.repeats, gate=args.gate)


if __name__ == "__main__":
    main()
