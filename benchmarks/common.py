"""Shared benchmark utilities."""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")
_RECORDS = []


def time_us(fn, *args, iters: int = 5, warmup: int = 2,
            reduce: str = "mean") -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    agg = min(ts) if reduce == "min" else sum(ts) / len(ts)
    return agg * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def record(op: str, shape, us, speedup_vs_prev=None, note: str = "",
           parity_only: bool = False):
    """Accumulate one machine-readable benchmark row (see write_bench_json).

    ``parity_only`` rows carry us=null: interpret-mode kernel runs are a
    correctness harness, not a timing -- recording 0.0 us used to read as
    infinite speedup in the perf trajectory.  Compiled timings are emitted
    instead whenever the backend actually runs the kernel (TPU).
    """
    _RECORDS.append(dict(
        op=op,
        shape=list(shape),
        us=None if parity_only else round(us, 1),
        speedup_vs_prev=None if speedup_vs_prev is None else round(speedup_vs_prev, 2),
        note=("parity_only: " + note if parity_only else note),
    ))


def bench_header() -> dict:
    """Schema version + host fingerprint every BENCH_*.json must carry:
    wall-clock rows are only a trajectory point relative to the host that
    produced them."""
    from repro.obs import BENCH_SCHEMA_VERSION, host_fingerprint
    return dict(schema_version=BENCH_SCHEMA_VERSION, host=host_fingerprint())


def write_bench_json(path: str = _BENCH_JSON) -> str:
    """Dump accumulated records so later PRs have a perf trajectory."""
    with open(path, "w") as f:
        json.dump(dict(**bench_header(), records=_RECORDS), f, indent=2)
        f.write("\n")
    return path
