"""Shared benchmark utilities."""
import sys
import time
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def time_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
