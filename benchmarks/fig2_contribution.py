"""Fig. 2: bit-product contribution analysis + 2D-array optimization.

Validates the paper's design derivation: the top-3 bit-products carry
~half the output contribution -> route them to DCIM; LSB truncation /
split-DAC shrink the analog array."""
import numpy as np

from .common import emit
from repro.core import DEFAULT_CONFIG, contribution_table
from repro.core.costmodel import _array_caps


def run():
    cfg = DEFAULT_CONFIG
    ct = contribution_table(cfg)
    flat = np.sort(ct.flatten())[::-1]
    top3 = flat[:3].sum()
    emit("fig2.top3_contribution_pct", 0.0,
         f"{100*top3:.1f}% (paper: ~50% -> DCIM group)")
    # cumulative contribution of top-k products
    for k in (1, 3, 6, 10):
        emit(f"fig2.topk_cum_pct.k{k}", 0.0, f"{100*flat[:k].sum():.1f}%")
    naive_caps = sum(2.0 ** (j + k) for j in range(7) for k in range(7))
    opt_caps = _array_caps(cfg)
    emit("fig2.array_caps_naive", 0.0, f"{naive_caps:.0f} unit caps")
    emit("fig2.array_caps_optimized", 0.0,
         f"{opt_caps:.0f} unit caps ({naive_caps/opt_caps:.1f}x reduction "
         "via DCIM-split + split-DAC)")
    adc_req = int(np.ceil(np.log2(16 * (127 * 127 - 8192) / 2048 + 1)))
    emit("fig2.required_adc_bits", 0.0,
         f"{adc_req + 1}b incl sign (paper: 7b)")


if __name__ == "__main__":
    run()
