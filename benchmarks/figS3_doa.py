"""Fig. S3: application demo -- DOA estimation on the C-CIM macro.

MUSIC direction-of-arrival estimation for a ULA (the paper's [17-19]
application family): the complex covariance (X @ X^H) and the
noise-subspace spectrum projections (E_n^H @ a(theta)) run through the
emulated complex-CIM macro; the eigendecomposition stays in the digital
backend (Fig. S3's DBP).  Paper claim: < 4% RMSE vs the fp32 software
implementation."""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_us
from repro.core import DEFAULT_CONFIG
from repro.core.complex_mac import complex_cim_matmul


def _steering(n_sensors, thetas_deg):
    d = 0.5  # half-wavelength spacing
    k = jnp.arange(n_sensors)[:, None]
    th = jnp.deg2rad(jnp.asarray(thetas_deg))[None, :]
    return jnp.exp(2j * jnp.pi * d * k * jnp.sin(th)).astype(jnp.complex64)


def _music_spectrum(X, n_src, grid, cim: bool, key):
    n = X.shape[0]
    if cim:
        R = complex_cim_matmul(X, X.conj().T, DEFAULT_CONFIG, noise_key=key)
    else:
        R = X @ X.conj().T
    R = R / X.shape[1]
    w, v = jnp.linalg.eigh(R)             # digital backend (Fig. S3 DBP)
    En = v[:, : n - n_src]                # noise subspace
    A = _steering(n, grid)                # (n, G)
    if cim:
        proj = complex_cim_matmul(En.conj().T, A, DEFAULT_CONFIG,
                                  noise_key=jax.random.fold_in(key, 1))
    else:
        proj = En.conj().T @ A
    p = 1.0 / jnp.maximum(jnp.sum(jnp.abs(proj) ** 2, axis=0), 1e-9)
    return p


def _estimate(p, grid, n_src):
    p = np.asarray(p)
    idx = []
    order = np.argsort(p)[::-1]
    for i in order:
        if all(abs(grid[i] - grid[j]) > 5 for j in idx):
            idx.append(i)
        if len(idx) == n_src:
            break
    return sorted(grid[i] for i in idx)


def run(seed: int = 0, n_trials: int = 12):
    n_sensors, n_snap, n_src = 8, 64, 2
    grid = np.arange(-60.0, 60.5, 0.5)
    rng = np.random.default_rng(seed)
    errs_cim, errs_sw, spec_nmse = [], [], []
    t_us = None
    for t in range(n_trials):
        true = np.sort(rng.uniform(-50, 50, n_src))
        while np.diff(true).min() < 12:
            true = np.sort(rng.uniform(-50, 50, n_src))
        A = _steering(n_sensors, true)
        S = (rng.standard_normal((n_src, n_snap)) +
             1j * rng.standard_normal((n_src, n_snap))) / np.sqrt(2)
        N = (rng.standard_normal((n_sensors, n_snap)) +
             1j * rng.standard_normal((n_sensors, n_snap))) * 0.05
        X = jnp.asarray(A @ S + N, jnp.complex64)
        key = jax.random.PRNGKey(seed * 100 + t)
        p_sw = _music_spectrum(X, n_src, grid, cim=False, key=key)
        if t_us is None:
            t_us = time_us(lambda: _music_spectrum(X, n_src, grid, True, key),
                           iters=1, warmup=1)
        p_cim = _music_spectrum(X, n_src, grid, cim=True, key=key)
        est_sw = _estimate(p_sw, grid, n_src)
        est_cim = _estimate(p_cim, grid, n_src)
        errs_sw.append(np.sqrt(np.mean((np.array(est_sw) - true) ** 2)))
        errs_cim.append(np.sqrt(np.mean((np.array(est_cim) - true) ** 2)))
        # compare log-spectra: MUSIC peaks are 1/eps-scaled, so linear NMSE
        # is dominated by meaningless peak-height ratios
        ps = 10 * np.log10(np.asarray(p_sw) / np.asarray(p_sw).max())
        pc = 10 * np.log10(np.asarray(p_cim) / np.asarray(p_cim).max())
        spec_nmse.append(np.linalg.norm(pc - ps) / np.linalg.norm(ps))

    fov = 120.0
    rmse_pct = 100 * np.mean(errs_cim) / fov
    emit("figS3.doa_rmse_cim_deg", t_us,
         f"{np.mean(errs_cim):.2f} deg RMSE over {n_trials} trials "
         f"({rmse_pct:.2f}% of FOV; paper: <4% vs software)")
    emit("figS3.doa_rmse_software_deg", 0.0,
         f"{np.mean(errs_sw):.2f} deg (fp32 MUSIC reference)")
    emit("figS3.spectrum_nmse_pct", 0.0,
         f"{100*np.mean(spec_nmse):.2f}% spectrum NMSE vs software")
    assert rmse_pct < 4.0, "paper claim violated"


if __name__ == "__main__":
    run()
