"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus section markers).
  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.parse_args()

    from . import (fig2_contribution, fig5_transfer, fig6_rms, figS1_cost,
                   figS2_montecarlo, figS3_doa, kernel_bench)

    print("name,us_per_call,derived")
    sections = [
        ("fig2 (contribution analysis)", fig2_contribution.run),
        ("fig5 (transfer function / INL)", fig5_transfer.run),
        ("fig6 (C-MAC RMS error + energy)", fig6_rms.run),
        ("figS1 (area/latency/power vs baselines)", figS1_cost.run),
        ("figS2 (Monte-Carlo mismatch)", figS2_montecarlo.run),
        ("figS3 (DOA application)", figS3_doa.run),
        ("kernels (emulation fidelity/speed)", kernel_bench.run),
    ]
    failures = []
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # keep the suite running; report at the end
            failures.append((name, repr(e)))
            print(f"# FAILED: {name}: {e!r}")
    from .common import write_bench_json
    write_bench_json()  # idempotent: flush whatever rows were recorded
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
