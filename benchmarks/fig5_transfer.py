"""Fig. 5: measured transfer function + INL.

Sweep the input from -FS to +FS with all weights fixed at -127 (exactly
the paper's measurement protocol), record the CIM output vs the ideal
line, report max INL (the paper notes max INL at zero crossing) and gain
error."""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_us
from repro.core import DEFAULT_CONFIG, fabricate, hybrid_mac_bit_true


def run(seed: int = 0):
    cfg = DEFAULT_CONFIG
    macro = fabricate(jax.random.PRNGKey(seed), cfg)
    sweep = jnp.arange(-127, 128)
    x = jnp.broadcast_to(sweep[:, None], (255, cfg.acc_len))  # uniform vector
    w = jnp.full((255, cfg.acc_len), -127)
    fn = jax.jit(lambda a, b: hybrid_mac_bit_true(a, b, macro, cfg)["y8"])
    us = time_us(fn, x, w)
    y = np.asarray(fn(x, w), np.float64)

    ideal = np.asarray(sweep) * (-127.0) * cfg.acc_len / cfg.dcim_lsb
    # gain via least squares (paper: "almost no gain error")
    g = float(np.dot(y, ideal) / np.dot(ideal, ideal))
    inl = y - g * ideal
    lsb = 1.0  # one output LSB (= 2^11 in product scale)
    emit("fig5.transfer_sweep", us,
         f"255-point sweep, W=-127 (paper protocol)")
    emit("fig5.gain_error_pct", 0.0, f"{100*abs(1-g):.2f}% (paper: ~0)")
    emit("fig5.max_inl_lsb", 0.0,
         f"{np.abs(inl).max()/lsb:.2f} LSB at code "
         f"{int(sweep[int(np.abs(inl).argmax())])} "
         "(paper: max INL at zero crossing)")
    zc = np.abs(inl[126:129]).max() / lsb
    emit("fig5.inl_at_zero_crossing_lsb", 0.0, f"{zc:.2f} LSB")


if __name__ == "__main__":
    run()
