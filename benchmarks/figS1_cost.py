"""Fig. S1: area / latency / power vs duplicated-weight and sequential
complex-CIM baselines; plus the accuracy-equivalence check (all three
designs compute the same function; error correlation differs)."""
import jax
import numpy as np

from .common import emit
from repro.core import DEFAULT_CONFIG, baselines, fabricate
from repro.core.costmodel import (density_mb_per_mm2, figS1_comparison,
                                  macro_area_breakdown)


def run(seed: int = 0):
    cfg = DEFAULT_CONFIG
    cmp = figS1_comparison(cfg)
    for k in ("this_work", "duplicated", "sequential"):
        c = cmp[k]
        emit(f"figS1.{k}", 0.0,
             f"area {c['area_mm2']*1e3:.1f}e-3mm2 | latency "
             f"{c['latency_cycles_per_cmac']:.2f} conv/CMAC | power "
             f"{c['power_rel']:.2f}x")
    s = cmp["savings"]
    emit("figS1.savings", 0.0,
         f"area -{s['area_pct_vs_duplicated']:.0f}% (paper -35%), latency "
         f"-{s['latency_pct_vs_sequential']:.0f}% (paper -54%), power "
         f"-{s['power_pct_vs_duplicated']:.0f}% (paper -24%)")
    emit("figS1.density", 0.0,
         f"{density_mb_per_mm2():.2f} Mb/mm2 (paper: 1.80, 2x prior 6T "
         "[12-13])")
    a = macro_area_breakdown(cfg)
    emit("figS1.area_breakdown", 0.0,
         f"sram {a['sram']*1e3:.1f} + caps_extra {a['caps_extra']*1e3:.1f} "
         f"+ dcim {a['dcim']*1e3:.2f} + adc {a['adc']*1e3:.2f} + ctrl "
         f"{a['ctrl']*1e3:.2f} e-3mm2 (caps live on M7 above the array)")

    # functional equivalence of the three dataflows (same math, one weight
    # residency in this work / sequential, two draws in duplicated)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = lambda k: jax.random.randint(k, (4, cfg.acc_len), -127, 128).clip(-127, 127)
    xr, xi, wr, wi = q(ks[0]), q(ks[1]), q(ks[2]), q(ks[3])
    m1, m2 = fabricate(ks[4], cfg), fabricate(ks[5], cfg)
    d_re, d_im = baselines.duplicated_cmac(xr, xi, wr, wi, m1, m2, cfg)
    s_re, s_im = baselines.sequential_cmac(xr, xi, wr, wi, m1, cfg)
    exact_re = np.asarray((xr * wr - xi * wi).sum(-1))
    err_d = np.abs(np.asarray(d_re) * cfg.dcim_lsb - exact_re).max()
    err_s = np.abs(np.asarray(s_re) * cfg.dcim_lsb - exact_re).max()
    emit("figS1.functional_equivalence", 0.0,
         f"max |err| duplicated {err_d:.0f} vs sequential {err_s:.0f} "
         f"(both <= few ADC LSB = {cfg.dcim_lsb})")


if __name__ == "__main__":
    run()
