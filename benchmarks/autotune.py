"""Autotune the CIM GEMM block schedules for an architecture's decode
shapes and persist the winners to benchmarks/TUNING_CACHE.json.

  PYTHONPATH=src python -m benchmarks.autotune --arch minicpm-2b --smoke

The search times the full prepacked serving op per candidate block (see
repro.kernels.ccim_matmul.autotune), so the cache reflects the decode hot
path end to end.  ops.py / ccim.py consult the cache at trace time: the
serve loop and the continuous-batching scheduler pick tuned blocks when
their executables are built and never recompile across steps.  Every
candidate is bit-identical (int32 partial sums), so a stale or missing
cache only costs speed -- CI uploads the file as an artifact.
"""
import argparse
import os
import sys

try:
    from .common import emit
except ImportError:   # direct script execution (python benchmarks/autotune.py)
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit


def decode_shapes(arch: str, smoke: bool = True, batches=(2, 4)) -> list:
    """The (M, K, N) GEMMs one decode step of ``arch`` actually runs,
    fused projection groups included (models.layers._dense_group)."""
    from repro.configs import get_config
    cfg = get_config(arch, smoke=smoke)
    D = cfg.d_model
    shapes = set()
    for B in batches:
        # hybrid (zamba2) runs BOTH: mamba layers plus a shared attn+mlp
        # block, so it collects the attention/MLP shapes too
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            dh, hq, hkv = cfg.head_dim, cfg.padded_heads, cfg.padded_kv_heads
            shapes.add((B, D, (hq + 2 * hkv) * dh))   # fused QKV
            shapes.add((B, hq * dh, D))               # wo
            if cfg.d_ff:
                shapes.add((B, D, 2 * cfg.d_ff))      # fused gate/up
                shapes.add((B, cfg.d_ff, D))          # w2
        if cfg.family in ("ssm", "hybrid"):
            DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            shapes.add((B, D, 2 * DI + 2 * N + H))    # fused w_z/w_x/w_bc/w_dt
            shapes.add((B, DI, D))                    # out_proj
    return sorted(shapes)


def run(arch: str = "minicpm-2b", smoke: bool = True, batches=(2, 4),
        iters: int = 5) -> str:
    from repro.kernels.ccim_matmul import autotune

    shapes = decode_shapes(arch, smoke, batches)
    shapes.append((4, 1024, 256))   # the kernel-bench decode reference shape
    results = autotune.autotune_shapes(shapes, iters=iters)
    for name, entry in results.items():
        detail = (f"chunk_block {entry['chunk_block']}"
                  if "chunk_block" in entry
                  else f"bn {entry['bn']} bk {entry['bk']}")
        emit(f"tune.{name}", entry["us"], detail)
    path = autotune.save()
    print(f"# wrote {path} ({len(results)} entries, arch {arch})")
    print(f"# {autotune.cache_summary()}")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batches", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--iters", type=int, default=5)
    args = ap.parse_args()
    run(args.arch, args.smoke, tuple(args.batches), args.iters)


if __name__ == "__main__":
    main()
