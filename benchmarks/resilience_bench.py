"""Resilience benchmark: fault injection, drift detection, failover cost.

  PYTHONPATH=src python benchmarks/resilience_bench.py --smoke

Writes BENCH_resilience.json and enforces the PR's closed-loop
acceptance gates inline (the CI step fails on any breach):

  off-path identity   arming + disarming a FaultModel leaves the plain
                      serve loop's StableHLO fingerprints byte-identical
                      for every scheduler variant -- fault-free serving
                      never pays for the chaos machinery.  A fault-ON
                      segment lowering is ALSO fingerprinted and must
                      DIFFER, proving the injection is actually wired
                      into the compiled loop (an off-path gate that
                      passes because the feature is dead would be
                      meaningless).
  clean guarded       the watchdog-guarded serve of a fault-free
                      workload stays GREEN, takes zero failover
                      actions, and emits tokens bit-identical to the
                      plain continuous-batching scheduler.
  detection           a seeded mid-stream capacitor-drift ramp drives
                      the debounced state to RED within a bounded
                      token count, deterministically.
  fidelity recovery   end-to-end logits rel-RMS vs the float reference:
                      the drifted plan degrades, the failover rung
                      restores RMS to <= 2x the clean plan's RMS.
  zero-recompile      every rung's segment executable is compiled up
                      front; the census asserts failover never
                      compiles (and never repacks -- all rungs serve
                      one pack, enforced by the engine's pack guard).

Per-rung throughput cost (the price of each degradation level) is
recorded as median-of-repeats tok/s but NOT gated -- it is a same-host
trajectory number, everything above is a determinism property.
"""
import argparse
import dataclasses
import json
import os
import statistics
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_resilience.json")

# the seeded chaos scenario: per-column capacitor gain/offset drift
# ramping in mid-workload.  onset/period are device loop iterations.
_DRIFT = dict(seed=3, gain_amp=0.6, offset_lsb=2.0, schedule="ramp",
              onset=4, period=16)
# tokens the debounced watchdog gets to leave GREEN (the workload emits
# ~40; detection typically lands around half that)
_DETECTION_BUDGET_TOKENS = 32
# failover must restore end-to-end RMS to within this factor of the
# clean plan's RMS vs the float reference
_RMS_RECOVERY_FACTOR = 2.0


def _workload(cfg, prompt_len, n_requests, seed):
    from repro.launch.scheduler import mixed_length_requests
    return mixed_length_requests(n_requests, prompt_len, cfg.vocab_size,
                                 stop_lengths=(4, 16, 8, 12), seed=seed)


def run_fingerprints(params, cfg, fault) -> dict:
    """Off-path byte-identity: plain-loop StableHLO before vs after an
    arm/disarm cycle, plus the wiring proof (fault-on segment differs)."""
    from repro.launch.scheduler import ContinuousBatchingScheduler
    from repro.obs import scheduler_fingerprint
    from repro.obs.fingerprint import VARIANTS, hlo_fingerprint
    from repro.launch.paging import PagedLayout
    from repro.resilience import faults as rfaults

    def make(name):
        kw = dict(slots=2, prompt_len=16, max_new_cap=4, seed=0)
        if name == "paged":
            kw["paged"] = PagedLayout(block_size=8, n_tbl=3, n_blocks=12)
        elif name == "speculative":
            kw["draft_k"] = 2
        return ContinuousBatchingScheduler(params, cfg, **kw)

    before = {v: scheduler_fingerprint(make(v), 2) for v in VARIANTS}
    # arm, lower a faulted segment (the wiring proof), disarm
    seg_off = hlo_fingerprint(make("contiguous").segment_hlo_text(2))
    with rfaults.inject(fault):
        seg_on = hlo_fingerprint(make("contiguous").segment_hlo_text(2))
    after = {v: scheduler_fingerprint(make(v), 2) for v in VARIANTS}

    identical = before == after
    wired = seg_on != seg_off
    print(f"# fingerprints: off-path {'identical' if identical else 'MOVED'}"
          f" across {len(before)} variants; fault-on segment "
          f"{'differs (wired)' if wired else 'UNCHANGED (dead feature!)'}")
    if not identical:
        moved = sorted(v for v in before if before[v] != after[v])
        raise SystemExit(f"arming a FaultModel changed the fault-free serve "
                         f"loop lowering: {moved}")
    if not wired:
        raise SystemExit("fault-armed segment lowered identically to the "
                         "clean segment -- injection is not wired in")
    return dict(plain_loop=before, identical_after_arm_cycle=True,
                segment_fault_off=seg_off, segment_fault_on=seg_on,
                fault_segment_differs=True)


def run_clean_guarded(params, cfg, prompt_len, n_requests, seed,
                      segment_iters) -> dict:
    """Fault-free guarded serving: GREEN, zero actions, token parity."""
    from repro.resilience.failover import GuardedServer, default_probe
    from repro.resilience.watchdog import GREEN, Watchdog

    server = GuardedServer(
        params, cfg, slots=2, prompt_len=prompt_len, max_new_cap=16,
        seed=seed, watchdog=Watchdog(), probe=default_probe(params),
        segment_iters=segment_iters)
    reqs = _workload(cfg, prompt_len, n_requests, seed)
    report, log = server.run(reqs)
    want = server.scheduler().run(reqs).tokens_by_rid()
    got = report.tokens_by_rid()
    parity = all(np.array_equal(got[r], want[r]) for r in want)
    print(f"# clean guarded: state {server.watchdog.state}, "
          f"{len(log.actions)} actions, token parity "
          f"{'OK' if parity else 'FAILED'}, {report.tok_s:.1f} tok/s, "
          f"{log.n_compiles} compiles ({len(server.ladder)} rungs)")
    if server.watchdog.state != GREEN or log.actions:
        raise SystemExit(
            f"clean workload tripped the watchdog: state "
            f"{server.watchdog.state}, {len(log.actions)} failover actions")
    if not parity:
        raise SystemExit("guarded serving changed tokens on a fault-free "
                         "workload vs the plain scheduler")
    if log.n_compiles != len(server.ladder):
        raise SystemExit(f"expected one compile per rung "
                         f"({len(server.ladder)}), got {log.n_compiles}")
    return dict(state=server.watchdog.state, n_actions=len(log.actions),
                token_parity=True, tok_s=round(report.tok_s, 2),
                n_compiles=log.n_compiles,
                probe_clean_floor=round(server.probe.clean_floor, 6),
                resilience=log.to_dict())


def run_detection(params, cfg, fault, prompt_len, n_requests, seed,
                  segment_iters) -> dict:
    """Seeded mid-stream drift: RED within the token budget, escalation
    to the immune rung, zero recompiles at failover time."""
    from repro.resilience.failover import GuardedServer, default_probe
    from repro.resilience.watchdog import RED, Watchdog, WatchdogConfig

    server = GuardedServer(
        params, cfg, slots=2, prompt_len=prompt_len, max_new_cap=16,
        seed=seed, fault=fault,
        watchdog=Watchdog(WatchdogConfig(debounce=1)),
        probe=default_probe(params, fault=fault),
        segment_iters=segment_iters)
    reqs = _workload(cfg, prompt_len, n_requests, seed)
    report, log = server.run(reqs)
    det = log.detection_tokens
    print(f"# detection: state {server.watchdog.state}, detected at "
          f"{det} tokens (budget {_DETECTION_BUDGET_TOKENS}), "
          f"{len(log.actions)} action(s), final rung "
          f"'{log.rung_labels[log.final_rung]}', {log.n_compiles} compiles")
    if server.watchdog.state != RED:
        raise SystemExit(f"seeded drift not escalated to RED "
                         f"(state {server.watchdog.state})")
    if det is None or det > _DETECTION_BUDGET_TOKENS:
        raise SystemExit(f"detection at {det} tokens blew the "
                         f"{_DETECTION_BUDGET_TOKENS}-token budget")
    if log.final_rung != len(server.ladder) - 1 or not log.actions:
        raise SystemExit("RED did not escalate to the top (digital) rung")
    if log.n_compiles != len(server.ladder):
        raise SystemExit(f"failover compiled mid-run: {log.n_compiles} "
                         f"compiles for {len(server.ladder)} rungs")
    return dict(fault=dataclasses.asdict(fault),
                state=server.watchdog.state, detection_tokens=det,
                budget_tokens=_DETECTION_BUDGET_TOKENS,
                final_rung=log.rung_labels[log.final_rung],
                n_actions=len(log.actions), n_compiles=log.n_compiles,
                tok_s=round(report.tok_s, 2), resilience=log.to_dict())


def run_rms(raw_params, packed_params, cfg, fault, t_drift: int = 48
            ) -> dict:
    """End-to-end logits RMS vs the float reference: clean plan, drifted
    plan (no failover), and the failover rung under the SAME drift."""
    from repro.core.ccim import DEFAULT_CONFIG
    from repro.plan.plan import DeploymentPlan, PlanEntry
    from repro.plan.profiler import (calibration_batch, planned_logits,
                                     reference_logits, rel_rms)
    from repro.resilience import faults as rfaults
    from repro.resilience.failover import derive_exact_plan

    plan = cfg.cim_plan or DeploymentPlan.uniform(
        PlanEntry(cfg=cfg.cim_cfg or DEFAULT_CONFIG,
                  fidelity=cfg.cim_fidelity))
    dig = derive_exact_plan(plan)
    toks = calibration_batch(cfg, batch=2, seq_len=8)
    ref = np.asarray(reference_logits(raw_params, cfg, toks), np.float64)

    def rms(p, armed):
        if armed:
            with rfaults.inject(fault), rfaults.clock(t_drift):
                y = planned_logits(packed_params, cfg, toks, p,
                                   noise_seed=None)
        else:
            y = planned_logits(packed_params, cfg, toks, p, noise_seed=None)
        return float(rel_rms(np.asarray(y, np.float64), ref))

    clean = rms(plan, armed=False)
    drift = rms(plan, armed=True)
    failover = rms(dig, armed=True)
    ratio = failover / clean if clean > 0 else float("inf")
    print(f"# rms (t={t_drift}): clean {clean:.4f}, drifted "
          f"{drift:.4f}, failover {failover:.4f} "
          f"({ratio:.2f}x clean, gate <= {_RMS_RECOVERY_FACTOR}x)")
    if failover > _RMS_RECOVERY_FACTOR * clean:
        raise SystemExit(
            f"failover rung RMS {failover:.4f} exceeds "
            f"{_RMS_RECOVERY_FACTOR}x the clean plan's {clean:.4f}")
    if drift <= failover:
        raise SystemExit(
            f"drifted plan RMS {drift:.4f} not worse than the failover "
            f"rung's {failover:.4f} -- the scenario exercises nothing")
    return dict(t_drift=t_drift, fault=dataclasses.asdict(fault),
                rms_clean=round(clean, 6),
                rms_drift_no_failover=round(drift, 6),
                rms_drift_failover=round(failover, 6),
                failover_vs_clean=round(ratio, 4),
                gate_factor=_RMS_RECOVERY_FACTOR)


def run_ladder_cost(params, cfg, prompt_len, n_requests, seed,
                    segment_iters, repeats) -> list:
    """Throughput at every rung of the ladder (the degradation price),
    clean runs, median of repeats -- trajectory numbers, not gated."""
    from repro.resilience.failover import GuardedServer

    server = GuardedServer(
        params, cfg, slots=2, prompt_len=prompt_len, max_new_cap=16,
        seed=seed, segment_iters=segment_iters)
    reqs = _workload(cfg, prompt_len, n_requests, seed)
    server.compile_for(n_requests)
    rows = []
    for i, rung in enumerate(server.ladder):
        server.start_rung = i          # every rung is precompiled above
        runs = [server.run(reqs)[0].tok_s for _ in range(repeats)]
        med = statistics.median(runs)
        rows.append(dict(rung=i, label=rung.label,
                         tok_s_median=round(med, 2),
                         tok_s_runs=[round(r, 2) for r in runs]))
        print(f"# ladder rung {i} ({rung.label}): {med:.1f} tok/s "
              f"(median of {repeats})")
    if server.n_compiles != len(server.ladder):
        raise SystemExit(f"ladder sweep recompiled: {server.n_compiles} "
                         f"compiles for {len(server.ladder)} rungs")
    return rows


def run(arch: str = "minicpm-2b", smoke: bool = True, prompt_len: int = 8,
        n_requests: int = 4, repeats: int = 3, seed: int = 0,
        segment_iters: int = 4, path: str = _BENCH_JSON) -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.resilience.faults import FaultModel

    cfg = get_config(arch, smoke=smoke)
    cfg = dataclasses.replace(cfg, cim_mode=True)
    raw_params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    params = jax.block_until_ready(lm.pack_cim_params(raw_params, cfg))
    fault = FaultModel(**_DRIFT)

    try:
        from .common import bench_header
    except ImportError:
        from common import bench_header
    result = dict(
        **bench_header(),
        config=dict(arch=arch, smoke=smoke, prompt_len=prompt_len,
                    n_requests=n_requests, repeats=repeats, seed=seed,
                    segment_iters=segment_iters),
        fingerprints=run_fingerprints(params, cfg, fault),
        clean_guarded=run_clean_guarded(params, cfg, prompt_len,
                                        n_requests, seed, segment_iters),
        detection=run_detection(params, cfg, fault, prompt_len,
                                n_requests, seed, segment_iters),
        rms=run_rms(raw_params, params, cfg, fault),
        ladder=run_ladder_cost(params, cfg, prompt_len, n_requests, seed,
                               segment_iters, repeats),
    )
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True, help="--no-smoke runs the full-size arch")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--segment-iters", type=int, default=4)
    args = ap.parse_args()
    run(args.arch, args.smoke, args.prompt_len, args.requests,
        args.repeats, segment_iters=args.segment_iters)


if __name__ == "__main__":
    main()
