"""Fig. S2: Monte-Carlo RMS error vs capacitor mismatch (design viability).

Sweeps sigma_unit around the designed 2.96% across many fabricated dies;
shows the hybrid architecture keeps RMS error flat up to the design point
(the DCIM group carries the mismatch-critical MSBs)."""
import dataclasses

import jax
import numpy as np

from .common import emit
from repro.core import DEFAULT_CONFIG, fabricate, hybrid_mac_bit_true


def _die_rms(cfg, die_key, data_key, n=2048):
    k1, k2 = jax.random.split(data_key)
    xq = jax.random.randint(k1, (n, cfg.acc_len), -127, 128).clip(-127, 127)
    wq = jax.random.randint(k2, (n, cfg.acc_len), -127, 128).clip(-127, 127)
    macro = fabricate(die_key, cfg)
    out = hybrid_mac_bit_true(xq, wq, macro, cfg)
    err = np.asarray(out["y8"] * cfg.dcim_lsb - out["exact"], np.float64)
    fs = 2 * 64 * cfg.dcim_lsb
    return 100 * np.sqrt(np.mean((err / fs) ** 2))


def run(seed: int = 0, n_dies: int = 8):
    base = DEFAULT_CONFIG
    data_key = jax.random.PRNGKey(seed + 999)
    for mult in (0.5, 1.0, 2.0, 4.0):
        cfg = dataclasses.replace(base, sigma_unit=0.0296 * mult)
        dies = [_die_rms(cfg, jax.random.PRNGKey(seed + i), data_key)
                for i in range(n_dies)]
        emit(f"figS2.mc_rms_at_{mult:.1f}x_mismatch", 0.0,
             f"sigma_u={100*cfg.sigma_unit:.2f}%: "
             f"{np.mean(dies):.3f}% rms (die-to-die std "
             f"{np.std(dies):.3f}) over {n_dies} dies")
    emit("figS2.conclusion", 0.0,
         "flat through the 2.96% design point -> viable (paper Fig. S2)")


if __name__ == "__main__":
    run()
