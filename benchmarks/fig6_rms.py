"""Fig. 6: C-MAC RMS error under uniform inputs (no sparsity) + energy.

Paper: 0.435% rms, lowest among CIM prototypes [3-6,12-14]; 35.0 TOPS/W;
ACIM power dominates.  We reproduce the protocol bit-true and compare the
functional baselines."""
import jax
import numpy as np

from .common import emit, time_us
from repro.core import (DEFAULT_CONFIG, baselines, fabricate,
                        hybrid_mac_bit_true, hybrid_mac_ideal)
from repro.core.costmodel import energy_per_conversion_pj, tops_per_watt


def _rms_pct(y8, exact, cfg):
    err = np.asarray(y8 * cfg.dcim_lsb - exact, np.float64)
    fs = 2 * 64 * cfg.dcim_lsb
    return 100 * np.sqrt(np.mean((err / fs) ** 2))


def run(seed: int = 0, n: int = 16384):
    cfg = DEFAULT_CONFIG
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    xq = jax.random.randint(ks[0], (n, cfg.acc_len), -127, 128).clip(-127, 127)
    wq = jax.random.randint(ks[1], (n, cfg.acc_len), -127, 128).clip(-127, 127)
    macro = fabricate(ks[2], cfg)

    fn = jax.jit(lambda a, b, k: hybrid_mac_bit_true(a, b, macro, cfg,
                                                     noise_key=k))
    us = time_us(fn, xq, wq, ks[3], iters=3)
    out = fn(xq, wq, ks[3])
    emit("fig6.rms_this_work_pct", us,
         f"{_rms_pct(out['y8'], out['exact'], cfg):.3f}% rms "
         "(paper measured: 0.435%; mismatch+rounding 0.29% + dynamic "
         "noise calibrated at 0.45 LSB)")

    ideal = hybrid_mac_ideal(xq, wq, cfg)
    emit("fig6.rms_quantization_floor_pct", 0.0,
         f"{_rms_pct(ideal, out['exact'], cfg):.3f}% rms (ADC rounding only)")

    cfg_a = baselines.all_analog_config(cfg)
    macro_a = fabricate(ks[3], cfg_a)
    out_a = hybrid_mac_bit_true(xq, wq, macro_a, cfg_a)
    emit("fig6.rms_all_analog_pct", 0.0,
         f"{_rms_pct(out_a['y8'], out_a['exact'], cfg_a):.3f}% rms "
         "(conventional ACIM [4-5]: MSB mismatch dominates)")

    emit("fig6.rms_all_digital_pct", 0.0,
         "0.000% rms (exact [11]; costs area/power, see figS1)")

    e = energy_per_conversion_pj(cfg)
    emit("fig6.energy_breakdown_pj", 0.0,
         f"array {e['array']:.3f} + adc {e['adc']:.3f} + dcim {e['dcim']:.3f}"
         f" + drivers {e.get('drivers', 0):.3f} = {e['total']:.3f} pJ/conv "
         "(ACIM-side dominates, as measured)")
    emit("fig6.tops_per_watt", 0.0,
         f"{tops_per_watt(cfg):.1f} TOPS/W derived (paper measured: 35.0)")


if __name__ == "__main__":
    run()
