"""Deployment-plan Pareto benchmark: global single-config vs planned-mixed.

  PYTHONPATH=src python benchmarks/plan_pareto.py --smoke

For each benchmarked arch (a dense and an SSM config, exercising both
projection families) this runs the full planner pipeline at smoke scale:

  1. profile per-projection output-RMS sensitivity over a candidate grid
     (D/A split 6..0, ADC width by the no-clip rule, accumulate length
     16/32) with deterministic analog-noise emulation on;
  2. evaluate three deployment points on (RMS error, modeled cost,
     measured decode tok/s):
       global_digital  -- all-digital CIM everywhere (accuracy/cost ceiling)
       global_hybrid   -- the paper's 28nm prototype config everywhere
       planned_mixed   -- greedy-knapsack plan at the global-hybrid
                          accuracy budget
     plus planned_tight (60% of the budget -- forces digital onto the
     sensitive projections, showing a genuinely mixed assignment);
  3. write BENCH_plan.json and FAIL (exit 1) if the planned-mixed point
     is dominated by the global-hybrid point (worse accuracy AND worse
     modeled cost) -- the planner must sit on the Pareto front.

Measured tok/s comes from the serve driver on the SAME plan (packed,
AOT-compiled, zero recompiles across decode steps); RMS and modeled cost
come from repro.plan's profiler/cost machinery.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_plan.json")

BENCH_ARCHS = ("minicpm-2b", "mamba2-130m")


def _bench_candidates():
    # trimmed grid (full sweep is (6..0) x (16, 32)): CI runs one forward
    # per (site, candidate), so candidate count is the smoke-runtime knob
    from repro import plan as P
    return P.default_candidates(n_dcim_sweep=(6, 3, 0),
                                acc_len_sweep=(16, 32))


def _measure_tok_s(arch, smoke, plan, batch, prompt_len, gen):
    from repro.launch.serve import serve
    _, stats = serve(arch, smoke=smoke, batch=batch, prompt_len=prompt_len,
                     gen=gen, plan=plan, pack=True, return_stats=True)
    return stats["decode_tok_s"]


def run_arch(arch: str, smoke: bool, batch: int, prompt_len: int, gen: int,
             seed: int = 0) -> dict:
    import jax

    from repro import plan as P
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config(arch, smoke=smoke)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    tokens = P.calibration_batch(cfg, batch=batch, seq_len=prompt_len,
                                 seed=seed)
    cands = _bench_candidates()
    ref = P.reference_logits(params, cfg, tokens)   # shared float reference
    profile = P.profile_sensitivities(params, cfg, tokens, cands, ref=ref)

    res = P.pareto_search(params, cfg, tokens, candidates=cands,
                          profile=profile, ref=ref)
    res_tight = P.pareto_search(params, cfg, tokens, candidates=cands,
                                profile=profile, ref=ref, budget_scale=0.6)

    plans = {
        "global_digital": P.DeploymentPlan.uniform(
            P.digital_candidate().entry),
        "global_hybrid": P.DeploymentPlan.uniform(
            P.prototype_candidate().entry),
        "planned_mixed": res.plan,
        "planned_tight": res_tight.plan,
    }
    points = {}
    for name, plan in plans.items():
        pt = P.evaluate_plan(params, cfg, tokens, plan, profile, ref=ref)
        if name != "planned_tight":      # tight point: rms/cost axes only
            pt["decode_tok_s"] = _measure_tok_s(arch, smoke, plan, batch,
                                                prompt_len, gen)
        points[name] = {k: round(float(v), 6) for k, v in pt.items()}
    points["planned_mixed"]["assignment"] = dict(res.assignment)
    points["planned_tight"]["assignment"] = dict(res_tight.assignment)

    pm, gh = points["planned_mixed"], points["global_hybrid"]
    dominated = (pm["measured_rms"] > gh["measured_rms"]
                 and pm["combined"] > gh["combined"])
    dominates = (pm["combined"] < gh["combined"]
                 and pm["measured_rms"] <= gh["measured_rms"])
    out = dict(
        sites={s: profile.macs_per_token(s) for s in profile.sites},
        sensitivity=profile.as_table(),
        points=points,
        planned_dominated_by_global_hybrid=dominated,
        planned_dominates_global_hybrid=dominates,
        search=dict(n_moves=len(res.moves), n_reverts=res.n_reverts,
                    budget_measured=round(res.budget_measured, 6)),
    )
    print(f"# {arch}: planned-mixed rms {pm['measured_rms']:.4f} @ cost "
          f"{pm['combined']:.3f} ({pm['decode_tok_s']} tok/s) vs "
          f"global-hybrid rms {gh['measured_rms']:.4f} @ cost "
          f"{gh['combined']:.3f} ({gh['decode_tok_s']} tok/s) -> "
          f"{'DOMINATES' if dominates else 'on front'}"
          f"{' [DOMINATED!]' if dominated else ''}")
    return out


def run(smoke: bool = True, batch: int = 2, prompt_len: int = 16,
        gen: int = 16, archs=BENCH_ARCHS, path: str = _BENCH_JSON) -> dict:
    try:
        from .common import bench_header
    except ImportError:
        from common import bench_header
    result = dict(**bench_header(),
                  config=dict(smoke=smoke, batch=batch,
                              prompt_len=prompt_len, gen=gen,
                              archs=list(archs)),
                  archs={})
    for arch in archs:
        result["archs"][arch] = run_arch(arch, smoke, batch, prompt_len, gen)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# wrote {path}")
    bad = [a for a, r in result["archs"].items()
           if r["planned_dominated_by_global_hybrid"]]
    if bad:
        raise SystemExit(
            f"planned-mixed point DOMINATED by global-hybrid on {bad} "
            "(worse accuracy AND worse modeled cost) -- planner regression")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--archs", nargs="*", default=list(BENCH_ARCHS))
    args = ap.parse_args()
    run(args.smoke, args.batch, args.prompt_len, args.gen, args.archs)


if __name__ == "__main__":
    main()
