"""Horizontal projection fusion + skinny-M decode kernels: fused execution
must be a pure scheduling transform -- per-projection outputs (including
the analog-noise draw), per-request tokens and every kernel route stay
bit-identical to the unfused/per-projection baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan as P
from repro.configs import get_config
from repro.core import (DEFAULT_CONFIG, FusedPackedCimWeights,
                        PackedCimWeights, cim_matmul, pack_cim_weights)
from repro.core.engine import packed_cim_matmul
from repro.models import lm

D = DEFAULT_CONFIG


def _entry(label, **kw):
    return P.PlanEntry(cfg=dataclasses.replace(D, **kw), fidelity="fast",
                       label=label)


def _model(arch="minicpm-2b", seed=0, seq=8):
    cfg = get_config(arch, smoke=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, seq), 0,
                              cfg.vocab_size)
    return cfg, params, jnp.asarray(toks)


def _logits(params, cfg, toks):
    y, _ = lm.forward(params, cfg, toks, remat=False)
    return np.asarray(y)


# ---------------------------------------------------------------------------
# fused == unfused, packed and unpacked, across model families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["minicpm-2b", "mamba2-130m",
                                  "qwen2-moe-a2.7b", "zamba2-1.2b"])
def test_fused_forward_bit_identical(arch):
    """QKV / gate-up / mamba-input / shared-block fusion across families:
    fused forward == unfused forward, for raw AND prepacked weights."""
    cfg, params, toks = _model(arch)
    on = dataclasses.replace(cfg, cim_mode=True)
    off = dataclasses.replace(on, cim_fuse=False)
    ref = _logits(params, off, toks)
    np.testing.assert_array_equal(ref, _logits(params, on, toks))
    np.testing.assert_array_equal(
        ref, _logits(lm.pack_cim_params(params, off), off, toks))
    np.testing.assert_array_equal(
        ref, _logits(lm.pack_cim_params(params, on), on, toks))


def test_fused_noise_streams_bit_identical():
    """Per-segment noise draws: fusion must reproduce each projection's
    OWN path-folded noise stream, not one wide draw."""
    cfg, params, toks = _model()
    on = dataclasses.replace(cfg, cim_mode=True, cim_noise_seed=13)
    off = dataclasses.replace(on, cim_fuse=False)
    ref = _logits(params, off, toks)
    np.testing.assert_array_equal(ref, _logits(params, on, toks))
    np.testing.assert_array_equal(
        ref, _logits(lm.pack_cim_params(params, on), on, toks))


# ---------------------------------------------------------------------------
# plan-keyed grouping: mixed plans fuse only entry-compatible sites
# ---------------------------------------------------------------------------


HETERO = P.DeploymentPlan.from_dict({
    "attn/wq": _entry("hybrid5/adc8", n_dcim_products=5, adc_bits=8),
    "mlp/w1": P.FLOAT_ENTRY,
    "mlp/w2": P.DIGITAL_ENTRY,
}, default=_entry("hybrid3/adc8/L32", acc_len=32, adc_bits=8))


def test_heterogeneous_plan_splits_groups():
    cfg, params, toks = _model()
    pcfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=HETERO,
                               cim_noise_seed=3)
    packed = lm.pack_cim_params(params, pcfg)
    blk = packed["layers"]
    # wq's entry differs -> wk+wv fuse without it; w1 is float -> no w1+w3
    assert isinstance(blk["attn"]["wk+wv"], FusedPackedCimWeights)
    assert isinstance(blk["attn"]["wq"], PackedCimWeights)
    assert blk["attn"]["wq"].cfg.n_dcim_products == 5
    assert "w1+w3" not in blk["mlp"] and "w1" in blk["mlp"]
    # and the split grouping still serves bit-identically
    off = dataclasses.replace(pcfg, cim_fuse=False)
    ref = _logits(params, off, toks)
    np.testing.assert_array_equal(ref, _logits(params, pcfg, toks))
    np.testing.assert_array_equal(ref, _logits(packed, pcfg, toks))


def test_exact_fidelity_sites_fuse():
    """All-digital (exact) plans fuse too -- quantization-only sites have
    column-local arithmetic just like the fast path."""
    cfg, params, toks = _model()
    pcfg = dataclasses.replace(
        cfg, cim_mode=True,
        cim_plan=P.DeploymentPlan.uniform(P.DIGITAL_ENTRY))
    packed = lm.pack_cim_params(params, pcfg)
    assert isinstance(packed["layers"]["attn"]["wq+wk+wv"],
                      FusedPackedCimWeights)
    off = dataclasses.replace(pcfg, cim_fuse=False)
    np.testing.assert_array_equal(_logits(params, off, toks),
                                  _logits(packed, pcfg, toks))


# ---------------------------------------------------------------------------
# serving: lock-step driver and continuous-batching scheduler
# ---------------------------------------------------------------------------


def test_serve_fused_tokens_match_unfused():
    from repro.launch.serve import serve
    ref = serve("minicpm-2b", smoke=True, batch=2, prompt_len=8, gen=4,
                cim=True, pack=False, fuse=False)
    for pack, fuse in ((False, True), (True, True)):
        got = serve("minicpm-2b", smoke=True, batch=2, prompt_len=8, gen=4,
                    cim=True, pack=pack, fuse=fuse)
        np.testing.assert_array_equal(ref, got)


def test_scheduler_fused_tokens_match_unfused():
    """Continuous batching over fused packed weights: per-request tokens
    identical to the unfused scheduler run (and, inside serve_continuous,
    to the lock-step baseline)."""
    from repro.launch.serve import serve_continuous
    kw = dict(smoke=True, slots=2, prompt_len=8, n_requests=4,
              stop_lengths=(3, 5, 4, 2), cim=True, pack=True)
    toks_off, st_off = serve_continuous("minicpm-2b", fuse=False, **kw)
    toks_on, st_on = serve_continuous("minicpm-2b", fuse=True, **kw)
    assert st_off["tokens_match_lockstep"] and st_on["tokens_match_lockstep"]
    for rid in toks_off:
        np.testing.assert_array_equal(toks_off[rid], toks_on[rid])


# ---------------------------------------------------------------------------
# skinny-M decode kernels (Pallas interpret parity) + chunk-block schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 128, 128), (4, 1024, 256),
                                   (32, 96, 8)])
def test_skinny_pallas_kernel_parity(shape):
    """The skinny-M prepacked kernel (M padded to the int8 sublane, planes
    VMEM-resident) is bit-identical to the fast-GEMM reference."""
    M, K, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    p = pack_cim_weights(w, D)
    u = cim_matmul(x, w, D, use_pallas=False)
    q = cim_matmul(x, p, D, use_pallas=True)       # skinny route (M <= 32)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


@pytest.mark.parametrize("kw", [dict(n_dcim_products=0, adc_bits=9),
                                dict(n_dcim_products=6),
                                dict(acc_len=32, adc_bits=8)])
def test_skinny_pallas_nondefault_splits(kw):
    """Every deployment-plan design point routes through the skinny kernel
    at decode shapes (plane count / ADC geometry as static meta)."""
    cfg = dataclasses.replace(D, **kw)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (4, 160))
    w = jax.random.normal(k2, (160, 64))
    p = pack_cim_weights(w, cfg)
    u = cim_matmul(x, w, cfg, use_pallas=False)
    q = cim_matmul(x, p, cfg, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_chunk_block_is_pure_scheduling():
    """Any fast-GEMM chunk block gives bit-identical results (what makes
    the autotuner numerics-free), including noisy fused-segment runs."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (4, 200))
    w = jax.random.normal(k2, (200, 24))
    p = pack_cim_weights(w, D)
    nk = jax.random.PRNGKey(7)
    ka, kb = jax.random.split(nk)
    ref = packed_cim_matmul(x, p, D, noise_key=(ka, kb), use_pallas=False,
                            noise_segments=(10, 14))
    for cb in (1, 3, 8, 64):
        y = packed_cim_matmul(x, p, D, noise_key=(ka, kb), use_pallas=False,
                              noise_segments=(10, 14), chunk_block=cb)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """Tuned entries persist, reload, and drive trace-time lookups; a
    missing cache falls back to the heuristics."""
    from repro.kernels.ccim_matmul import autotune as at
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "cache.json"))
    at._state["entries"] = None          # drop state from other tests
    at.tuned_chunk_block.cache_clear()
    # heuristic fallback: skinny M collapses the scan to one step
    assert at.tuned_chunk_block(4, 64, 256, 16) == 64
    assert at.tuned_chunk_block(256, 64, 256, 16) == 16
    entry = at.autotune_chunk_block(4, 256, 64, iters=1)
    assert entry["chunk_block"] in [int(c) for c in entry["candidates_us"]]
    at.save()
    at._state["entries"] = None          # force reload from disk
    at.tuned_chunk_block.cache_clear()
    assert at.tuned_chunk_block(4, 16, 64, 16) == entry["chunk_block"]
    # and the tuned block serves bit-identically to the default
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (4, 256))
    p = pack_cim_weights(jax.random.normal(k2, (256, 64)), D)
    np.testing.assert_array_equal(
        np.asarray(packed_cim_matmul(x, p, D, use_pallas=False)),
        np.asarray(packed_cim_matmul(x, p, D, use_pallas=False,
                                     chunk_block=16)))
    at._state["entries"] = None
    at.tuned_chunk_block.cache_clear()
