"""Model-layer semantics: flash==plain attention, SSD chunked==recurrent,
prefill+decode==forward, MoE dispatch conservation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models import layers as L
from repro.models.config import ModelConfig

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
            d_ff=128, vocab_size=256, flash_block=16, dtype="float32")


def _dense_cfg(**kw):
    return ModelConfig(name="t", family="dense", **{**BASE, **kw})


def test_flash_equals_plain_attention():
    cfg_f = _dense_cfg(attn_impl="flash")
    cfg_p = _dense_cfg(attn_impl="plain")
    key = jax.random.PRNGKey(0)
    p, _ = L.attention_init(key, cfg_f, jnp.float32)
    x = jax.random.normal(key, (2, 48, cfg_f.d_model))
    pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))
    of, _ = L.attention_apply(p, x, cfg_f, pos, jnp.bool_(False))
    op, _ = L.attention_apply(p, x, cfg_p, pos, jnp.bool_(False))
    np.testing.assert_allclose(np.asarray(of), np.asarray(op),
                               rtol=2e-4, atol=2e-5)


def test_flash_equals_plain_sliding_window():
    cfg_f = _dense_cfg(attn_impl="flash", sliding_window=8,
                       layer_pattern="local_global")
    cfg_p = dataclasses.replace(cfg_f, attn_impl="plain")
    key = jax.random.PRNGKey(1)
    p, _ = L.attention_init(key, cfg_f, jnp.float32)
    x = jax.random.normal(key, (2, 40, cfg_f.d_model))
    pos = jnp.broadcast_to(jnp.arange(40)[None], (2, 40))
    for loc in (True, False):
        of, _ = L.attention_apply(p, x, cfg_f, pos, jnp.bool_(loc))
        op, _ = L.attention_apply(p, x, cfg_p, pos, jnp.bool_(loc))
        np.testing.assert_allclose(np.asarray(of), np.asarray(op),
                                   rtol=2e-4, atol=2e-5)


def test_flash_gradients_match_plain():
    """Custom-VJP flash backward == autodiff through plain attention."""
    for extra in ({}, dict(attn_softcap=50.0),
                  dict(sliding_window=8, layer_pattern="local_global")):
        cfg_f = _dense_cfg(attn_impl="flash", **extra)
        cfg_p = dataclasses.replace(cfg_f, attn_impl="plain")
        key = jax.random.PRNGKey(42)
        p, _ = L.attention_init(key, cfg_f, jnp.float32)
        x = jax.random.normal(key, (2, 48, cfg_f.d_model)) * 0.5
        pos = jnp.broadcast_to(jnp.arange(48)[None], (2, 48))

        def loss(params, xx, cfg):
            o, _ = L.attention_apply(params, xx, cfg, pos, jnp.bool_(True))
            return jnp.sum(o * o)

        gf = jax.grad(loss, argnums=(0, 1))(p, x, cfg_f)
        gp = jax.grad(loss, argnums=(0, 1))(p, x, cfg_p)
        for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)


def test_ssd_chunked_equals_recurrent():
    """Mamba2 chunked (train) path == step-by-step recurrence (decode)."""
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=32,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=64,
                      ssm_state=8, ssm_head_dim=8, ssm_chunk=8,
                      dtype="float32")
    key = jax.random.PRNGKey(2)
    p, _ = L.mamba2_init(key, cfg, jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_par, (state_par, _) = L.mamba2_apply(p, x, cfg)

    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.ssm_conv_width
    state = jnp.zeros((B, H, P, N), jnp.float32)
    conv = (jnp.zeros((B, W - 1, cfg.d_inner), jnp.float32),
            jnp.zeros((B, W - 1, 2 * N), jnp.float32))
    outs = []
    for t in range(S):
        y, (state, conv) = L.mamba2_apply(p, x[:, t:t + 1], cfg,
                                          ssm_state=state, conv_state=conv,
                                          decode=True)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_par), np.asarray(state),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("family,extra", [
    ("dense", {}),
    ("dense", dict(layer_pattern="local_global", sliding_window=8,
                   attn_softcap=50.0, logit_softcap=30.0)),
    ("moe", dict(n_experts=8, top_k=2, moe_d_ff=64, d_ff=0)),
    ("ssm", dict(ssm_state=8, ssm_head_dim=16, ssm_chunk=8, n_heads=0,
                 n_kv_heads=0, d_ff=0)),
    ("hybrid", dict(ssm_state=8, ssm_head_dim=16, ssm_chunk=8,
                    shared_attn_period=2, n_layers=4)),
])
def test_prefill_decode_matches_forward(family, extra):
    """Greedy decode after prefill == argmax of the teacher-forced logits."""
    cfg = ModelConfig(name="t", family=family, **{**BASE, **extra})
    key = jax.random.PRNGKey(3)
    params, _ = lm.init(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits_fwd, _ = lm.forward(params, cfg, toks, remat=False)

    cache = lm.init_cache(cfg, B, S + 4, dtype=jnp.float32)
    logits_pre, cache = lm.prefill(params, cfg, toks, cache)
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(logits_fwd[:, -1]),
                               rtol=5e-3, atol=5e-4)
    # decode one step with the true next token == forward on extended seq
    nxt = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0,
                             cfg.vocab_size)
    logits_dec, cache = lm.decode_step(params, cfg, nxt, cache)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    logits_fwd2, _ = lm.forward(params, cfg, toks2, remat=False)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_fwd2[:, -1]),
                               rtol=5e-3, atol=5e-4)


def test_moe_aux_loss_and_balance():
    cfg = ModelConfig(name="m", family="moe",
                      **{**BASE, "d_ff": 0},
                      n_experts=8, top_k=2, moe_d_ff=64)
    key = jax.random.PRNGKey(5)
    p, _ = L.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) > 0
    assert not bool(jnp.isnan(y).any())


def test_chunked_ce_matches_dense_ce():
    cfg = _dense_cfg()
    key = jax.random.PRNGKey(6)
    params, _ = lm.init(key, cfg)
    toks = jax.random.randint(key, (2, 40), 0, cfg.vocab_size)
    loss_chunked = lm.lm_loss(params, cfg, toks, remat=False)
    logits, aux = lm.forward(params, cfg, toks, remat=False)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, toks[:, 1:, None], axis=-1)[..., 0]
    np.testing.assert_allclose(float(loss_chunked), float(nll.mean() + aux),
                               rtol=1e-5)


def test_cim_mode_forward_and_grad():
    """The paper's macro as execution mode: close to fp output, grads flow."""
    cfg = _dense_cfg(cim_mode=True)
    cfg_fp = _dense_cfg()
    key = jax.random.PRNGKey(7)
    params, _ = lm.init(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    loss_cim = lm.lm_loss(params, cfg, toks, remat=False)
    loss_fp = lm.lm_loss(params, cfg_fp, toks, remat=False)
    assert abs(float(loss_cim) - float(loss_fp)) / float(loss_fp) < 0.2
    g = jax.grad(lambda p: lm.lm_loss(p, cfg, toks, remat=False))(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda v: float(jnp.sum(jnp.abs(v))), g))
    assert np.isfinite(gn) and gn > 0
