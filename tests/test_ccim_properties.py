"""Property tests for the CCIMConfig knob sweeps the deployment planner
relies on: for EVERY n_dcim_products in 0..6 the D/A split must be a
clean partition of the 49 bit-products, ordered by significance, with a
consistent LSB -- otherwise per-projection plans would silently change
the arithmetic rather than the design point."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # [test] extra absent: fixed-grid fallback
    from _prop_fallback import given, settings, st

from repro.core import CCIMConfig, DEFAULT_CONFIG
from repro.core.ccim import _dcim_by_j, _dcim_terms, fold_dcim_planes

NB = DEFAULT_CONFIG.n_mag_bits


def _cfg(k: int) -> CCIMConfig:
    return dataclasses.replace(DEFAULT_CONFIG, n_dcim_products=k)


@settings(deadline=None, max_examples=7)
@given(st.integers(min_value=0, max_value=6))
def test_dcim_products_ordering_and_significance(k):
    """Top-k products are sorted by significance (j+k desc, then j desc)
    and are exactly the k most significant cells of the 7x7 table."""
    cfg = _cfg(k)
    prods = cfg.dcim_products
    assert len(prods) == k
    sig = [j + kk for j, kk in prods]
    assert sig == sorted(sig, reverse=True)
    for (j1, k1), (j2, k2) in zip(prods, prods[1:]):
        assert (j1 + k1, j1) >= (j2 + k2, j2)
    # every excluded cell is no more significant than the least included
    if k:
        floor_sig = min(sig)
        border = sum(1 for j in range(NB) for kk in range(NB)
                     if j + kk > floor_sig)
        assert border <= k  # all strictly-more-significant cells included


@settings(deadline=None, max_examples=7)
@given(st.integers(min_value=0, max_value=6))
def test_dcim_lsb_consistency(k):
    """dcim_lsb == 2^(min significance of the DCIM group); the all-analog
    split keeps the prototype's 2^11 conversion scale (wider ADC instead)."""
    cfg = _cfg(k)
    if k == 0:
        assert cfg.dcim_lsb == 1 << (2 * NB - 3)   # 2^11
    else:
        assert cfg.dcim_lsb == 1 << min(j + kk for j, kk in cfg.dcim_products)
    # every DCIM weight-table entry is an exact power-of-two multiple of
    # the LSB (integer counting logic -- no fractional weights)
    t = cfg.dcim_weight_table()
    for j, kk in cfg.dcim_products:
        assert t[j, kk] * cfg.dcim_lsb == 1 << (j + kk)


@settings(deadline=None, max_examples=7)
@given(st.integers(min_value=0, max_value=6))
def test_weight_tables_partition_all_49_products(k):
    """dcim_weight_table + acim_weight_table jointly cover every (j, k)
    bit-product EXACTLY once, at its true significance 2^(j+k)."""
    cfg = _cfg(k)
    dcim = cfg.dcim_weight_table().astype(np.int64) * cfg.dcim_lsb
    acim = cfg.acim_weight_table().astype(np.int64)
    assert dcim.shape == acim.shape == (NB, NB)
    for j in range(NB):
        for kk in range(NB):
            want = 1 << (j + kk)
            got = (int(dcim[j, kk]), int(acim[j, kk]))
            # exactly one side owns the product, at full significance
            assert got in ((want, 0), (0, want)), (j, kk, got)
    assert int((dcim > 0).sum()) == k
    assert int((acim > 0).sum()) == NB * NB - k


@settings(deadline=None, max_examples=7)
@given(st.integers(min_value=0, max_value=6))
def test_folded_planes_reproduce_dcim_terms(k):
    """The folded weight planes (ONE per distinct x bit j -- the static
    plane count the prepacked kernels take as meta) reproduce the
    elementwise DCIM value for random SMF operands."""
    cfg = _cfg(k)
    key = jax.random.PRNGKey(k)
    kx, kw = jax.random.split(key)
    xq = jax.random.randint(kx, (64,), -127, 128).clip(-127, 127)
    wq = jax.random.randint(kw, (64,), -127, 128).clip(-127, 127)
    d_elem, _, _ = _dcim_terms(xq, wq, cfg)
    planes = fold_dcim_planes(wq, cfg)
    by_j = list(_dcim_by_j(cfg))
    assert len(planes) == len(by_j)                 # plane count == |{j}|
    sx = jnp.where(xq < 0, -1, 1)
    mx = jnp.abs(xq)
    folded = sum((sx * ((mx >> j) & 1)) * p for j, p in zip(by_j, planes))
    np.testing.assert_array_equal(np.asarray(folded if k else 0 * xq),
                                  np.asarray(d_elem))


@settings(deadline=None, max_examples=7)
@given(st.integers(min_value=0, max_value=6))
def test_exact_decomposition_dcim_plus_acim(k):
    """For every split, DCIM + ideal-ACIM == the exact integer product
    (the partition is lossless before the ADC)."""
    cfg = _cfg(k)
    key = jax.random.PRNGKey(100 + k)
    kx, kw = jax.random.split(key)
    xq = jax.random.randint(kx, (16,), -127, 128).clip(-127, 127)
    wq = jax.random.randint(kw, (16,), -127, 128).clip(-127, 127)
    d_elem, a_elem, _ = _dcim_terms(xq, wq, cfg)
    exact = xq.astype(jnp.int32) * wq.astype(jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(d_elem * cfg.dcim_lsb + a_elem), np.asarray(exact))
