"""Plan-cascade speculative decoding: analog draft / deployed verify from
one packed weight set (plan/draft.py, lm.verify_step, scheduler spec mode).

The load-bearing properties:

  * pack compatibility -- an all-analog config with the pack's
    ``n_mag_bits``/``acc_len`` serves the SAME PackedCimWeights a hybrid
    plan packed (the folded planes are simply never read), so the draft
    plan costs zero extra memory and zero repacks;
  * distribution identity -- greedy speculative output is BIT-identical
    to non-speculative decode (the accept rule keeps exactly the verify
    model's argmax chain), and at temperature > 0 the scheduler's
    per-request key streams keep pooled speculative runs bit-identical
    to solo speculative runs;
  * scheduler edges -- EOS landing inside an accepted draft block,
    ``max_new`` truncating mid-block, and mid-stream slot refill while
    other slots are mid-draft must all preserve token parity with solo
    and non-speculative runs.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.ccim import DEFAULT_CONFIG
from repro.core.engine import (pack_cim_weights, pack_compatible,
                               packed_cim_matmul)
from repro.launch.scheduler import (ContinuousBatchingScheduler,
                                    mixed_length_requests)
from repro.models import lm
from repro.plan import (FLOAT_ENTRY, HYBRID_ENTRY, DeploymentPlan,
                        derive_draft_plan, draft_plan_for_model,
                        draft_plan_sweep, min_adc_bits)


def _params(arch, cim=False, pack=False, seed=0):
    cfg = get_config(arch, smoke=True)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    if pack:
        params = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)
    return params, cfg


def _pool_tokens(params, cfg, requests, prompt_len, cap, slots=2,
                 temperature=0.0, draft_k=0, draft_plan=None):
    pool = ContinuousBatchingScheduler(params, cfg, slots=slots,
                                       prompt_len=prompt_len,
                                       max_new_cap=cap,
                                       temperature=temperature,
                                       draft_k=draft_k,
                                       draft_plan=draft_plan)
    report = pool.run(requests)
    return report.tokens_by_rid(), report


# ---------------------------------------------------------------------------
# pack compatibility: one pack, two plans
# ---------------------------------------------------------------------------


def _analog_cfg(base=DEFAULT_CONFIG, adc_bits=None):
    cfg = dataclasses.replace(base, n_dcim_products=0)
    return dataclasses.replace(
        cfg, adc_bits=adc_bits if adc_bits is not None else min_adc_bits(cfg))


def test_pack_compatible_predicate():
    hybrid = DEFAULT_CONFIG
    analog = _analog_cfg()
    assert pack_compatible(hybrid, hybrid)
    assert pack_compatible(hybrid, analog)
    # narrower SAR on the analog side is still the same layout
    assert pack_compatible(hybrid, dataclasses.replace(analog, adc_bits=5))
    # but an analog pack cannot serve a hybrid plan (no folded planes)...
    assert not pack_compatible(analog, hybrid)
    # ...and layout-bearing fields must match exactly
    assert not pack_compatible(
        hybrid, dataclasses.replace(analog, acc_len=hybrid.acc_len * 2))
    assert not pack_compatible(
        hybrid, dataclasses.replace(analog, n_mag_bits=hybrid.n_mag_bits - 1))


def test_hybrid_pack_serves_analog_subset_bit_identical():
    """Weights packed under the hybrid config, served under its all-analog
    shadow: bit-identical to packing under the analog config directly."""
    K, N, M = 64, 32, 4
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (K, N))
    x = jax.random.normal(kx, (M, K))
    hybrid, analog = DEFAULT_CONFIG, _analog_cfg()
    pk_h = jax.jit(pack_cim_weights, static_argnums=(1,))(w, hybrid)
    pk_a = jax.jit(pack_cim_weights, static_argnums=(1,))(w, analog)
    y_sub = packed_cim_matmul(x, pk_h, analog)
    y_ref = packed_cim_matmul(x, pk_a, analog)
    np.testing.assert_array_equal(np.asarray(y_sub), np.asarray(y_ref))
    # the hybrid pack still serves the hybrid plan unchanged
    y_h = packed_cim_matmul(x, pk_h, hybrid)
    assert np.asarray(y_h).shape == (M, N)
    # a clipping-width subset also goes through (values differ, no raise)
    packed_cim_matmul(x, pk_h, dataclasses.replace(analog, adc_bits=5))


def test_pack_mismatch_still_raises():
    K, N = 64, 32
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, K))
    pk = jax.jit(pack_cim_weights, static_argnums=(1,))(w, DEFAULT_CONFIG)
    bad = dataclasses.replace(_analog_cfg(), acc_len=DEFAULT_CONFIG.acc_len * 2)
    with pytest.raises(ValueError, match="packed for a different"):
        packed_cim_matmul(x, pk, bad)


# ---------------------------------------------------------------------------
# draft-plan derivation
# ---------------------------------------------------------------------------


def test_derive_draft_plan_entries():
    plan = DeploymentPlan.from_dict(
        {"attn.q": HYBRID_ENTRY, "lm_head": FLOAT_ENTRY},
        default=HYBRID_ENTRY)
    dp = derive_draft_plan(plan)
    by_path = dict(dp.entries)
    # float sites stay float (off-macro: draft == verify there)
    assert by_path["lm_head"] == FLOAT_ENTRY
    # CIM sites lose their DCIM planes but keep the pack-layout fields
    drafted = by_path["attn.q"]
    assert drafted.cfg.n_dcim_products == 0
    assert drafted.cfg.acc_len == HYBRID_ENTRY.cfg.acc_len
    assert drafted.cfg.n_mag_bits == HYBRID_ENTRY.cfg.n_mag_bits
    assert drafted.cfg.adc_bits == min_adc_bits(
        dataclasses.replace(HYBRID_ENTRY.cfg, n_dcim_products=0))
    assert pack_compatible(HYBRID_ENTRY.cfg, drafted.cfg)
    assert drafted.label.startswith("draft-analog0/")
    assert dp.default.cfg.n_dcim_products == 0


def test_draft_plan_sweep_widths():
    plan = DeploymentPlan.uniform(HYBRID_ENTRY)
    points = draft_plan_sweep(plan, adc_deltas=(0, -1, -2, -3))
    assert len(points) == 4
    widths = []
    for label, dp in points:
        assert pack_compatible(HYBRID_ENTRY.cfg, dp.default.cfg)
        widths.append(dp.default.cfg.adc_bits)
        assert label == f"analog0/adc{dp.default.cfg.adc_bits}"
    # strictly decreasing SAR width = strictly increasing aggressiveness
    assert widths == sorted(widths, reverse=True)
    assert len(set(widths)) == len(widths)


def test_draft_plan_for_model_global_cim():
    cfg = dataclasses.replace(get_config("minicpm-2b", smoke=True),
                              cim_mode=True)
    dp = draft_plan_for_model(cfg)
    assert dp.default.cfg.n_dcim_products == 0
    assert dp.default.fidelity == "fast"


# ---------------------------------------------------------------------------
# verify_step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cim,pack", [(False, False), (True, True)])
def test_verify_step_matches_decode_chain(cim, pack):
    """One wide verify forward over (B, S) tokens produces the same logits
    as S chained decode steps, bitwise -- for fp and packed-CIM models --
    and does NOT advance the cache position (the caller commits)."""
    arch = "minicpm-2b" if cim else "musicgen-medium"
    params, cfg = _params(arch, cim=cim, pack=pack)
    B, P, S = 2, 8, 4
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P),
                                      dtype=np.int32))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S),
                                    dtype=np.int32))
    cache = lm.init_cache(cfg, B, P + S + 1)
    _, cache = lm.prefill(params, cfg, prompt, cache)

    chain = []
    c = dict(cache)
    for i in range(S):
        lg, c = lm.decode_step(params, cfg, toks[:, i:i + 1], c)
        chain.append(lg[:, -1])
    chained = jnp.stack(chain, axis=1)

    vlg, vcache = lm.verify_step(params, cfg, toks, dict(cache))
    np.testing.assert_array_equal(np.asarray(vlg), np.asarray(chained))
    np.testing.assert_array_equal(np.asarray(vcache["pos"]),
                                  np.asarray(cache["pos"]))


def test_verify_step_rejects_recurrent_families():
    params, cfg = _params("mamba2-130m")
    cache = lm.init_cache(cfg, 1, 8)
    toks = jnp.zeros((1, 3), jnp.int32)
    with pytest.raises(NotImplementedError):
        lm.verify_step(params, cfg, toks, cache)


def test_scheduler_rejects_speculative_ssm():
    params, cfg = _params("mamba2-130m")
    with pytest.raises(NotImplementedError):
        ContinuousBatchingScheduler(params, cfg, slots=1, prompt_len=8,
                                    max_new_cap=4, draft_k=2)


# ---------------------------------------------------------------------------
# speculative scheduler: distribution identity
# ---------------------------------------------------------------------------


def test_spec_pool_greedy_bit_identical_to_nonspec():
    """Packed-CIM pool with an analog draft plan: greedy tokens are
    bit-identical to the non-speculative pool, and the report carries the
    draft counters."""
    params, cfg = _params("minicpm-2b", cim=True, pack=True)
    P, CAP = 8, 6
    reqs = mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=(3, 6, 4))
    base, _ = _pool_tokens(params, cfg, reqs, P, CAP)
    dp = draft_plan_for_model(cfg)
    got, report = _pool_tokens(params, cfg, reqs, P, CAP, draft_k=3,
                               draft_plan=dp)
    for rid, toks in base.items():
        np.testing.assert_array_equal(got[rid], toks)
    assert report.n_drafted > 0
    assert 0.0 <= report.acceptance_rate <= 1.0
    assert report.n_steps < sum(len(t) for t in base.values())


def test_aggressive_draft_rejections_stay_bit_identical():
    """A clipping draft plan (SAR far below the no-clip width) gets real
    rejections -- and the accept/correct rule still reproduces the verify
    chain exactly."""
    params, cfg = _params("minicpm-2b", cim=True, pack=True)
    P, CAP = 8, 6
    reqs = mixed_length_requests(2, P, cfg.vocab_size, stop_lengths=(6,))
    base, _ = _pool_tokens(params, cfg, reqs, P, CAP)
    dp = draft_plan_for_model(cfg, adc_bits=5)
    got, report = _pool_tokens(params, cfg, reqs, P, CAP, draft_k=3,
                               draft_plan=dp)
    for rid, toks in base.items():
        np.testing.assert_array_equal(got[rid], toks)
    assert report.acceptance_rate < 1.0   # the clipping draft does diverge


def test_temperature_spec_pool_matches_spec_solo():
    """Sampled speculative decoding: per-request key streams keep pooled
    and solo speculative runs bit-identical (same rejection-sampling and
    resample draws per round)."""
    params, cfg = _params("musicgen-medium")
    P, CAP, T = 8, 6, 0.7
    reqs = mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=(3, 6))
    solo = {}
    for r in reqs:
        toks, _ = _pool_tokens(params, cfg, [r], P, CAP, slots=1,
                               temperature=T, draft_k=3)
        solo[r.rid] = toks[r.rid]
    got, _ = _pool_tokens(params, cfg, reqs, P, CAP, temperature=T,
                          draft_k=3)
    for rid, toks in got.items():
        np.testing.assert_array_equal(toks, solo[rid])


# ---------------------------------------------------------------------------
# speculative scheduler: variable tokens-per-step edges
# ---------------------------------------------------------------------------


def test_eos_inside_accepted_draft_block():
    """Stop tokens chosen to land in the MIDDLE of an accepted draft block
    end the request exactly where the solo non-speculative stream does
    (stop token included, nothing after it emitted)."""
    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 10
    reqs = mixed_length_requests(2, P, cfg.vocab_size,
                                 stop_lengths=(CAP, CAP))
    base, _ = _pool_tokens(params, cfg, reqs, P, CAP)

    stopped, want = [], {}
    for r, k in zip(reqs, (2, 5)):     # both fall inside a k=4 draft block
        stop = int(base[r.rid][k])
        first = int(np.nonzero(base[r.rid] == stop)[0][0])
        want[r.rid] = base[r.rid][:first + 1]
        stopped.append(dataclasses.replace(r, stop_token=stop))

    got, _ = _pool_tokens(params, cfg, stopped, P, CAP, draft_k=4)
    assert len(got[stopped[0].rid]) != len(got[stopped[1].rid])
    for rid, toks in want.items():
        np.testing.assert_array_equal(got[rid], toks)
        assert got[rid][-1] == dict((r.rid, r) for r in stopped)[rid].stop_token


def test_max_new_truncates_mid_block():
    """Per-request max_new budgets that are not multiples of the draft
    block length truncate mid-block without emitting past the budget."""
    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 7
    reqs = mixed_length_requests(3, P, cfg.vocab_size, stop_lengths=(3, 7, 5))
    base, _ = _pool_tokens(params, cfg, reqs, P, CAP)
    got, _ = _pool_tokens(params, cfg, reqs, P, CAP, draft_k=4)
    for rid, toks in base.items():
        np.testing.assert_array_equal(got[rid], toks)
        assert len(got[rid]) == reqs[rid].max_new_tokens


def test_refill_mid_draft_bit_identical_to_solo():
    """3x more requests than slots: slots refill mid-stream while their
    neighbors are mid-draft; every request's tokens equal its solo
    NON-speculative run exactly (greedy identity composed with the
    refill determinism contract)."""
    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 6
    reqs = mixed_length_requests(6, P, cfg.vocab_size,
                                 stop_lengths=(2, 6, 3, 5))
    solo = {}
    for r in reqs:
        toks, _ = _pool_tokens(params, cfg, [r], P, CAP, slots=1)
        solo[r.rid] = toks[r.rid]
    got, report = _pool_tokens(params, cfg, reqs, P, CAP, draft_k=3)
    assert report.n_admits == len(reqs)
    for rid, toks in got.items():
        np.testing.assert_array_equal(toks, solo[rid])


def test_refill_mid_draft_paged_rollback_bit_identical():
    """REGRESSION -- speculative rollback on a PAGED pool while slots
    refill mid-stream.  The hazard chain this pins down: a draft/verify
    round writes KV rows up to pos+K into a slot's blocks before rolling
    ``pos`` back; meanwhile a NEIGHBORING slot is harvested and re-armed,
    which frees and re-grants pool blocks.  If rollback touched the block
    table, or if a dead/filling slot's draft writes were not redirected
    to the trash block, the recycled blocks would carry stale rows and
    tokens would diverge.  Every request must match its solo
    non-speculative contiguous run bit for bit, and the pool must recycle
    (more total block-grants than the pool holds)."""
    from repro.launch.paging import PagedLayout

    params, cfg = _params("musicgen-medium")
    P, CAP, K = 8, 6, 3
    reqs = mixed_length_requests(6, P, cfg.vocab_size,
                                 stop_lengths=(2, 6, 3, 5))
    solo = {}
    for r in reqs:
        toks, _ = _pool_tokens(params, cfg, [r], P, CAP, slots=1)
        solo[r.rid] = toks[r.rid]
    # pool of 18 usable blocks; 6 requests x ~5 blocks each (prompt +
    # budget + draft headroom) forces several free->re-grant cycles
    lay = PagedLayout(block_size=4, n_tbl=6, n_blocks=19)
    sched = ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=P, max_new_cap=CAP, draft_k=K,
        paged=lay, prefill_chunk=4)
    report = sched.run(reqs)
    assert report.n_admits == len(reqs)
    assert report.n_drafted > 0
    got = report.tokens_by_rid()
    for rid, toks in got.items():
        np.testing.assert_array_equal(toks, solo[rid])


@pytest.mark.parametrize("paged", [False, True])
def test_adaptive_draft_k_greedy_invariant(paged):
    """Adaptive draft depth (acceptance-EMA-driven rung switching) may
    change HOW MANY tokens each round drafts, never WHICH tokens are
    emitted: greedy output is bit-identical to the fixed-k scheduler at
    every rung, because accept-longest-prefix + correction reproduces
    the verify model's argmax chain at any draft depth."""
    from repro.launch.paging import PagedLayout

    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 6
    reqs = mixed_length_requests(5, P, cfg.vocab_size,
                                 stop_lengths=(2, 6, 4, 5))
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP)
    if paged:
        kw.update(paged=PagedLayout(block_size=4, n_tbl=6, n_blocks=24),
                  prefill_chunk=4)
    want = ContinuousBatchingScheduler(
        params, cfg, draft_k=4, **kw).run(reqs).tokens_by_rid()
    sched = ContinuousBatchingScheduler(
        params, cfg, draft_k=4, adaptive_draft_k=True, **kw)
    assert sched._rungs == [4, 2, 1]
    report = sched.run(reqs)
    got = report.tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert report.n_drafted > 0


def test_adaptive_draft_k_requires_speculative():
    params, cfg = _params("musicgen-medium")
    with pytest.raises(ValueError, match="adaptive_draft_k"):
        ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=8,
                                    max_new_cap=6, adaptive_draft_k=True)


# ---------------------------------------------------------------------------
# autotune cache robustness (satellite)
# ---------------------------------------------------------------------------


@pytest.fixture
def tuning_cache(tmp_path, monkeypatch):
    from repro.kernels.ccim_matmul import autotune as at
    path = tmp_path / "TUNING_CACHE.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))

    def reset():
        at._state.update(path=None, entries=None)
        at.tuned_chunk_block.cache_clear()

    reset()
    yield at, path, reset
    reset()


@pytest.mark.parametrize("garbage", [
    '{"version": 1, "entr',                       # truncated mid-write
    "not json at all {{{",                        # plain garbage
    "[1, 2, 3]",                                  # valid JSON, wrong shape
    '{"version": 1, "entries": [1, 2]}',          # entries not a dict
])
def test_corrupt_tuning_cache_falls_back_with_warning(tuning_cache, garbage):
    at, path, reset = tuning_cache
    path.write_text(garbage)
    with pytest.warns(UserWarning, match="tuning cache"):
        assert at.lookup("anything") is None
    # heuristic defaults still come out (trace-time lookups must not raise)
    from repro.core.ccim import _CHUNK_BLOCK, _SKINNY_M
    reset()
    with pytest.warns(UserWarning):
        assert at.tuned_chunk_block(4, 64, 128, 16) == 64      # skinny -> C
        assert at.tuned_chunk_block(256, 64, 128, 16) == (
            64 if 256 <= _SKINNY_M else _CHUNK_BLOCK)
    assert at.tuned_skinny_blocks(64, 128, 16, 4) is None


def test_non_dict_cache_entry_is_ignored(tuning_cache):
    at, path, reset = tuning_cache
    key = at.chunk_key(4, 64, 128, 16)
    path.write_text(
        '{"version": 1, "entries": {"%s": 7}}' % key)
    # a scalar where an entry dict belongs is dropped, not crashed on
    assert at.lookup(key) is None
    assert at.tuned_chunk_block(4, 64, 128, 16) == 64


def test_valid_cache_and_missing_cache(tuning_cache):
    at, path, reset = tuning_cache
    # missing file: silent heuristic fallback, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert at.lookup("x") is None
    key = at.chunk_key(4, 64, 128, 16)
    path.write_text(
        '{"version": 1, "entries": {"%s": {"chunk_block": 8}}}' % key)
    reset()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert at.tuned_chunk_block(4, 64, 128, 16) == 8
