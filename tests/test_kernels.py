"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape and
dtype sweeps per the brief."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ccim as core_ccim
from repro.kernels.ccim_matmul import (ccim_matmul, ccim_matmul_pallas,
                                       ccim_matmul_ref)
from repro.kernels.int8_matmul import (int8_matmul, int8_matmul_pallas,
                                       int8_matmul_ref)


def _rand_q(key, shape, dtype=jnp.int8):
    return jax.random.randint(key, shape, -127, 128).clip(-127, 127).astype(dtype)


SHAPES = [
    (8, 32, 16, dict(bm=8, bn=16, bk=32)),
    (16, 64, 8, dict(bm=8, bn=8, bk=32)),
    (32, 128, 32, dict(bm=16, bn=32, bk=64)),
    (8, 256, 128, dict(bm=8, bn=128, bk=128)),
]


@pytest.mark.parametrize("m,k,n,blocks", SHAPES)
def test_ccim_kernel_vs_ref_sweep(m, k, n, blocks):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * k + n))
    xq = _rand_q(k1, (m, k))
    wq = _rand_q(k2, (k, n))
    out = ccim_matmul_pallas(xq, wq, interpret=True, **blocks)
    ref = ccim_matmul_ref(xq, wq)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("m,k,n,blocks", SHAPES)
def test_int8_kernel_vs_ref_sweep(m, k, n, blocks):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(k1, (m, k))
    w = jax.random.normal(k2, (k, n))
    sx = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    sw = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / sw), -127, 127).astype(jnp.int8)
    out = int8_matmul_pallas(xq, wq, sx.astype(jnp.float32),
                             sw.astype(jnp.float32), interpret=True, **blocks)
    ref = int8_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_ccim_kernel_matches_core_model():
    """Kernel numerics == core's ideal-analog macro arithmetic (two
    independent implementations of the paper's dataflow)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    xq = _rand_q(k1, (16, 64), jnp.int32)
    wq = _rand_q(k2, (64, 16), jnp.int32)
    ker = ccim_matmul_pallas(xq.astype(jnp.int8), wq.astype(jnp.int8),
                             bm=16, bn=16, bk=64, interpret=True)
    core = core_ccim.cim_matmul_int(xq, wq, None, fidelity="fast")
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(core))


def test_ccim_float_wrapper_accuracy():
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(k1, (32, 256))
    w = jax.random.normal(k2, (256, 64))
    y = ccim_matmul(x, w, use_pallas=True, interpret=True)
    ref = x @ w
    fs = float(jnp.abs(x).max() * jnp.abs(w).max() * 256)
    assert float(jnp.abs(y - ref).max()) < 0.02 * fs


def test_kernel_nonaligned_padding():
    """ops.py must handle K not divisible by acc_len and ragged M/N."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(13))
    x = jax.random.normal(k1, (5, 37))
    w = jax.random.normal(k2, (37, 11))
    y = ccim_matmul(x, w, use_pallas=True, interpret=True)
    assert y.shape == (5, 11)
    ref = x @ w
    fs = float(jnp.abs(x).max() * jnp.abs(w).max() * 37)
    assert float(jnp.abs(y - ref).max()) < 0.05 * fs


def test_pick_block_prefers_padding_over_tiny_blocks():
    """Dims >= the preferred block keep it (ragged part is padded) instead
    of degrading to small non-MXU-aligned blocks; small dims round up to
    the next power of two."""
    from repro.kernels.ccim_matmul.ops import _pick_block
    assert _pick_block(96, 128) == 128   # used to shrink
    assert _pick_block(160, 128) == 128  # used to degrade to 32
    assert _pick_block(128, 128) == 128
    assert _pick_block(257, 128) == 128
    assert _pick_block(8, 128) == 8
    assert _pick_block(5, 128) == 8
    assert _pick_block(1, 128) == 1
    assert _pick_block(33, 32) == 32


@pytest.mark.parametrize("m,k,n", [(96, 96, 96), (160, 528, 40)])
def test_kernel_padded_blocks_match_ref(m, k, n):
    """Shapes that now pad up to the preferred block must stay exact."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + k + n))
    xq = _rand_q(k1, (m, k), jnp.int32)
    wq = _rand_q(k2, (k, n), jnp.int32)
    from repro.kernels.ccim_matmul.ops import ccim_matmul_int as kernel_int
    out = kernel_int(xq, wq, use_pallas=True, interpret=True)
    kp = (k + 15) // 16 * 16
    ref = ccim_matmul_ref(jnp.pad(xq, ((0, 0), (0, kp - k))),
                          jnp.pad(wq, ((0, kp - k), (0, 0))))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_int8_wrapper_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(17))
    x = jax.random.normal(k1, (16, 128)).astype(dtype)
    w = jax.random.normal(k2, (128, 32)).astype(dtype)
    y = int8_matmul(x, w, use_pallas=True, interpret=True)
    ref = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05
