"""End-to-end training/serving drivers (smoke-scale)."""
import numpy as np

from repro.launch.serve import serve
from repro.launch.train import train


def test_train_loss_decreases():
    """Loss trends down on the synthetic stream.  The signal at smoke
    scale is slow (hash-uniform tokens: the only learnable structure is
    flattening the logits toward uniform, and early global-norm clipping
    scales steps down ~9x), so compare halves of a 60-step run instead of
    the tails of a 12-step one -- the old window was inside the noise."""
    _, _, losses = train("minicpm-2b", smoke=True, steps=60, batch=4,
                         seq=48, log_every=100)
    losses = np.asarray(losses)
    assert np.isfinite(losses).all()
    assert losses[30:].mean() < losses[:30].mean(), losses


def test_train_wsd_arch_uses_wsd():
    from repro.launch.specs import make_train_step
    from repro.configs import get_config
    _, ocfg = make_train_step(get_config("minicpm-2b", smoke=True))
    assert ocfg.schedule == "wsd"


def test_serve_greedy_deterministic():
    a = serve("musicgen-medium", smoke=True, batch=2, prompt_len=16, gen=4)
    b = serve("musicgen-medium", smoke=True, batch=2, prompt_len=16, gen=4)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 4)


def test_serve_cim_mode_runs():
    """Serving with the macro emulation on every projection."""
    out = serve("minicpm-2b", smoke=True, batch=2, prompt_len=8, gen=2,
                cim=True)
    assert out.shape == (2, 2)


def test_train_cim_qat_step():
    """QAT: one train step through the macro (STE backward)."""
    _, _, losses = train("mamba2-130m", smoke=True, steps=2, batch=2,
                         seq=32, cim=True, log_every=100)
    assert np.isfinite(losses).all()
