"""Continuous-batching scheduler: slot lifecycle, determinism contract,
serving-path PRNG/bookkeeping regressions (launch/scheduler.py, serve.py).

The load-bearing property throughout: a request's tokens depend only on
(params, prompt, rid) -- never on pool placement, pool companions, or
admission time.  Every test compares pooled execution against solo runs
or a different execution plan and asserts BIT-identical tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.scheduler import (ContinuousBatchingScheduler,
                                    mixed_length_requests, sampling_key)
from repro.launch.serve import serve, serve_continuous
from repro.models import lm


def _params(arch, cim=False, pack=False, seed=0):
    cfg = get_config(arch, smoke=True)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    if pack:
        # pack under jit, like serve.py: the bit-identity contract is
        # between jit-packed and per-call conditioning (eager packing
        # fuses the scale math differently at the last bit)
        params = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)
    return params, cfg


def _solo_tokens(params, cfg, requests, prompt_len, cap, temperature=0.0):
    """Each request alone in a 1-slot pool -- the reference stream."""
    solo = ContinuousBatchingScheduler(params, cfg, slots=1,
                                       prompt_len=prompt_len,
                                       max_new_cap=cap,
                                       temperature=temperature)
    return {r.rid: solo.run([r]).tokens_by_rid()[r.rid] for r in requests}


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------


def test_eos_at_different_steps_per_slot():
    """Two pooled requests with stop tokens chosen to fire at different
    depths each end exactly where their solo stream first emits the stop
    token (stop token included in the output)."""
    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 10
    reqs = mixed_length_requests(2, P, cfg.vocab_size,
                                 stop_lengths=(CAP, CAP))
    solo = _solo_tokens(params, cfg, reqs, P, CAP)

    stopped, want = [], {}
    for r, k in zip(reqs, (2, 5)):       # stop fires at different steps
        stop = int(solo[r.rid][k])
        first = int(np.nonzero(solo[r.rid] == stop)[0][0])
        want[r.rid] = solo[r.rid][:first + 1]
        stopped.append(dataclasses.replace(r, stop_token=stop))

    pool = ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=P,
                                       max_new_cap=CAP)
    got = pool.run(stopped).tokens_by_rid()
    assert len(got[stopped[0].rid]) != len(got[stopped[1].rid])
    for rid, toks in want.items():
        np.testing.assert_array_equal(got[rid], toks)
        assert got[rid][-1] == stopped[rid].stop_token


@pytest.mark.parametrize("arch", ["musicgen-medium", "mamba2-130m",
                                  "zamba2-1.2b"])
def test_refill_bit_identical_to_solo(arch):
    """3x more requests than slots: every slot is refilled mid-stream at
    least once, and each request's tokens equal its solo run exactly --
    for attention, pure-SSM and hybrid (shared-attn) cache families."""
    params, cfg = _params(arch)
    P, CAP = 8, 6
    reqs = mixed_length_requests(6, P, cfg.vocab_size,
                                 stop_lengths=(2, 6, 3, 5))
    solo = _solo_tokens(params, cfg, reqs, P, CAP)
    pool = ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=P,
                                       max_new_cap=CAP)
    report = pool.run(reqs)
    assert report.n_admits == len(reqs)
    for rid, toks in report.tokens_by_rid().items():
        np.testing.assert_array_equal(toks, solo[rid])


def test_packed_vs_unpacked_parity_under_scheduler():
    """Prepacked CIM weights through the scheduler: bit-identical to the
    per-call conditioning path under slot refill (pack is a caching
    transform; the scheduler must preserve that)."""
    params_u, cfg = _params("minicpm-2b", cim=True)
    params_p, _ = _params("minicpm-2b", cim=True, pack=True)
    P, CAP = 8, 5
    reqs = mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=(2, 5, 3))
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP)
    got_u = ContinuousBatchingScheduler(params_u, cfg, **kw).run(reqs)
    got_p = ContinuousBatchingScheduler(params_p, cfg, **kw).run(reqs)
    for rid, toks in got_u.tokens_by_rid().items():
        np.testing.assert_array_equal(got_p.tokens_by_rid()[rid], toks)


def test_temperature_pool_matches_solo():
    """Sampled decoding: per-request PRNG streams (fold_in by rid) make
    temperature > 0 runs bit-identical between pool and solo."""
    params, cfg = _params("musicgen-medium")
    P, CAP = 8, 6
    reqs = mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=(3, 6))
    solo = _solo_tokens(params, cfg, reqs, P, CAP, temperature=0.7)
    pool = ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=P,
                                       max_new_cap=CAP, temperature=0.7)
    for rid, toks in pool.run(reqs).tokens_by_rid().items():
        np.testing.assert_array_equal(toks, solo[rid])


def test_continuous_matches_lockstep_and_reports():
    """End-to-end driver: continuous vs lock-step token parity is asserted
    inside serve_continuous; stats expose occupancy/latency/steps."""
    _, stats = serve_continuous("musicgen-medium", slots=2, prompt_len=8,
                                n_requests=4, stop_lengths=(2, 6, 4),
                                repeats=1)
    assert stats["tokens_match_lockstep"]
    cont, lock = stats["continuous"], stats["lockstep"]
    assert cont["total_tokens"] == lock["total_tokens"]
    assert cont["n_steps"] < lock["n_steps"]          # the scheduling win
    assert cont["occupancy"] > lock["occupancy"]
    for row in (cont, lock):
        assert row["p50_s"] <= row["p95_s"] <= row["wall_s"] + 1e-6


def test_reset_slot_zeroes_one_slot():
    cfg = get_config("zamba2-1.2b", smoke=True)  # ssm + conv + shared kv
    cache = lm.init_cache(cfg, 2, 8)
    cache = {k: v + jnp.ones((), v.dtype) for k, v in cache.items()}
    cache = lm.reset_slot(cache, jnp.int32(1))
    for k, v in cache.items():
        axis = 0 if k == "pos" else 1
        kept = np.asarray(jnp.take(v, 0, axis=axis))
        zeroed = np.asarray(jnp.take(v, 1, axis=axis))
        assert (kept == 1).all(), k
        assert (zeroed == 0).all(), k


# ---------------------------------------------------------------------------
# serving-path PRNG regressions (serve.py)
# ---------------------------------------------------------------------------


def test_sampling_stream_differs_from_init():
    """serve.py used to feed PRNGKey(seed) to both lm.init and the
    sampler; the sampling stream must be a distinct fold of the seed."""
    for seed in (0, 1, 7):
        init_key = jax.random.PRNGKey(seed)
        skey = sampling_key(seed)
        assert np.asarray(init_key != skey).any(), seed
        # and the streams they induce diverge
        a = jax.random.uniform(init_key, (4,))
        b = jax.random.uniform(skey, (4,))
        assert not np.allclose(np.asarray(a), np.asarray(b)), seed


def test_first_token_sampled_with_temperature():
    """The first post-prefill token goes through the sampler too (it used
    to be unconditionally greedy while later tokens sampled)."""
    greedy = serve("musicgen-medium", batch=4, prompt_len=8, gen=2)
    hot = serve("musicgen-medium", batch=4, prompt_len=8, gen=2,
                temperature=8.0)
    assert (greedy[:, 0] != hot[:, 0]).any()
    # determinism per seed is preserved
    hot2 = serve("musicgen-medium", batch=4, prompt_len=8, gen=2,
                 temperature=8.0)
    np.testing.assert_array_equal(hot, hot2)


def test_vlm_prefill_tok_s_counts_frontend_tokens():
    """prefill_tok_s must count the n_frontend_tokens the vlm family
    prepends, not just the text prompt."""
    cfg = get_config("paligemma-3b", smoke=True)
    batch, prompt_len = 2, 8
    _, stats = serve("paligemma-3b", batch=batch, prompt_len=prompt_len,
                     gen=2, return_stats=True)
    implied_len = stats["prefill_tok_s"] * stats["prefill_s"] / batch
    true_len = prompt_len + cfg.n_frontend_tokens
    assert abs(implied_len - true_len) / true_len < 0.05, stats
