"""Device-resident serving telemetry (repro.obs + scheduler rings).

The telemetry contract has three load-bearing clauses, each pinned here:

1. OBSERVER EFFECT = ZERO TOKENS: a metrics-on scheduler emits tokens
   bit-identical to the metrics-off one, across greedy and sampled
   decoding and all three loop variants (contiguous / paged /
   speculative).  Rings only read values the loop already computes.
2. RINGS TELL THE TRUTH: the TTFT read back from the device event ring
   equals the instrumented runner's host-observed ``first_iter`` exactly
   (iteration units, no estimation), and ring overflow saturates --
   counters stay exact, rows drop, tokens never corrupt.
3. THE OFF SWITCH IS REAL: metrics-off lowering is deterministic and
   contains no donation scaffolding; metrics-on compiles a separate
   executable (cross-commit byte-identity of the off program is gated in
   benchmarks/serve_bench.py --check-regression).

Plus the host half: the Prometheus exposition must parse.
"""
import dataclasses
import json
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.paging import PagedLayout
from repro.launch.scheduler import (ContinuousBatchingScheduler,
                                    mixed_length_requests)
from repro.models import lm
from repro.obs import MetricsRegistry, ObsConfig, scheduler_fingerprint
from repro.obs import rings as R

P, CAP = 8, 4
STOPS = (2, 4, 3, 4)


@pytest.fixture(scope="module")
def packed_cim():
    """Packed CIM params: the serving-shaped tree, so the metrics-on path
    exercises the ADC-clip taps through the packed GEMM."""
    cfg = get_config("minicpm-2b", smoke=True)
    cfg = dataclasses.replace(cfg, cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(0), cfg)
    packed = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)
    return packed, cfg


def _variant_kwargs(variant):
    if variant == "paged":
        return dict(paged=PagedLayout(block_size=8, n_tbl=2, n_blocks=12))
    if variant == "speculative":
        return dict(draft_k=2)
    return {}


def _requests(cfg):
    return mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=STOPS)


# ---------------------------------------------------------------------------
# 1. metrics on/off token bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("variant", ["contiguous", "paged", "speculative"])
def test_tokens_bit_identical_on_off(packed_cim, variant, temperature):
    params, cfg = packed_cim
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP,
              temperature=temperature, **_variant_kwargs(variant))
    reqs = _requests(cfg)
    off = ContinuousBatchingScheduler(params, cfg, **kw).run(reqs)
    on = ContinuousBatchingScheduler(params, cfg, obs=ObsConfig(),
                                     **kw).run(reqs)
    assert off.obs is None and on.obs is not None
    want = off.tokens_by_rid()
    got = on.tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"request {rid}: telemetry rings changed tokens "
                    f"({variant}, T={temperature})")
    snap = on.obs
    assert snap.counters["tokens"] == sum(len(t) for t in want.values())
    # every request has a complete admit/first/finish span on the ring
    assert sorted(s["rid"] for s in snap.spans) == sorted(want)
    for s in snap.spans:
        assert s["admit_iter"] is not None
        assert s["first_iter"] is not None and s["finish_iter"] is not None
        assert s["admit_iter"] <= s["first_iter"] <= s["finish_iter"]
    if variant == "speculative":
        # draft plan == serve plan here, so greedy acceptance is total
        assert snap.acceptance_rate == snap.acceptance_rate  # not NaN
    if variant == "paged":
        assert snap.min_free_blocks is not None


# ---------------------------------------------------------------------------
# 2. ring truth: TTFT and overflow
# ---------------------------------------------------------------------------


def test_ring_ttft_equals_instrumented_first_iter(packed_cim):
    params, cfg = packed_cim
    sched = ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=P,
                                        max_new_cap=CAP, obs=ObsConfig())
    reqs = _requests(cfg)
    rep = sched.run(reqs)
    assert rep.obs.ttft_iters == {f.rid: f.first_iter
                                  for f in rep.finished}
    ri, _ = sched.run_instrumented(reqs)
    assert rep.obs.ttft_iters == {f.rid: f.first_iter
                                  for f in ri.finished}


def test_ring_overflow_saturates_without_corrupting_tokens(packed_cim):
    params, cfg = packed_cim
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP)
    reqs = _requests(cfg)
    want = ContinuousBatchingScheduler(params, cfg, **kw).run(
        reqs).tokens_by_rid()
    # 4 requests x 3 events each = 12 event rows into a 4-row ring, and
    # an iteration ring far smaller than the workload's n_iter
    tiny = ContinuousBatchingScheduler(
        params, cfg, obs=ObsConfig(event_cap=4, iter_cap=2), **kw)
    rep = tiny.run(reqs)
    snap = rep.obs
    got = rep.tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert snap.dropped_events == 3 * len(reqs) - 4
    assert len(snap.events) == 4          # the recorded prefix survives
    assert snap.recorded_iters == 2
    # counters are saturating scalars, not ring rows: still exact
    assert snap.counters["tokens"] == sum(len(t) for t in want.values())
    assert json.dumps(snap.to_dict())     # harvest stays JSON-able


def test_ring_push_saturation_unit():
    obs = R.init_obs_state(ObsConfig(event_cap=3, iter_cap=2))
    for i in range(5):
        obs = R.ring_push(obs, R.EV_ADMIT, i, 10 + i)
    assert int(obs["ev_n"]) == 5          # attempts keep counting
    np.testing.assert_array_equal(np.asarray(obs["ev"])[:, 1], [0, 1, 2])
    # a gated push neither writes nor advances the cursor
    obs2 = R.ring_push(obs, R.EV_FINISH, 9, 99, do=False)
    assert int(obs2["ev_n"]) == 5
    np.testing.assert_array_equal(np.asarray(obs2["ev"]),
                                  np.asarray(obs["ev"]))


# ---------------------------------------------------------------------------
# 3. the off switch
# ---------------------------------------------------------------------------


def test_metrics_off_lowering_deterministic_and_donation_free(packed_cim):
    params, cfg = packed_cim
    mk = lambda **kw: ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=P, max_new_cap=CAP, **kw)
    fp_off = scheduler_fingerprint(mk(), 2)
    assert scheduler_fingerprint(mk(), 2) == fp_off   # deterministic
    fp_on = scheduler_fingerprint(mk(obs=ObsConfig()), 2)
    assert fp_on != fp_off                # separate executables
    text_off = mk().loop_hlo_text(2)
    text_on = mk(obs=ObsConfig()).loop_hlo_text(2)
    # off: no donation scaffolding at all; on: every ring leaf aliases
    assert "tf.aliasing_output" not in text_off
    assert text_on.count("tf.aliasing_output") >= len(R.OBS_LEAVES)
    # capacities are part of the static shape: a different ring size is
    # a different executable, never a runtime reallocation
    assert fp_on != scheduler_fingerprint(
        mk(obs=ObsConfig(event_cap=8, iter_cap=8)), 2)


# ---------------------------------------------------------------------------
# host half: exposition format
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [^ ]+$")


def test_prometheus_exposition_parses(packed_cim):
    params, cfg = packed_cim
    sched = ContinuousBatchingScheduler(params, cfg, slots=2, prompt_len=P,
                                        max_new_cap=CAP, obs=ObsConfig())
    snap = sched.run(_requests(cfg)).obs
    reg = MetricsRegistry()
    snap.register(reg)
    text = reg.export_prometheus()
    assert text.endswith("\n")
    typed = set()
    for line in text.splitlines():
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split()
            assert kind in ("counter", "gauge", "histogram")
            typed.add(name)
        elif not line.startswith("#"):
            assert _SAMPLE_RE.match(line), f"unparseable sample: {line!r}"
    assert {"serve_tokens_total", "serve_ttft_seconds",
            "serve_occupancy"} <= typed
    # histogram invariants: cumulative buckets, +Inf == _count
    h = reg.histogram("serve_ttft_seconds")
    cum = np.cumsum(h.counts)
    assert (np.diff(cum) >= 0).all()
    assert cum[-1] == h.count == len(snap.ttft_iters)

    # the JSON snapshot mirrors the same samples
    js = reg.snapshot()
    assert js["serve_tokens_total"][0]["value"] == snap.counters["tokens"]
    assert js["serve_ttft_seconds"][0]["count"] == len(snap.ttft_iters)


# ---------------------------------------------------------------------------
# 7. degenerate rings: empty workloads and fully-dropped event rings
# ---------------------------------------------------------------------------


def test_harvest_empty_workload_is_nan_safe():
    """Zero iterations, zero tokens: every derived statistic must come
    back NaN (never a ZeroDivisionError or an empty-percentile crash),
    serialize as None, and publish no NaN gauges."""
    cfg = ObsConfig()
    snap = R.harvest_obs(cfg, jax.device_get(R.init_obs_state(cfg)),
                         n_iter=0, wall_s=0.0, slots=2, n_steps=0)
    assert snap.counters["tokens"] == 0 and snap.ttft_iters == {}
    p = snap.ttft_percentiles_iters()
    assert p["ttft_p50_iters"] != p["ttft_p50_iters"]      # NaN, no crash
    d = snap.to_dict()
    json.dumps(d)                                          # NaN-free JSON
    assert d["ttft_p50_iters"] is None and d["ttft_p95_s"] is None
    assert d["occupancy_mean"] is None
    reg = MetricsRegistry()
    snap.register(reg)
    text = reg.export_prometheus()
    assert "serve_occupancy" not in text                   # NaN gauge skipped
    assert "serve_stall_factor_iters" not in text
    assert reg.histogram("serve_ttft_seconds").count == 0


def test_harvest_fully_dropped_event_ring():
    """A saturated event ring that lost every first-token row: spans are
    partial, TTFT is empty, percentiles are NaN -- and the snapshot
    still serializes and registers cleanly."""
    cfg = ObsConfig(event_cap=2)
    obs = R.init_obs_state(cfg)
    for rid in range(4):                  # 4 admits into a 2-row ring
        obs = R.ring_push(obs, R.EV_ADMIT, rid, rid)
    snap = R.harvest_obs(cfg, jax.device_get(obs), n_iter=4, wall_s=0.1,
                         slots=2, n_steps=4)
    assert snap.dropped_events == 2
    assert snap.ttft_iters == {}
    assert all(s["first_iter"] is None for s in snap.spans)
    d = snap.to_dict()
    json.dumps(d)
    assert d["ttft_p95_iters"] is None
    reg = MetricsRegistry()
    snap.register(reg)
    assert "NaN" not in reg.export_prometheus()
