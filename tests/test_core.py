"""Core macro-model tests: bit-true arithmetic, ADC, error statistics."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # [test] extra absent: fixed-grid fallback
    from _prop_fallback import given, settings, st

from repro.core import (
    DEFAULT_CONFIG, baselines, cim_matmul, cim_matmul_int,
    complex_cim_matmul, contribution_table, costmodel, fabricate,
    hybrid_mac_bit_true, hybrid_mac_fast, hybrid_mac_ideal, ideal_macro,
    quantize_smf, sar_adc, smf_scale,
)

CFG = DEFAULT_CONFIG


def _rand_q(key, shape):
    return jax.random.randint(key, shape, -127, 128).clip(-127, 127)


# ---------------------------------------------------------------------------
# construction facts from the paper
# ---------------------------------------------------------------------------


def test_top3_contribution_is_half():
    ct = contribution_table(CFG)
    top3 = float(np.sort(ct.flatten())[-3:].sum())
    assert abs(top3 - 0.508) < 0.002  # paper Fig.2: "half"


def test_dcim_range_pm64():
    assert CFG.dcim_max == 64  # paper: DCIM in [-64, +64]
    assert CFG.dcim_products == ((6, 6), (6, 5), (5, 6))
    assert CFG.dcim_lsb == 2 ** 11


def test_acim_fits_7bit_adc():
    """Max |ACIM|/2^11 = 62 < 64: the hybrid split makes 7b sufficient."""
    full = jnp.full((1, 16), 127)
    out = hybrid_mac_ideal(full, full, CFG)
    # all-max inputs: exact = 16*127^2; DCIM = 64; code <= 62
    assert int(out[0]) == 16 * 127 * 127 // 2048  # == 126


def test_adc_dnl_sizing_rule():
    assert abs(costmodel.adc_dnl_lsb_rms(CFG) - 0.33) < 0.01  # paper: 0.33


def test_density_matches_paper():
    assert abs(costmodel.density_mb_per_mm2() - 1.80) < 0.02


# ---------------------------------------------------------------------------
# bit-true arithmetic
# ---------------------------------------------------------------------------


def test_ideal_macro_error_at_most_half_adc_lsb():
    key = jax.random.PRNGKey(1)
    xq = _rand_q(key, (64, 16))
    wq = _rand_q(jax.random.PRNGKey(2), (64, 16))
    out = hybrid_mac_bit_true(xq, wq, ideal_macro(CFG), CFG)
    err = np.asarray(out["y8"] * CFG.dcim_lsb - out["exact"])
    assert np.abs(err).max() <= CFG.dcim_lsb // 2  # rounding only


def test_fast_equals_bit_true_for_ideal_macro():
    key = jax.random.PRNGKey(3)
    xq = _rand_q(key, (32, 16))
    wq = _rand_q(jax.random.PRNGKey(4), (32, 16))
    a = hybrid_mac_bit_true(xq, wq, ideal_macro(CFG), CFG)
    b = hybrid_mac_fast(xq, wq, None, CFG)
    np.testing.assert_array_equal(a["y8"], b["y8"])
    np.testing.assert_array_equal(a["dcim"], b["dcim"])
    np.testing.assert_array_equal(a["a_ideal"], b["a_ideal"])


def test_fast_noise_moment_matches_bit_true():
    """Fast path's matched Gaussian ~ bit-true mismatch std (2nd moment).

    Compared with dynamic (comparator) noise off, isolating the cap-
    mismatch term whose variance the fast path matches analytically."""
    import dataclasses
    cfg = dataclasses.replace(CFG, comparator_noise_lsb=0.0,
                              sigma_vref_pol=0.0)
    n = 4000
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    xq = _rand_q(ks[0], (n, 16))
    wq = _rand_q(ks[1], (n, 16))
    macro = fabricate(ks[2], cfg)
    bt = hybrid_mac_bit_true(xq, wq, macro, cfg)
    err_bt = np.asarray(bt["a_real"] - bt["a_ideal"], np.float64)
    ft = hybrid_mac_fast(xq, wq, ks[3], cfg)
    err_ft = np.asarray(ft["a_real"] - ft["a_ideal"], np.float64)
    # same scale within 25% (bit-true has per-die frozen pattern)
    assert 0.75 < err_ft.std() / max(err_bt.std(), 1e-9) < 1.33


def test_sar_adc_ideal_is_midtread_rounding():
    v = jnp.linspace(-63.4, 62.4, 253)
    code = sar_adc(v, jnp.zeros((7,)), CFG)
    np.testing.assert_array_equal(np.asarray(code),
                                  np.clip(np.floor(np.asarray(v) + 0.5),
                                          -64, 63))


def test_sar_adc_monotonic_with_mismatch():
    macro = fabricate(jax.random.PRNGKey(7), CFG)
    v = jnp.linspace(-64, 63, 1000)
    code = np.asarray(sar_adc(v, macro.adc_cap_eps, CFG))
    assert (np.diff(code) >= 0).all()  # SAR with cap mismatch stays monotone


# ---------------------------------------------------------------------------
# RMS error: the paper's headline accuracy claim (Fig. 6)
# ---------------------------------------------------------------------------


def test_rms_error_near_paper_value():
    """Uniform inputs, bit-true hybrid path: RMS ~ 0.435% of full scale."""
    n = 8192
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    xq = _rand_q(ks[0], (n, 16))
    wq = _rand_q(ks[1], (n, 16))
    macro = fabricate(ks[2], CFG)
    out = hybrid_mac_bit_true(xq, wq, macro, CFG, noise_key=ks[3])
    err = np.asarray(out["y8"] * CFG.dcim_lsb - out["exact"], np.float64)
    fs = 2 * 64 * CFG.dcim_lsb  # output full scale (8b at 2^11)
    rms_pct = 100 * np.sqrt(np.mean((err / fs) ** 2))
    # paper: 0.435% measured (model calibrated: 0.45 +/- 0.05 here)
    assert 0.35 < rms_pct < 0.55, rms_pct
    # and the static-only (mismatch + rounding) floor sits below it
    out0 = hybrid_mac_bit_true(xq, wq, macro, CFG)
    err0 = np.asarray(out0["y8"] * CFG.dcim_lsb - out0["exact"], np.float64)
    rms0 = 100 * np.sqrt(np.mean((err0 / fs) ** 2))
    assert rms0 < rms_pct


def test_hybrid_beats_all_analog():
    """The paper's motivation: all-analog CIM has worse MSB mismatch.

    Static mismatch isolated (no dynamic noise / polarity asymmetry);
    averaged over dies so a lucky draw can't flip the comparison."""
    import dataclasses
    cfg_h = dataclasses.replace(CFG, sigma_vref_pol=0.0)
    cfg_a = dataclasses.replace(baselines.all_analog_config(CFG),
                                sigma_vref_pol=0.0)
    n = 4096
    ks = jax.random.split(jax.random.PRNGKey(13), 2)
    xq = _rand_q(ks[0], (n, 16))
    wq = _rand_q(ks[1], (n, 16))

    def die_std(cfg, seed):
        macro = fabricate(jax.random.PRNGKey(seed), cfg)
        out = hybrid_mac_bit_true(xq, wq, macro, cfg)
        return np.asarray(out["y8"] * cfg.dcim_lsb - out["exact"],
                          np.float64).std()

    std_h = np.mean([die_std(cfg_h, s) for s in range(3)])
    std_a = np.mean([die_std(cfg_a, s) for s in range(3)])
    assert std_h < std_a, (std_h, std_a)


# ---------------------------------------------------------------------------
# GEMM + complex paths
# ---------------------------------------------------------------------------


def test_cim_matmul_int_matches_chunked_ideal():
    key = jax.random.PRNGKey(17)
    xq = _rand_q(key, (8, 64))
    wq = _rand_q(jax.random.PRNGKey(18), (64, 8))
    y = cim_matmul_int(xq, wq, None, CFG, None, "fast")
    exact = np.asarray(xq) @ np.asarray(wq)
    # 4 chunks, each off by <= 2^10
    assert np.abs(np.asarray(y) - exact).max() <= 4 * CFG.dcim_lsb // 2


def test_complex_mac_accuracy():
    key = jax.random.PRNGKey(19)
    k1, k2, k3 = jax.random.split(key, 3)
    x = (jax.random.normal(k1, (8, 64)) + 1j * jax.random.normal(k2, (8, 64))
         ).astype(jnp.complex64)
    w = (jax.random.normal(k2, (64, 8)) + 1j * jax.random.normal(k3, (64, 8))
         ).astype(jnp.complex64)
    y = complex_cim_matmul(x, w, CFG, noise_key=key)
    ref = x @ w
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.25  # random-sum cancellation inflates rel err; FS-relative
    # full-scale-relative error is what the paper reports:
    fs = float(jnp.abs(ref).max())
    assert float(jnp.abs(y - ref).max()) / fs < 0.2


def test_figS1_cost_savings_directionally_match():
    s = costmodel.figS1_comparison(CFG)["savings"]
    assert 25 < s["area_pct_vs_duplicated"] < 45      # paper: 35%
    assert 50 < s["latency_pct_vs_sequential"] < 60   # paper: 54%
    assert 15 < s["power_pct_vs_duplicated"] < 33     # paper: 24%


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_quantize_range(seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (32,)) * 10
    s = smf_scale(x)
    q = quantize_smf(x, s)
    assert int(jnp.max(jnp.abs(q))) <= 127
    assert int(jnp.max(jnp.abs(q))) == 127  # max-abs scaling is tight


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_ideal_macro_halflsb(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    xq = _rand_q(k1, (4, 16))
    wq = _rand_q(k2, (4, 16))
    out = hybrid_mac_fast(xq, wq, None, CFG)
    err = np.abs(np.asarray(out["y8"] * CFG.dcim_lsb - out["exact"]))
    assert err.max() <= CFG.dcim_lsb // 2
    assert np.abs(np.asarray(out["dcim"])).max() <= CFG.dcim_max


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_prop_gemm_scale_invariance(seed, m):
    """Dequantized CIM GEMM error is bounded relative to full scale."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, 32))
    w = jax.random.normal(k2, (32, 4))
    y = cim_matmul(x, w, CFG)
    ref = x @ w
    fs = float(jnp.abs(x).max() * jnp.abs(w).max() * 32)
    assert float(jnp.abs(y - ref).max()) < 0.05 * fs
