"""Parity tests for the single-pass complex kernel and the matmul-ized
fast path: both must be bit-identical to the implementations they replace
(fused kernel vs 4-call reference; batched-matmul fast GEMM vs the legacy
elementwise-broadcast formulation), across ragged shapes and with/without
the injected noise draw."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ccim as core_ccim
from repro.core.complex_mac import complex_cim_matmul_int
from repro.kernels.ccim_complex import (ccim_complex_matmul,
                                        ccim_complex_matmul_int,
                                        ccim_complex_matmul_pallas,
                                        ccim_complex_matmul_ref)


def _rand_q(key, shape, dtype=jnp.int32):
    return jax.random.randint(key, shape, -127, 128).clip(-127, 127).astype(dtype)


def _complex_operands(seed, m, k, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (_rand_q(ks[0], (m, k)), _rand_q(ks[1], (m, k)),
            _rand_q(ks[2], (k, n)), _rand_q(ks[3], (k, n)))


SHAPES = [
    (8, 32, 16, dict(bm=8, bn=16, bk=32)),
    (16, 64, 8, dict(bm=8, bn=8, bk=32)),
    (32, 128, 32, dict(bm=16, bn=32, bk=64)),
    (8, 256, 128, dict(bm=8, bn=128, bk=128)),
]


@pytest.mark.parametrize("m,k,n,blocks", SHAPES)
def test_fused_complex_kernel_vs_4call_ref(m, k, n, blocks):
    xr, xi, wr, wi = _complex_operands(m * k + n, m, k, n)
    i8 = lambda v: v.astype(jnp.int8)
    yr, yi = ccim_complex_matmul_pallas(i8(xr), i8(xi), i8(wr), i8(wi),
                                        interpret=True, **blocks)
    rr, ri = ccim_complex_matmul_ref(xr, xi, wr, wi)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(ri))


@pytest.mark.parametrize("m,k,n", [
    (5, 37, 11),     # everything ragged, K odd
    (16, 80, 32),    # K a multiple of acc_len but not of bk
    (96, 96, 96),    # dims that used to degrade _pick_block to bm=32
    (3, 16, 3),      # single chunk
])
def test_fused_complex_ops_wrapper_ragged(m, k, n):
    """ops.py padding must keep the fused kernel bit-identical to the
    4-call core reference on shapes the block picker has to pad."""
    xr, xi, wr, wi = _complex_operands(1000 + m * k + n, m, k, n)
    yr, yi = ccim_complex_matmul_int(xr, xi, wr, wi,
                                     use_pallas=True, interpret=True)
    rr, ri = complex_cim_matmul_int(xr, xi, wr, wi, None,
                                    fidelity="fast", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(yi), np.asarray(ri))


@pytest.mark.parametrize("m,k,n", [
    (8, 64, 8),      # aligned
    (5, 37, 11),     # odd K, ragged M/N
    (16, 80, 32),    # K not divisible by the scan block's acc_len span
    (7, 129, 9),     # K % acc_len == 1
])
@pytest.mark.parametrize("with_noise", [False, True])
def test_fast_matmulized_vs_broadcast_bit_identical(m, k, n, with_noise):
    ks = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n), 3)
    xq = _rand_q(ks[0], (m, k))
    wq = _rand_q(ks[1], (k, n))
    nk = ks[2] if with_noise else None
    new = core_ccim.cim_matmul_int(xq, wq, None, noise_key=nk,
                                   fidelity="fast", use_pallas=False)
    old = core_ccim.cim_matmul_int(xq, wq, None, noise_key=nk,
                                   fidelity="fast_broadcast")
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


@pytest.mark.parametrize("with_noise", [False, True])
def test_complex_4call_matmulized_vs_broadcast(with_noise):
    xr, xi, wr, wi = _complex_operands(77, 8, 48, 8)
    nk = jax.random.PRNGKey(5) if with_noise else None
    new = complex_cim_matmul_int(xr, xi, wr, wi, None, noise_key=nk,
                                 fidelity="fast", use_pallas=False)
    old = complex_cim_matmul_int(xr, xi, wr, wi, None, noise_key=nk,
                                 fidelity="fast_broadcast")
    for a, b in zip(new, old):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_float_wrapper_accuracy():
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(11), 4)
    x = (jax.random.normal(k1, (16, 128))
         + 1j * jax.random.normal(k2, (16, 128))).astype(jnp.complex64)
    w = (jax.random.normal(k3, (128, 16))
         + 1j * jax.random.normal(k4, (128, 16))).astype(jnp.complex64)
    y = ccim_complex_matmul(x, w, use_pallas=True, interpret=True)
    ref = x @ w
    fs = float(jnp.abs(ref).max())
    assert float(jnp.abs(y - ref).max()) / fs < 0.2


def test_complex_dispatch_prefers_fused_kernel():
    """complex_cim_matmul_int(use_pallas=True) must match the fused ops
    wrapper exactly (it routes there for noise-free fast GEMMs)."""
    xr, xi, wr, wi = _complex_operands(23, 8, 64, 8)
    via_dispatch = complex_cim_matmul_int(xr, xi, wr, wi, None,
                                          fidelity="fast", use_pallas=True)
    direct = ccim_complex_matmul_int(xr, xi, wr, wi,
                                     use_pallas=True, interpret=True)
    for a, b in zip(via_dispatch, direct):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
