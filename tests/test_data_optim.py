"""Data pipeline determinism/skip-ahead + optimizer/schedule tests."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # [test] extra absent: fixed-grid fallback
    from _prop_fallback import given, settings, st

from repro.data import DataConfig, Prefetcher, batch_at
from repro.optim import (OptConfig, adamw_update,
                         init_opt_state, warmup_cosine, wsd)

DCFG = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)


def test_data_deterministic_and_step_indexed():
    a = batch_at(DCFG, 5)["tokens"]
    b = batch_at(DCFG, 5)["tokens"]
    c = batch_at(DCFG, 6)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()
    assert a.min() >= 0 and a.max() < DCFG.vocab_size


def test_data_shards_partition_global_batch():
    full = batch_at(DCFG, 7)["tokens"]
    parts = [batch_at(DCFG, 7, shard=i, n_shards=4)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_prefetcher_matches_batch_at():
    pf = Prefetcher(DCFG, start_step=2)
    try:
        s, b = next(pf)
        assert s == 2
        np.testing.assert_array_equal(b["tokens"], batch_at(DCFG, 2)["tokens"])
        s, b = next(pf)
        assert s == 3
    finally:
        pf.close()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8))
def test_prop_data_shard_consistency(step, n_shards):
    cfg = DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1)
    full = batch_at(cfg, step)["tokens"]
    if cfg.global_batch % n_shards:
        return
    parts = [batch_at(cfg, step, i, n_shards)["tokens"]
             for i in range(n_shards)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    ocfg = OptConfig(peak_lr=0.15, warmup=5, total_steps=200,
                     weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, ocfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = adamw_update(params, g, state, ocfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_wsd_phases():
    kw = dict(peak_lr=1.0, warmup=10, total=100)
    assert float(wsd(5, **kw)) < 1.0                  # warming up
    assert abs(float(wsd(50, **kw)) - 1.0) < 1e-6     # stable
    assert float(wsd(99, **kw)) < 0.2                 # decaying
    assert float(warmup_cosine(100, **kw)) <= 0.11    # cosine floor


def test_moment_dtype_bf16_halves_memory():
    params = {"w": jnp.zeros((128, 128), jnp.bfloat16)}
    s32 = init_opt_state(params, OptConfig(moment_dtype="float32"))
    s16 = init_opt_state(params, OptConfig(moment_dtype="bfloat16"))
    assert s32["m"]["w"].dtype == jnp.float32
    assert s16["m"]["w"].dtype == jnp.bfloat16


def test_grad_clip_applied():
    ocfg = OptConfig(peak_lr=1e-3, warmup=1, total_steps=10, grad_clip=1.0,
                     weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params, ocfg)
    huge = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw_update(params, huge, state, ocfg)
    assert np.isfinite(np.asarray(p2["w"])).all()
    assert float(jnp.abs(p2["w"]).max()) < 1.0
