"""Fault tolerance: atomic checkpoints, resume-exactness, failure injection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.train import train


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 3, t, meta={"arch": "x"})
    assert ckpt.latest_step(d) == 3
    r = ckpt.restore(d, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_meta(d)["arch"] == "x"


def test_keep_last_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        ckpt.save(d, s, _tree(), keep_last=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and ckpt.latest_step(d) == 5


def test_no_tmp_dirs_left(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    assert not [x for x in os.listdir(d) if x.startswith("tmp")]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "none"), _tree())


def test_resume_is_bitwise_exact(tmp_path):
    """Train 6 straight vs 3 + crash + resume 3: same final loss."""
    d = str(tmp_path / "ck")
    _, _, losses_full = train("minicpm-2b", smoke=True, steps=6,
                              batch=2, seq=32, ckpt_dir="", log_every=100)
    with pytest.raises(RuntimeError, match="injected failure"):
        train("minicpm-2b", smoke=True, steps=6, batch=2, seq=32,
              ckpt_dir=d, ckpt_every=3, fail_at=4, log_every=100)
    _, _, losses_resumed = train("minicpm-2b", smoke=True, steps=6,
                                 batch=2, seq=32, ckpt_dir=d, resume=True,
                                 ckpt_every=3, log_every=100)
    np.testing.assert_allclose(losses_full[3:], losses_resumed,
                               rtol=1e-5, atol=1e-6)


def test_restore_reshard_to_mesh(tmp_path):
    """Elastic path: checkpoint restores under a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(d, 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = ckpt.restore(d, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]


# ---------------------------------------------------------------------------
# corrupt-checkpoint hardening: every failure mode surfaces as a
# CheckpointError naming the problem, never a bare KeyError/zlib error
# ---------------------------------------------------------------------------


def _npz_path(d, step=0):
    return os.path.join(d, f"step_{step:010d}", "state.npz")


def test_restore_truncated_archive_raises_checkpoint_error(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, _tree())
    p = _npz_path(d)
    with open(p, "rb") as f:
        data = f.read()
    with open(p, "wb") as f:
        f.write(data[: len(data) // 2])      # short write / torn disk
    with pytest.raises(ckpt.CheckpointError, match="truncated|corrupt"):
        ckpt.restore(d, _tree())
    with pytest.raises(ckpt.CheckpointError):
        ckpt.verify(d)


def test_restore_missing_leaf_names_it(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 0, t)
    bigger = dict(t, extra=jnp.zeros((2,), jnp.float32))
    with pytest.raises(ckpt.CheckpointError, match="extra"):
        ckpt.restore(d, bigger)


def test_restore_shape_mismatch_names_leaf(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, _tree())
    wrong = dict(_tree(), a=jnp.zeros((3, 3), jnp.float32))
    with pytest.raises(ckpt.CheckpointError, match="a.*shape|shape.*a"):
        ckpt.restore(d, wrong)


def test_verify_roundtrip_and_target_diff(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 2, t, meta={"arch": "x"})
    rep = ckpt.verify(d, target=t)
    assert rep["ok"] and rep["step"] == 2
    assert rep["target_leaves_matched"] == len(jax.tree.leaves(t))
    with pytest.raises(ckpt.CheckpointError, match="mismatch"):
        ckpt.verify(d, target=dict(t, extra=jnp.zeros((1,))))


def test_verify_bad_meta_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, _tree())
    with open(os.path.join(d, "step_0000000000", "meta.json"), "w") as f:
        f.write("{not json")
    with pytest.raises(ckpt.CheckpointError, match="meta"):
        ckpt.verify(d)


def test_verify_cli_exit_codes(tmp_path):
    from repro.checkpoint.__main__ import main
    d = str(tmp_path / "ck")
    assert main([d, "--verify"]) == 2            # nothing there
    ckpt.save(d, 0, _tree())
    assert main([d, "--verify"]) == 0            # intact
    p = _npz_path(d)
    with open(p, "rb") as f:
        data = f.read()
    with open(p, "wb") as f:
        f.write(data[: len(data) // 2])
    assert main([d, "--verify"]) == 1            # corrupt
