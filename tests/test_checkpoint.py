"""Fault tolerance: atomic checkpoints, resume-exactness, failure injection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.train import train


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    ckpt.save(d, 3, t, meta={"arch": "x"})
    assert ckpt.latest_step(d) == 3
    r = ckpt.restore(d, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_meta(d)["arch"] == "x"


def test_keep_last_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(6):
        ckpt.save(d, s, _tree(), keep_last=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2 and ckpt.latest_step(d) == 5


def test_no_tmp_dirs_left(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, _tree())
    assert not [x for x in os.listdir(d) if x.startswith("tmp")]


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "none"), _tree())


def test_resume_is_bitwise_exact(tmp_path):
    """Train 6 straight vs 3 + crash + resume 3: same final loss."""
    d = str(tmp_path / "ck")
    _, _, losses_full = train("minicpm-2b", smoke=True, steps=6,
                              batch=2, seq=32, ckpt_dir="", log_every=100)
    with pytest.raises(RuntimeError, match="injected failure"):
        train("minicpm-2b", smoke=True, steps=6, batch=2, seq=32,
              ckpt_dir=d, ckpt_every=3, fail_at=4, log_every=100)
    _, _, losses_resumed = train("minicpm-2b", smoke=True, steps=6,
                                 batch=2, seq=32, ckpt_dir=d, resume=True,
                                 ckpt_every=3, log_every=100)
    np.testing.assert_allclose(losses_full[3:], losses_resumed,
                               rtol=1e-5, atol=1e-6)


def test_restore_reshard_to_mesh(tmp_path):
    """Elastic path: checkpoint restores under a different sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ckpt.save(d, 0, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    r = ckpt.restore(d, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding == sh["w"]
