import os
import sys

# Smoke tests and benches must see ONE device -- the 512-device XLA flag
# lives exclusively in launch/dryrun.py (see the brief).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
