"""Paged KV cache: block-allocator properties, paged-vs-contiguous token
parity through the continuous-batching scheduler, shared-prefix reuse and
chunked prefill (launch/paging.py, launch/scheduler.py paged mode,
models/lm.py paged cache plumbing, kernels/paged_attn).

The load-bearing contract everywhere: paging changes WHERE KV rows live,
never a single token.  Every scheduler test compares the paged pool
(single-shot, chunked, prefix-shared, alternate geometry) against the
contiguous scheduler or a paged reference run and asserts BIT-identical
tokens, greedy and sampled.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _prop_fallback import given, settings, st

from repro.configs import get_config
from repro.launch.paging import (BlockAllocator, PagedLayout,
                                 contiguous_kv_bytes, plan_prefix_sharing)
from repro.launch.scheduler import ContinuousBatchingScheduler, Request
from repro.models import lm


def _params(arch, seed=0):
    cfg = get_config(arch, smoke=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    return params, cfg


def _mixed_requests(cfg, n, plens, caps, seed, stop=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        plens[i % len(plens)],
                                        dtype=np.int32),
                    max_new_tokens=caps[i % len(caps)], stop_token=stop)
            for i in range(n)]


# ---------------------------------------------------------------------------
# block allocator properties (host reference model)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_allocator_random_ops_never_leak_or_alias(seed):
    """Random alloc/share/write(CoW)/free sequences keep every invariant
    the on-device allocator relies on: refcounts non-negative, the free
    list holds exactly the ref==0 blocks with no duplicates, the trash
    block stays pinned, and no two live chains alias a block they both
    think they own exclusively (CoW splits before a shared write)."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(n_blocks=24)
    chains = []                                       # list of block lists
    for _ in range(120):
        op = rng.integers(0, 4)
        if op == 0 and alloc.n_free >= 1:             # alloc a new chain
            n = int(rng.integers(1, min(4, alloc.n_free) + 1))
            chains.append(alloc.alloc(n))
        elif op == 1 and chains:                      # share a prefix
            src = chains[int(rng.integers(0, len(chains)))]
            if src:
                k = int(rng.integers(1, len(src) + 1))
                alloc.share(src[:k])
                chains.append(list(src[:k]))
        elif op == 2 and chains:                      # CoW write
            ci = int(rng.integers(0, len(chains)))
            if chains[ci]:
                bi = int(rng.integers(0, len(chains[ci])))
                try:
                    alloc.write(chains[ci], bi)
                except MemoryError:
                    pass                              # pool full: no split
        elif op == 3 and chains:                      # free a whole chain
            alloc.free(chains.pop(int(rng.integers(0, len(chains)))))
        alloc.check()
        # exclusivity: a ref==1 block appears in exactly one chain
        flat = [b for c in chains for b in c]
        for b in set(flat):
            if alloc.ref[b] == 1:
                assert flat.count(b) == 1, f"ref-1 block {b} aliased"
    for c in chains:
        alloc.free(c)
    alloc.check()
    assert alloc.n_free == 23                         # all but trash block


def test_allocator_rejects_double_free_and_bad_share():
    alloc = BlockAllocator(n_blocks=8)
    (b,) = alloc.alloc(1)
    alloc.free([b])
    with pytest.raises(ValueError):
        alloc.free([b])
    with pytest.raises(ValueError):
        alloc.share([b])                              # free block
    with pytest.raises(ValueError):
        alloc.share([0])                              # trash block
    with pytest.raises(MemoryError):
        alloc.alloc(8)                                # > pool - trash


def test_cow_write_splits_shared_block_only():
    alloc = BlockAllocator(n_blocks=8)
    donor = alloc.alloc(3)
    sharer = list(donor)
    alloc.share(sharer)
    nb = alloc.write(sharer, 1)
    assert sharer == [donor[0], nb, donor[2]]
    assert nb != donor[1]                             # split happened
    assert alloc.ref[donor[1]] == 1 and alloc.ref[nb] == 1
    assert alloc.write(sharer, 1) == nb               # exclusive: in place
    alloc.check()


# ---------------------------------------------------------------------------
# prefix-sharing planner
# ---------------------------------------------------------------------------


def test_prefix_plan_shares_full_blocks_only():
    rng = np.random.default_rng(0)
    sys_p = rng.integers(0, 100, 10, dtype=np.int32)
    a = np.concatenate([sys_p, [1, 2, 3]])
    b = np.concatenate([sys_p, [4, 5, 6]])
    c = rng.integers(0, 100, 13, dtype=np.int32)
    plan = plan_prefix_sharing([a, b, c], block_size=4, n_tbl=8)
    # 10 shared tokens = 2 full blocks (the half-filled third block is
    # recomputed, never shared); c shares nothing
    assert plan.share_src[0] == -1 and plan.n_shared_blocks[0] == 0
    assert plan.share_src[1] == 0 and plan.n_shared_blocks[1] == 2
    assert plan.share_src[2] == -1
    # the donor carries one pin per shared block for the one sharer
    assert plan.pin_counts[0, :2].tolist() == [1, 1]
    assert plan.pin_counts[0, 2:].sum() == 0
    # identical prompts share at most (plen-1)//bs blocks: the sharer
    # still recomputes the row its first sampled token conditions on
    plan2 = plan_prefix_sharing([a, a.copy()], block_size=4, n_tbl=8)
    assert plan2.n_shared_blocks[1] == (len(a) - 1) // 4
    off = plan_prefix_sharing([a, b], block_size=4, n_tbl=8, enable=False)
    assert (off.share_src == -1).all()


def test_paged_layout_bytes_accounting():
    cfg = get_config("qwen3-14b", smoke=True)
    lay = PagedLayout(block_size=4, n_tbl=8, n_blocks=32)
    assert lay.tokens_per_slot == 32
    assert lay.blocks_for(9) == 3
    # contiguous(slots*max_seq rows) == paged pool holding the same rows
    assert (contiguous_kv_bytes(cfg, slots=2, max_seq=64)
            == lay.kv_bytes(cfg, n_blocks=2 * lay.blocks_for(64)))
    assert lay.kv_bytes(cfg, n_blocks=4) < lay.kv_bytes(cfg)


# ---------------------------------------------------------------------------
# scheduler parity: paged == contiguous, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_paged_matches_contiguous_tokens(temperature):
    """Same exact-length workload through the contiguous pool and the
    paged pool (single-shot prefill): bitwise-identical tokens, greedy
    and sampled."""
    params, cfg = _params("qwen3-14b")
    P, CAP = 16, 10
    reqs = _mixed_requests(cfg, 5, [P], [6, 10, 4], seed=1)
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP,
              temperature=temperature, seed=7)
    want = ContinuousBatchingScheduler(params, cfg, **kw).run(
        reqs).tokens_by_rid()
    lay = PagedLayout(block_size=4, n_tbl=10, n_blocks=40)
    got = ContinuousBatchingScheduler(
        params, cfg, paged=lay, **kw).run(reqs).tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_chunked_shared_prefill_is_bit_exact(temperature):
    """Chunked prefill + shared-prefix reuse vs single-shot unshared
    paged prefill on a mixed-length multi-tenant workload: identical
    tokens, and the prefix plan actually shares blocks (the test would
    pass vacuously otherwise)."""
    params, cfg = _params("qwen3-14b")
    P, CAP = 16, 10
    rng = np.random.default_rng(2)
    sys_p = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    reqs = []
    for i in range(6):
        if i % 2 == 0:
            tail = rng.integers(0, cfg.vocab_size, [4, 2, 4][i // 2],
                                dtype=np.int32)
            prompt = np.concatenate([sys_p, tail])
        else:
            prompt = rng.integers(0, cfg.vocab_size, [9, 16, 13][i // 2],
                                  dtype=np.int32)
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=[6, 10, 4][i % 3],
                            stop_token=3))
    plan = plan_prefix_sharing([r.prompt for r in reqs], 4, 10)
    assert plan.n_shared_blocks.max() == 3            # 12-token prefix
    lay = PagedLayout(block_size=4, n_tbl=10, n_blocks=40)
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP,
              temperature=temperature, seed=7, paged=lay)
    want = ContinuousBatchingScheduler(
        params, cfg, prefix_sharing=False, **kw).run(reqs).tokens_by_rid()
    got = ContinuousBatchingScheduler(
        params, cfg, prefill_chunk=8, prefix_sharing=True,
        **kw).run(reqs).tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_paged_pool_equals_solo_and_block_geometry_invariance():
    """A request's sampled tokens do not depend on pool companions,
    admission order, or the block geometry carrying its KV rows."""
    params, cfg = _params("qwen3-14b")
    P, CAP = 16, 8
    reqs = _mixed_requests(cfg, 4, [16, 9, 13], [6, 8], seed=3)
    kw = dict(slots=2, prompt_len=P, max_new_cap=CAP, temperature=0.7,
              seed=5, prefill_chunk=8)
    pool = ContinuousBatchingScheduler(
        params, cfg, paged=PagedLayout(4, 10, 40), **kw)
    tokens = pool.run(reqs).tokens_by_rid()
    for r in reqs[:2]:
        solo = pool.run([r]).tokens_by_rid()[r.rid]
        np.testing.assert_array_equal(tokens[r.rid], solo)
    alt = ContinuousBatchingScheduler(
        params, cfg, paged=PagedLayout(8, 5, 24), **kw)
    alt_tokens = alt.run(reqs).tokens_by_rid()
    for rid in tokens:
        np.testing.assert_array_equal(alt_tokens[rid], tokens[rid])


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "mamba2-130m"])
def test_paged_recurrent_families_bit_exact(arch):
    """Hybrid and pure-SSM families through the paged pool with chunked
    prefill: the recurrent state rides per-slot dense buffers (only
    attention KV is paged), decode steps must not corrupt a
    mid-prefill slot's recurrence, and tokens stay bit-identical to the
    contiguous scheduler."""
    params, cfg = _params(arch)
    reqs = _mixed_requests(cfg, 4, [16], [6, 9], seed=4)
    kw = dict(slots=2, prompt_len=16, max_new_cap=10, temperature=0.7,
              seed=5)
    want = ContinuousBatchingScheduler(params, cfg, **kw).run(
        reqs).tokens_by_rid()
    got = ContinuousBatchingScheduler(
        params, cfg, paged=PagedLayout(4, 10, 40), prefill_chunk=8,
        **kw).run(reqs).tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_block_recycling_and_refcount_drain():
    """A workload needing ~1.5x the pool in block-grants only fits if
    harvest returns every finished request's blocks to the free list
    (refcount algebra closes); peak occupancy stays within the pool."""
    params, cfg = _params("qwen3-14b")
    lay = PagedLayout(block_size=4, n_tbl=12, n_blocks=48)
    sched = ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=16, max_new_cap=10, seed=0,
        paged=lay, prefill_chunk=8)
    reqs = _mixed_requests(cfg, 12, [16], [8], seed=5, stop=-1)
    rep = sched.run(reqs)
    assert rep.total_tokens == 12 * 8
    # 12 requests x 6 blocks each = 72 grants > 47 allocatable blocks
    assert 0 < rep.peak_blocks <= lay.n_blocks - 1
    assert rep.n_admits == 12


def test_arrival_schedule_and_instrumented_runner_token_invariance():
    """Poisson-style arrival gating and the host-stepped instrumented
    runner both execute the identical compiled iteration: tokens match
    the pure device loop bit for bit, and TTFT percentiles come back
    finite."""
    params, cfg = _params("qwen3-14b")
    reqs = _mixed_requests(cfg, 5, [16, 9], [6, 8], seed=6)
    sched = ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=16, max_new_cap=10,
        temperature=0.7, seed=5, paged=PagedLayout(4, 10, 40),
        prefill_chunk=8)
    want = sched.run(reqs).tokens_by_rid()
    rep, timeline = sched.run_instrumented(reqs,
                                           arrival_iters=[0, 1, 3, 6, 9])
    got = rep.tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    ttft = rep.ttft_percentiles()
    assert np.isfinite(ttft["ttft_p50_s"]) and ttft["ttft_p95_s"] > 0
    assert (timeline["branch"] == 2).sum() > 0        # prefill iterations
    assert timeline["iter_s"].shape == timeline["branch"].shape


def test_paged_admission_rejections():
    params, cfg = _params("qwen3-14b")
    lay = PagedLayout(block_size=4, n_tbl=6, n_blocks=24)
    sched = ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=16, max_new_cap=8, paged=lay)
    long_req = Request(rid=0, prompt=np.zeros(17, np.int32),
                       max_new_tokens=4)
    with pytest.raises(ValueError, match="outside"):
        sched.run([long_req])
    over = Request(rid=0, prompt=np.zeros(16, np.int32), max_new_tokens=12)
    with pytest.raises(ValueError, match="> cap"):
        sched.run([over])
    # without pinned shared blocks the guard cannot fire (the layout
    # capacity check already forces n_blocks-1 >= n_tbl >= worst grant),
    # so the too-small case needs a shared prefix: the donor's pinned
    # blocks plus the worst-case fresh grant exceed the allocatable pool
    with pytest.raises(ValueError, match="pool too small"):
        tiny = ContinuousBatchingScheduler(
            params, cfg, slots=1, prompt_len=16, max_new_cap=8,
            paged=PagedLayout(block_size=4, n_tbl=6, n_blocks=7))
        same = np.arange(16, dtype=np.int32)
        tiny.run([Request(rid=0, prompt=same, max_new_tokens=8),
                  Request(rid=1, prompt=same, max_new_tokens=8)])
    with pytest.raises(ValueError, match="run_lockstep"):
        sched.run_lockstep([Request(rid=0, prompt=np.zeros(16, np.int32),
                                    max_new_tokens=4)])
