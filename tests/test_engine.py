"""Prepacked-weight CIM execution engine: pack-once/serve-many must be a
pure caching transform -- bit-identical to per-call weight conditioning
for every fidelity, pytree-transparent (jit / vmap / scan / checkpoint),
and wired through the model zoo's serving stack."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import (
    CimEngine, DEFAULT_CONFIG, PackedCimWeights,
    cim_linear, cim_linear_packed, cim_matmul, cim_matmul_int,
    complex_cim_matmul, fabricate, pack_cim_weights,
    pack_complex_cim_weights,
)

CFG = DEFAULT_CONFIG


def _xw(seed=0, m=8, k=100, n=8):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.normal(k1, (m, k)), jax.random.normal(k2, (k, n))


# ---------------------------------------------------------------------------
# pytree mechanics
# ---------------------------------------------------------------------------


def test_packed_pytree_roundtrip():
    _, w = _xw()
    p = pack_cim_weights(w, CFG)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    r = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(r, PackedCimWeights)
    assert (r.k_dim, r.n_dim) == (p.k_dim, p.n_dim)  # static meta survives
    for a, b in zip(leaves, jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_eager_equals_jit_bit_identical():
    """PR-3 caveat, closed: the packing pipeline is jit-compiled
    internally, so eager and outer-jit packing produce BIT-IDENTICAL
    leaves even at model scale (stacked bf16 projections under vmap --
    eager packing used to differ by one ulp in the per-channel scale,
    flipping occasional quantized magnitudes)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import lm
    cfg = dataclasses.replace(get_config("minicpm-2b", smoke=True),
                              cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(0), cfg)
    pe = lm.pack_cim_params(params, cfg)                      # "eager" call
    pj = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)  # serve-style
    for (pa, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(pe),
                               jax.tree_util.tree_leaves_with_path(pj)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"eager != jit pack at {jax.tree_util.keystr(pa)}")
    # and the op-level pack: eager call == explicit outer jit
    _, w = _xw(seed=12)
    qe = pack_cim_weights(w, CFG)
    qj = jax.jit(lambda v: pack_cim_weights(v, CFG))(w)
    for a, b in zip(jax.tree_util.tree_leaves(qe),
                    jax.tree_util.tree_leaves(qj)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_through_jit_and_vmap():
    _, w = _xw()
    p_eager = pack_cim_weights(w, CFG)
    p_jit = jax.jit(lambda v: pack_cim_weights(v, CFG))(w)
    for a, b in zip(jax.tree_util.tree_leaves(p_eager),
                    jax.tree_util.tree_leaves(p_jit)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity through jit preserves structure + metadata
    r = jax.jit(lambda t: t)(p_jit)
    assert (r.k_dim, r.n_dim) == (p_jit.k_dim, p_jit.n_dim)
    # stacked packing (the scanned-layer-stack shape)
    ws = jnp.stack([w, 2 * w, -w])
    ps = jax.vmap(lambda v: pack_cim_weights(v, CFG))(ws)
    assert ps.mag.shape[0] == 3
    one = jax.tree.map(lambda v: v[1], ps)
    ref = pack_cim_weights(2 * w, CFG)
    np.testing.assert_array_equal(np.asarray(one.mag), np.asarray(ref.mag))
    np.testing.assert_array_equal(np.asarray(one.pallas_w),
                                  np.asarray(ref.pallas_w))


# ---------------------------------------------------------------------------
# packed-vs-unpacked parity (the acceptance bar: bit-identical everywhere)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fidelity", ["fast", "fast_broadcast", "bit_true",
                                      "exact"])
def test_packed_parity_all_fidelities(fidelity):
    x, w = _xw(seed=1)
    p = pack_cim_weights(w, CFG)
    macro = fabricate(jax.random.PRNGKey(7), CFG)
    nk = jax.random.PRNGKey(9)
    u = cim_matmul(x, w, CFG, noise_key=nk, macro=macro, fidelity=fidelity,
                   use_pallas=False)
    q = cim_matmul(x, p, CFG, noise_key=nk, macro=macro, fidelity=fidelity,
                   use_pallas=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_packed_parity_noise_free_and_int():
    x, w = _xw(seed=2)
    p = pack_cim_weights(w, CFG)
    u = cim_matmul(x, w, CFG, use_pallas=False)
    q = cim_matmul(x, p, CFG, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))
    xq = jax.random.randint(jax.random.PRNGKey(3), (8, 100), -127, 128)
    wq = p.wq()
    ui = cim_matmul_int(xq, wq, None, CFG, None, "fast", use_pallas=False)
    qi = cim_matmul_int(xq, p, None, CFG, None, "fast", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ui), np.asarray(qi))


def test_packed_parity_pallas_interpret():
    """Prepacked-plane kernel path == in-kernel decomposition path."""
    x, w = _xw(seed=4, m=8, k=96, n=8)
    p = pack_cim_weights(w, CFG)
    u = cim_matmul(x, w, CFG, use_pallas=True)
    q = cim_matmul(x, p, CFG, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_packed_parity_complex():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    x = (jax.random.normal(k1, (8, 64))
         + 1j * jax.random.normal(k2, (8, 64))).astype(jnp.complex64)
    w = (jax.random.normal(k2, (64, 8))
         + 1j * jax.random.normal(k3, (64, 8))).astype(jnp.complex64)
    p = pack_complex_cim_weights(jnp.real(w), jnp.imag(w), CFG)
    for use_pallas in (False, True):   # 4-pass GEMM and fused kernel paths
        u = complex_cim_matmul(x, w, CFG, use_pallas=use_pallas)
        q = complex_cim_matmul(x, p, CFG, use_pallas=use_pallas)
        np.testing.assert_array_equal(np.asarray(u), np.asarray(q))
    u = complex_cim_matmul(x, w, CFG, noise_key=k3, use_pallas=False)
    q = complex_cim_matmul(x, p, CFG, noise_key=k3, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_packed_parity_nondefault_config():
    """A non-prototype macro config packs/serves correctly too (no Pallas
    routing: the kernels hardcode the prototype's numerics)."""
    cfg = dataclasses.replace(CFG, acc_len=8)
    x, w = _xw(seed=6, k=40)
    p = pack_cim_weights(w, cfg)
    u = cim_matmul(x, w, cfg, use_pallas=False)
    q = cim_matmul(x, p, cfg, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_packed_config_mismatch_rejected():
    """Serving a pack under a different macro config must error, not
    silently misread the folded planes (the pack IS cfg-specific)."""
    cfg = dataclasses.replace(CFG, n_dcim_products=1)
    x, w = _xw(seed=7, k=48)
    p = pack_cim_weights(w, cfg)
    with pytest.raises(ValueError, match="different CCIMConfig"):
        cim_matmul(x, p, CFG, use_pallas=False)


# ---------------------------------------------------------------------------
# STE / engine handle
# ---------------------------------------------------------------------------


def test_cim_linear_packed_forward_and_ste_backward():
    x, w = _xw(seed=8)
    p = pack_cim_weights(w, CFG)
    y_u = cim_linear(x, w, None, CFG, "fast", False)
    y_p = cim_linear_packed(x, p, None, CFG, "fast", False)
    np.testing.assert_array_equal(np.asarray(y_u), np.asarray(y_p))
    # backward: gradients flow to activations through the DEQUANTIZED
    # array contents (frozen weights get no cotangent)
    g = jax.grad(lambda v: jnp.sum(cim_linear_packed(v, p, None, CFG,
                                                     "fast", False)))(x)
    ref = jnp.ones((x.shape[0], p.n_dim)) @ p.dequantized().T
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref), rtol=1e-5)


def test_engine_handle_dispatch():
    x, w = _xw(seed=10)
    eng = CimEngine(cfg=CFG, fidelity="fast", use_pallas=False)
    p = eng.pack(w)
    np.testing.assert_array_equal(np.asarray(eng.matmul(x, w)),
                                  np.asarray(eng.matmul(x, p)))


# ---------------------------------------------------------------------------
# checkpoint round-trip (pay the PTQ cost once per deployment)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_packed(tmp_path):
    _, w = _xw(seed=11)
    tree = {"proj": pack_cim_weights(w, CFG), "other": jnp.ones((3,))}
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, tree)
    target = jax.tree.map(jnp.zeros_like, tree)
    r = ckpt.restore(d, target)
    assert isinstance(r["proj"], PackedCimWeights)
    assert (r["proj"].k_dim, r["proj"].n_dim) == (100, 8)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored pack serves identically
    x, _ = _xw(seed=11)
    np.testing.assert_array_equal(
        np.asarray(cim_matmul(x, tree["proj"], CFG, use_pallas=False)),
        np.asarray(cim_matmul(x, r["proj"], CFG, use_pallas=False)))


# ---------------------------------------------------------------------------
# end-to-end: packed serving == unpacked serving, token for token
# ---------------------------------------------------------------------------


def test_serve_packed_matches_unpacked():
    from repro.launch.serve import serve
    u = serve("minicpm-2b", smoke=True, batch=2, prompt_len=8, gen=3,
              cim=True, pack=False)
    p = serve("minicpm-2b", smoke=True, batch=2, prompt_len=8, gen=3,
              cim=True, pack=True)
    np.testing.assert_array_equal(u, p)


def test_pack_cim_params_structure():
    from repro.configs import get_config
    from repro.core import FusedPackedCimWeights
    from repro.models import lm
    cfg = dataclasses.replace(get_config("minicpm-2b", smoke=True),
                              cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(0), cfg, pack_cim=True)
    blk = params["layers"]
    # plan-compatible input-sharing groups fuse into ONE wide pack each
    qkv = blk["attn"]["wq+wk+wv"]
    assert isinstance(qkv, FusedPackedCimWeights)
    assert qkv.seg_names == ("wq", "wk", "wv")
    assert sum(qkv.seg_dims) == qkv.packed.n_dim
    assert isinstance(blk["mlp"]["w1+w3"], FusedPackedCimWeights)
    # wo/w2 consume different activations -> stay individually packed
    assert isinstance(blk["attn"]["wo"], PackedCimWeights)
    assert isinstance(blk["mlp"]["w2"], PackedCimWeights)
    # stacked leading layer axis survives packing (scan-sliceable)
    assert qkv.packed.mag.shape[0] == cfg.n_layers
    # non-projection leaves stay float
    assert not isinstance(params["embed"], PackedCimWeights)
    assert not isinstance(blk["ln1"], PackedCimWeights)
    # fusion off -> the PR-2 per-projection structure
    cfg0 = dataclasses.replace(cfg, cim_fuse=False)
    p0, _ = lm.init(jax.random.PRNGKey(0), cfg0, pack_cim=True)
    assert isinstance(p0["layers"]["attn"]["wq"], PackedCimWeights)
