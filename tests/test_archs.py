"""Per-assigned-architecture smoke tests: REDUCED config of the same
family, one forward/train step on CPU, output shapes + no NaNs (the FULL
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params, axes = lm.init(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.family == "vlm":
        fe = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)

    logits, aux = lm.forward(params, cfg, toks, fe, remat=False)
    s_total = S + (cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, cfg, toks, fe))(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params, _ = lm.init(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fe = None
    if cfg.family == "vlm":
        fe = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    cache = lm.init_cache(cfg, B, S + 4 + cfg.n_frontend_tokens)
    logits, cache = lm.prefill(params, cfg, toks, cache, fe)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(2):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode"
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters (no drift)."""
    spec = {
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 0, 151936),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff and cfg.vocab_size == v, arch
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("qwen2-moe-a2.7b").top_k == 4
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").top_k == 2
    assert get_config("gemma2-9b").logit_softcap == 30.0
    assert get_config("qwen3-14b").qk_norm
    assert get_config("minicpm-2b").lr_schedule == "wsd"


def test_arctic_is_480b_scale():
    from repro.launch.specs import param_shapes_and_axes, param_count
    shapes, _ = param_shapes_and_axes(get_config("arctic-480b"))
    n = param_count(shapes)
    assert 4.2e11 < n < 5.4e11, f"arctic params {n:.3e}"
