"""Deployment-planner subsystem tests: plan pytree/resolution semantics,
mixed-fidelity execution parity (packed == unpacked, incl. noise), the
profiler/search contracts, cost-model anchoring, and the generalized
prepacked Pallas kernel serving every plan design point."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plan as P
from repro.configs import get_config
from repro.core import DEFAULT_CONFIG, PackedCimWeights, cim_matmul, costmodel
from repro.core import pack_cim_weights
from repro.models import lm

D = DEFAULT_CONFIG


def _entry(label="h", **kw):
    fid = kw.pop("fidelity", "fast")
    return P.PlanEntry(cfg=dataclasses.replace(D, **kw), fidelity=fid,
                       label=label)


# ---------------------------------------------------------------------------
# plan semantics: static, hashable, path resolution
# ---------------------------------------------------------------------------


def test_plan_resolution_and_fallback():
    plan = P.DeploymentPlan.from_dict(
        {"attn/wq": P.DIGITAL_ENTRY, "w2": _entry("a", n_dcim_products=0,
                                                  adc_bits=8)},
        default=P.HYBRID_ENTRY)
    assert plan.resolve("attn/wq").fidelity == "exact"       # exact path
    assert plan.resolve("mlp/w2").label == "a"               # basename
    assert plan.resolve("shared/mlp/w2").label == "a"        # basename, deep
    assert plan.resolve("attn/wk") == P.HYBRID_ENTRY         # default
    assert plan.resolve(None) == P.HYBRID_ENTRY


def test_plan_hashable_and_order_independent():
    a = P.DeploymentPlan.from_dict({"x": P.DIGITAL_ENTRY,
                                    "y": P.HYBRID_ENTRY})
    b = P.DeploymentPlan.from_dict({"y": P.HYBRID_ENTRY,
                                    "x": P.DIGITAL_ENTRY})
    assert a == b and hash(a) == hash(b)
    # rides inside the frozen ModelConfig (jit-static packing requires it)
    cfg = dataclasses.replace(get_config("minicpm-2b", smoke=True),
                              cim_mode=True, cim_plan=a)
    hash(cfg)


def test_plan_rejects_unservable_fidelity():
    with pytest.raises(ValueError, match="fidelity"):
        P.PlanEntry(fidelity="bit_true")


# ---------------------------------------------------------------------------
# planned execution: bit-exact contracts through the model zoo
# ---------------------------------------------------------------------------


def _model(arch="minicpm-2b", seed=0):
    cfg = get_config(arch, smoke=True)
    params, _ = lm.init(jax.random.PRNGKey(seed), cfg)
    toks = jnp.asarray(P.calibration_batch(cfg, batch=1, seq_len=8))
    return cfg, params, toks


def test_float_plan_is_bit_identical_to_fp():
    cfg, params, toks = _model()
    ref, _ = lm.forward(params, cfg, toks, remat=False)
    pcfg = dataclasses.replace(
        cfg, cim_mode=True, cim_plan=P.DeploymentPlan.uniform(P.FLOAT_ENTRY))
    out, _ = lm.forward(params, pcfg, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_uniform_prototype_plan_matches_global_cim():
    cfg, params, toks = _model()
    g, _ = lm.forward(params, dataclasses.replace(cfg, cim_mode=True), toks,
                      remat=False)
    pcfg = dataclasses.replace(
        cfg, cim_mode=True,
        cim_plan=P.DeploymentPlan.uniform(P.prototype_candidate().entry))
    u, _ = lm.forward(params, pcfg, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(u))


MIXED = P.DeploymentPlan.from_dict({
    "mlp/w2": P.DIGITAL_ENTRY,
    "attn/wq": _entry("analog0/adc8", n_dcim_products=0, adc_bits=8),
    "attn/wo": _entry("hybrid5/adc8", n_dcim_products=5, adc_bits=8),
    "mlp/w3": P.FLOAT_ENTRY,
}, default=_entry("hybrid3/adc8/L32", acc_len=32, adc_bits=8))


def test_mixed_pack_structure_and_config_meta():
    from repro.core import FusedPackedCimWeights
    cfg, params, _ = _model()
    pcfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=MIXED)
    packed = lm.pack_cim_params(params, pcfg)
    blk = packed["layers"]
    # float-fidelity site stays a raw float matrix (and blocks w1+w3 fusion)
    assert not isinstance(blk["mlp"]["w3"], PackedCimWeights)
    assert "w1+w3" not in blk["mlp"]
    # every other site packs under ITS OWN entry's config (static meta)
    assert blk["mlp"]["w2"].cfg == D                      # digital: default
    assert blk["attn"]["wq"].cfg.n_dcim_products == 0
    # stacked pack: axis 0 is the scanned layer axis, axis 1 plane count
    assert blk["attn"]["wq"].pallas_planes.shape[1] == 0  # no folded planes
    assert blk["attn"]["wo"].cfg.n_dcim_products == 5
    # fusion is keyed by the plan: wq has its own entry, so only the
    # entry-compatible wk/wv fuse (the group SPLITS, it doesn't disappear)
    kv = blk["attn"]["wk+wv"]
    assert isinstance(kv, FusedPackedCimWeights)
    assert kv.packed.cfg.acc_len == 32                    # plan default
    assert blk["attn"]["wq"].mag.shape[0] == cfg.n_layers  # scan axis kept


def test_planned_forward_packed_matches_unpacked_incl_noise():
    cfg, params, toks = _model()
    pcfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=MIXED,
                               cim_noise_seed=11)
    packed = lm.pack_cim_params(params, pcfg)
    u, _ = lm.forward(params, pcfg, toks, remat=False)
    q, _ = lm.forward(packed, pcfg, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


def test_planned_serve_end_to_end_packed_parity():
    from repro.launch.serve import serve
    u = serve("minicpm-2b", batch=2, prompt_len=8, gen=3, plan=MIXED,
              pack=False, noise_seed=7)
    p = serve("minicpm-2b", batch=2, prompt_len=8, gen=3, plan=MIXED,
              pack=True, noise_seed=7)
    np.testing.assert_array_equal(u, p)


def test_planned_scheduler_serves_unchanged():
    """A planned+packed model through the continuous-batching scheduler:
    one AOT-compiled loop, zero recompiles, tokens identical to the
    lock-step baseline (asserted inside serve_continuous)."""
    from repro.launch.serve import serve_continuous
    _, st = serve_continuous("minicpm-2b", slots=2, prompt_len=8,
                             n_requests=4, stop_lengths=(3, 5, 4, 2),
                             plan=MIXED, pack=True)
    assert st["tokens_match_lockstep"]


def test_planned_ssm_family():
    cfg, params, toks = _model("mamba2-130m")
    plan = P.DeploymentPlan.from_dict(
        {"mamba/out_proj": P.DIGITAL_ENTRY},
        default=_entry("hybrid3/adc8/L32", acc_len=32, adc_bits=8))
    pcfg = dataclasses.replace(cfg, cim_mode=True, cim_plan=plan,
                               cim_noise_seed=3)
    packed = lm.pack_cim_params(params, pcfg)
    u, _ = lm.forward(params, pcfg, toks, remat=False)
    q, _ = lm.forward(packed, pcfg, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(q))


# ---------------------------------------------------------------------------
# profiler + search contracts
# ---------------------------------------------------------------------------


def _small_candidates():
    return [P.digital_candidate(), P.prototype_candidate(),
            P.make_candidate("hybrid3/adc8/L32",
                             dataclasses.replace(D, acc_len=32, adc_bits=8))]


def test_profiler_and_search_contracts():
    cfg, params, toks = _model()
    cands = _small_candidates()
    sites = ["mlp/w2", "mlp/w3", "attn/wv"]
    prof = P.profile_sensitivities(params, cfg, toks, cands, sites=sites)
    # digital (quantization-only) is the accuracy ceiling at every site
    for s in sites:
        assert prof.rms[s]["digital"] < prof.rms[s][cands[1].label]
        assert prof.rms[s]["digital"] < 0.1
    # macs accounting matches the stacked leaf shapes
    assert prof.macs_per_token("mlp/w2") == cfg.n_layers * 256 * 128

    res = P.pareto_search(params, cfg, toks, candidates=cands,
                          profile=prof, sites=sites)
    # every profiled site is assigned, the plan serves it
    assert set(res.assignment) == set(sites)
    # budget respected end-to-end (validated measurement)
    assert res.measured_rms <= res.budget_measured * 1.02 + 1e-9
    # the knapsack only ever cheapens the all-digital starting point
    assert res.cost["combined"] <= res.cost_digital["combined"] + 1e-9
    # with the default (prototype) budget the planned point must not be
    # MORE expensive than running the prototype everywhere (domination
    # contract, enforced at bench scale by plan_pareto.py)
    assert res.cost["combined"] <= res.cost_budget_plan["combined"] + 1e-9
    # tightening the budget spends more digital, never less accuracy
    tight = P.pareto_search(params, cfg, toks, candidates=cands,
                            profile=prof, sites=sites, budget_scale=0.3)
    assert tight.measured_rms <= res.budget_measured * 0.3 * 1.02 + 1e-9
    assert tight.cost["combined"] >= res.cost["combined"] - 1e-9


def test_search_with_partial_precomputed_profile():
    """A precomputed profile that lacks the digital/budget columns gets
    them auto-profiled and merged (used to KeyError), and a ``sites``
    subset passed alongside a wider profile restricts the plan scope
    (used to be silently ignored)."""
    cfg, params, toks = _model()
    proto = P.prototype_candidate()
    sites = ["mlp/w2", "mlp/w3"]
    prof = P.profile_sensitivities(params, cfg, toks, [proto],
                                   sites=sites + ["attn/wv"])
    res = P.pareto_search(params, cfg, toks, candidates=[proto],
                          profile=prof, sites=sites)
    assert set(res.assignment) == set(sites)           # scope respected
    assert "digital" in res.profile.labels             # merged column
    with pytest.raises(ValueError, match="not in the precomputed profile"):
        P.pareto_search(params, cfg, toks, candidates=[proto], profile=prof,
                        sites=["attn/wq"])


def test_search_rejects_candidate_label_collisions():
    """Candidate identity is label-keyed (profile columns, assignments):
    a user candidate aliasing the reserved 'digital' label, or duplicate
    labels, must fail loudly instead of silently mixing rows."""
    cfg, params, toks = _model()
    impostor = P.make_candidate(
        "digital", dataclasses.replace(D, n_dcim_products=1))
    with pytest.raises(ValueError, match="reserved"):
        P.pareto_search(params, cfg, toks, candidates=[impostor])
    proto = P.prototype_candidate()
    dup = P.make_candidate(proto.label,
                           dataclasses.replace(D, adc_bits=6))
    with pytest.raises(ValueError, match="duplicate candidate labels"):
        P.pareto_search(params, cfg, toks, candidates=[proto, dup])


def test_profiler_unknown_site_rejected():
    cfg, params, toks = _model()
    with pytest.raises(ValueError, match="unknown projection site"):
        P.profile_sensitivities(params, cfg, toks, _small_candidates(),
                                sites=["attn/nope"])


def test_shared_block_macs_count_per_group_execution():
    """The zamba2 shared block's weights park once but EXECUTE once per
    layer group: energy/latency cost per token must scale with the group
    count while area (parked weights) must not."""
    cfg, params, toks = _model("zamba2-1.2b")
    sites = ["shared/attn/wq", "mamba/w_z"]
    prof = P.profile_sensitivities(params, cfg, toks,
                                   [P.prototype_candidate()], sites=sites)
    n_groups = cfg.n_layers // cfg.shared_attn_period
    assert n_groups > 1
    assert (prof.macs_per_token("shared/attn/wq")
            == n_groups * prof.weights_per_site("shared/attn/wq"))
    assert (prof.macs_per_token("mamba/w_z")
            == prof.weights_per_site("mamba/w_z"))


def test_serve_noise_seed_requires_cim():
    from repro.launch.serve import serve
    with pytest.raises(ValueError, match="needs\\s+cim=True"):
        serve("minicpm-2b", batch=2, prompt_len=8, gen=3, noise_seed=7)


# ---------------------------------------------------------------------------
# cost model anchoring (satellite: macro_cost + paper headline ratios)
# ---------------------------------------------------------------------------


def test_figS1_headline_ratios_reproduced():
    s = costmodel.figS1_comparison(D)["savings"]
    assert abs(s["area_pct_vs_duplicated"] - 35.0) < 5.0
    assert abs(s["latency_pct_vs_sequential"] - 54.0) < 1.5
    assert abs(s["power_pct_vs_duplicated"] - 24.0) < 1.0
    assert abs(costmodel.tops_per_watt(D) - 35.0) < 1.0


def test_macro_cost_defaults_and_orderings():
    hybrid = costmodel.macro_cost(D)
    digital = costmodel.macro_cost(D, "exact")
    analog = costmodel.macro_cost(
        dataclasses.replace(D, n_dcim_products=0, adc_bits=8))
    # per-MAC energy consistent with the conversion accounting
    e = costmodel.energy_per_conversion_pj(D)["total"]
    assert hybrid.energy_pj_per_mac == pytest.approx(e / D.acc_len)
    # the paper's premise: all-digital costs the most area AND energy,
    # the hybrid undercuts the all-analog design too (bigger ADC + DACs)
    assert digital.area_mm2_per_kb > analog.area_mm2_per_kb \
        > hybrid.area_mm2_per_kb
    assert digital.energy_pj_per_mac > analog.energy_pj_per_mac \
        > hybrid.energy_pj_per_mac
    # longer accumulates amortize per-conversion overhead
    l32 = costmodel.macro_cost(dataclasses.replace(D, acc_len=32,
                                                   adc_bits=8))
    assert l32.energy_pj_per_mac < hybrid.energy_pj_per_mac
    assert l32.latency_cyc_per_mac == hybrid.latency_cyc_per_mac / 2
    with pytest.raises(ValueError, match="no cost model"):
        costmodel.macro_cost(D, "float")


def test_min_adc_bits_matches_prototype():
    assert P.min_adc_bits(D) == D.adc_bits                     # top-3 -> 7b
    assert P.min_adc_bits(
        dataclasses.replace(D, n_dcim_products=0)) == 8        # all-analog


# ---------------------------------------------------------------------------
# generalized prepacked Pallas kernel: every plan design point, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [
    dict(),                                       # prototype top-3
    dict(n_dcim_products=1),
    dict(n_dcim_products=5, adc_bits=8),
    dict(n_dcim_products=0, adc_bits=8),          # all-analog, no planes
    dict(acc_len=32, adc_bits=8),                 # planner's long-accumulate
])
def test_prepacked_pallas_serves_all_plan_points(kw):
    cfg = dataclasses.replace(D, **kw)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (8, 100))
    w = jax.random.normal(k2, (100, 24))
    p = pack_cim_weights(w, cfg)
    ref = cim_matmul(x, w, cfg, use_pallas=False)        # unpacked fast GEMM
    y = cim_matmul(x, p, cfg, use_pallas=True)           # kernel (interpret)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(y))
