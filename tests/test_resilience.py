"""Resilience stack: fault injection, drift watchdog, failover ladder.

Four property groups:

  fault model      deterministic, seeded, schedule-correct severity; the
                   fault-OFF serve loop lowers byte-identical StableHLO
                   (arming + disarming leaves no trace), while a
                   fault-ON segment lowers DIFFERENTLY (the wiring
                   proof); the digital exact path is immune.
  watchdog         debounced escalation (can jump to RED), one-level
                   recovery, and NO false positives: clean guarded
                   serving stays GREEN with zero failover actions across
                   contiguous / paged / speculative variants, tokens
                   bit-identical to the plain scheduler.
  detection        a seeded mid-stream drift ramp reaches RED within a
                   bounded token count, deterministically across
                   independently-built servers.
  ladder           every rung serves the deployed pack without repacking
                   (core.engine.pack_compatible), and the guarded run's
                   compile census proves failover never compiles.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.ccim import DEFAULT_CONFIG
from repro.core.engine import pack_compatible, packed_cim_matmul_int
from repro.launch.paging import PagedLayout
from repro.launch.scheduler import (ContinuousBatchingScheduler,
                                    mixed_length_requests)
from repro.models import lm
from repro.obs import scheduler_fingerprint
from repro.obs.fingerprint import hlo_fingerprint
from repro.plan.plan import DeploymentPlan, PlanEntry
from repro.resilience import faults as F
from repro.resilience.failover import (GuardedServer, derive_exact_plan,
                                       derive_ladder, default_probe)
from repro.resilience.watchdog import (GREEN, RED, Watchdog, WatchdogConfig,
                                       first_packed_leaf)

P, CAP = 8, 4
STOPS = (2, 4, 3, 4)

# the canonical chaos scenario shared with benchmarks/resilience_bench.py:
# per-column capacitor gain/offset drift ramping in mid-workload
DRIFT = F.FaultModel(seed=3, gain_amp=0.6, offset_lsb=2.0,
                     schedule="ramp", onset=4, period=16)


@pytest.fixture(scope="module")
def packed_cim():
    cfg = get_config("minicpm-2b", smoke=True)
    cfg = dataclasses.replace(cfg, cim_mode=True)
    params, _ = lm.init(jax.random.PRNGKey(0), cfg)
    packed = jax.jit(lambda p: lm.pack_cim_params(p, cfg))(params)
    return packed, cfg


def _requests(cfg):
    return mixed_length_requests(4, P, cfg.vocab_size, stop_lengths=STOPS)


def _variant_kwargs(variant):
    if variant == "paged":
        return dict(paged=PagedLayout(block_size=8, n_tbl=2, n_blocks=12))
    if variant == "speculative":
        return dict(draft_k=2)
    return {}


# ---------------------------------------------------------------------------
# 1. the fault model itself
# ---------------------------------------------------------------------------


def test_fault_model_parse_roundtrip():
    m = F.FaultModel.parse(
        "gain_amp=0.5,schedule=ramp,onset=8,period=32,"
        "stuck_frac=0.001,stuck_mode=sign,seed=7")
    assert m.gain_amp == 0.5 and m.schedule == "ramp" and m.onset == 8
    assert m.period == 32 and m.stuck_frac == 0.001
    assert m.stuck_mode == "sign" and m.seed == 7
    with pytest.raises((ValueError, TypeError)):
        F.FaultModel.parse("no_such_knob=1")
    with pytest.raises(ValueError):
        F.FaultModel(schedule="sinusoid")


def test_severity_schedules():
    step = F.FaultModel(schedule="step", onset=4)
    ramp = F.FaultModel(schedule="ramp", onset=4, period=8)
    assert float(step.severity(3)) == 0.0 and float(step.severity(4)) == 1.0
    assert float(ramp.severity(4)) == 0.0
    assert float(ramp.severity(8)) == pytest.approx(0.5)
    assert float(ramp.severity(100)) == 1.0
    burst = F.FaultModel(schedule="burst", onset=0, period=8, duty=0.5)
    on = [float(burst.severity(t)) for t in range(8)]
    assert on == [1.0] * 4 + [0.0] * 4


def test_severity_accepts_traced_clock():
    m = F.FaultModel(schedule="ramp", onset=2, period=4)
    got = jax.jit(m.severity)(jnp.int32(4))
    assert float(got) == pytest.approx(0.5)


def test_column_patterns_seeded():
    a1, o1 = DRIFT.column_patterns(16)
    a2, o2 = DRIFT.column_patterns(16)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    b1, _ = dataclasses.replace(DRIFT, seed=DRIFT.seed + 1).column_patterns(16)
    assert not np.array_equal(np.asarray(a1), np.asarray(b1))


# ---------------------------------------------------------------------------
# 2. off-path byte-identity and epilogue wiring
# ---------------------------------------------------------------------------


def test_fault_off_lowering_byte_identical(packed_cim):
    params, cfg = packed_cim

    def make():
        return ContinuousBatchingScheduler(params, cfg, slots=2,
                                           prompt_len=P, max_new_cap=CAP)

    before = scheduler_fingerprint(make(), 2)
    seg_off = hlo_fingerprint(make().segment_hlo_text(2))
    with F.inject(DRIFT):
        assert F.active()
        seg_on = hlo_fingerprint(make().segment_hlo_text(2))
    assert not F.active()
    after = scheduler_fingerprint(make(), 2)
    assert before == after, \
        "arming a FaultModel changed the fault-free serve loop lowering"
    assert seg_on != seg_off, \
        "fault-armed segment lowered identically -- injection not wired in"


def test_epilogue_fault_deterministic_and_clocked(packed_cim):
    params, cfg = packed_cim
    leaf = first_packed_leaf(params)
    xq = jax.random.randint(jax.random.PRNGKey(1), (4, leaf.k_dim),
                            -127, 128, jnp.int32)

    def fast(t=None):
        if t is None:
            return np.asarray(packed_cim_matmul_int(
                xq, leaf, None, leaf.cfg, fidelity="fast"))
        with F.inject(DRIFT), F.clock(t):
            return np.asarray(packed_cim_matmul_int(
                xq, leaf, None, leaf.cfg, fidelity="fast"))

    clean = fast()
    np.testing.assert_array_equal(fast(t=0), clean)      # pre-onset
    hot1, hot2 = fast(t=64), fast(t=64)
    np.testing.assert_array_equal(hot1, hot2)            # deterministic
    assert not np.array_equal(hot1, clean), \
        "full-severity drift left the analog epilogue unchanged"


def test_digital_exact_path_immune(packed_cim):
    params, cfg = packed_cim
    leaf = first_packed_leaf(params)
    xq = jax.random.randint(jax.random.PRNGKey(2), (4, leaf.k_dim),
                            -127, 128, jnp.int32)
    clean = np.asarray(packed_cim_matmul_int(xq, leaf, None, leaf.cfg,
                                             fidelity="exact"))
    with F.inject(DRIFT), F.clock(64):
        hot = np.asarray(packed_cim_matmul_int(xq, leaf, None, leaf.cfg,
                                               fidelity="exact"))
    np.testing.assert_array_equal(hot, clean)


def test_stuck_weight_faults_seeded(packed_cim):
    params, cfg = packed_cim
    m = F.FaultModel(seed=11, stuck_frac=0.01, stuck_mode="mag_msb")
    f1 = F.apply_weight_faults(m, params)
    f2 = F.apply_weight_faults(m, params)
    a, b = first_packed_leaf(f1), first_packed_leaf(f2)
    np.testing.assert_array_equal(np.asarray(a.mag), np.asarray(b.mag))
    orig = first_packed_leaf(params)
    wq0, wq1 = np.asarray(orig.wq()), np.asarray(a.wq())
    frac = np.mean(wq0 != wq1)
    assert 0 < frac < 0.05, \
        f"stuck_frac=0.01 flipped {frac:.3f} of weights (expected ~1%)"
    # the faulted pack serves: both fidelities see the SAME corrupt cells
    xq = jax.random.randint(jax.random.PRNGKey(3), (2, a.k_dim),
                            -127, 128, jnp.int32)
    ex = np.asarray(packed_cim_matmul_int(xq, a, None, a.cfg,
                                          fidelity="exact"))
    assert not np.array_equal(
        ex, np.asarray(packed_cim_matmul_int(xq, orig, None, orig.cfg,
                                             fidelity="exact")))


# ---------------------------------------------------------------------------
# 3. the watchdog state machine
# ---------------------------------------------------------------------------


def test_watchdog_debounce_blocks_single_outlier():
    wd = Watchdog(WatchdogConfig(debounce=2, recover=2))
    ob = lambda clip: wd.observe(n_tokens=0, n_iter=0, clip_rate=clip)
    assert ob(0.0) == GREEN
    assert ob(9.9) == GREEN          # first breach: debounced
    assert ob(0.0) == GREEN          # outlier forgotten
    assert ob(9.9) == GREEN
    assert ob(9.9) == RED            # persistent: jumps straight to RED


def test_watchdog_recovery_one_level_at_a_time():
    wd = Watchdog(WatchdogConfig(debounce=1, recover=2))
    ob = lambda clip: wd.observe(n_tokens=0, n_iter=0, clip_rate=clip)
    assert ob(9.9) == RED
    assert ob(0.0) == RED
    assert ob(0.0) == "AMBER"        # two clean windows: one step down
    assert ob(0.0) == "AMBER"
    assert ob(0.0) == GREEN


def test_watchdog_probe_and_acceptance_signals():
    wd = Watchdog(WatchdogConfig(debounce=1))
    assert wd.observe(n_tokens=0, n_iter=0, probe_ratio=1.0) == GREEN
    assert wd.observe(n_tokens=0, n_iter=0, probe_ratio=50.0) == RED
    wd2 = Watchdog(WatchdogConfig(debounce=1))
    assert wd2.observe(n_tokens=0, n_iter=0, accept_rate=0.9) == GREEN
    assert wd2.observe(n_tokens=0, n_iter=0, accept_rate=0.1) == RED


def test_watchdog_clean_snapshots_green(packed_cim):
    """False-positive guard at the snapshot level: real clean serve
    telemetry, classified as one window, must stay GREEN."""
    params, cfg = packed_cim
    from repro.obs import ObsConfig
    for variant in ("contiguous", "paged", "speculative"):
        sched = ContinuousBatchingScheduler(
            params, cfg, slots=2, prompt_len=P, max_new_cap=CAP,
            obs=ObsConfig(), **_variant_kwargs(variant))
        rep = sched.run(_requests(cfg))
        wd = Watchdog()
        assert wd.observe_snapshot(rep.obs) == GREEN, \
            f"clean {variant} snapshot tripped the watchdog: " \
            f"{wd.history[-1].reasons}"


# ---------------------------------------------------------------------------
# 4. the ladder and the guarded server
# ---------------------------------------------------------------------------


def test_ladder_is_pack_compatible():
    base = PlanEntry(cfg=DEFAULT_CONFIG, fidelity="fast")
    plan = DeploymentPlan.uniform(base)
    for spec in (False, True):
        rungs, start = derive_ladder(plan, speculative=spec)
        assert 0 <= start < len(rungs)
        assert rungs[-1].label == "digital"
        for rung in rungs:
            for plans in (rung.plan, rung.draft_plan):
                if plans is None:
                    continue
                for _, e in list(plans.entries) + [(None, plans.default)]:
                    if e.fidelity == "float":
                        continue
                    assert pack_compatible(base.cfg, e.cfg), \
                        f"rung {rung.label} entry not servable from the " \
                        f"deployed pack"
    dig = derive_exact_plan(plan)
    assert dig.default.fidelity == "exact"
    assert dig.default.cfg == base.cfg


@pytest.mark.parametrize("variant", ["contiguous", "paged", "speculative"])
def test_clean_guarded_green_and_token_parity(packed_cim, variant):
    """The false-positive gate: a fault-free workload through the full
    guarded stack (watchdog + probe + ladder) stays GREEN, takes zero
    failover actions, compiles once per rung, and emits tokens
    bit-identical to the plain scheduler."""
    params, cfg = packed_cim
    kw = _variant_kwargs(variant)
    server = GuardedServer(
        params, cfg, slots=2, prompt_len=P, max_new_cap=CAP,
        watchdog=Watchdog(), probe=default_probe(params),
        segment_iters=4, **kw)
    reqs = _requests(cfg)
    report, log = server.run(reqs)
    assert server.watchdog.state == GREEN, \
        f"{variant}: clean run left GREEN: {server.watchdog.to_dict()}"
    assert log.actions == [], f"{variant}: clean run took failover actions"
    assert log.n_compiles == len(server.ladder)
    want = ContinuousBatchingScheduler(
        params, cfg, slots=2, prompt_len=P, max_new_cap=CAP,
        **kw).run(reqs).tokens_by_rid()
    got = report.tokens_by_rid()
    for rid in want:
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"request {rid}: guarded serving changed tokens "
                    f"({variant})")


def test_drift_detection_bounded_and_deterministic(packed_cim):
    """Seeded drift reaches RED within a bounded token count, escalates
    to the digital rung without compiling, and two independently-built
    servers agree window for window."""
    params, cfg = packed_cim
    # a longer workload than the GREEN-path tests: the drift ramp needs
    # iterations to develop before the debounced machine can trip
    reqs = mixed_length_requests(4, P, cfg.vocab_size,
                                 stop_lengths=(4, 16, 8, 12))

    def chaos_run():
        server = GuardedServer(
            params, cfg, slots=2, prompt_len=P, max_new_cap=16,
            fault=DRIFT, watchdog=Watchdog(WatchdogConfig(debounce=1)),
            probe=default_probe(params, fault=DRIFT), segment_iters=4)
        _, log = server.run(reqs)
        return server, log

    s1, log1 = chaos_run()
    s2, log2 = chaos_run()
    assert s1.watchdog.state == RED
    assert log1.detection_tokens is not None
    assert log1.detection_tokens <= 32, \
        f"detection at {log1.detection_tokens} tokens blew the budget"
    assert log1.final_rung == len(s1.ladder) - 1
    assert log1.actions and log1.n_compiles == len(s1.ladder)
    assert log1.to_dict() == log2.to_dict(), \
        "chaos runs are not deterministic across server instances"


def test_guarded_start_rung_validation(packed_cim):
    params, cfg = packed_cim
    with pytest.raises(ValueError):
        GuardedServer(params, cfg, slots=2, prompt_len=P, max_new_cap=CAP,
                      start_rung=7)
    with pytest.raises(ValueError):
        GuardedServer(params, cfg, slots=2, prompt_len=P, max_new_cap=CAP,
                      segment_iters=0)
