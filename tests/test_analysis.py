"""cimlint (repro.analysis): every rule class must FIRE on a seeded
violation and stay SILENT on the real package.

The seeded-violation half is the analyzer's own regression net: a rule
that stops firing is indistinguishable from a clean repo, so each rule
gets a minimal guilty fixture (trace, kernel/VMEM, grid-aliasing, AST)
and an innocent twin.  The clean-pass half pins the tier-1.5 CI gate:
``--strict`` passing on HEAD is an acceptance criterion, so a test
failure here means either a real regression in src/repro or an analyzer
false positive -- both block.
"""
import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import kernels as AK
from repro.analysis import lint as AL
from repro.analysis import obs_rules as OB
from repro.analysis import tracer as AT
from repro.analysis.report import AnalysisReport, Violation, load_baseline
from repro.kernels.ccim_matmul import autotune


def _rules(report):
    return {v.rule for v in report.violations}


# ---------------------------------------------------------------------------
# trace rules (seeded)
# ---------------------------------------------------------------------------


def test_trace_f64_fires():
    from jax.experimental import enable_x64
    rep = AnalysisReport()
    with enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
            jnp.arange(4, dtype=jnp.float64))
        AT.check_no_f64("seeded", jaxpr, rep)
    assert "TRACE-F64" in _rules(rep)


def test_trace_f64_clean_on_f32():
    rep = AnalysisReport()
    jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
        jnp.arange(4, dtype=jnp.float32))
    AT.check_no_f64("clean", jaxpr, rep)
    assert rep.passed


def test_trace_host_sync_fires_inside_while_body():
    def guilty(x):
        def body(v):
            y = jax.pure_callback(
                lambda a: np.asarray(a) + 1, jax.ShapeDtypeStruct((), x.dtype),
                v)
            return y
        return jax.lax.while_loop(lambda v: v < 10, body, x)

    rep = AnalysisReport()
    AT.check_no_host_sync("seeded", jax.make_jaxpr(guilty)(jnp.float32(0)),
                          rep)
    viols = [v for v in rep.violations if v.rule == "TRACE-HOST-SYNC"]
    assert viols and "while" in viols[0].detail


def test_trace_donation_fires_when_alias_impossible():
    # the donated operand never reaches an output with a matching
    # shape/dtype, so XLA cannot alias it -> the donation is silently lost
    def f(x, dead):
        return x * 2.0

    rep = AnalysisReport()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # XLA warns about the lost donation
        AT.check_donation("seeded", f, (1,),
                          (jnp.zeros((4,)), jnp.zeros((8, 8))), rep)
    assert "TRACE-DONATION" in _rules(rep)


def test_trace_donation_clean_when_aliased():
    def f(x, cache):
        return x, cache + 1.0

    rep = AnalysisReport()
    AT.check_donation("clean", f, (1,),
                      (jnp.zeros((4,)), jnp.zeros((8, 8))), rep)
    assert rep.passed
    assert rep.census["donation"]["clean"]["aliased_buffers"] >= 1


@dataclasses.dataclass(frozen=True)
class _BadCfg:
    # a frozen dataclass whose hash dies at call time (list field)
    knobs: list
    cim_plan: object = None


def test_trace_static_hash_fires_on_unhashable_cfg():
    rep = AnalysisReport()
    AT.check_static_keys(_BadCfg(knobs=[1, 2]), {}, rep)
    assert "TRACE-STATIC-HASH" in _rules(rep)


def test_trace_static_leak_fires_on_array_in_meta():
    from repro.core.engine import PackedCimWeights
    z = jnp.zeros((2, 2), jnp.int8)
    leaky = PackedCimWeights(
        scale=jnp.ones((1, 2)), sign=z, mag=z, gemm_w=jnp.zeros((1, 2, 2)),
        gemm_planes=jnp.zeros((1, 2, 2)), pallas_w=z,
        pallas_planes=jnp.zeros((1, 2, 2)),
        k_dim=2, n_dim=2, cfg=jnp.zeros((1,)))   # <- array in a meta slot
    rep = AnalysisReport()
    AT.check_static_keys(_BadCfg(knobs=[]), {"w": leaky}, rep)
    # the array in the static slot trips the leak rule (and, being
    # unhashable, the hash rule too)
    assert "TRACE-STATIC-LEAK" in _rules(rep)


# ---------------------------------------------------------------------------
# kernel rules (seeded, via hand-built records)
# ---------------------------------------------------------------------------


def _record(grid, specs, scratch=0, name="seeded"):
    return AK.PallasCallRecord(name=name, grid=grid, specs=specs,
                               scratch_bytes=scratch,
                               num_scalar_prefetch=0, scalar_shapes=[])


def test_kernel_block_fires_on_misaligned_lane():
    spec = AK.SpecView((48, 100), lambda i: (i, 0), (96, 200), jnp.float32)
    rep = AnalysisReport()
    AK.check_blocking(_record((2,), [spec]), rep)
    assert "KERNEL-BLOCK" in _rules(rep)


def test_kernel_block_fires_on_int8_sublane():
    # 16 rows of int8: below the 32-sublane floor and not the whole axis
    spec = AK.SpecView((16, 128), lambda i: (i, 0), (64, 128), jnp.int8)
    rep = AnalysisReport()
    AK.check_blocking(_record((4,), [spec]), rep)
    assert any("sublane" in v.detail for v in rep.violations)


def test_kernel_block_clean_on_whole_axis():
    # lane dim 100 < 128 but spans the full axis: resident, no alignment
    spec = AK.SpecView((32, 100), lambda i: (i, 0), (64, 100), jnp.float32)
    rep = AnalysisReport()
    AK.check_blocking(_record((2,), [spec]), rep)
    assert rep.passed


def test_kernel_vmem_fires_over_budget():
    # 1024x4096 f32 double-buffered = 32 MiB > 16 MiB
    spec = AK.SpecView((1024, 4096), lambda i: (i, 0), (4096, 4096),
                       jnp.float32)
    rep = AnalysisReport()
    AK.check_vmem(_record((4,), [spec]), rep)
    assert "KERNEL-VMEM" in _rules(rep)
    assert rep.vmem_table and not rep.vmem_table[0]["ok"]


def test_kernel_vmem_resident_counts_once():
    # grid-invariant block: counted 1x (resident), stays under budget
    spec = AK.SpecView((1024, 2560), lambda i: (0, 0), (1024, 2560),
                       jnp.float32)
    rep = AnalysisReport()
    AK.check_vmem(_record((4,), [spec]), rep)
    assert rep.passed
    assert rep.vmem_table[0]["blocks"][0]["buffers"] == 1


def test_kernel_race_fires_on_noncontiguous_revisit():
    out = AK.SpecView((8, 8), lambda i: (i % 2, 0), (16, 8), jnp.float32,
                      is_output=True)
    rep = AnalysisReport()
    AK.check_grid_aliasing(_record((4,), [out]), rep)
    assert "KERNEL-RACE" in _rules(rep)


def test_kernel_race_clean_on_accumulation_order():
    # canonical GEMM: k innermost, output tile (i, j) revisited only by
    # the contiguous run of k steps
    out = AK.SpecView((8, 8), lambda i, j, k: (i, j), (16, 16, 8),
                      jnp.float32, is_output=True)
    rep = AnalysisReport()
    AK.check_grid_aliasing(_record((2, 2, 4), [out]), rep)
    assert rep.passed


def test_spy_captures_real_dispatch():
    records = []
    AK.capture_ccim_matmul(records, M=4, K=256, N=256,
                           cfg=AK.CCIMConfig())
    assert records, "spy saw no pallas_call on the skinny decode path"
    rec = records[0]
    assert rec.grid and rec.specs
    rep = AnalysisReport()
    AK.check_record(rec, rep)
    assert rep.passed


# ---------------------------------------------------------------------------
# tuning-cache validation (the autotune loader satellite)
# ---------------------------------------------------------------------------


def test_entry_violation_rules():
    bad_bn = {"bn": 96, "bk": 512}       # 96 not lane-aligned
    bad_bk = {"bn": 128, "bk": 100}      # 100 not sublane/acc aligned
    huge = {"bn": 512, "bk": 512}        # blows the 8 MiB residency budget
    key = "tpu|skinny_pallas|K8192|N1024|L16|P2"
    assert autotune.entry_violation(key, bad_bn)
    assert autotune.entry_violation(key, bad_bk)
    assert autotune.entry_violation(
        "tpu|skinny_pallas|K65536|N1024|L16|P4", huge)
    assert autotune.entry_violation(key, {"bn": 128, "bk": 512}) is None
    assert autotune.entry_violation(
        "cpu|fast_gemm|gemv|C16|N128|L16", {"chunk_block": 64})
    assert autotune.entry_violation(
        "cpu|fast_gemm|gemv|C16|N128|L16", {"chunk_block": 8}) is None


def test_entries_drop_illegal_cached_blocks(tmp_path, monkeypatch):
    cache = {"version": 1, "entries": {
        "tpu|skinny_pallas|K1024|N512|L16|P2": {"bn": 96, "bk": 512},
        "cpu|fast_gemm|gemv|C16|N128|L16": {"chunk_block": 8},
    }}
    p = tmp_path / "TUNING_CACHE.json"
    p.write_text(json.dumps(cache))
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(p))
    autotune._state["entries"] = None    # force reload from the new path
    try:
        with pytest.warns(UserWarning, match="illegal tuning cache"):
            entries = autotune._entries()
        assert "cpu|fast_gemm|gemv|C16|N128|L16" in entries
        assert "tpu|skinny_pallas|K1024|N512|L16|P2" not in entries
    finally:
        autotune._state["entries"] = None   # other tests reload the real one


# ---------------------------------------------------------------------------
# AST rules (seeded fixtures)
# ---------------------------------------------------------------------------


def _lint(src, relpath="pkg/mod.py"):
    rep = AnalysisReport()
    AL.lint_source(relpath, src, rep)
    return rep


def test_ast_import_config_fires():
    rep = _lint("import jax\njax.config.update('jax_enable_x64', True)\n")
    assert "AST-IMPORT-CONFIG" in _rules(rep)


def test_ast_import_config_allows_function_scope_and_main():
    rep = _lint(
        "import jax\n"
        "def setup():\n"
        "    jax.config.update('jax_enable_x64', True)\n"
        "if __name__ == '__main__':\n"
        "    jax.config.update('jax_platform_name', 'cpu')\n")
    assert rep.passed


def test_ast_impure_trace_fires():
    rep = _lint(
        "import jax, time\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * time.time()\n")
    assert "AST-IMPURE-TRACE" in _rules(rep)


def test_ast_impure_trace_ignores_jax_random_and_host_fns():
    rep = _lint(
        "import jax, time\n"
        "@jax.jit\n"
        "def f(x, key):\n"
        "    return x + jax.random.normal(key, x.shape)\n"
        "def bench(f, x):\n"
        "    t0 = time.time()\n"
        "    f(x)\n"
        "    return time.time() - t0\n")
    assert rep.passed


def test_ast_host_sync_fires_in_while_body():
    rep = _lint(
        "import jax\nimport numpy as np\n"
        "def body(c):\n"
        "    return c + np.asarray([1])\n"
        "def run(c):\n"
        "    return jax.lax.while_loop(lambda c: c[0] < 3, body, c)\n")
    assert "AST-HOST-SYNC" in _rules(rep)


def test_ast_host_sync_fires_transitively_through_switch():
    rep = _lint(
        "import jax\n"
        "def helper(c):\n"
        "    return c.item()\n"
        "def branch(c):\n"
        "    return helper(c)\n"
        "def run(i, c):\n"
        "    return jax.lax.switch(i, [branch, lambda c: c], c)\n")
    assert "AST-HOST-SYNC" in _rules(rep)


def test_ast_host_sync_ignores_host_side_harvest():
    rep = _lint(
        "import jax\nimport numpy as np\n"
        "def run(c):\n"
        "    out = jax.lax.while_loop(lambda c: c[0] < 3,\n"
        "                             lambda c: c + 1, c)\n"
        "    return np.asarray(out)\n")
    assert rep.passed


def test_ast_static_meta_fires_on_unfrozen_dataclass():
    rep = _lint(
        "import dataclasses, jax\n"
        "@dataclasses.dataclass\n"
        "class Meta:\n"
        "    k: int\n"
        "jax.tree_util.register_dataclass(Meta, data_fields=[],\n"
        "                                 meta_fields=['k'])\n")
    assert "AST-STATIC-META" in _rules(rep)


def test_ast_static_meta_clean_on_frozen():
    rep = _lint(
        "import dataclasses, jax\n"
        "@dataclasses.dataclass(frozen=True)\n"
        "class Meta:\n"
        "    k: int\n"
        "jax.tree_util.register_dataclass(Meta, data_fields=[],\n"
        "                                 meta_fields=['k'])\n")
    assert rep.passed


def test_ast_noise_seed_fires_in_numerics_module():
    src = ("import jax\n"
           "def noisy(cfg):\n"
           "    return jax.random.PRNGKey(0)\n")
    rep = _lint(src, relpath="core/ccim.py")
    assert "AST-NOISE-SEED" in _rules(rep)
    # same code outside the numerics modules is fine (init-time seeding)
    assert _lint(src, relpath="models/lm.py").passed


def test_ast_noise_seed_clean_on_fold_in():
    rep = _lint(
        "import jax\n"
        "def noisy(cfg, tag):\n"
        "    return jax.random.fold_in(\n"
        "        jax.random.PRNGKey(cfg.cim_noise_seed), tag)\n",
        relpath="models/layers.py")
    assert rep.passed


# ---------------------------------------------------------------------------
# obs (telemetry) rules: seeded violation + innocent twin
# ---------------------------------------------------------------------------


def test_obs_ring_donation_fires_on_dropped_alias():
    # two leaves "donated" but the lowering only honored one alias
    rep = AnalysisReport()
    OB.check_ring_donation(
        "seeded", 'arg {tf.aliasing_output = 0 : i32} ...', 2, rep)
    viols = [v for v in rep.violations if v.rule == "OBS-RING-DONATION"]
    assert viols and "copied every" in viols[0].detail


def test_obs_ring_donation_clean_when_all_leaves_alias():
    rep = AnalysisReport()
    text = " ".join('{tf.aliasing_output = %d : i32}' % i for i in range(5))
    OB.check_ring_donation("clean", text, 5, rep)
    assert rep.passed
    assert rep.census["obs_donation"]["clean"]["aliased_buffers"] == 5


def test_obs_host_sync_fires_on_callback_metric():
    # a "telemetry" implementation that ships a counter through a host
    # callback inside the loop body -- exactly what the rings forbid
    def guilty(x):
        def body(v):
            jax.debug.callback(lambda a: None, v)   # the callback metric
            return v + 1
        return jax.lax.while_loop(lambda v: v < 8, body, x)

    rep = AnalysisReport()
    OB.check_obs_host_sync("seeded", jax.make_jaxpr(guilty)(jnp.int32(0)),
                           rep)
    viols = [v for v in rep.violations if v.rule == "OBS-HOST-SYNC"]
    assert viols and "while" in viols[0].detail


def test_obs_host_sync_clean_on_ring_push():
    # the innocent twin: the same counter kept on-device via a ring push
    from repro.obs.rings import ObsConfig, init_obs_state, ring_push

    def clean(x):
        obs = init_obs_state(ObsConfig(event_cap=4, iter_cap=4))

        def body(carry):
            v, ob = carry
            ob = ring_push(ob, 0, v, v, do=v % 2 == 0)
            return v + 1, ob
        return jax.lax.while_loop(lambda c: c[0] < 8, body, (x, obs))

    rep = AnalysisReport()
    OB.check_obs_host_sync("clean", jax.make_jaxpr(clean)(jnp.int32(0)), rep)
    assert rep.passed


def test_obs_audit_clean_on_real_scheduler():
    rep = AnalysisReport()
    OB.audit_obs(rep)
    assert rep.passed, rep.summary()
    don = rep.census["obs_donation"]["scheduler_loop[obs]"]
    assert don["aliased_buffers"] >= don["ring_leaves"] == 5


# ---------------------------------------------------------------------------
# report / baseline plumbing
# ---------------------------------------------------------------------------


def test_baseline_diff_waives_only_known_keys(tmp_path):
    rep = AnalysisReport()
    rep.add("KERNEL-VMEM", "k@a", "old")
    p = tmp_path / "ANALYSIS.json"
    rep.save(str(p))
    base = load_baseline(str(p))

    cur = AnalysisReport()
    cur.add("KERNEL-VMEM", "k@a", "still here")   # waived
    cur.add("KERNEL-VMEM", "k@b", "new")          # not waived
    new = cur.new_violations(base)
    assert [v.where for v in new] == ["k@b"]
    assert load_baseline(str(tmp_path / "missing.json")) == set()


def test_violation_str_and_counts():
    rep = AnalysisReport()
    rep.add("X", "y", "z")
    assert "X" in str(Violation("X", "y", "z"))
    assert rep.counts() == {"X": 1}
    assert not rep.passed


# ---------------------------------------------------------------------------
# clean pass over the real package (the CI gate's contract)
# ---------------------------------------------------------------------------


def test_lint_clean_on_real_package():
    rep = AnalysisReport()
    root = os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    n = AL.lint_package(root, rep)
    assert n > 50
    assert rep.passed, rep.summary()


def test_kernel_sweep_clean_on_real_package():
    rep = AnalysisReport()
    recs = AK.sweep_kernels(rep)
    assert rep.passed, rep.summary()
    # all five kernel families dispatched
    names = {r.name for r in recs}
    assert len(names) >= 5, names
    # every design point (n_dcim 0-6 x adc 7-9 x L16/32) audited
    assert rep.census["design_points"] == 42
    assert len(recs) >= 42 * len(AK.SHAPE_CLASS_MS)


def test_trace_audit_clean_on_serve_path():
    rep = AnalysisReport()
    AT.audit_serve_path(rep, with_scheduler=False)
    assert rep.passed, rep.summary()
    assert rep.census["n_executables"] >= 4
    don = rep.census["donation"]
    assert all(d["aliased_buffers"] >= d["donated_leaves"]
               for d in don.values())
