"""Sharding rules + compressed gradient all-reduce."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import compressed_psum_mean, dp_axes, spec_for
from repro.distributed.compression import (make_compressed_grad_allreduce,
                                            shard_map)


class FakeMesh:
    """Mesh-shaped stand-in for rule tests (no devices needed)."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape)


M = FakeMesh((16, 16), ("data", "model"))
MP = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_tp_divisible_dims_shard():
    assert spec_for((4608, 18432), ("embed", "ff"), M) == P(None, "model")
    assert spec_for((256000, 3584), ("vocab", "embed"), M) == P("model", None)


def test_param_indivisible_replicates_not_relocates():
    # minicpm vocab=122753 is not divisible by 16 -> replicate, never shard
    # a contracting dim (the 80GB-all-reduce lesson, see sharding.py)
    assert spec_for((122753, 2304), ("vocab", "embed"), M,
                    relocate=False) == P(None, None)


def test_cache_relocation_gives_split_kv():
    # 8 kv heads < 16-way model axis -> sequence dim takes the TP shard
    s = spec_for((40, 128, 32768, 8, 128),
                 ("layers", "batch", "seq", "kv_heads", "head_dim"), M,
                 overrides={"batch": ("data",), "kv_heads": "model"})
    assert s == P(None, ("data",), "model", None, None)


def test_long_context_batch1_shards_sequence():
    s = spec_for((6, 1, 524288, 32, 64),
                 ("layers", "batch", "seq", "kv_heads", "head_dim"), M,
                 overrides={"batch": ("data",), "kv_heads": "model"})
    # batch=1 can't shard -> dp relocates to the sequence (SP)
    assert s == P(None, None, ("data",), "model", None)


def test_fsdp_shards_embed_over_data():
    s = spec_for((7168, 4864), ("embed", "ff"), M, fsdp=True,
                 relocate=False)
    assert s == P("data", "model")


def test_dp_axes_multi_pod():
    assert dp_axes(M) == ("data",)
    assert dp_axes(MP) == ("pod", "data")


def test_moe_expert_sharding():
    s = spec_for((128, 7168, 4864), ("experts", "embed", "moe_ff"), M,
                 fsdp=True, relocate=False)
    assert s == P("model", "data", None)


# ---------------------------------------------------------------------------
# compressed gradient all-reduce (error feedback)
# ---------------------------------------------------------------------------


def test_compressed_psum_identity_on_single_shard():
    mesh = jax.make_mesh((1,), ("data",))
    g = jnp.linspace(-1, 1, 64).reshape(8, 8)
    e = jnp.zeros_like(g)

    @shard_map(mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    def run(gl, el):
        return compressed_psum_mean(gl, el, "data")

    mean, err = run(g, e)
    # single shard: mean == dequantized(quantized(g)); err = residual
    np.testing.assert_allclose(np.asarray(mean + err), np.asarray(g),
                               rtol=0, atol=1e-6)
    assert float(jnp.abs(err).max()) <= float(jnp.abs(g).max()) / 127 + 1e-6


def test_error_feedback_reduces_bias_over_steps():
    """With error feedback, the accumulated compressed sum tracks the true
    sum (residual stays bounded instead of drifting)."""
    mesh = jax.make_mesh((1,), ("data",))
    g = jnp.full((16,), 0.003)   # much smaller than max-scale step
    e = jnp.zeros_like(g)
    acc_true, acc_comp = 0.0, 0.0

    @shard_map(mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    def run(gl, el):
        return compressed_psum_mean(gl, el, "data")

    for _ in range(50):
        mean, e = run(g, e)
        acc_true += float(g[0])
        acc_comp += float(mean[0])
    assert abs(acc_comp - acc_true) / acc_true < 0.05


def test_tree_allreduce_wrapper():
    mesh = jax.make_mesh((1,), ("data",))
    fn = make_compressed_grad_allreduce(mesh)
    grads = {"a": jnp.ones((4, 4)), "b": jnp.full((3,), -2.0)}
    errs = jax.tree.map(jnp.zeros_like, grads)
    mean, new_err = fn(grads, errs)
    np.testing.assert_allclose(np.asarray(mean["a"]), 1.0, atol=0.02)
    np.testing.assert_allclose(np.asarray(mean["b"]), -2.0, atol=0.04)
