"""Deterministic stand-in for hypothesis when the [test] extra is absent.

Provides just the surface test_core / test_data_optim use -- ``given``,
``settings``, ``strategies.integers`` -- by expanding each property test
into a small pytest parametrization over a fixed sample grid (bounds,
midpoint, one interior point).  Far weaker than real hypothesis, but the
properties still execute and the suite collects green without the extra.
"""
from __future__ import annotations

import inspect
import itertools

import pytest


class _Integers:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def samples(self):
        mid = (self.lo + self.hi) // 2
        interior = min(self.hi, self.lo + 12345)
        return sorted({self.lo, mid, interior, self.hi})


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Integers:
        return _Integers(min_value, max_value)


st = _Strategies()


def settings(**_kwargs):
    def deco(fn):
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        names = list(inspect.signature(fn).parameters)[: len(strategies)]
        cases = list(itertools.product(*(s.samples() for s in strategies)))
        if len(strategies) == 1:
            cases = [c[0] for c in cases]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
