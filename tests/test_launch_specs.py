"""Dry-run machinery unit tests (no 512-device flag needed: the rules and
shape logic are mesh-shape-driven)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch import hlo_analysis
from repro.launch.specs import (SHAPES, applicable, batch_specs,
                                param_count,
                                param_shapes_and_axes)


def test_applicability_matrix():
    runs, skips = [], []
    for arch in ARCHS:
        for shape in SHAPES:
            ok, _ = applicable(get_config(arch), shape)
            (runs if ok else skips).append((arch, shape))
    assert len(runs) + len(skips) == 40  # the assigned 40 cells
    assert ("mamba2-130m", "long_500k") in runs
    assert ("zamba2-1.2b", "long_500k") in runs
    assert ("qwen3-14b", "long_500k") in skips     # full attention
    assert ("gemma2-9b", "long_500k") in skips     # global layers still O(S)
    assert len(skips) == 8


@pytest.mark.parametrize("arch", ARCHS)
def test_param_shapes_and_axes_align(arch):
    shapes, axes = param_shapes_and_axes(get_config(arch))
    assert param_count(shapes) > 1e8


def test_param_counts_sane():
    expect = {  # incl. TP head padding (see ModelConfig.tp_head_pad)
        "minicpm-2b": (2.2e9, 3.5e9),
        "qwen3-14b": (13e9, 16e9),
        "starcoder2-7b": (6.3e9, 11e9),
        "gemma2-9b": (8.0e9, 11e9),
        "mamba2-130m": (1.2e8, 2.4e8),
        "qwen2-moe-a2.7b": (13e9, 16e9),
        "arctic-480b": (4.2e11, 5.4e11),
        "paligemma-3b": (2.3e9, 3.6e9),
        "zamba2-1.2b": (1.0e9, 1.9e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        shapes, _ = param_shapes_and_axes(get_config(arch))
        n = param_count(shapes)
        assert lo < n < hi, f"{arch}: {n:.3e} not in ({lo:.1e},{hi:.1e})"


def test_hlo_analysis_counts_trips():
    import jax.numpy as jnp

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = hlo_analysis.analyse(compiled.as_text())
    expect = 7 * 2 * 64 ** 3
    assert abs(r["dot_flops"] - expect) / expect < 0.05


def test_batch_specs_shapes():
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))
    cfg = get_config("qwen3-14b")
    shapes, spec = batch_specs(cfg, "train_4k", FakeMesh())
    assert shapes["tokens"].shape == (256, 4096)
    cfgv = get_config("paligemma-3b")
    shapes, spec = batch_specs(cfgv, "train_4k", FakeMesh())
    # vlm: 256 patch embeddings + 3840 text tokens = 4096 total positions
    assert shapes["tokens"].shape == (256, 4096 - 256)
    assert shapes["frontend_embs"].shape == (256, 256, 2048)
